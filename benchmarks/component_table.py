"""Paper Table I analog: component-level MAE (original), SWAPPER best
single-bit reduction, and the theoretical (oracle) bound, over the multiplier
library at 8/12/16 bits, signed/unsigned, with commutative controls."""
from __future__ import annotations

import time

import repro.core as C

# representative set: non-commutative members + commutative controls
MULTS_8 = ["mul8u_trunc0_4", "mul8u_trunc2_4", "mul8u_perf0_1", "mul8u_bam_v2_h1",
           "mul8u_mitch13_0", "mul8u_drum3_4", "mul8u_drum2_6",
           "mul8s_trunc0_4", "mul8s_bam_v2_h1", "mul8s_drum3_4",
           "mul8u_trunc2_2", "mul8u_drum4_4"]           # last two commutative
MULTS_12 = ["mul12u_trunc0_6", "mul12u_bam_v3_h1", "mul12u_drum4_6",
            "mul12s_trunc1_7", "mul12s_mitch10_13"]
MULTS_16 = ["mul16u_trunc0_8", "mul16u_drum2_14", "mul16s_trunc0_8",
            "mul16s_bam_v4_h1", "mul16s_drum5_8", "mul16s_mitch10_13",
            "mul16s_trunc4_4"]                           # last commutative


def run(metric: str = "mae", quick: bool = False):
    rows = []
    t_all = time.time()
    sets = [(MULTS_8, None), (MULTS_12, None), (MULTS_16, 10 if not quick else 8)]
    if quick:
        sets = [(MULTS_8[:4], None), (MULTS_16[:2], 8)]
    for mults, sample_bits in sets:
        for name in mults:
            m = C.get(name)
            t0 = time.time()
            res = C.component_sweep(m, tile=256, sample_bits=sample_bits)
            dt = time.time() - t0
            best = res.best(metric)
            rows.append(dict(
                mult=name,
                commutative=bool(m.commutative) if m.commutative is not None else None,
                original=res.noswap.metric(metric),
                swapper_reduction=res.reduction(metric),
                theoretical_reduction=res.theoretical_reduction(metric),
                best_cfg=best.short(),
                exhaustive=sample_bits is None,
                seconds=dt,
            ))
    return {"rows": rows, "metric": metric, "total_s": time.time() - t_all}


def format_table(out) -> str:
    lines = [f"Component-level ({out['metric'].upper()}) — Table I analog",
             f"{'multiplier':22s} {'orig':>12s} {'SWAPPER':>9s} {'Theor.':>9s}  best-bit  comm"]
    for r in out["rows"]:
        lines.append(
            f"{r['mult']:22s} {r['original']:12.2f} {100*r['swapper_reduction']:8.2f}% "
            f"{100*r['theoretical_reduction']:8.2f}%  {r['best_cfg']:9s} "
            f"{'C' if r['commutative'] else 'NC'}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
