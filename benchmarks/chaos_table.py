"""Chaos table: deterministic fault-injection scenarios over the serving
stack, with recovery outcomes as CI gates.

Every scenario arms a fixed ``fleet.chaos.FaultPlan`` (no randomness in
*when* faults fire — the CI chaos lane replays the identical sequence every
run) against the production recovery paths grown in PR 7:

* **store** — publish killed mid temp-write (crash atomicity + orphan
  sweep), CURRENT torn to garbage (newest-on-disk fallback), policy JSON
  corrupted after publish (reader degrades to newest *loadable*);
* **quarantine** — NaN / Inf / outlier-poisoned telemetry records must be
  quarantined before the ring buffers and never fire a retune;
* **canary + rollback** — an impossible canary margin must reject the
  retune winner and keep the incumbent; a post-adoption regime shift past
  the guard band must auto-roll-back to last-good bit-identically, and the
  post-recovery MAE must settle back inside the guard band;
* **scheduler** — an injected replica kill mid-drain is survived by the
  supervisor pattern; an injected step stall plus zero-deadline requests
  produces timeout completions (not a crash); a bounded queue sheds;
* **armed-but-idle** — an installed harness whose plan never matches must
  leave token-granular serving bit-identical to the wave oracle with zero
  decode retraces (chaos hooks are free when idle).

``run()`` returns recovery-outcome booleans and counters; the
``benchmarks.regress`` rules gate the booleans (``rollbacks_recovered ==
rollbacks_triggered``, ``replica_crashes_survived``, post-recovery MAE
within the guard band) into BENCH_7.json.

    PYTHONPATH=src python -m benchmarks.chaos_table [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs.base import AxPolicy

MULT = "mul8u_trunc0_4"
# the CI chaos lane's pinned seed: FaultPlan.seeded(CHAOS_SEED) is recorded
# in the artifact for provenance, so a regression report names the exact
# fault sequence that ran
CHAOS_SEED = 1337


def _policy(cfg=None):
    import repro.runtime as R

    return R.SwapPolicy(MULT, configs={"*": cfg})


def _tiny():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _controller(start_cfg, store=None, **kw):
    import repro.runtime as R

    cfg = dict(decay=0.4, drift_threshold=0.05, min_observe_steps=2,
               cooldown_steps=2, buffer_size=1024)
    cfg.update(kw)
    ctrl = R.AdaptiveController(_policy(start_cfg), targets=("stream",),
                                cfg=R.AdaptiveConfig(**cfg), store=store)
    ctrl.warmup()
    return ctrl


# ---------------------------------------------------------------------------
# 1. store faults: crash-atomic publish, torn CURRENT, corrupt policy
# ---------------------------------------------------------------------------

def bench_store_faults():
    import os

    import repro.core as C
    from repro.fleet import PolicyReader, PolicyStore, chaos

    out = {"faults": 0}

    with tempfile.TemporaryDirectory() as tmp:
        store = PolicyStore(tmp)
        store.publish(_policy(C.SwapConfig("A", 3, 0)))
        plan = chaos.FaultPlan([chaos.FaultSpec("store.publish",
                                                "kill_mid_write", at=0)])
        crashed = False
        with chaos.active(plan) as h:
            try:
                store.publish(_policy(C.SwapConfig("B", 5, 1)))
            except chaos.InjectedFault:
                crashed = True
            out["faults"] += len(h.fired)
        atomic = (crashed and store.current_version() == 1
                  and store.versions() == [1])
        store2 = PolicyStore(tmp, recover_stale_s=0.0)   # orphan sweep
        swept = not any(f.endswith(".tmp") for f in os.listdir(tmp))
        resumed = store2.publish(_policy(C.SwapConfig("B", 5, 1))) == 2
        out["publish_crash_atomic"] = bool(atomic and swept and resumed)

    with tempfile.TemporaryDirectory() as tmp:
        store = PolicyStore(tmp)
        store.publish(_policy(C.SwapConfig("A", 3, 0)))
        reader = PolicyReader(store, ("stream",), backoff_s=0.0)
        plan = chaos.FaultPlan([chaos.FaultSpec("store.publish",
                                                "torn_current", at=0)])
        with chaos.active(plan) as h:
            try:
                store.publish(_policy(C.SwapConfig("B", 5, 1)))
            except chaos.InjectedFault:
                pass
            out["faults"] += len(h.fired)
        # CURRENT is garbage but v2 committed: fall back to newest on disk,
        # the replica adopts it, and the next writer allocates past it
        out["torn_current_recovered"] = bool(
            store.current_version() == 2 and reader.poll() is True
            and reader.version == 2
            and PolicyStore(tmp).publish(_policy(C.SwapConfig("A", 1, 1))) == 3)

    with tempfile.TemporaryDirectory() as tmp:
        store = PolicyStore(tmp)
        store.publish(_policy(C.SwapConfig("A", 3, 0)))
        plan = chaos.FaultPlan([chaos.FaultSpec("store.after_publish",
                                                "corrupt_policy", at=0)])
        with chaos.active(plan) as h:
            store.publish(_policy(C.SwapConfig("B", 5, 1)))   # then corrupted
            out["faults"] += len(h.fired)
        reader = PolicyReader(store, ("stream",), retries=2, backoff_s=0.0)
        out["corrupt_policy_fallback"] = bool(
            reader.version == 1 and reader.read_errors >= 1
            and reader.policy.lookup("stream") == C.SwapConfig("A", 3, 0))

    out["survived"] = bool(out["publish_crash_atomic"]
                           and out["torn_current_recovered"]
                           and out["corrupt_policy_fallback"])
    return out


# ---------------------------------------------------------------------------
# 2. telemetry quarantine under poisoned records
# ---------------------------------------------------------------------------

def bench_quarantine():
    from repro.fleet import chaos

    rng = np.random.default_rng(3)
    ctrl = _controller(None)
    plan = chaos.FaultPlan(
        [chaos.FaultSpec("controller.observe", k, at=a)
         for a, k in ((3, "poison_nan"), (4, "poison_inf"), (5, "poison_nan"),
                      (6, "poison_inf"), (7, "poison_nan"))])
    with chaos.active(plan) as h:
        for _ in range(10):
            ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                                  rng.integers(0, 256, 2048))
        fired = len(h.fired)
    snap = ctrl.telemetry.snapshot()["stream"]
    kept_out = bool(fired == 5
                    and ctrl.quarantine.quarantined >= fired
                    and ctrl.retunes == []
                    and np.isfinite(snap["bit_probs"]).all()
                    and np.isfinite(snap["ew_mae"]))
    return {
        "faults": fired,
        "quarantined": ctrl.quarantine.quarantined,
        "by_reason": dict(ctrl.quarantine.by_reason),
        "poison_kept_out": kept_out,
        "survived": kept_out,
    }


# ---------------------------------------------------------------------------
# 3. canaried rollout + auto-rollback
# ---------------------------------------------------------------------------

def bench_canary_rollback():
    from repro.fleet import PolicyStore, chaos

    out = {"faults": 0}
    rng = np.random.default_rng(4)

    # canary rejection: an impossible holdout margin keeps the incumbent
    with tempfile.TemporaryDirectory() as tmp:
        store = PolicyStore(tmp)
        ctrl = _controller(None, store=store, canary=True, canary_margin=1.0,
                           min_observe_steps=1, cooldown_steps=0)
        ctrl.resume_from_store()
        for _ in range(3):
            ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                                  rng.integers(0, 256, 2048))
        cache = ctrl.scorer_cache_size()
        ev = ctrl.retune("stream")
        out["canary_rejected"] = bool(
            ev.promoted is False and store.current_version() == 1
            and store.candidate_version() is None
            and ctrl.scorer_cache_size() == cache)

    # auto-rollback: retune on a low-error regime (with an injected retune
    # stall — the sweep must survive being slow), then shift past the guard
    with tempfile.TemporaryDirectory() as tmp:
        store = PolicyStore(tmp)
        ctrl = _controller(None, store=store, canary=True,
                           drift_threshold=10.0, min_observe_steps=1,
                           cooldown_steps=0, rollback_guard=0.5,
                           rollback_min_steps=2, rollback_window=32)
        ctrl.resume_from_store()
        for _ in range(4):
            ctrl.observe_operands("stream", rng.integers(0, 64, 2048),
                                  rng.integers(0, 64, 2048))
        plan = chaos.FaultPlan([chaos.FaultSpec("controller.retune",
                                                "stall_retune", at=0,
                                                arg=0.001)])
        with chaos.active(plan) as h:
            ev = ctrl.retune("stream")
            out["faults"] += len(h.fired)
        promoted = bool(ev.promoted and store.current_version() == 2)
        import repro.runtime as R

        last_good = R.SwapPolicy.from_json(store.load(1).to_json())
        for _ in range(12):                    # regressed regime
            ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                                  rng.integers(128, 256, 2048))
            if ctrl.rollbacks:
                break
        out["rollbacks_triggered"] = len(ctrl.rollbacks)
        recovered = bool(ctrl.rollbacks
                         and store.current_version() == 1
                         and ctrl.policy.configs_equal(last_good))
        out["rollbacks_recovered"] = int(recovered)
        out["rollbacks_all_recovered"] = bool(
            promoted and recovered
            and out["rollbacks_recovered"] == out["rollbacks_triggered"])

        # post-recovery: the original regime must settle the smoothed MAE
        # back inside the guard band of the pre-adoption baseline
        baseline = ctrl.rollbacks[0]["baseline"] if ctrl.rollbacks else 0.0
        for _ in range(10):
            ctrl.observe_operands("stream", rng.integers(0, 64, 2048),
                                  rng.integers(0, 64, 2048))
        post = float(ctrl.telemetry.snapshot()["stream"]["ew_mae"])
        out["baseline_mae"] = float(baseline)
        out["post_recovery_mae"] = post
        out["post_recovery_mae_within_band"] = bool(
            baseline > 0 and post <= baseline * (1.0 + 0.5))

    out["survived"] = bool(out["canary_rejected"]
                           and out["rollbacks_all_recovered"]
                           and out["post_recovery_mae_within_band"])
    return out


# ---------------------------------------------------------------------------
# 4. scheduler faults: replica kill, stalled step + deadlines, shedding
# ---------------------------------------------------------------------------

def bench_scheduler_faults(quick: bool):
    from repro.fleet import BatcherConfig, ContinuousBatcher, Request, chaos

    cfg, params = _tiny()
    rng = np.random.default_rng(5)
    out = {"faults": 0}

    def _reqs(n, deadline_s=None):
        return [Request(rid, rng.integers(0, cfg.vocab, 6), max_new=3,
                        deadline_s=deadline_s) for rid in range(n)]

    # replica kill mid-drain, supervised restart (the launch/serve pattern)
    bat = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4, token_granular=True))
    for r in _reqs(4):
        bat.submit(r)
    plan = chaos.FaultPlan([chaos.FaultSpec("sched.step", "crash_replica",
                                            at=2)])
    done, crashes = [], 0
    with chaos.active(plan) as h:
        while bat.pending() or crashes == 0:
            try:
                done.extend(bat.run())
                break
            except chaos.InjectedFault:
                crashes += 1
        out["faults"] += len(h.fired)
    rids = [c.rid for c in done]
    out["replica_crashes_injected"] = crashes
    out["replica_crashes_survived"] = int(
        crashes == 1 and bat.pending() == 0 and len(rids) == len(set(rids)))

    # injected step stall + a zero-deadline request: timeout, never a crash
    bat2 = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4, token_granular=True))
    for r in _reqs(3):
        bat2.submit(r)
    bat2.submit(Request(9, rng.integers(0, cfg.vocab, 6), max_new=3,
                        deadline_s=0.0))
    plan = chaos.FaultPlan([chaos.FaultSpec("sched.step", "stall_step",
                                            at=1, arg=0.005)])
    with chaos.active(plan) as h:
        done2 = bat2.run()
        out["faults"] += len(h.fired)
    by_rid = {c.rid: c for c in done2}
    out["timeouts"] = bat2.stats["timeouts"]
    out["stall_deadlines_respected"] = bool(
        by_rid[9].status == "timeout"
        and all(by_rid[r].status == "ok" for r in (0, 1, 2))
        and bat2.stats["decode_retraces_post_warmup"] == 0)

    # bounded admission queue sheds deterministically
    bat3 = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4, max_queue=2))
    accepted = [bat3.submit(r) for r in _reqs(5)]
    done3 = bat3.run()
    out["shed"] = bat3.stats["shed"]
    out["shed_respects_bound"] = bool(
        accepted == [True, True, False, False, False]
        and out["shed"] == 3 and len(done3) == 2)

    out["survived"] = bool(out["replica_crashes_survived"]
                           >= out["replica_crashes_injected"]
                           and out["stall_deadlines_respected"]
                           and out["shed_respects_bound"])
    return out


# ---------------------------------------------------------------------------
# 5. armed-but-idle: chaos hooks must be free when no fault matches
# ---------------------------------------------------------------------------

def bench_armed_idle(quick: bool):
    from repro.fleet import BatcherConfig, ContinuousBatcher, Request, chaos

    cfg, params = _tiny()
    n_req = 4 if quick else 6
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 8)))
               for _ in range(n_req)]
    budgets = [int(rng.integers(1, 4)) for _ in range(n_req)]

    def serve(token_granular, armed):
        bat = ContinuousBatcher(
            params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                       new_token_bucket=4,
                                       token_granular=token_granular))
        for rid, (p, m) in enumerate(zip(prompts, budgets)):
            bat.submit(Request(rid, p.copy(), max_new=m))
        if armed:
            idle = chaos.FaultPlan([chaos.FaultSpec(
                "sched.step", "crash_replica", at=10 ** 6)])
            with chaos.active(idle) as h:
                done = bat.run()
            assert h.fired == []
        else:
            done = bat.run()
        return {c.rid: np.asarray(c.tokens) for c in done}, bat

    oracle, _ = serve(token_granular=False, armed=False)
    got, bat = serve(token_granular=True, armed=True)
    identical = bool(set(oracle) == set(got)
                     and all(np.array_equal(oracle[r], got[r])
                             for r in oracle))
    zero_retraces = bool(bat.stats["decode_retraces_post_warmup"] == 0)
    return {
        "faults": 0,
        "armed_idle_bit_identical": identical,
        "armed_idle_zero_retraces": zero_retraces,
        "survived": bool(identical and zero_retraces),
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = False):
    from repro.fleet import chaos

    store = bench_store_faults()
    quarantine = bench_quarantine()
    canary = bench_canary_rollback()
    sched = bench_scheduler_faults(quick)
    idle = bench_armed_idle(quick)
    sections = (store, quarantine, canary, sched, idle)
    return {
        "bench": "chaos_table",
        "quick": quick,
        "seed": CHAOS_SEED,
        "seeded_plan": chaos.FaultPlan.seeded(CHAOS_SEED).describe(),
        "faults_injected": sum(s["faults"] for s in sections),
        # store
        "publish_crash_atomic": store["publish_crash_atomic"],
        "torn_current_recovered": store["torn_current_recovered"],
        "corrupt_policy_fallback": store["corrupt_policy_fallback"],
        # quarantine
        "poison_kept_out": quarantine["poison_kept_out"],
        "quarantined": quarantine["quarantined"],
        "quarantine_by_reason": quarantine["by_reason"],
        # canary + rollback
        "canary_rejected": canary["canary_rejected"],
        "rollbacks_triggered": canary["rollbacks_triggered"],
        "rollbacks_recovered": canary["rollbacks_recovered"],
        "rollbacks_all_recovered": canary["rollbacks_all_recovered"],
        "baseline_mae": canary["baseline_mae"],
        "post_recovery_mae": canary["post_recovery_mae"],
        "post_recovery_mae_within_band":
            canary["post_recovery_mae_within_band"],
        # scheduler
        "replica_crashes_injected": sched["replica_crashes_injected"],
        "replica_crashes_survived": sched["replica_crashes_survived"],
        "timeouts": sched["timeouts"],
        "shed": sched["shed"],
        "stall_deadlines_respected": sched["stall_deadlines_respected"],
        "shed_respects_bound": sched["shed_respects_bound"],
        # armed-but-idle
        "armed_idle_bit_identical": idle["armed_idle_bit_identical"],
        "armed_idle_zero_retraces": idle["armed_idle_zero_retraces"],
        "survived_all": bool(all(s["survived"] for s in sections)),
    }


def format_table(out) -> str:
    def flag(b):
        return "RECOVERED" if b else "FAILED"

    lines = [
        "Chaos — injected faults and recovery outcomes (PR 7)",
        (f"{out['faults_injected']} faults injected "
         f"(pinned seed {out['seed']} for the CI lane)"),
        f"{'fault':42s} {'outcome':>10s}",
        (f"{'publish killed mid temp-write':42s} "
         f"{flag(out['publish_crash_atomic']):>10s}"),
        (f"{'CURRENT pointer torn to garbage':42s} "
         f"{flag(out['torn_current_recovered']):>10s}"),
        (f"{'policy JSON corrupted after publish':42s} "
         f"{flag(out['corrupt_policy_fallback']):>10s}"),
        (f"{'telemetry poisoned (NaN/Inf)':42s} "
         f"{flag(out['poison_kept_out']):>10s}   "
         f"({out['quarantined']} quarantined, 0 retunes)"),
        (f"{'canary holdout rejects retune winner':42s} "
         f"{flag(out['canary_rejected']):>10s}"),
        (f"{'post-adoption regression past guard':42s} "
         f"{flag(out['rollbacks_all_recovered']):>10s}   "
         f"({out['rollbacks_recovered']}/{out['rollbacks_triggered']} "
         f"rolled back, post-recovery MAE {out['post_recovery_mae']:.3f} "
         f"vs baseline {out['baseline_mae']:.3f})"),
        (f"{'replica killed mid-drain':42s} "
         f"{flag(out['replica_crashes_survived'] >= out['replica_crashes_injected']):>10s}   "
         f"({out['replica_crashes_survived']}/"
         f"{out['replica_crashes_injected']} supervised restarts)"),
        (f"{'step stalled + zero-deadline requests':42s} "
         f"{flag(out['stall_deadlines_respected']):>10s}   "
         f"({out['timeouts']} timeouts)"),
        (f"{'admission past bounded queue':42s} "
         f"{flag(out['shed_respects_bound']):>10s}   "
         f"({out['shed']} shed)"),
        (f"{'armed-but-idle harness':42s} "
         f"{'IDENTICAL' if out['armed_idle_bit_identical'] else 'DIVERGED':>10s}   "
         f"(zero retraces: {out['armed_idle_zero_retraces']})"),
        f"all scenarios survived: {out['survived_all']}",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print(format_table(run(quick=args.quick)))


if __name__ == "__main__":
    main()
