"""Format the dry-run roofline JSONL files (launch/dryrun.py --out) into the
EXPERIMENTS.md §Roofline table."""
from __future__ import annotations

import json
import os

FILES = ["dryrun_16x16.jsonl", "dryrun_2x16x16.jsonl"]


def load(paths=None):
    rows = []
    for p in paths or FILES:
        full = p if os.path.exists(p) else os.path.join(os.path.dirname(__file__), "..", p)
        if not os.path.exists(full):
            continue
        with open(full) as f:
            for line in f:
                if line.strip():
                    rows.append(json.loads(line))
    return rows


def format_table(rows) -> str:
    lines = [
        f"{'arch':20s} {'shape':12s} {'mesh':8s} {'tC(s)':>9s} {'tM(s)':>9s} "
        f"{'tX(s)':>9s} {'dominant':10s} {'useful':>7s} {'roofl%':>7s} {'mem/dev':>8s}"
    ]
    for r in rows:
        if r.get("status") == "skip":
            lines.append(f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
                         f"-- skipped: {r['reason'][:60]}")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:20s} {r['shape']:12s} {r.get('mesh','?'):8s} "
                         f"-- ERROR: {r.get('error','?')[:60]}")
            continue
        mem = r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        lines.append(
            f"{r['arch']:20s} {r['shape']:12s} {r['mesh']:8s} "
            f"{r['t_compute']:9.4f} {r['t_memory']:9.4f} {r['t_collective']:9.4f} "
            f"{r['dominant']:10s} {r['useful_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:6.2f}% {mem:7.1f}G"
        )
    return "\n".join(lines)


def run():
    rows = load()
    return {"rows": rows, "n": len(rows)}


if __name__ == "__main__":
    print(format_table(load()))
