"""Serving table: wave-granular vs token-granular continuous batching on
mixed-length AxBench-derived request traces.

The trace is deterministic and derived from the repo's AxBench application
inputs (``repro.apps.sobel``'s structured synthetic image): prompt lengths
and token budgets are read off consecutive pixel rows, so the mix of short
and long requests follows the app data rather than a hand-picked
distribution.  Both batchers serve the SAME trace with the SAME seeds:

* **wave** — the PR-3 design (now pad-masked with per-slot budgets): slots
  rebind only at wave boundaries, so a finished request strands its slot
  until the wave drains;
* **token** — per-slot cache positions + mid-flight admission: a finished
  slot splices the next FIFO request into its cache region at the next
  step boundary (``fleet.scheduler``, ``BatcherConfig.token_granular``).

Deterministic counters (the CI gate, ``benchmarks.regress``): per-request
token bit-identity between the two modes, slot occupancy (token mode must
meet or beat wave mode — the whole point of the feature), zero recompiles
of the token-step program across splices and a policy update.  Wall
tokens/s is informational.

A third serve (PR 7) exercises the hardened admission path: a bounded
queue sheds overflow deterministically (every submit happens before the
drain, so ``shed == submitted - max_queue`` exactly), and zero-deadline
requests time out — under an injected per-step stall
(``fleet.chaos``) — without crashing the drain.  ``shed_respects_bound``
and ``timeouts_match_deadlines`` join the CI gate.

The PR-8 QoR-observability additions ride on the SAME token serve used
for the identity check: per-request error attribution (tile-granular —
the controller runs ``tile_rows=2``), the SLO/error-budget engine, and a
live StatsD push exporter are all enabled, and the bit-identity /
zero-retrace gates are re-verified under that instrumentation.
``qor_attribution_live`` (every completion carries a top-k per-target
error-share summary with a per-tile annotation), ``corr_ids_unique``,
and ``statsd_lines_sent > 0`` join the CI gate.

    PYTHONPATH=src python -m benchmarks.serving_table [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import AxPolicy

MULT = "mul8s_trunc0_4"


def _tiny():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2,
                              ax=AxPolicy(mult_name=MULT, backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _controller(cfg, tile_rows: int = 0):
    import repro.runtime as R

    return R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10 ** 6,
                             tile_rows=tile_rows))


def axbench_trace(cfg, n_req: int, max_prompt: int, max_new: int):
    """Mixed-length requests derived from AxBench app data: row ``r`` of the
    sobel input image sets request ``r``'s prompt length (row mean) and
    token budget (row std) — deterministic, reproducible, app-shaped."""
    from repro.apps import sobel
    from repro.fleet import Request

    img = sobel.gen_inputs(max(32, n_req), seed=11)["img"]  # (side, side) [0,1]
    rng = np.random.default_rng(5)
    reqs = []
    for rid in range(n_req):
        row = img[rid % img.shape[0]]
        L = 2 + int(row.mean() * (max_prompt - 2))
        budget = 1 + int(min(1.0, 4.0 * row.std()) * (max_new - 1))
        reqs.append(Request(rid, rng.integers(0, cfg.vocab, L),
                            max_new=budget))
    return reqs


def run(quick: bool = False):
    from repro.fleet import BatcherConfig, ContinuousBatcher
    from repro.fleet import Request  # noqa: F401  (re-export for callers)
    from repro.serve import engine as E

    cfg, params = _tiny()
    n_req = 10 if quick else 24
    T = 6 if quick else 10
    buckets = (8, 16)

    from repro import obs

    def serve(token_granular: bool, slo=None, tile_rows: int = 0):
        bcfg = BatcherConfig(n_slots=4, prompt_buckets=buckets,
                             new_token_bucket=T,
                             token_granular=token_granular)
        ctrl = _controller(cfg, tile_rows=tile_rows)
        bat = ContinuousBatcher(params, cfg, bcfg, adaptive=ctrl)
        if slo is not None:
            ctrl.attach_slo(slo)
            bat.attach_slo(slo)
        for r in axbench_trace(cfg, n_req, max_prompt=max(buckets), max_new=T):
            bat.submit(Request(r.rid, r.tokens.copy(), r.max_new))
        t0 = time.perf_counter()
        done = bat.run()
        dt = time.perf_counter() - t0
        toks = {c.rid: c.tokens.tolist() for c in done}
        return toks, bat, sum(len(t) for t in toks.values()) / dt, done

    # the token serve carries the FULL PR-8 instrumentation — per-request
    # attribution (tile-granular), the SLO engine, and a StatsD exporter
    # pushed right after the drain — and the identity/retrace gates below
    # must hold with all of it live.  The wave serve stays the bare oracle.
    slo = obs.SLOEngine(obs.default_serving_slos(qor_targets=cfg.ax.targets))
    wave_toks, wave_bat, wave_tps, _ = serve(False)
    tok_toks, tok_bat, tok_tps, tok_done = serve(True, slo=slo, tile_rows=2)

    with tempfile.TemporaryDirectory() as td:
        mirror = os.path.join(td, "metrics.statsd")
        sx = obs.StatsdExporter("127.0.0.1", 8125, mirror=mirror)
        statsd_lines_sent = sx.push(obs.default_registry())
        sx.close()
    qor_attribution_live = bool(tok_done) and all(
        c.corr is not None and c.qor is not None and c.qor["top"]
        and c.qor["basis"] == "request"
        and any("top_tile" in e for e in c.qor["top"])
        for c in tok_done)
    corr_ids_unique = len({c.corr for c in tok_done}) == len(tok_done)
    slo_latency_events = slo.events("ttft") + slo.events("e2e")
    # per-request latency percentiles off the batchers' request logs
    # (submit -> first token / retirement; wave TTFT == e2e by construction
    # — the whole wave is one fused dispatch).  Wall-clock: informational
    # on CPU, the deterministic fields below stay the gates.
    wave_lat = wave_bat.latency_summary()
    tok_lat = tok_bat.latency_summary()

    bit_identical = (set(wave_toks) == set(tok_toks)
                     and all(wave_toks[r] == tok_toks[r] for r in wave_toks))

    # zero recompiles: splices and a mid-trace-style policy update must not
    # add programs — flip the policy and serve a second token-granular trace
    import repro.core as C

    sizes0 = [f._cache_size() for f in E._TOKEN_FNS.values()]
    # same tile_rows as the instrumented serve above: tile telemetry is part
    # of the compiled step's signature, so the retrace check must hold the
    # granularity fixed while flipping the (traced) policy values
    ctrl = _controller(cfg, tile_rows=2)
    ctrl.policy.set_config("mlp", C.SwapConfig("B", 5, 1))
    bat2 = ContinuousBatcher(
        params, cfg,
        BatcherConfig(n_slots=4, prompt_buckets=buckets, new_token_bucket=T,
                      token_granular=True), adaptive=ctrl)
    for r in axbench_trace(cfg, n_req // 2, max_prompt=max(buckets), max_new=T):
        bat2.submit(Request(r.rid, r.tokens.copy(), r.max_new))
    bat2.run()
    sizes1 = [f._cache_size() for f in E._TOKEN_FNS.values()]
    zero_recompiles = bool(sizes1 == sizes0 and all(s == 1 for s in sizes1))

    # hardened admission (PR 7): bounded queue + deadlines under a stalled
    # step — all submits land before the drain, so shed and timeout counts
    # are deterministic
    from repro.fleet import chaos

    max_queue = 6
    bat3 = ContinuousBatcher(
        params, cfg,
        BatcherConfig(n_slots=4, prompt_buckets=buckets, new_token_bucket=T,
                      token_granular=True, max_queue=max_queue),
        adaptive=_controller(cfg))
    trace3 = axbench_trace(cfg, max_queue + 2, max_prompt=max(buckets),
                           max_new=T)
    expired_rids = {4, 5}                      # lapse before any step runs
    accepted = [bat3.submit(Request(r.rid, r.tokens.copy(), r.max_new,
                                    deadline_s=(0.0 if r.rid in expired_rids
                                                else None)))
                for r in trace3]
    stall = chaos.FaultPlan([chaos.FaultSpec("sched.step", "stall_step",
                                             at=1, arg=0.002)])
    with chaos.active(stall):
        done3 = bat3.run()
    status3 = {c.rid: c.status for c in done3}
    shed_ok = (accepted == [True] * max_queue + [False] * 2
               and bat3.stats["shed"] == 2)
    timeouts_ok = all(
        status3.get(rid) == ("timeout" if rid in expired_rids else "ok")
        for rid in range(max_queue))

    return {
        "bench": "serving_table",
        "quick": quick,
        "requests": n_req,
        "trace": "axbench-sobel-derived mixed lengths",
        "wave_occupancy": wave_bat.occupancy(),
        "token_granular_occupancy": tok_bat.occupancy(),
        "wave_tokens_per_s": wave_tps,
        "token_granular_tokens_per_s": tok_tps,
        "wave_waves": wave_bat.stats["waves"],
        "token_splices": tok_bat.stats["splices"],
        "wave_backfilled": wave_bat.stats["backfilled"],
        "bit_identical_requests": bool(bit_identical),
        "zero_recompiles": zero_recompiles,
        "decode_retraces_post_warmup":
            tok_bat.stats["decode_retraces_post_warmup"],
        "shed": bat3.stats["shed"],
        "timeouts": bat3.stats["timeouts"],
        "stragglers": bat3.stats["stragglers"],
        "shed_respects_bound": bool(shed_ok),
        "timeouts_match_deadlines": bool(timeouts_ok),
        "qor_attribution_live": bool(qor_attribution_live),
        "corr_ids_unique": bool(corr_ids_unique),
        "qor_fleet_share": {t: round(s, 4)
                            for t, s in tok_bat.qor.fleet_share().items()},
        "slo_latency_events": int(slo_latency_events),
        "slo_alerts": len(slo.alerting()),
        "statsd_lines_sent": int(statsd_lines_sent),
        "wave_ttft_p50_s": wave_lat.get("ttft_p50"),
        "wave_ttft_p99_s": wave_lat.get("ttft_p99"),
        "wave_e2e_p50_s": wave_lat.get("e2e_p50"),
        "wave_e2e_p99_s": wave_lat.get("e2e_p99"),
        "token_ttft_p50_s": tok_lat.get("ttft_p50"),
        "token_ttft_p99_s": tok_lat.get("ttft_p99"),
        "token_e2e_p50_s": tok_lat.get("e2e_p50"),
        "token_e2e_p99_s": tok_lat.get("e2e_p99"),
    }


def _ms(v) -> str:
    return "-" if v is None else f"{v * 1e3:.0f}ms"


def format_table(out) -> str:
    lines = [
        "Serving — wave vs token-granular continuous batching (PR 5)",
        f"trace: {out['requests']} requests, {out['trace']}",
        (f"{'mode':16s} {'occupancy':>10s} {'tokens/s*':>10s} "
         f"{'ttft_p50*':>10s} {'ttft_p99*':>10s} {'e2e_p99*':>10s}"),
        (f"{'wave':16s} {out['wave_occupancy']:>10.2f} "
         f"{out['wave_tokens_per_s']:>10.1f} "
         f"{_ms(out['wave_ttft_p50_s']):>10s} "
         f"{_ms(out['wave_ttft_p99_s']):>10s} "
         f"{_ms(out['wave_e2e_p99_s']):>10s}   "
         f"({out['wave_waves']} waves, {out['wave_backfilled']} backfilled)"),
        (f"{'token-granular':16s} {out['token_granular_occupancy']:>10.2f} "
         f"{out['token_granular_tokens_per_s']:>10.1f} "
         f"{_ms(out['token_ttft_p50_s']):>10s} "
         f"{_ms(out['token_ttft_p99_s']):>10s} "
         f"{_ms(out['token_e2e_p99_s']):>10s}   "
         f"({out['token_splices']} mid-flight splices)"),
        f"per-request tokens bit-identical to wave oracle: "
        f"{out['bit_identical_requests']}",
        f"zero recompiles across splices + policy update:  "
        f"{out['zero_recompiles']}",
        (f"bounded queue + deadlines under injected stall: "
         f"{out['shed']} shed (bound ok: {out['shed_respects_bound']}), "
         f"{out['timeouts']} timeouts (deadlines ok: "
         f"{out['timeouts_match_deadlines']}), "
         f"{out['stragglers']} straggler steps flagged"),
        (f"QoR attribution on every completion (top-k + tile): "
         f"{out['qor_attribution_live']} "
         f"(corr ids unique: {out['corr_ids_unique']}, fleet share "
         + " ".join(f"{t}={s:.2f}"
                    for t, s in out['qor_fleet_share'].items()) + ")"),
        (f"SLO engine live ({out['slo_latency_events']} latency events, "
         f"{out['slo_alerts']} alerts) + statsd push "
         f"({out['statsd_lines_sent']} lines) during the gated serve"),
        "  (* CPU wall in this container; occupancy / identity /"
        " recompile counts are the gate metrics)",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print(format_table(run(quick=args.quick)))


if __name__ == "__main__":
    main()
