"""Benchmark driver — one function per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full]

Prints each table and a ``name,us_per_call,derived`` CSV summary line per
benchmark (derived = the table's headline number).  Also runs the hot-path
perf microbenchmarks and writes ``BENCH_2.json`` (old-vs-new dispatch /
reduction / decode numbers — the regression baseline for later PRs).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (adaptive_table, app_table, component_table, hw_table,
               perf_table, roofline_table)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small fast subset")
    ap.add_argument("--full", action="store_true", help="all multipliers + ALL parts")
    ap.add_argument("--bench-out", default="BENCH_2.json",
                    help="perf_table JSON artifact path")
    args = ap.parse_args()

    csv = ["name,us_per_call,derived"]

    t0 = time.time()
    comp = component_table.run(quick=args.quick)
    print(component_table.format_table(comp))
    n_calls = len(comp["rows"])
    best = max(r["swapper_reduction"] for r in comp["rows"])
    csv.append(f"component_table,{1e6*(time.time()-t0)/max(n_calls,1):.0f},"
               f"best_mae_reduction={100*best:.1f}%")

    t0 = time.time()
    app = app_table.run(quick=args.quick, full=args.full)
    print("\n" + app_table.format_table(app))
    gains = []
    for r in app["rows"]:
        base, swapped = r["noswap"], r["swapper_app"]
        if r["minimize"] and base > 0:
            gains.append((base - swapped) / base)
        elif not r["minimize"] and base > 0:
            gains.append((swapped - base) / base)
    best_gain = max(gains) if gains else 0.0
    csv.append(f"app_table,{1e6*(time.time()-t0)/max(len(app['rows']),1):.0f},"
               f"best_app_gain={100*best_gain:.1f}%")

    t0 = time.time()
    ad = adaptive_table.run(quick=args.quick)
    print("\n" + adaptive_table.format_table(ad))
    csv.append(f"adaptive_table,{1e6*(time.time()-t0)/max(len(ad['rows']),1):.0f},"
               f"adaptive_gain_vs_static={100*ad['gain_vs_static']:.1f}%"
               f" retunes={ad['retunes']}"
               f" telemetry_us_per_step={ad['telemetry_us_per_step']:.0f}")

    t0 = time.time()
    perf = perf_table.run(quick=args.quick)
    print("\n" + perf_table.format_table(perf))
    perf_table.write_json(perf, args.bench_out)
    print(f"(perf_table written to {args.bench_out})")
    d = perf["matmul_dispatch"]
    csv.append(f"perf_table,{1e6*(time.time()-t0):.0f},"
               f"dispatch={d['static_2mm']['dot_generals']}->"
               f"{d['static_stacked']['dot_generals']}"
               f" reduction_steps_ratio={perf['kernel_reduction']['reduction_step_ratio']:.0f}x"
               f" decode_speedup={perf['decode']['speedup']:.2f}x")

    t0 = time.time()
    hw = hw_table.run()
    print("\n" + hw_table.format_table(hw))
    csv.append(f"hw_table,{1e6*(time.time()-t0):.0f},"
               f"mxu_swap_overhead={100*hw['mxu_swap_overhead']:.1f}%")

    rl = roofline_table.run()
    if rl["n"]:
        print("\nRoofline (from dry-run artifacts):")
        print(roofline_table.format_table(rl["rows"]))
        ok = [r for r in rl["rows"] if r.get("status") == "ok"]
        if ok:
            bestr = max(r["roofline_fraction"] for r in ok)
            csv.append(f"roofline_table,0,best_roofline_fraction={100*bestr:.1f}%")
    else:
        print("\n(roofline: no dryrun_*.jsonl found — run repro.launch.dryrun --all)")

    print("\n" + "\n".join(csv))


if __name__ == "__main__":
    main()
