"""Benchmark driver — one function per paper table.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--full] [--check]

Prints each table and a ``name,us_per_call,derived`` CSV summary line per
benchmark (derived = the table's headline number).  Also runs the hot-path
perf microbenchmarks plus the fleet-, token-granular-serving-,
chaos-recovery-, and audit-report microbenchmarks and writes
``BENCH_8.json`` (dispatch / reduction / decode / fleet / tile-adaptation
/ serving / chaos / audit numbers — this PR's point on the perf
trajectory).  ``--check`` then diffs the artifact's deterministic counters
against the committed baseline (``benchmarks/baselines/BENCH_7.json``) and
exits non-zero on regression — wall times are reported informationally
only (see ``benchmarks.regress``).
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (adaptive_table, app_table, audit_report, chaos_table,
               component_table, fleet_table, hw_table, perf_table, regress,
               roofline_table, serving_table)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="small fast subset")
    ap.add_argument("--full", action="store_true", help="all multipliers + ALL parts")
    ap.add_argument("--bench-out", default="BENCH_8.json",
                    help="perf/fleet/tile/serving/chaos/audit JSON artifact "
                         "path")
    ap.add_argument("--check", action="store_true",
                    help="fail on deterministic-counter regression vs --baseline")
    ap.add_argument("--baseline", default="benchmarks/baselines/BENCH_7.json",
                    help="committed baseline artifact for --check")
    ap.add_argument("--audit", default=None, metavar="PATH",
                    help="audit.jsonl for the audit report row (default: "
                         "synthesized promoted-retune history)")
    args = ap.parse_args()

    csv = ["name,us_per_call,derived"]

    t0 = time.time()
    comp = component_table.run(quick=args.quick)
    print(component_table.format_table(comp))
    n_calls = len(comp["rows"])
    best = max(r["swapper_reduction"] for r in comp["rows"])
    csv.append(f"component_table,{1e6*(time.time()-t0)/max(n_calls,1):.0f},"
               f"best_mae_reduction={100*best:.1f}%")

    t0 = time.time()
    app = app_table.run(quick=args.quick, full=args.full)
    print("\n" + app_table.format_table(app))
    gains = []
    for r in app["rows"]:
        base, swapped = r["noswap"], r["swapper_app"]
        if r["minimize"] and base > 0:
            gains.append((base - swapped) / base)
        elif not r["minimize"] and base > 0:
            gains.append((swapped - base) / base)
    best_gain = max(gains) if gains else 0.0
    csv.append(f"app_table,{1e6*(time.time()-t0)/max(len(app['rows']),1):.0f},"
               f"best_app_gain={100*best_gain:.1f}%")

    t0 = time.time()
    ad = adaptive_table.run(quick=args.quick)
    print("\n" + adaptive_table.format_table(ad))
    csv.append(f"adaptive_table,{1e6*(time.time()-t0)/max(len(ad['rows']),1):.0f},"
               f"adaptive_gain_vs_static={100*ad['gain_vs_static']:.1f}%"
               f" retunes={ad['retunes']}"
               f" telemetry_us_per_step={ad['telemetry_us_per_step']:.0f}"
               f" tile_best_gain={100*ad['tile']['best_gain']:.1f}%")

    t0 = time.time()
    perf = perf_table.run(quick=args.quick)
    print("\n" + perf_table.format_table(perf))
    d = perf["matmul_dispatch"]
    csv.append(f"perf_table,{1e6*(time.time()-t0):.0f},"
               f"dispatch={d['static_2mm']['dot_generals']}->"
               f"{d['static_stacked']['dot_generals']}"
               f" reduction_steps_ratio={perf['kernel_reduction']['reduction_step_ratio']:.0f}x"
               f" decode_speedup={perf['decode']['speedup']:.2f}x")

    t0 = time.time()
    fleet = fleet_table.run(quick=args.quick)
    print("\n" + fleet_table.format_table(fleet))
    fa = fleet["adaptive_decode"]
    csv.append(f"fleet_table,{1e6*(time.time()-t0):.0f},"
               f"adaptive_dispatch={fa['stepwise_dispatch_per_gen']}->"
               f"{fa['fused_dispatch_per_gen']}"
               f" fused_speedup={fa['speedup']:.2f}x"
               f" slot_util={100*fleet['scheduler']['slot_utilization']:.0f}%")

    t0 = time.time()
    srv = serving_table.run(quick=args.quick)
    print("\n" + serving_table.format_table(srv))
    csv.append(f"serving_table,{1e6*(time.time()-t0):.0f},"
               f"occupancy={srv['wave_occupancy']:.2f}->"
               f"{srv['token_granular_occupancy']:.2f}"
               f" splices={srv['token_splices']}"
               f" bit_identical={srv['bit_identical_requests']}"
               f" qor_live={srv['qor_attribution_live']}"
               f" statsd_lines={srv['statsd_lines_sent']}")

    t0 = time.time()
    cha = chaos_table.run(quick=args.quick)
    print("\n" + chaos_table.format_table(cha))
    csv.append(f"chaos_table,{1e6*(time.time()-t0):.0f},"
               f"faults={cha['faults_injected']}"
               f" rollbacks={cha['rollbacks_recovered']}/"
               f"{cha['rollbacks_triggered']}"
               f" survived_all={cha['survived_all']}")

    t0 = time.time()
    aud = audit_report.run(quick=args.quick, audit_path=args.audit)
    print("\n" + audit_report.format_table(aud))
    gr = aud["gain_realization"]
    csv.append(f"audit_report,{1e6*(time.time()-t0):.0f},"
               f"rejection_rate={aud['rejection_rate']:.2f}"
               f" gain_realization={'-' if gr is None else f'{gr:.2f}'}"
               f" slo_veto_blocks_promotion="
               f"{aud['slo_veto_blocks_promotion']}")

    perf["fleet"] = fleet
    perf["tile_adaptation"] = ad["tile"]
    perf["serving"] = srv
    perf["chaos"] = cha
    perf["audit"] = aud
    perf_table.write_json(perf, args.bench_out)
    print(f"(perf+fleet+tile+serving+chaos+audit tables written to "
          f"{args.bench_out})")

    t0 = time.time()
    hw = hw_table.run()
    print("\n" + hw_table.format_table(hw))
    csv.append(f"hw_table,{1e6*(time.time()-t0):.0f},"
               f"mxu_swap_overhead={100*hw['mxu_swap_overhead']:.1f}%")

    rl = roofline_table.run()
    if rl["n"]:
        print("\nRoofline (from dry-run artifacts):")
        print(roofline_table.format_table(rl["rows"]))
        ok = [r for r in rl["rows"] if r.get("status") == "ok"]
        if ok:
            bestr = max(r["roofline_fraction"] for r in ok)
            csv.append(f"roofline_table,0,best_roofline_fraction={100*bestr:.1f}%")
    else:
        print("\n(roofline: no dryrun_*.jsonl found — run repro.launch.dryrun --all)")

    print("\n" + "\n".join(csv))

    if args.check:
        failures, notes = regress.check_files(args.bench_out, args.baseline)
        print(f"\nperf gate vs {args.baseline}:")
        for line in notes:
            print(f"  {line}")
        if failures:
            for line in failures:
                print(f"  REGRESSION {line}")
            sys.exit(1)
        print("  gate: ok (no deterministic-counter regressions)")


if __name__ == "__main__":
    main()
