"""Paper Tables II/III analog: application-level error for the AxBench-in-JAX
suite under Original/FxP/NoSwap/SWAPPER(Comp)/SWAPPER(App)/Theoretical, for a
set of non-commutative mul16s circuits, in the MD+LO (and optionally ALL)
configuration of Eq. 6."""
from __future__ import annotations

import time

import repro.apps as A
import repro.core as C

DEFAULT_MULTS = ["mul16s_drum5_8", "mul16s_bam_v4_h1", "mul16s_mitch10_13"]
FULL_MULTS = DEFAULT_MULTS + ["mul16s_trunc0_8", "mul16s_trunc1_9"]

_N = {"ssim": 64, "are": 256, "miss_rate": 256}
TEST_SEED, TRAIN_SEED = 1234, 42


def run(quick: bool = False, full: bool = False, parts_list=None):
    mults = FULL_MULTS if full else DEFAULT_MULTS
    apps = sorted(A.ALL_APPS) if not quick else ["sobel", "inversek2j"]
    parts_list = parts_list or [C.PART_MD_LO] + ([C.PART_ALL] if full else [])
    if quick:
        mults = mults[:1]

    comp_best = {}
    for mname in mults:
        res = C.component_sweep(C.get(mname), tile=128, sample_bits=9)
        comp_best[mname] = res.best("mae")

    rows = []
    t_all = time.time()
    for app_name in apps:
        app = A.ALL_APPS[app_name]
        n = _N[app.metric_name] if not quick else 48
        v_fp, _ = A.evaluate(app, "fp", n=n, seed=TEST_SEED)
        v_fxp, _ = A.evaluate(app, "fxp", n=n, seed=TEST_SEED)
        for parts in parts_list:
            pname = "ALL" if parts == C.PART_ALL else "MD_LO"
            if app.kind == "int16" and parts == C.PART_ALL:
                continue  # jpeg has a single (direct mul16s) configuration
            for mname in mults:
                mult = C.get(mname)
                v_nosw, _ = A.evaluate(app, None, mult=mult, parts=parts, n=n, seed=TEST_SEED)
                v_comp, _ = A.evaluate(app, comp_best[mname], mult=mult, parts=parts,
                                       n=n, seed=TEST_SEED)
                cfg_app, _, _ = A.tune_app(app, mult, parts=parts, n=n, seed=TRAIN_SEED)
                v_app, _ = A.evaluate(app, cfg_app, mult=mult, parts=parts, n=n,
                                      seed=TEST_SEED)
                v_theor, _ = A.evaluate(app, "oracle", mult=mult, parts=parts, n=n,
                                        seed=TEST_SEED)
                rows.append(dict(
                    app=app_name, metric=app.metric_name, minimize=app.minimize,
                    parts=pname, mult=mname, original=v_fp, fxp=v_fxp,
                    noswap=v_nosw, swapper_comp=v_comp, swapper_app=v_app,
                    theoretical=v_theor,
                    app_cfg=(cfg_app.short() if cfg_app else "NoSwap"),
                ))
    return {"rows": rows, "total_s": time.time() - t_all}


def format_table(out) -> str:
    lines = ["Application-level — Tables II/III analog"]
    cur = None
    for r in out["rows"]:
        hdr = (r["app"], r["parts"])
        if hdr != cur:
            cur = hdr
            arrow = "lower is better" if r["minimize"] else "higher is better"
            lines.append(f"\n[{r['app']} / {r['parts']}] metric={r['metric']} ({arrow}) "
                         f"original={r['original']:.4f} fxp={r['fxp']:.4f}")
            lines.append(f"  {'mult':22s} {'NoSwap':>9s} {'Comp.':>9s} {'App.':>9s} "
                         f"{'Theor.':>9s}  app-cfg")
        lines.append(
            f"  {r['mult']:22s} {r['noswap']:9.4f} {r['swapper_comp']:9.4f} "
            f"{r['swapper_app']:9.4f} {r['theoretical']:9.4f}  {r['app_cfg']}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
