"""Paper Table IV analog: the cost of the SWAPPER mechanism itself.

The paper synthesizes the swap front-end in 45 nm (power/area/delay); the TPU
analog is the kernel-level overhead of the fused single-bit decision:

  * 'mxu' backend: NoSwap = 1 int8 MXU matmul over K, SWAPPER = 1 K-stacked
    int8 matmul over 2K (the factorization limbs concatenated along the
    inner dimension) -> measured FLOP ratio and wall time on the
    exact/ax/swap variants.
  * 'kernel' (VPU/pallas, interpret) wall time per multiply.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
import repro.kernels as K
from repro.configs.base import AxPolicy
from repro.quant.ax import ax_matmul_int


def _time(f, *args, n=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / n


def run(m=256, k=256, n_=256):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (m, k)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (k, n_)).astype(np.int8))
    rows = []

    exact = jax.jit(lambda a, b: jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32))
    t_exact = _time(exact, a, b)
    rows.append(dict(impl="exact int8 matmul (MXU path)", seconds=t_exact, ratio=1.0))

    pol_ns = AxPolicy(mult_name="mul8s_trunc0_4", backend="mxu", swap_enabled=False)
    f_ns = jax.jit(lambda a, b: ax_matmul_int(a, b, pol_ns))
    t_ns = _time(f_ns, a, b)
    rows.append(dict(impl="ax NoSwap (mxu, 1 matmul)", seconds=t_ns, ratio=t_ns / t_exact))

    pol_sw = AxPolicy(mult_name="mul8s_trunc0_4", backend="mxu")
    f_sw = jax.jit(lambda a, b: ax_matmul_int(a, b, pol_sw))
    t_sw = _time(f_sw, a, b)
    rows.append(dict(impl="ax SWAPPER (mxu, K-stacked 1 matmul)", seconds=t_sw,
                     ratio=t_sw / t_exact))

    mult = C.get("mul8s_trunc0_4")
    t_kns = _time(lambda a, b: K.ax_matmul(a, b, mult, None, block_m=128,
                                           block_n=128, block_k=128), a, b, n=2)
    rows.append(dict(impl="ax NoSwap (pallas VPU, interpret)", seconds=t_kns,
                     ratio=t_kns / t_exact))
    t_ksw = _time(lambda a, b: K.ax_matmul(a, b, mult, C.SwapConfig("A", 3, 0),
                                           block_m=128, block_n=128, block_k=128),
                  a, b, n=2)
    rows.append(dict(impl="ax SWAPPER (pallas VPU, interpret)", seconds=t_ksw,
                     ratio=t_ksw / t_exact,
                     swap_overhead_vs_noswap=t_ksw / t_kns - 1.0))
    return {"rows": rows, "shape": (m, k, n_),
            "mxu_swap_overhead": t_sw / t_ns - 1.0}


def format_table(out) -> str:
    lines = [f"SWAPPER mechanism cost — Table IV analog (matmul {out['shape']})",
             f"{'implementation':42s} {'seconds':>10s} {'vs exact':>9s}"]
    for r in out["rows"]:
        lines.append(f"{r['impl']:42s} {r['seconds']:10.5f} {r['ratio']:8.2f}x")
    lines.append(f"MXU-path swap overhead vs NoSwap: {100*out['mxu_swap_overhead']:.1f}% "
                 "(paper 45nm: ~2-22% area, ~2-10% power, ~2-5% delay)")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
