"""CI perf-regression gate over the BENCH_*.json trajectory.

Wall-clock on a shared CI host is noise; the *deterministic* counters are
not: jaxpr ``dot_general`` dispatch counts, per-tile kernel reduction trip
counts, bit-identity flags, and the fleet path's dispatches-per-generation
are pure functions of the compiled programs.  ``check()`` compares the fresh
artifact against the committed baseline and fails on any counter that got
worse; wall-time movement is reported informationally only.

    PYTHONPATH=src python -m benchmarks.run --quick --check \
        [--baseline benchmarks/baselines/BENCH_7.json]
"""
from __future__ import annotations

import json
from typing import List, Tuple

__all__ = ["RULES", "WALL_NOTES", "check", "check_files"]

# (dotted path, rule): 'le' — new value must not exceed baseline;
# 'true' — must be truthy in the new artifact; 'ge:<other path>' — must be
# >= another value of the SAME (new) artifact (cross-section invariants,
# e.g. token-granular occupancy must meet the wave baseline it replaces);
# 'ratio>=<min>' — the value (already a ratio in the artifact, e.g. a
# speedup) must meet an absolute floor, independent of any baseline: a
# wall-derived ratio of two measurements taken on the SAME host in the SAME
# run divides the host speed out, so unlike raw wall time it can gate —
# floors sit well under the observed container values (0.80-0.92) to
# absorb CI noise while still catching a path collapsing.
# Paths missing from either side are skipped (older baselines predate newer
# sections).
RULES = [
    ("matmul_dispatch.static_stacked.dot_generals", "le"),
    ("matmul_dispatch.dyn_stacked.dot_generals", "le"),
    ("kernel_reduction.slab8_reduction_steps_per_tile", "le"),
    ("decode.bit_identical", "true"),
    ("fleet.adaptive_decode.fused_dispatch_per_gen", "le"),
    ("fleet.adaptive_decode.bit_identical", "true"),
    ("fleet.adaptive_decode.telemetry_identical", "true"),
    ("fleet.adaptive_decode.retrace_free", "true"),
    # per-tile adaptation (PR 4): the controller's tile loop must keep
    # beating the layer-granular policy on at least one app stream, with a
    # recompile-free tile re-tune (deterministic: fixed seeds, counter data)
    ("tile_adaptation.tile_beats_layer", "true"),
    # token-granular serving (PR 5): mid-flight admission must produce the
    # wave oracle's per-request tokens bit-exactly, never lose occupancy to
    # the wave design it replaces, and never add compiled programs across
    # splices / policy updates
    ("serving.bit_identical_requests", "true"),
    ("serving.zero_recompiles", "true"),
    ("serving.token_granular_occupancy", "ge:serving.wave_occupancy"),
    # observability (PR 6): the live recompile gauge the scheduler asserts
    # on — decode retraces after warmup must be exactly zero
    ("serving.decode_retraces_post_warmup", "le"),
    # robustness (PR 7): recovery outcomes of the chaos_table fault suite.
    # All 'true'/'ge:' rules read the NEW artifact only, so a baseline that
    # predates the chaos section can never skip-neutralize the gate once
    # the section exists.
    ("serving.shed_respects_bound", "true"),
    ("serving.timeouts_match_deadlines", "true"),
    ("chaos.publish_crash_atomic", "true"),
    ("chaos.torn_current_recovered", "true"),
    ("chaos.corrupt_policy_fallback", "true"),
    ("chaos.poison_kept_out", "true"),
    ("chaos.canary_rejected", "true"),
    # every triggered rollback recovered (counts compared inside the bool),
    # and the replica survived at least as many kills as were injected
    ("chaos.rollbacks_all_recovered", "true"),
    ("chaos.rollbacks_recovered", "ge:chaos.rollbacks_triggered"),
    ("chaos.replica_crashes_survived", "ge:chaos.replica_crashes_injected"),
    ("chaos.post_recovery_mae_within_band", "true"),
    ("chaos.stall_deadlines_respected", "true"),
    ("chaos.shed_respects_bound", "true"),
    ("chaos.armed_idle_bit_identical", "true"),
    ("chaos.armed_idle_zero_retraces", "true"),
    ("chaos.survived_all", "true"),
    # QoR observability (PR 8): the bit-identity serve now runs with
    # per-request attribution + the SLO engine + a StatsD push exporter all
    # live — every completion must carry a top-k per-target/tile error-share
    # summary under unique correlation ids, and an alerting veto-bearing
    # SLO must block an otherwise-confirmed canary promotion (audited)
    ("serving.qor_attribution_live", "true"),
    ("serving.corr_ids_unique", "true"),
    ("audit.slo_veto_blocks_promotion", "true"),
    ("audit.scenario.alert_audited", "true"),
    ("audit.scenario.veto_audited", "true"),
    # ratio floors (PR 6): Pallas slab + K-stacked dynamic-dispatch
    # speedups are same-run wall ratios, gated against absolute minima
    ("kernel_reduction.static_speedup", "ratio>=0.6"),
    ("kernel_reduction.grid_speedup", "ratio>=0.6"),
    ("matmul_dispatch.dyn_speedup", "ratio>=0.6"),
    ("matmul_dispatch.static_speedup", "ratio>=0.6"),
]

# informational wall-time trajectory (never gating)
WALL_NOTES = [
    "matmul_dispatch.static_stacked.us_per_call",
    "matmul_dispatch.dyn_stacked.us_per_call",
    "kernel_reduction.static_slab8_us",
    "decode.scan_steps_per_s",
    "serving.wave_tokens_per_s",
    "serving.token_granular_tokens_per_s",
    "serving.wave_e2e_p99_s",
    "serving.token_e2e_p99_s",
    "serving.token_ttft_p99_s",
    "chaos.post_recovery_mae",
    "chaos.baseline_mae",
    "audit.gain_realization",
]


def _get(d, path: str):
    for part in path.split("."):
        if not isinstance(d, dict) or part not in d:
            return None
        d = d[part]
    return d


def check(new: dict, baseline: dict) -> Tuple[List[str], List[str]]:
    """(failures, notes): failures non-empty == perf regression."""
    failures, notes = [], []
    for path, rule in RULES:
        nv = _get(new, path)
        if rule == "true":
            if nv is None:
                continue
            if not nv:
                failures.append(f"{path}: expected truthy, got {nv!r}")
            continue
        if rule.startswith("ratio>="):
            # absolute floor on a same-run wall ratio — no baseline involved
            floor = float(rule[len("ratio>="):])
            if nv is None:
                continue
            if nv < floor:
                failures.append(
                    f"{path}: {nv:.3f} < floor {floor} (path collapsed)")
            else:
                notes.append(f"{path}: {nv:.3f} >= floor {floor} ok")
            continue
        if rule.startswith("ge:"):
            # same-artifact invariant: both sides read from the NEW artifact
            ov = _get(new, rule[3:])
            if nv is None or ov is None:
                continue
            if nv < ov:
                failures.append(f"{path}: {nv} < {rule[3:]} ({ov}) (regression)")
            else:
                notes.append(f"{path}: {nv} >= {rule[3:]} ({ov}) ok")
            continue
        bv = _get(baseline, path)
        if nv is None or bv is None:
            continue
        if nv > bv:
            failures.append(f"{path}: {bv} -> {nv} (regression)")
        else:
            notes.append(f"{path}: {bv} -> {nv} ok")
    for path in WALL_NOTES:
        nv, bv = _get(new, path), _get(baseline, path)
        if nv is not None and bv is not None and bv:
            notes.append(f"(wall, informational) {path}: {bv:.1f} -> {nv:.1f} "
                         f"({nv / bv:.2f}x)")
    return failures, notes


def check_files(new_path: str, baseline_path: str) -> Tuple[List[str], List[str]]:
    with open(new_path) as f:
        new = json.load(f)
    with open(baseline_path) as f:
        baseline = json.load(f)
    return check(new, baseline)
