"""Fleet-serving table: the adaptive hot path at one-dispatch-per-generation
plus the continuous-batching scheduler and the policy store.

Three sections (single-process; the multi-device psum path is exercised by
tests/test_fleet.py and examples/fleet_serve.py under forced host devices):

* **adaptive decode** — the stepwise adaptive loop (one host dispatch per
  token, the PR-1 design) vs the fused telemetry-through-scan-carry decode
  (ONE dispatch per generation): wall steps/s, token bit-identity, telemetry
  bit-identity, and the zero-retrace check across a policy update.
* **scheduler** — variable-length synthetic requests through the
  ``ContinuousBatcher``: requests/s, slot utilization, waves, and compiled
  shape classes (one per prompt bucket).
* **policy store** — publish/load round-trip wall time and version
  monotonicity.

``run()``'s deterministic counters (dispatches per generation, identity
flags, retrace-freedom) feed the ``benchmarks.regress`` CI gate via the
``fleet`` section of BENCH_3.json.

    PYTHONPATH=src python -m benchmarks.fleet_table [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AxPolicy

MULT = "mul8s_trunc0_4"


def _tiny():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2,
                              ax=AxPolicy(mult_name=MULT, backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _controller(cfg, store=None):
    import repro.runtime as R

    return R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10 ** 6), store=store)


def _snap_equal(a, b) -> bool:
    if set(a) != set(b):
        return False
    for t in a:
        for f in ("mae", "wce", "ep", "n", "n_steps"):
            if a[t][f] != b[t][f]:
                return False
        if not np.array_equal(a[t]["bit_probs"], b[t]["bit_probs"]):
            return False
    return True


# ---------------------------------------------------------------------------
# 1. adaptive decode: stepwise loop vs fused scan
# ---------------------------------------------------------------------------

def bench_adaptive_decode(quick: bool):
    import repro.core as C
    from repro.serve import ServeConfig, generate
    from repro.serve import engine as E

    cfg, params = _tiny()
    T = 12 if quick else 24
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}

    out = {"new_tokens": T,
           # by construction: the stepwise loop issues one jitted decode-step
           # call per generated token; the fused path runs the whole loop as
           # one lax.scan inside one jitted call
           "stepwise_dispatch_per_gen": T - 1,
           "fused_dispatch_per_gen": 1}
    toks, snaps = {}, {}
    for name, fused in (("stepwise", False), ("fused", True)):
        ctrl = _controller(cfg)
        scfg = ServeConfig(max_new_tokens=T, fused=fused)
        toks[name] = np.asarray(
            generate(params, prompt, cfg, scfg, adaptive=ctrl))   # compile
        snaps[name] = ctrl.telemetry.snapshot()
        best = float("inf")
        for _ in range(2 if quick else 3):
            c2 = _controller(cfg)
            t0 = time.perf_counter()
            jax.block_until_ready(
                generate(params, prompt, cfg, scfg, adaptive=c2))
            best = min(best, time.perf_counter() - t0)
        out[f"{name}_steps_per_s"] = (T - 1) / best
    out["bit_identical"] = bool(np.array_equal(toks["stepwise"], toks["fused"]))
    out["telemetry_identical"] = _snap_equal(snaps["stepwise"], snaps["fused"])
    out["speedup"] = out["fused_steps_per_s"] / out["stepwise_steps_per_s"]

    # zero-retrace across a re-tune: flip the policy, regenerate, and check
    # the fused program cache kept exactly one entry per shape class
    ctrl = _controller(cfg)
    scfg = ServeConfig(max_new_tokens=T, fused=True)
    generate(params, prompt, cfg, scfg, adaptive=ctrl)
    ctrl.policy.set_config("mlp", C.SwapConfig("B", 5, 1))
    generate(params, prompt, cfg, scfg, adaptive=ctrl)
    sizes = [f._cache_size() for f in E._ADAPTIVE_FNS.values()]
    out["retrace_free"] = bool(all(s == 1 for s in sizes))
    return out


# ---------------------------------------------------------------------------
# 2. continuous-batching scheduler
# ---------------------------------------------------------------------------

def bench_scheduler(quick: bool):
    from repro.fleet import BatcherConfig, ContinuousBatcher, Request

    cfg, params = _tiny()
    n_req = 8 if quick else 16
    bcfg = BatcherConfig(n_slots=4, prompt_buckets=(8, 16), new_token_bucket=8)
    bat = ContinuousBatcher(params, cfg, bcfg, adaptive=_controller(cfg))
    rng = np.random.default_rng(1)
    for rid in range(n_req):
        L = int(rng.integers(4, 17))
        bat.submit(Request(rid, rng.integers(0, cfg.vocab, L),
                           max_new=int(rng.integers(1, 9))))
    t0 = time.perf_counter()
    done = bat.run()
    dt = time.perf_counter() - t0
    s = bat.stats
    useful = s["real_tokens"]
    total = useful + s["padded_tokens"] + s["filler_tokens"]
    return {
        "requests": len(done),
        "waves": s["waves"],
        "requests_per_s": len(done) / dt,
        "slot_utilization": useful / total if total else 1.0,
        "all_served": len(done) == n_req,
    }


# ---------------------------------------------------------------------------
# 3. policy store
# ---------------------------------------------------------------------------

def bench_store(quick: bool):
    import repro.runtime as R
    from repro.fleet import PolicyReader, PolicyStore

    n = 16 if quick else 64
    with tempfile.TemporaryDirectory() as tmp:
        store = PolicyStore(tmp)
        policy = R.SwapPolicy(MULT, configs={"*": None})
        t0 = time.perf_counter()
        import repro.core as C

        for i in range(n):
            policy.set_config("mlp", C.SwapConfig("A", i % 8, i % 2))
            store.publish(policy)
        publish_us = 1e6 * (time.perf_counter() - t0) / n
        reader = PolicyReader(store, ("mlp",))
        t0 = time.perf_counter()
        reader.poll()                       # no-op poll (version unchanged)
        poll_us = 1e6 * (time.perf_counter() - t0)
        monotonic = store.versions() == sorted(store.versions())
        current_ok = store.current_version() == n
        adopted_ok = reader.policy.configs_equal(policy)
    return {
        "publishes": n,
        "publish_us": publish_us,
        "noop_poll_us": poll_us,
        "versions_monotonic": bool(monotonic and current_ok),
        "reader_adopted_latest": bool(adopted_ok),
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = False):
    return {
        "bench": "fleet_table",
        "quick": quick,
        "adaptive_decode": bench_adaptive_decode(quick),
        "scheduler": bench_scheduler(quick),
        "store": bench_store(quick),
    }


def format_table(out) -> str:
    a, s, st = out["adaptive_decode"], out["scheduler"], out["store"]
    lines = [
        "Fleet serving — adaptive decode, scheduler, policy store (PR 3)",
        f"{'path':38s} {'old':>10s} {'new':>10s} {'gain':>8s}",
        (f"{'adaptive dispatches/generation':38s} "
         f"{a['stepwise_dispatch_per_gen']:>10d} "
         f"{a['fused_dispatch_per_gen']:>10d} "
         f"{a['stepwise_dispatch_per_gen'] / a['fused_dispatch_per_gen']:>7.0f}x"),
        (f"{'adaptive decode steps/s*':38s} {a['stepwise_steps_per_s']:>10.1f} "
         f"{a['fused_steps_per_s']:>10.1f} {a['speedup']:>7.2f}x"),
        f"adaptive fused bit-identical tokens:    {a['bit_identical']}",
        f"adaptive fused bit-identical telemetry: {a['telemetry_identical']}",
        f"policy update retrace-free:             {a['retrace_free']}",
        (f"scheduler: {s['requests']} requests in {s['waves']} waves, "
         f"{s['requests_per_s']:.2f} req/s*, slot utilization "
         f"{100 * s['slot_utilization']:.0f}%"),
        (f"store: publish {st['publish_us']:.0f}us*, no-op poll "
         f"{st['noop_poll_us']:.0f}us*, monotonic={st['versions_monotonic']}, "
         f"reader adopted latest={st['reader_adopted_latest']}"),
        "  (* CPU wall in this container; dispatch counts and identity flags"
        " are the gate metrics)",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print(format_table(run(quick=args.quick)))


if __name__ == "__main__":
    main()
