"""Audit-trail report: what the adaptation loop *actually did*.

``audit.jsonl`` (``repro.obs.audit``) accumulates every retune, canary
verdict, SLO alert, rollback and quarantine next to the policy store.
This module turns that history into the numbers an operator asks for:

* **gain realization** — promoted guarded retunes carry both the sweep's
  ``predicted_gain`` (full ring buffer) and the canary's holdout scores
  (``canary.incumbent - canary.winner`` = the *realized* gain on unseen
  operands).  The realization ratio is the honesty check on the tuner:
  a sweep that always predicts more than the holdout delivers is
  overfitting its buffer.
* **rejection rate** — what fraction of retune attempts the guarded
  rollout refused (canary holdout loss, or an alerting veto-bearing SLO).
* counts of rollbacks, quarantines, and SLO alert transitions.

It also runs a deterministic **SLO-veto scenario** (the BENCH_8 CI gate
``slo_veto_blocks_promotion``): a controller with canaried rollout gets an
already-burning QoR SLO attached, a manual retune's winner CONFIRMS on
the holdout, and the promotion must still be refused — with the veto and
the alert both landing in the audit log.

    PYTHONPATH=src python -m benchmarks.audit_report [--audit PATH]

With ``--audit`` the report summarizes an existing ``audit.jsonl``
(e.g. the one a ``--fleet`` serve wrote next to its policy store) instead
of synthesizing history; the veto scenario runs either way.
"""
from __future__ import annotations

import argparse
import json
import tempfile
from typing import List, Optional

import numpy as np

RETUNE_KINDS = ("retune", "canary_rejected", "slo_veto")


def read_events(path: str) -> List[dict]:
    """Parse an ``audit.jsonl`` leniently (skip torn/garbage lines — the
    log is append-only and a crash can tear the tail)."""
    out = []
    try:
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue
    except OSError:
        pass
    return out


def summarize(events: List[dict]) -> dict:
    """Roll an audit event list up into the operator-facing numbers."""
    by_kind: dict = {}
    for e in events:
        by_kind[e.get("kind", "?")] = by_kind.get(e.get("kind", "?"), 0) + 1
    attempts = sum(by_kind.get(k, 0) for k in RETUNE_KINDS)
    refused = by_kind.get("canary_rejected", 0) + by_kind.get("slo_veto", 0)
    predicted, realized, ratios = [], [], []
    for e in events:
        if e.get("kind") != "retune" or "canary" not in e:
            continue
        p = float(e.get("predicted_gain", 0.0))
        r = float(e["canary"]["incumbent"]) - float(e["canary"]["winner"])
        predicted.append(p)
        realized.append(r)
        if p > 0:
            ratios.append(r / p)
    return {
        "events": len(events),
        "by_kind": dict(sorted(by_kind.items())),
        "retune_attempts": attempts,
        "rejection_rate": (refused / attempts) if attempts else 0.0,
        "promoted_with_canary": len(realized),
        "predicted_gain_mean": float(np.mean(predicted)) if predicted else None,
        "realized_gain_mean": float(np.mean(realized)) if realized else None,
        "gain_realization": float(np.mean(ratios)) if ratios else None,
        "rollbacks": by_kind.get("rollback", 0),
        "quarantined": by_kind.get("quarantine", 0),
        "slo_alerts": by_kind.get("slo_alert", 0),
    }


def _controller(store, **kw):
    import repro.runtime as R

    cfg = dict(decay=0.4, drift_threshold=10.0,   # manual retunes only
               min_observe_steps=1, cooldown_steps=0, buffer_size=1024,
               canary=True)
    cfg.update(kw)
    ctrl = R.AdaptiveController(
        R.SwapPolicy("mul8u_trunc0_4", configs={"*": None}),
        targets=("stream",), cfg=R.AdaptiveConfig(**cfg), store=store)
    ctrl.warmup()
    ctrl.resume_from_store()
    return ctrl


def promoted_retune_history(root: str) -> List[dict]:
    """Synthesize a clean promoted guarded retune (no SLO attached): the
    canary CONFIRMS the sweep winner over the NoSwap incumbent and its
    holdout scores ride on the audited event — the gain-realization
    source."""
    from repro.fleet import PolicyStore

    store = PolicyStore(root)
    ctrl = _controller(store)
    rng = np.random.default_rng(5)
    for _ in range(3):
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    ev = ctrl.retune("stream")
    assert ev.promoted, "clean canary run should promote"
    return ctrl.audit.read()


def slo_veto_scenario(root: str) -> dict:
    """The CI-gated scenario: an alerting veto-bearing QoR SLO must block
    an otherwise-CONFIRMED canary promotion, keep the incumbent serving,
    and audit both the alert and the veto."""
    from repro.fleet import PolicyStore
    from repro.obs import SLOEngine, SLOSpec

    store = PolicyStore(root)
    ctrl = _controller(store)
    # absolute guard band at 0 with tiny windows: every observed MAE of the
    # truncation multiplier is "bad", so the spec burns to alerting within
    # min_events observes — deterministically, before the retune below
    engine = SLOEngine([SLOSpec(
        name="qor_stream", kind="qor", source="stream", threshold=0.0,
        objective=0.1, short_window=4, long_window=4, min_events=2,
        veto_promotion=True)], audit=ctrl.audit)
    ctrl.attach_slo(engine)
    rng = np.random.default_rng(5)
    for _ in range(4):
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    alert_live = engine.vetoes_promotion() == "qor_stream"
    ev = ctrl.retune("stream")
    kinds = [e["kind"] for e in ctrl.audit.read()]
    veto_events = [e for e in ctrl.audit.read() if e["kind"] == "slo_veto"]
    return {
        "alert_armed_before_retune": bool(alert_live),
        "promotion_blocked": not ev.promoted,
        "incumbent_kept": ctrl.policy.lookup("stream") is None,
        "store_untouched": store.current_version() == 1,
        "candidate_rejected": store.candidate_version() is None,
        "alert_audited": "slo_alert" in kinds,
        "veto_audited": bool(veto_events)
        and veto_events[0].get("vetoed_by") == "qor_stream",
        "slo_veto_blocks_promotion": bool(
            alert_live and not ev.promoted
            and ctrl.policy.lookup("stream") is None
            and store.current_version() == 1
            and "slo_alert" in kinds and veto_events),
    }


def run(quick: bool = False, audit_path: Optional[str] = None) -> dict:
    with tempfile.TemporaryDirectory() as td:
        if audit_path is not None:
            events = read_events(audit_path)
            source = audit_path
        else:
            events = promoted_retune_history(td + "/promoted")
            source = "synthetic (promoted-retune scenario)"
        veto = slo_veto_scenario(td + "/veto")
        # the veto scenario's own audit history joins the roll-up so the
        # summary always exercises every kind the report knows about
        events = events + read_events(td + "/veto/audit.jsonl")
    out = summarize(events)
    out.update({
        "bench": "audit_report",
        "quick": quick,
        "source": source,
        "scenario": veto,
        "slo_veto_blocks_promotion": veto["slo_veto_blocks_promotion"],
    })
    return out


def format_table(out) -> str:
    kinds = " ".join(f"{k}={v}" for k, v in out["by_kind"].items())
    fmt = lambda v: "-" if v is None else f"{v:.3f}"  # noqa: E731
    sc = out["scenario"]
    return "\n".join([
        "Audit report — retune/canary/rollback history (PR 8)",
        f"source: {out['source']}",
        f"events: {out['events']}  [{kinds}]",
        (f"retune attempts: {out['retune_attempts']}  "
         f"rejection rate: {out['rejection_rate']:.2f}  "
         f"rollbacks: {out['rollbacks']}  "
         f"quarantined: {out['quarantined']}  "
         f"slo alerts: {out['slo_alerts']}"),
        (f"gain: predicted {fmt(out['predicted_gain_mean'])} -> realized "
         f"{fmt(out['realized_gain_mean'])} on the canary holdout "
         f"(realization {fmt(out['gain_realization'])}, "
         f"{out['promoted_with_canary']} promoted events)"),
        (f"SLO-veto scenario: alert armed {sc['alert_armed_before_retune']}, "
         f"promotion blocked {sc['promotion_blocked']}, incumbent kept "
         f"{sc['incumbent_kept']}, store untouched {sc['store_untouched']}, "
         f"alert+veto audited "
         f"{sc['alert_audited'] and sc['veto_audited']}"),
        f"slo_veto_blocks_promotion: {out['slo_veto_blocks_promotion']}",
    ])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--audit", default=None, metavar="PATH",
                    help="summarize this audit.jsonl instead of synthesizing "
                         "a promoted-retune history")
    args = ap.parse_args()
    print(format_table(run(quick=args.quick, audit_path=args.audit)))


if __name__ == "__main__":
    main()
