"""Adaptive-runtime table: MAE under synthetic operand-distribution drift for
static-tuned vs oracle vs adaptive SWAPPER, plus telemetry overhead.

The stream visits distribution phases (the live-traffic stand-in).  The
static policy is tuned once on phase 0 — the paper's offline framework.  The
oracle re-tunes clairvoyantly at every phase boundary.  The adaptive
controller sees only streaming telemetry: it detects the bit-occupancy shift
and re-tunes from its live operand buffer (zero recompilations; the scorer
jit-cache size is reported to prove it).

The **per-tile rows** compare tile-granular against layer-granular policies
on operand streams derived from the AxBench-style apps: each app's multiply
operands split into row tiles with genuinely different distributions (raw
pixels vs gradient magnitudes for sobel, coordinates vs squared distances
for kmeans, link lengths vs angle products for inversek2j).  The
layer-granular config is tuned over the whole stream (full 4M+1 space); the
tile-granular grid is produced by the controller's own per-tile loop
(tile telemetry -> per-tile buffers -> ``retune_tiles`` -> published
``tile_grids``) and evaluated on a held-out draw.  Results feed the
``tile_adaptation`` section of BENCH_4.json.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.runtime import (AdaptiveConfig, AdaptiveController, SwapPolicy,
                           all_triples)
from repro.runtime.controller import _score_configs

MULT = "mul8u_trunc0_4"


def _phases(rng, n_batches, batch):
    """Three operand-distribution regimes (uint8 pairs)."""

    def high_a():
        return (rng.integers(128, 256, batch), rng.integers(0, 256, batch))

    def low_a():
        return (rng.integers(0, 128, batch), rng.integers(0, 256, batch))

    def gauss():
        a = np.clip(rng.normal(96, 32, batch), 0, 255).astype(np.int64)
        b = np.clip(rng.normal(160, 48, batch), 0, 255).astype(np.int64)
        return (a, b)

    return [("high_a", high_a, n_batches), ("low_a", low_a, n_batches),
            ("gauss", gauss, n_batches)]


def _tune_on(mult, a, b, triples, metric="mae"):
    scores = np.asarray(_score_configs(mult, jnp.asarray(a, jnp.int32),
                                       jnp.asarray(b, jnp.int32), triples, metric))
    best = int(np.argmin(scores))
    return None if best == 0 else C.all_configs(mult.bits)[best - 1]


def _app_tile_streams(half_rows: int, K: int):
    """Row-tiled operand streams derived from the AxBench-style app inputs.

    Each draw returns ``(A, B)`` with ``A`` a (2*half_rows, K) uint8 matrix
    whose two row tiles carry the app's two operand populations, and ``B``
    the shared multiplicand stream — the live-traffic stand-in for a
    projection whose token rows are distributionally structured."""
    from repro.apps.common import smooth_image

    def sobel(rng):
        img = smooth_image(half_rows * 2, K, int(rng.integers(1 << 30)))
        gx = np.abs(np.gradient(img, axis=1)) * 4.0      # edge magnitudes: small
        a = np.concatenate([img[:half_rows],              # tile 0: raw pixels
                            np.clip(gx[:half_rows], 0, 255)])  # tile 1: gradients
        b = np.tile(np.asarray([64.0, 128.0, 64.0]), half_rows * K)[:K]
        b = b * rng.uniform(0.5, 1.5, K)                  # jittered kernel coeffs
        return a, np.clip(b, 0, 255)

    def kmeans(rng):
        pts = rng.uniform(0, 1, (half_rows, K)) * 255.0   # tile 0: coordinates
        cen = rng.uniform(0.3, 0.7, (1, K))
        d2 = (rng.uniform(0, 1, (half_rows, K)) - cen) ** 2 * 255.0  # tile 1: sq dists
        return np.concatenate([pts, d2]), rng.uniform(0, 1, K) * 255.0

    def inversek2j(rng):
        links = rng.uniform(0.6, 1.0, (half_rows, K)) * 255.0  # tile 0: link lengths
        ang = np.abs(np.sin(rng.uniform(-np.pi, np.pi, (half_rows, K)))
                     * np.sin(rng.uniform(-np.pi / 2, np.pi / 2, (half_rows, K))))
        return (np.concatenate([links, ang * 160.0]),          # tile 1: angle products
                np.abs(np.cos(rng.uniform(-np.pi, np.pi, K))) * 255.0)

    return {"sobel": sobel, "kmeans": kmeans, "inversek2j": inversek2j}


def run_tile(quick: bool = False):
    """Tile-granular vs layer-granular MAE on the app-derived streams; the
    tile grid comes out of the controller's own closed per-tile loop."""
    mult = C.get(MULT)
    half = 8
    K = 128 if quick else 256
    n_train = 4 if quick else 8
    streams = _app_tile_streams(half, K)
    triples = jnp.asarray(all_triples(mult.bits))

    from repro.runtime.policy import triple_of

    rows = []
    for seed, (app, draw) in enumerate(streams.items()):
        rng = np.random.default_rng(97 + seed)
        ctrl = AdaptiveController(
            SwapPolicy(mult.name, configs={"*": None}), targets=("stream",),
            cfg=AdaptiveConfig(min_observe_steps=10 ** 9,   # no drift path here:
                               tile_rows=2,                 # granularity benchmark
                               tile_buffer_size=1024))
        ctrl.warmup()
        train = [draw(rng) for _ in range(n_train)]
        for a, b in train:
            ctrl.observe_operands("stream", jnp.asarray(a, jnp.int32),
                                  jnp.asarray(b, jnp.int32))
        # layer-granular: one config for the whole stream, full 4M+1 space
        at = np.concatenate([a.reshape(-1) for a, _ in train])
        bt = np.concatenate([np.tile(b, 2 * half) for _, b in train])
        layer_cfg = _tune_on(mult, at, bt, triples)
        # tile-granular: the controller's own per-tile re-tune over its
        # live per-tile buffers -> published SwapPolicy.tile_grids (the
        # scorer-cache delta proves the re-tune itself compiled nothing)
        cache0 = ctrl.scorer_cache_size()
        ctrl.retune_tiles("stream")
        retune_recompiles = ctrl.scorer_cache_size() - cache0
        grid = ctrl.policy.tile_grids["stream"]

        # held-out evaluation draw, scored per tile
        a, b = draw(rng)
        t_layer = np.asarray([triple_of(layer_cfg)], np.int32)
        layer_mae = tile_mae = 0.0
        for t in range(2):
            at_ = jnp.asarray(a[t * half:(t + 1) * half].reshape(-1), jnp.int32)
            bt_ = jnp.asarray(np.tile(b, half), jnp.int32)
            pair = jnp.asarray(np.concatenate([t_layer, grid[t]]), jnp.int32)
            maes = np.asarray(_score_configs(mult, at_, bt_, pair, "mae"))
            layer_mae += float(maes[0]) / 2
            tile_mae += float(maes[1]) / 2

        from repro.runtime.policy import triple_short

        rows.append(dict(
            app=app,
            layer_cfg="noswap" if layer_cfg is None else layer_cfg.short(),
            tile_cfgs=",".join(triple_short(t) for t in grid[:, 0, :]),
            layer_mae=layer_mae, tile_mae=tile_mae,
            gain=(layer_mae - tile_mae) / layer_mae if layer_mae else 0.0,
            retune_recompiles=retune_recompiles,
        ))
    return dict(
        rows=rows,
        tile_beats_layer=bool(any(r["gain"] > 0 for r in rows)),
        best_gain=float(max(r["gain"] for r in rows)),
    )


def run(quick: bool = False):
    mult = C.get(MULT)
    batch = 2048 if quick else 4096
    n_batches = 8 if quick else 12
    rng = np.random.default_rng(0)
    phases = _phases(rng, n_batches, batch)
    triples = jnp.asarray(all_triples(mult.bits))

    # static: offline-tuned on a phase-0 sample (the paper's framework)
    a0, b0 = phases[0][1]()
    static_cfg = _tune_on(mult, a0, b0, triples)

    # adaptive: telemetry -> drift -> re-tune, starting from the static config
    policy = SwapPolicy(mult.name, configs={"*": static_cfg})
    ctrl = AdaptiveController(
        policy, targets=("stream",),
        # buffer refreshes in buffer_size/RETUNE_SAMPLE=2 observed steps, so a
        # detected drift re-tunes on post-drift operands
        cfg=AdaptiveConfig(decay=0.3, drift_threshold=0.04, min_observe_steps=2,
                           cooldown_steps=2, buffer_size=1024),
    )
    ctrl.warmup()

    from repro.runtime.policy import triple_of

    rows = []
    observe_times = []   # per-step; median reported so the one-time compile
    scorer_entries_after_first = None   # harness shapes compile on the first
    for name, draw, nb in phases:       # batch; any later growth would be a
                                        # re-tune recompile (must stay 0)
        oracle_cfg = _tune_on(mult, *draw(), triples)
        ph = dict(phase=name, static=0.0, adaptive=0.0, oracle=0.0,
                  oracle_cfg="noswap" if oracle_cfg is None else oracle_cfg.short())
        for _ in range(nb):
            a, b = draw()
            aj = jnp.asarray(a, jnp.int32)
            bj = jnp.asarray(b, jnp.int32)
            # adaptive is scored with the policy active BEFORE this batch's
            # telemetry lands (honest online measurement)
            t3 = jnp.asarray(np.stack([
                triple_of(static_cfg),
                triple_of(ctrl.policy.lookup("stream")),
                triple_of(oracle_cfg),
            ]), jnp.int32)
            maes = np.asarray(_score_configs(mult, aj, bj, t3, "mae"))
            ph["static"] += float(maes[0]) / nb
            ph["adaptive"] += float(maes[1]) / nb
            ph["oracle"] += float(maes[2]) / nb
            t0 = time.perf_counter()
            ctrl.observe_operands("stream", aj, bj)
            observe_times.append(time.perf_counter() - t0)
            if scorer_entries_after_first is None:
                scorer_entries_after_first = ctrl.scorer_cache_size()
        rows.append(ph)

    tot = {k: float(np.mean([r[k] for r in rows])) for k in ("static", "adaptive", "oracle")}
    return dict(
        rows=rows,
        total=tot,
        retunes=len(ctrl.retunes),
        retune_log=[ev.describe() for ev in ctrl.retunes],
        telemetry_us_per_step=1e6 * float(np.median(observe_times)),
        retune_recompiles=ctrl.scorer_cache_size() - scorer_entries_after_first,
        gain_vs_static=((tot["static"] - tot["adaptive"]) / tot["static"]
                        if tot["static"] else 0.0),
        tile=run_tile(quick=quick),
    )


def format_table(out) -> str:
    lines = ["Adaptive SWAPPER under distribution drift (MAE; lower is better)",
             f"{'phase':10s} {'static':>10s} {'adaptive':>10s} {'oracle':>10s}  oracle-cfg"]
    for r in out["rows"]:
        lines.append(f"{r['phase']:10s} {r['static']:10.2f} {r['adaptive']:10.2f} "
                     f"{r['oracle']:10.2f}  {r['oracle_cfg']}")
    t = out["total"]
    lines.append(f"{'TOTAL':10s} {t['static']:10.2f} {t['adaptive']:10.2f} "
                 f"{t['oracle']:10.2f}")
    lines.append(f"re-tunes={out['retunes']} "
                 f"telemetry={out['telemetry_us_per_step']:.0f}us/step "
                 f"retune_recompiles={out['retune_recompiles']} "
                 f"adaptive_gain_vs_static={100*out['gain_vs_static']:.1f}%")
    for line in out["retune_log"]:
        lines.append(f"  {line}")
    tile = out.get("tile")
    if tile:
        lines.append("")
        lines.append("Per-tile adaptation on app-derived streams "
                     "(tile-granular vs layer-granular MAE; held-out draw)")
        lines.append(f"{'app':12s} {'layer':>10s} {'per-tile':>10s} {'gain':>7s}"
                     f"  layer-cfg / tile-cfgs")
        for r in tile["rows"]:
            lines.append(f"{r['app']:12s} {r['layer_mae']:10.2f} "
                         f"{r['tile_mae']:10.2f} {100*r['gain']:6.1f}%  "
                         f"{r['layer_cfg']} / ({r['tile_cfgs']})")
        lines.append(f"tile_beats_layer={tile['tile_beats_layer']} "
                     f"best_gain={100*tile['best_gain']:.1f}% "
                     f"tile_retune_recompiles="
                     f"{max(r['retune_recompiles'] for r in tile['rows'])}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
