"""Adaptive-runtime table: MAE under synthetic operand-distribution drift for
static-tuned vs oracle vs adaptive SWAPPER, plus telemetry overhead.

The stream visits distribution phases (the live-traffic stand-in).  The
static policy is tuned once on phase 0 — the paper's offline framework.  The
oracle re-tunes clairvoyantly at every phase boundary.  The adaptive
controller sees only streaming telemetry: it detects the bit-occupancy shift
and re-tunes from its live operand buffer (zero recompilations; the scorer
jit-cache size is reported to prove it).
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

import repro.core as C
from repro.runtime import AdaptiveConfig, AdaptiveController, SwapPolicy, all_triples
from repro.runtime.controller import _score_configs

MULT = "mul8u_trunc0_4"


def _phases(rng, n_batches, batch):
    """Three operand-distribution regimes (uint8 pairs)."""

    def high_a():
        return (rng.integers(128, 256, batch), rng.integers(0, 256, batch))

    def low_a():
        return (rng.integers(0, 128, batch), rng.integers(0, 256, batch))

    def gauss():
        a = np.clip(rng.normal(96, 32, batch), 0, 255).astype(np.int64)
        b = np.clip(rng.normal(160, 48, batch), 0, 255).astype(np.int64)
        return (a, b)

    return [("high_a", high_a, n_batches), ("low_a", low_a, n_batches),
            ("gauss", gauss, n_batches)]


def _tune_on(mult, a, b, triples, metric="mae"):
    scores = np.asarray(_score_configs(mult, jnp.asarray(a, jnp.int32),
                                       jnp.asarray(b, jnp.int32), triples, metric))
    best = int(np.argmin(scores))
    return None if best == 0 else C.all_configs(mult.bits)[best - 1]


def run(quick: bool = False):
    mult = C.get(MULT)
    batch = 2048 if quick else 4096
    n_batches = 8 if quick else 12
    rng = np.random.default_rng(0)
    phases = _phases(rng, n_batches, batch)
    triples = jnp.asarray(all_triples(mult.bits))

    # static: offline-tuned on a phase-0 sample (the paper's framework)
    a0, b0 = phases[0][1]()
    static_cfg = _tune_on(mult, a0, b0, triples)

    # adaptive: telemetry -> drift -> re-tune, starting from the static config
    policy = SwapPolicy(mult.name, configs={"*": static_cfg})
    ctrl = AdaptiveController(
        policy, targets=("stream",),
        # buffer refreshes in buffer_size/RETUNE_SAMPLE=2 observed steps, so a
        # detected drift re-tunes on post-drift operands
        cfg=AdaptiveConfig(decay=0.3, drift_threshold=0.04, min_observe_steps=2,
                           cooldown_steps=2, buffer_size=1024),
    )
    ctrl.warmup()

    from repro.runtime.policy import triple_of

    rows = []
    observe_times = []   # per-step; median reported so the one-time compile
    scorer_entries_after_first = None   # harness shapes compile on the first
    for name, draw, nb in phases:       # batch; any later growth would be a
                                        # re-tune recompile (must stay 0)
        oracle_cfg = _tune_on(mult, *draw(), triples)
        ph = dict(phase=name, static=0.0, adaptive=0.0, oracle=0.0,
                  oracle_cfg="noswap" if oracle_cfg is None else oracle_cfg.short())
        for _ in range(nb):
            a, b = draw()
            aj = jnp.asarray(a, jnp.int32)
            bj = jnp.asarray(b, jnp.int32)
            # adaptive is scored with the policy active BEFORE this batch's
            # telemetry lands (honest online measurement)
            t3 = jnp.asarray(np.stack([
                triple_of(static_cfg),
                triple_of(ctrl.policy.lookup("stream")),
                triple_of(oracle_cfg),
            ]), jnp.int32)
            maes = np.asarray(_score_configs(mult, aj, bj, t3, "mae"))
            ph["static"] += float(maes[0]) / nb
            ph["adaptive"] += float(maes[1]) / nb
            ph["oracle"] += float(maes[2]) / nb
            t0 = time.perf_counter()
            ctrl.observe_operands("stream", aj, bj)
            observe_times.append(time.perf_counter() - t0)
            if scorer_entries_after_first is None:
                scorer_entries_after_first = ctrl.scorer_cache_size()
        rows.append(ph)

    tot = {k: float(np.mean([r[k] for r in rows])) for k in ("static", "adaptive", "oracle")}
    return dict(
        rows=rows,
        total=tot,
        retunes=len(ctrl.retunes),
        retune_log=[ev.describe() for ev in ctrl.retunes],
        telemetry_us_per_step=1e6 * float(np.median(observe_times)),
        retune_recompiles=ctrl.scorer_cache_size() - scorer_entries_after_first,
        gain_vs_static=((tot["static"] - tot["adaptive"]) / tot["static"]
                        if tot["static"] else 0.0),
    )


def format_table(out) -> str:
    lines = ["Adaptive SWAPPER under distribution drift (MAE; lower is better)",
             f"{'phase':10s} {'static':>10s} {'adaptive':>10s} {'oracle':>10s}  oracle-cfg"]
    for r in out["rows"]:
        lines.append(f"{r['phase']:10s} {r['static']:10.2f} {r['adaptive']:10.2f} "
                     f"{r['oracle']:10.2f}  {r['oracle_cfg']}")
    t = out["total"]
    lines.append(f"{'TOTAL':10s} {t['static']:10.2f} {t['adaptive']:10.2f} "
                 f"{t['oracle']:10.2f}")
    lines.append(f"re-tunes={out['retunes']} "
                 f"telemetry={out['telemetry_us_per_step']:.0f}us/step "
                 f"retune_recompiles={out['retune_recompiles']} "
                 f"adaptive_gain_vs_static={100*out['gain_vs_static']:.1f}%")
    for line in out["retune_log"]:
        lines.append(f"  {line}")
    return "\n".join(lines)


if __name__ == "__main__":
    print(format_table(run()))
