"""Hot-path throughput microbenchmarks — the repo's perf trajectory.

Three old-vs-new comparisons, one per rebuilt hot path (PR 2):

* **matmul dispatch** — int8 ``dot_general`` count per mxu projection, read
  straight off the jaxpr: the legacy 2-matmul swap factorization
  (``ax_matmul_int_2mm`` / ``ax_matmul_int_dyn_2mm``) vs the K-stacked
  single-matmul path, plus wall time per call for both.
* **kernel reduction** — Pallas ``ax_matmul`` wall time with the legacy
  rank-1 K schedule (``k_slab=1``) vs the slab-vectorized reduction
  (``k_slab=8``), static and scalar-prefetch grid kernels.
* **decode throughput** — steps/sec of the per-token Python decode loop vs
  the fused on-device ``lax.scan`` decode on a tiny reduced model.

``run()`` returns the result dict; ``write_json()`` emits ``BENCH_2.json``
(machine-readable old-vs-new numbers) so later PRs can regress against this
one.  Standalone:

    PYTHONPATH=src python -m benchmarks.perf_table [--quick] [--out BENCH_2.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.core as C
import repro.kernels as K
from repro.configs.base import AxPolicy
from repro.quant.ax import (
    ax_matmul_int,
    ax_matmul_int_2mm,
    ax_matmul_int_dyn,
    ax_matmul_int_dyn_2mm,
)

MULT = "mul8s_trunc0_4"


# ---------------------------------------------------------------------------
# jaxpr op counting
# ---------------------------------------------------------------------------

def count_primitive(fn, *args, primitive: str = "dot_general") -> int:
    """Occurrences of ``primitive`` in the jaxpr of ``fn(*args)``, recursing
    into nested jaxprs (pjit/custom_vjp/cond/scan bodies)."""

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == primitive:
                n += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else [v]):
                    if hasattr(sub, "jaxpr"):      # ClosedJaxpr
                        n += walk(sub.jaxpr)
                    elif hasattr(sub, "eqns"):     # raw Jaxpr
                        n += walk(sub)
        return n

    return walk(jax.make_jaxpr(fn)(*args).jaxpr)


def _time(f, *args, n=10):
    """Best-of-n wall time (min is the standard noise-robust estimator on a
    shared/loaded host)."""
    jax.block_until_ready(f(*args))            # compile + warm
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        jax.block_until_ready(f(*args))
        best = min(best, time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# 1. mxu dispatch count + wall time
# ---------------------------------------------------------------------------

def bench_dispatch(quick: bool):
    m = 128 if quick else 256
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-127, 128, (m, m)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (m, m)).astype(np.int8))
    pol = AxPolicy(mult_name=MULT, backend="mxu")          # swap enabled
    dyn = jnp.asarray((1, 3, 0), jnp.int32)

    variants = {
        "static_2mm": (lambda a, b: ax_matmul_int_2mm(a, b, pol), (a, b)),
        "static_stacked": (lambda a, b: ax_matmul_int(a, b, pol), (a, b)),
        "dyn_2mm": (lambda a, b, d: ax_matmul_int_dyn_2mm(a, b, pol, d), (a, b, dyn)),
        "dyn_stacked": (lambda a, b, d: ax_matmul_int_dyn(a, b, pol, d), (a, b, dyn)),
    }
    out = {"shape": [m, m, m]}
    for name, (fn, args) in variants.items():
        out[name] = {
            "dot_generals": count_primitive(fn, *args),
            "us_per_call": 1e6 * _time(jax.jit(fn), *args),
        }
    for kind in ("static", "dyn"):
        old, new = out[f"{kind}_2mm"], out[f"{kind}_stacked"]
        out[f"{kind}_dispatch_ratio"] = (
            old["dot_generals"] / max(new["dot_generals"], 1))
        out[f"{kind}_speedup"] = old["us_per_call"] / max(new["us_per_call"], 1e-9)
    return out


# ---------------------------------------------------------------------------
# 2. kernel reduction wall time (rank-1 vs slab)
# ---------------------------------------------------------------------------

def bench_kernel(quick: bool):
    """Wall time AND per-tile reduction trip count for the legacy rank-1 K
    schedule vs the slab-vectorized one.  NOTE: this container runs the
    kernels in ``interpret=True`` on CPU, where per-iteration dispatch cost
    is not the TPU's — the trip count (``bk`` rank-1 steps vs ``bk/ks`` slab
    steps, i.e. the number of VPU select/multiply/reduce dispatches per
    tile) is the architecture-relevant number; wall time is recorded for the
    trajectory."""
    m = 128
    reps = 3 if quick else 6
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.integers(-128, 128, (m, m)).astype(np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (m, m)).astype(np.int8))
    mult = C.get(MULT)
    swap = C.SwapConfig("A", 3, 0)
    grid = jnp.broadcast_to(jnp.asarray((1, 3, 0), jnp.int32), (1, 1, 3))

    out = {"shape": [m, m, m], "block": [m, m, m]}
    # interleave the four variants inside ONE best-of-reps loop: interpret
    # mode takes tens of ms per call, so timing rank1 and slab8 in separate
    # sequential blocks lets host-load drift land on one side of the ratio
    # (the regress.py ratio floors then trip on pure noise); round-robin
    # sampling puts every load spike on all variants equally
    fns = {}
    for name, ks in (("rank1", 1), ("slab8", 8)):
        fns[f"static_{name}"] = (
            lambda a, b, ks=ks: K.ax_matmul(a, b, mult, swap, k_slab=ks))
        fns[f"grid_{name}"] = (
            lambda a, b, ks=ks: K.ax_matmul_grid(a, b, mult, grid, k_slab=ks))
        out[f"{name}_reduction_steps_per_tile"] = m // ks
    best = {k: float("inf") for k in fns}
    for f in fns.values():
        jax.block_until_ready(f(a, b))         # compile + warm
    for _ in range(reps):
        for k, f in fns.items():
            t0 = time.perf_counter()
            jax.block_until_ready(f(a, b))
            best[k] = min(best[k], time.perf_counter() - t0)
    for k, t in best.items():
        out[f"{k}_us"] = 1e6 * t
    out["reduction_step_ratio"] = (out["rank1_reduction_steps_per_tile"]
                                   / out["slab8_reduction_steps_per_tile"])
    out["static_speedup"] = out["static_rank1_us"] / out["static_slab8_us"]
    out["grid_speedup"] = out["grid_rank1_us"] / out["grid_slab8_us"]
    return out


# ---------------------------------------------------------------------------
# 3. decode throughput (python loop vs fused lax.scan)
# ---------------------------------------------------------------------------

def bench_decode(quick: bool):
    import repro.configs as CFG
    from repro.models import init_params
    from repro.serve import ServeConfig, generate

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    T = 16 if quick else 32
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}

    out = {"arch": cfg.name, "new_tokens": T}
    toks = {}
    for name, fused in (("loop", False), ("scan", True)):
        scfg = ServeConfig(max_new_tokens=T, fused=fused)
        toks[name] = np.asarray(generate(params, prompt, cfg, scfg))  # compile
        best = float("inf")
        for _ in range(2 if quick else 3):
            t0 = time.perf_counter()
            jax.block_until_ready(generate(params, prompt, cfg, scfg))
            best = min(best, time.perf_counter() - t0)
        out[f"{name}_steps_per_s"] = (T - 1) / best
    out["bit_identical"] = bool(np.array_equal(toks["loop"], toks["scan"]))
    out["speedup"] = out["scan_steps_per_s"] / out["loop_steps_per_s"]
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(quick: bool = False):
    return {
        "bench": "perf_table",
        "quick": quick,
        "matmul_dispatch": bench_dispatch(quick),
        "kernel_reduction": bench_kernel(quick),
        "decode": bench_decode(quick),
    }


def write_json(out, path: str = "BENCH_2.json") -> str:
    with open(path, "w") as fh:
        json.dump(out, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return path


def format_table(out) -> str:
    d, k, dec = out["matmul_dispatch"], out["kernel_reduction"], out["decode"]
    lines = [
        "Hot-path throughput — old vs new (PR 2)",
        f"{'path':34s} {'old':>12s} {'new':>12s} {'gain':>8s}",
        (f"{'mxu static dot_generals':34s} {d['static_2mm']['dot_generals']:>12d} "
         f"{d['static_stacked']['dot_generals']:>12d} "
         f"{d['static_dispatch_ratio']:>7.2f}x"),
        (f"{'mxu dyn dot_generals':34s} {d['dyn_2mm']['dot_generals']:>12d} "
         f"{d['dyn_stacked']['dot_generals']:>12d} "
         f"{d['dyn_dispatch_ratio']:>7.2f}x"),
        (f"{'mxu static us/call*':34s} {d['static_2mm']['us_per_call']:>12.1f} "
         f"{d['static_stacked']['us_per_call']:>12.1f} "
         f"{d['static_speedup']:>7.2f}x"),
        (f"{'mxu dyn us/call*':34s} {d['dyn_2mm']['us_per_call']:>12.1f} "
         f"{d['dyn_stacked']['us_per_call']:>12.1f} {d['dyn_speedup']:>7.2f}x"),
        (f"{'pallas reduction steps/tile':34s} "
         f"{k['rank1_reduction_steps_per_tile']:>12d} "
         f"{k['slab8_reduction_steps_per_tile']:>12d} "
         f"{k['reduction_step_ratio']:>7.2f}x"),
        (f"{'pallas static reduction us*':34s} {k['static_rank1_us']:>12.0f} "
         f"{k['static_slab8_us']:>12.0f} {k['static_speedup']:>7.2f}x"),
        (f"{'pallas grid reduction us*':34s} {k['grid_rank1_us']:>12.0f} "
         f"{k['grid_slab8_us']:>12.0f} {k['grid_speedup']:>7.2f}x"),
        "  (* CPU wall time in this container — dot_general count and"
        " steps/tile are the TPU-relevant dispatch metrics)",
        (f"{'decode steps/s':34s} {dec['loop_steps_per_s']:>12.1f} "
         f"{dec['scan_steps_per_s']:>12.1f} {dec['speedup']:>7.2f}x"),
        f"decode loop-vs-scan bit-identical: {dec['bit_identical']}",
    ]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_2.json")
    args = ap.parse_args()
    out = run(quick=args.quick)
    print(format_table(out))
    print(f"wrote {write_json(out, args.out)}")


if __name__ == "__main__":
    main()
