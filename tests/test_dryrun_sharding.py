"""Distribution-layer tests.

In-process tests use a small host-device mesh via a subprocess (jax locks the
device count at first init, so the 8-device cases run in a child python).
Sharding-rule unit tests run in-process.
"""
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

import repro.configs as CFG
from repro.configs.base import ParallelConfig
from repro.launch.sharding import param_spec, spec_for, axis_rules

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _fake_mesh(shape, axes):
    """Abstract mesh for rule tests (no devices needed).  The AbstractMesh
    constructor signature changed across jax releases: older takes
    (shape, axis_names), newer takes a ((name, size), ...) tuple."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return AbstractMesh(shape, axes)


def test_param_spec_rules():
    mesh = _fake_mesh((4, 2), ("data", "model"))
    par = ParallelConfig(fsdp=True)
    # 2-D weight sharding: embed over data, ff over model
    assert param_spec(("embed", "ff"), mesh, par, (64, 32)) == P("data", "model")
    # non-divisible dims are dropped to None
    assert param_spec(("embed", "ff"), mesh, par, (63, 32)) == P(None, "model")
    # duplicate mesh axes: first wins
    assert param_spec(("experts", "embed", "ff"), mesh, par, (8, 64, 32)) == P(
        "model", "data", None
    )
    # fsdp off -> embed replicated
    par2 = ParallelConfig(fsdp=False)
    assert param_spec(("embed", "ff"), mesh, par2, (64, 32)) == P(None, "model")


def test_batch_axes_multi_pod():
    mesh3 = _fake_mesh((2, 4, 2), ("pod", "data", "model"))
    rules = axis_rules(mesh3, ParallelConfig())
    assert rules["batch"] == ("pod", "data")
    mesh2 = _fake_mesh((4, 2), ("data", "model"))
    rules2 = axis_rules(mesh2, ParallelConfig())
    assert rules2["batch"] == "data"


_SUBPROC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import json
import jax
from repro.configs.base import ParallelConfig
from repro.launch import dryrun

mesh = jax.make_mesh({mesh_shape}, {mesh_axes})
par = ParallelConfig()
row = dryrun.run_cell("{arch}", "{shape}", False, par, verbose=False,
                      extrapolate=False, mesh=mesh)
print("RESULT:" + json.dumps({{k: row[k] for k in ("status", "arch", "shape")}}))
"""


def _run_sub(arch, shape, ndev=8, mesh_shape=(4, 2), mesh_axes=("data", "model")):
    code = _SUBPROC_SCRIPT.format(ndev=ndev, arch=arch, shape=shape,
                                  mesh_shape=mesh_shape, mesh_axes=mesh_axes)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=500)
    assert out.returncode == 0, out.stderr[-2000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(out.stdout[-2000:])


@pytest.mark.parametrize("arch,shape", [
    ("whisper-base", "train_4k"),
    ("mamba2-370m", "decode_32k"),
])
def test_dryrun_cell_small_mesh(arch, shape):
    """A full-config cell lowers+compiles on an 8-device host mesh (the
    production-mesh run is exercised by launch/dryrun.py --all)."""
    r = _run_sub(arch, shape)
    assert r["status"] == "ok", r


def test_dryrun_multipod_axes_small():
    """The 'pod' axis shards: (2,2,2) pod/data/model mesh compiles."""
    r = _run_sub("granite-moe-1b-a400m", "train_4k", ndev=8,
                 mesh_shape=(2, 2, 2), mesh_axes=("pod", "data", "model"))
    assert r["status"] == "ok", r


def test_long_context_skip_policy():
    from repro.launch.dryrun import skip_reason

    assert skip_reason("qwen2-72b", "long_500k") is not None
    assert skip_reason("gemma3-27b", "long_500k") is None
    assert skip_reason("mamba2-370m", "long_500k") is None
    assert skip_reason("qwen2-72b", "train_4k") is None
