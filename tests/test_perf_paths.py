"""PR-2 hot-path invariants: the K-stacked single-matmul mxu factorization is
bit-identical to the legacy 2-matmul form (every bit x value x operand, static
and dynamic), compiles to exactly one int8 dot_general, the slab-vectorized
Pallas reduction matches the oracle at every slab depth, and the fused
``lax.scan`` decode reproduces the Python-loop token sequence exactly."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.kernels as K
from repro.configs.base import AxPolicy
from repro.quant.ax import (
    ax_matmul_int,
    ax_matmul_int_2mm,
    ax_matmul_int_dyn,
    ax_matmul_int_dyn_2mm,
)


def _ops(shape, seed, dtype=np.int8, lo=-128, hi=128):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, shape).astype(dtype))


def _all_cfgs(bits=8):
    return [None] + C.all_configs(bits)


# ---------------------------------------------------------------------------
# K-stacked mxu path == 2-matmul form, exhaustively over the config space
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mname", ["mul8s_trunc0_4", "mul8s_perf0_1"])
def test_stacked_static_bit_identity_all_configs(mname):
    a = _ops((16, 32), 0)
    b = _ops((32, 24), 1)
    for cfg in _all_cfgs():
        if cfg is None:
            pol = AxPolicy(mult_name=mname, backend="mxu", swap_enabled=False)
        else:
            pol = AxPolicy(mult_name=mname, backend="mxu", swap_operand=cfg.operand,
                           swap_bit=cfg.bit, swap_value=cfg.value)
        got = np.asarray(ax_matmul_int(a, b, pol))
        ref = np.asarray(ax_matmul_int_2mm(a, b, pol))
        assert np.array_equal(got, ref), cfg
        # cross-check one backend-independent oracle per operand side
        if cfg is not None and cfg.bit == 3:
            emul = np.asarray(ax_matmul_int(
                a, b, dataclasses.replace(pol, backend="emul")))
            assert np.array_equal(got, emul), cfg


@pytest.mark.parametrize("mname", ["mul8s_trunc0_4", "mul8s_perf0_1"])
def test_stacked_dyn_bit_identity_all_triples(mname):
    from repro.runtime import all_triples

    a = _ops((16, 32), 2)
    b = _ops((32, 24), 3)
    pol = AxPolicy(mult_name=mname, backend="mxu")
    for triple in np.asarray(all_triples(8)):       # NoSwap + all 4M configs
        dyn = jnp.asarray(triple, jnp.int32)
        got = np.asarray(ax_matmul_int_dyn(a, b, pol, dyn))
        ref = np.asarray(ax_matmul_int_dyn_2mm(a, b, pol, dyn))
        assert np.array_equal(got, ref), triple


def test_stacked_dyn_matches_static_every_config():
    """dyn triple == static config through the NEW stacked path end to end."""
    from repro.core.swapper import cfg_to_triple

    a = _ops((8, 64), 4)
    b = _ops((64, 16), 5)
    for cfg in _all_cfgs():
        if cfg is None:
            pol = AxPolicy(backend="mxu", swap_enabled=False)
        else:
            pol = AxPolicy(backend="mxu", swap_operand=cfg.operand,
                           swap_bit=cfg.bit, swap_value=cfg.value)
        dyn = jnp.asarray(cfg_to_triple(cfg), jnp.int32)
        assert np.array_equal(
            np.asarray(ax_matmul_int(a, b, pol)),
            np.asarray(ax_matmul_int_dyn(a, b, AxPolicy(backend="mxu"), dyn))
        ), cfg


def _count_dot_generals(fn, *args):
    # one jaxpr-walking counter for tests and benchmarks (keep in sync once)
    from benchmarks.perf_table import count_primitive

    return count_primitive(fn, *args, primitive="dot_general")


def test_stacked_path_dispatches_single_matmul():
    """Acceptance criterion: one int8 dot_general per projection (was two)."""
    a = _ops((32, 32), 6)
    b = _ops((32, 32), 7)
    pol = AxPolicy(backend="mxu")                      # swap enabled
    dyn = jnp.asarray((1, 3, 0), jnp.int32)
    assert _count_dot_generals(lambda a, b: ax_matmul_int(a, b, pol), a, b) == 1
    assert _count_dot_generals(
        lambda a, b, d: ax_matmul_int_dyn(a, b, pol, d), a, b, dyn) == 1
    # the retained legacy forms really are the 2-matmul baselines
    assert _count_dot_generals(lambda a, b: ax_matmul_int_2mm(a, b, pol), a, b) == 2
    assert _count_dot_generals(
        lambda a, b, d: ax_matmul_int_dyn_2mm(a, b, pol, d), a, b, dyn) == 2


# ---------------------------------------------------------------------------
# slab-vectorized Pallas reduction == oracle at every slab depth
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_slab", [1, 2, 4, 8])
def test_kernel_slab_depths_match_oracle(k_slab):
    a = _ops((32, 64), 8)
    b = _ops((64, 32), 9)
    m = C.get("mul8s_drum3_4")
    swap = C.SwapConfig("B", 2, 0)
    got = K.ax_matmul(a, b, m, swap, block_m=32, block_n=32, block_k=16,
                      k_slab=k_slab)
    ref = K.ax_matmul_ref(a, b, m, swap)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_grid_kernel_slab_depths_match_oracle():
    rng = np.random.default_rng(10)
    a = _ops((64, 32), 11)
    b = _ops((32, 64), 12)
    m = C.get("mul8s_trunc0_4")
    grid = jnp.asarray(np.stack([
        rng.integers(0, 2, (2, 2)), rng.integers(0, 8, (2, 2)),
        rng.integers(0, 3, (2, 2)),
    ], axis=-1), jnp.int32)
    ref = K.ax_matmul_grid_ref(a, b, m, grid)
    for ks in (1, 4, 8):
        got = K.ax_matmul_grid(a, b, m, grid, block_m=32, block_n=32,
                               block_k=16, k_slab=ks)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), ks


def test_kernel_slab_handles_nondividing_depth():
    """k_slab falls back to the largest divisor of bk."""
    a = _ops((8, 24), 13)
    b = _ops((24, 8), 14)
    m = C.get("mul8s_trunc0_4")
    got = K.ax_matmul(a, b, m, C.SwapConfig("A", 5, 1), block_m=8, block_n=8,
                      block_k=24, k_slab=8)           # 8 does not divide 24
    ref = K.ax_matmul_ref(a, b, m, C.SwapConfig("A", 5, 1))
    assert np.array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# fused lax.scan decode == Python-loop decode
# ---------------------------------------------------------------------------

def _tiny_model():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_scan_decode_matches_python_loop(temperature):
    from repro.serve import ServeConfig, generate

    cfg, params = _tiny_model()
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)}
    kw = dict(max_new_tokens=7, temperature=temperature)
    o_loop = generate(params, prompt, cfg, ServeConfig(fused=False, **kw))
    o_scan = generate(params, prompt, cfg, ServeConfig(fused=True, **kw))
    assert o_scan.shape == (2, 7)
    assert np.array_equal(np.asarray(o_loop), np.asarray(o_scan))


def test_telemetry_decimation_gates_summary():
    """observe_every=k: only every k-th step's records reach the controller,
    and the gated-off summaries are lax.cond-skipped zeros in-graph."""
    import repro.runtime as R
    from repro.serve import ServeConfig, generate

    cfg, params = _tiny_model()
    rng = np.random.default_rng(1)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)), jnp.int32)}

    def run(k):
        policy = R.SwapPolicy.from_ax_policy(cfg.ax)
        ctrl = R.AdaptiveController(policy, targets=cfg.ax.targets,
                                    cfg=R.AdaptiveConfig(min_observe_steps=10**6))
        out = generate(params, prompt, cfg,
                       ServeConfig(max_new_tokens=10, observe_every=k),
                       adaptive=ctrl)
        assert out.shape == (2, 10)
        return {t: s["n_steps"] for t, s in ctrl.telemetry.snapshot().items()}

    full, dec = run(1), run(3)
    for t in full:
        assert full[t] == 9          # every decode step observed
        assert dec[t] == 3           # steps 0, 3, 6 only


def test_gated_summary_is_zero_and_ungated_matches():
    """The traced gate switches between the real record and all-zeros without
    changing shapes/dtypes (one compiled program serves both)."""
    import repro.runtime as R

    mult = C.get("mul8u_trunc0_4")
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(0, 256, R.TELEMETRY_SAMPLE), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, R.TELEMETRY_SAMPLE), jnp.int32)
    dyn = jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)

    f = jax.jit(lambda gate: R.operand_summary(a, b, mult, dyn, gate=gate))
    on = jax.device_get(f(jnp.bool_(True)))
    off = jax.device_get(f(jnp.bool_(False)))
    ref = jax.device_get(R.operand_summary(a, b, mult, dyn))
    assert f._cache_size() == 1
    for k in ref:
        assert np.array_equal(on[k], ref[k]), k
        assert not np.any(off[k]), k
        assert off[k].dtype == ref[k].dtype and off[k].shape == ref[k].shape, k
