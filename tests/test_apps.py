"""AxBench-in-JAX application tests: precise-FxP fidelity, approximation
degradation, SWAPPER recovery, and tuner behaviour."""
import numpy as np
import pytest

import repro.apps as A
import repro.core as C

FAST_N = {"ssim": 48, "are": 128, "miss_rate": 128}


@pytest.mark.parametrize("name", sorted(A.ALL_APPS))
def test_fxp_close_to_original(name):
    """Paper Table II 'FxP' row: fixed-point conversion degrades only mildly."""
    app = A.ALL_APPS[name]
    v, out = A.evaluate(app, "fxp", n=FAST_N[app.metric_name], seed=1234)
    assert np.isfinite(v)
    if app.metric_name == "ssim":
        assert v > 0.9
    elif app.metric_name == "are":
        assert v < 0.02
    else:
        assert v < 0.02


@pytest.mark.parametrize("name", sorted(A.ALL_APPS))
def test_approximation_degrades(name):
    """NoSwap approximate version is measurably worse than precise FxP."""
    app = A.ALL_APPS[name]
    mult = C.get("mul16s_mitch10_13")
    n = FAST_N[app.metric_name]
    v_fxp, _ = A.evaluate(app, "fxp", n=n, seed=1234)
    v_ax, _ = A.evaluate(app, None, mult=mult, n=n, seed=1234)
    if app.minimize:
        assert v_ax > v_fxp
    else:
        assert v_ax < v_fxp


def test_swapper_recovers_jpeg():
    """App-level tuned SWAPPER never hurts on train (NoSwap is a candidate)
    and the recovered config is near-NoSwap-or-better on the test split
    (paper Fig. 2 protocol: tune on train inputs, report on test)."""
    app = A.ALL_APPS["jpeg"]
    mult = C.get("mul16s_bam_v4_h1")
    cfg, train_val, table = A.tune_app(app, mult, n=48, seed=42)
    assert train_val >= table[None]  # tuning includes NoSwap; can only help
    v_nosw, _ = A.evaluate(app, None, mult=mult, n=48, seed=1234)
    v_app, _ = A.evaluate(app, cfg, mult=mult, n=48, seed=1234)
    assert v_app >= v_nosw - 0.02  # small generalization slack


def test_app_tuner_consistency():
    """The tuner's reported train metric matches re-evaluating the chosen
    config on the train inputs."""
    app = A.ALL_APPS["blackscholes"]
    mult = C.get("mul16s_drum5_8")
    cfg, val, table = A.tune_app(app, mult, n=128, seed=42)
    v, _ = A.evaluate(app, cfg, mult=mult, n=128, seed=42)
    assert v == pytest.approx(val, rel=1e-6)
    assert len(table) == 4 * 16 + 1  # the full 4M space + NoSwap candidate
    assert min(table.values()) == pytest.approx(val, rel=1e-6)


def test_md_lo_better_than_all():
    """Paper: approximating HI (ALL config) is far more damaging than MD+LO."""
    app = A.ALL_APPS["blackscholes"]
    mult = C.get("mul16s_trunc0_8")
    v_mdlo, _ = A.evaluate(app, None, mult=mult, parts=C.PART_MD_LO, n=128, seed=1234)
    v_all, _ = A.evaluate(app, None, mult=mult, parts=C.PART_ALL, n=128, seed=1234)
    assert v_all >= v_mdlo


def test_ssim_properties():
    img = A.smooth_image(64, 64, 0)
    import jax.numpy as jnp

    assert float(A.ssim(jnp.asarray(img), jnp.asarray(img))) == pytest.approx(1.0, abs=1e-6)
    noisy = img + np.random.default_rng(0).normal(0, 25, img.shape)
    v = float(A.ssim(jnp.asarray(img), jnp.asarray(noisy)))
    assert 0.0 < v < 0.95


def test_jmeint_reference_balance():
    """Synthetic triangle pairs produce a non-degenerate hit/miss mix."""
    app = A.ALL_APPS["jmeint"]
    inputs = app.gen_inputs(256, 5)
    ref = app.reference(inputs)
    frac = ref.mean()
    assert 0.1 < frac < 0.9
