"""SWAPPER semantics + tuning-framework correctness tests."""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # property tests skip without hypothesis

import repro.core as C


def _full_grid(bits, signed):
    vals = C.operand_values(bits, signed)
    A, B = np.meshgrid(vals, vals, indexing="ij")
    return vals, A.ravel().astype(np.int32), B.ravel().astype(np.int32)


def test_swap_semantics():
    """apply_swapper literally computes m(b,a) where the decision bit matches."""
    m = C.get("mul8u_trunc0_4")
    cfg = C.SwapConfig("A", 3, 0)
    a, b = np.int32([5, 13, 8, 255]), np.int32([7, 1, 200, 3])
    got = np.asarray(C.apply_swapper(m, jnp.asarray(a), jnp.asarray(b), cfg))
    for i in range(len(a)):
        swap = ((int(a[i]) >> 3) & 1) == 0
        ref = m.fn(jnp.int32(b[i] if swap else a[i]), jnp.int32(a[i] if swap else b[i]))
        assert int(got[i]) == int(np.asarray(ref))


def test_swap_on_commutative_is_noop():
    m = C.get("mul8u_trunc2_2")  # commutative
    a = np.arange(256, dtype=np.int32)
    b = a[::-1].copy()
    for cfg in [C.SwapConfig("A", 0, 1), C.SwapConfig("B", 7, 0)]:
        p0 = np.asarray(m.fn(jnp.asarray(a), jnp.asarray(b)))
        p1 = np.asarray(C.apply_swapper(m, jnp.asarray(a), jnp.asarray(b), cfg))
        assert np.array_equal(p0, p1)


def test_dyn_matches_static():
    m = C.get("mul8u_bam_v2_h1")
    a, b = np.int32([3, 77, 129, 255]), np.int32([9, 250, 17, 255])
    for cfg in C.all_configs(8)[:6]:
        ref = np.asarray(C.apply_swapper(m, jnp.asarray(a), jnp.asarray(b), cfg))
        got = np.asarray(
            C.apply_swapper_dyn(m, jnp.asarray(a), jnp.asarray(b), *C.cfg_to_dyn(cfg))
        )
        assert np.array_equal(ref, got)


def test_swap_mask_signed_negative_operands():
    """Two's-complement bit extraction: for negative int8 operands the mask
    must read the bit of the 8-bit representation (e.g. -1 = 0xFF has every
    bit set), matching a uint8 view of the same values."""
    a = np.arange(-128, 128, dtype=np.int32)
    b = np.zeros_like(a)
    for bit in range(8):
        for value in (0, 1):
            cfg = C.SwapConfig("A", bit, value)
            mask = np.asarray(C.swap_mask(jnp.asarray(a), jnp.asarray(b), cfg))
            expect = ((a.astype(np.uint8).astype(np.int64) >> bit) & 1) == value
            assert np.array_equal(mask, expect), (bit, value)


def test_dyn_matches_static_all_configs_signed():
    """cfg_to_dyn / apply_swapper_dyn equivalence with the static path over
    the whole 4M config space (plus NoSwap) on signed operands."""
    m = C.get("mul8s_bam_v2_h1")
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(-128, 128, 512).astype(np.int32))
    b = jnp.asarray(rng.integers(-128, 128, 512).astype(np.int32))
    for cfg in [None] + C.all_configs(8):
        if cfg is None:
            ref = m.fn(a, b)
        else:
            ref = C.apply_swapper(m, a, b, cfg)
        got = C.apply_swapper_dyn(m, a, b, *C.cfg_to_dyn(cfg))
        assert np.array_equal(np.asarray(ref), np.asarray(got)), cfg


def test_oracle_never_exceeds_either_order_signed():
    """oracle_mult error <= min over both operand orders, signed full grid."""
    m = C.get("mul8s_trunc0_4")
    o = C.oracle_mult(m)
    _, A, B = _full_grid(8, True)
    Aj, Bj = jnp.asarray(A), jnp.asarray(B)
    ex = np.asarray(m.exact_product(Aj, Bj)).astype(np.int64)
    e_orc = np.abs(np.asarray(o.fn(Aj, Bj)).astype(np.int64) - ex)
    e0 = np.abs(np.asarray(m.fn(Aj, Bj)).astype(np.int64) - ex)
    e1 = np.abs(np.asarray(m.fn(Bj, Aj)).astype(np.int64) - ex)
    assert (e_orc <= e0).all()
    assert (e_orc <= e1).all()
    assert np.array_equal(e_orc, np.minimum(e0, e1))


def test_oracle_never_worse_pointwise():
    m = C.get("mul8u_drum2_6")
    o = C.oracle_mult(m)
    _, A, B = _full_grid(8, False)
    Aj, Bj = jnp.asarray(A), jnp.asarray(B)
    ex = np.asarray(m.exact_product(Aj, Bj)).astype(np.int64)
    e_orc = np.abs(np.asarray(o.fn(Aj, Bj)).astype(np.int64) - ex)
    e0 = np.abs(np.asarray(m.fn(Aj, Bj)).astype(np.int64) - ex)
    e1 = np.abs(np.asarray(m.fn(Bj, Aj)).astype(np.int64) - ex)
    assert np.array_equal(e_orc, np.minimum(e0, e1))


# ---------------------------------------------------------------------------
# component-level tuning: cross-check the rank-1 row/col-sum framework against
# a brute-force per-config evaluation on the full 8-bit grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mul8u_trunc0_4", "mul8s_bam_v2_h1"])
def test_component_sweep_matches_bruteforce(name):
    m = C.get(name)
    res = C.component_sweep(m, tile=128)
    _, A, B = _full_grid(8, m.signed)
    Aj, Bj = jnp.asarray(A), jnp.asarray(B)
    ex = m.exact_product(Aj, Bj)

    # NoSwap
    p0 = m.fn(Aj, Bj)
    assert res.noswap.mae == pytest.approx(C.mae(p0, ex, m.signed), rel=1e-12)
    assert res.noswap.wce == C.wce(p0, ex, m.signed)
    assert res.noswap.ep == pytest.approx(C.ep(p0, ex, m.signed), rel=1e-12)
    assert res.noswap.mse == pytest.approx(C.mse(p0, ex, m.signed), rel=1e-5)
    assert res.noswap.are == pytest.approx(C.are(p0, ex, m.signed), rel=1e-4)

    # a few configs brute-forced
    for cfg in [C.SwapConfig("A", 3, 0), C.SwapConfig("B", 6, 1), C.SwapConfig("B", 0, 0)]:
        ps = C.apply_swapper(m, Aj, Bj, cfg)
        assert res.per_config[cfg].mae == pytest.approx(C.mae(ps, ex, m.signed), rel=1e-12)
        assert res.per_config[cfg].wce == C.wce(ps, ex, m.signed)

    # oracle
    orc = C.oracle_mult(m)
    po = orc.fn(Aj, Bj)
    assert res.oracle.mae == pytest.approx(C.mae(po, ex, m.signed), rel=1e-12)


def test_component_sweep_improves_noncommutative():
    """The paper's headline claim: single-bit swapping reduces MAE for
    non-commutative multipliers; oracle is an upper bound on the gain."""
    m = C.get("mul8u_trunc0_4")
    res = C.component_sweep(m, tile=256)
    red = res.reduction("mae")
    theor = res.theoretical_reduction("mae")
    assert red > 0.05            # SWAPPER finds a useful bit
    assert theor >= red - 1e-12  # oracle bounds it
    assert res.per_config[res.best("mae")].mae < res.noswap.mae


def test_component_sweep_no_gain_for_commutative():
    m = C.get("mul8u_trunc2_2")
    res = C.component_sweep(m, tile=256)
    assert res.reduction("mae") == pytest.approx(0.0, abs=1e-12)
    assert res.theoretical_reduction("mae") == pytest.approx(0.0, abs=1e-12)


def test_sampled_sweep_close_to_exhaustive():
    m = C.get("mul8u_drum2_6")
    full = C.component_sweep(m, tile=256)
    samp = C.component_sweep(m, tile=64, sample_bits=6, seed=7)
    assert samp.noswap.mae == pytest.approx(full.noswap.mae, rel=0.25)


@settings(max_examples=50, deadline=None)
@given(bit=st.integers(0, 7), value=st.integers(0, 1), op=st.sampled_from(["A", "B"]))
def test_swap_mask_property(bit, value, op):
    """Property: the swap mask matches the named bit of the named operand."""
    a = np.arange(256, dtype=np.int32)
    b = (255 - a).astype(np.int32)
    cfg = C.SwapConfig(op, bit, value)
    mask = np.asarray(C.swap_mask(jnp.asarray(a), jnp.asarray(b), cfg))
    src = a if op == "A" else b
    assert np.array_equal(mask, ((src >> bit) & 1) == value)


# ---------------------------------------------------------------------------
# two-bit decisions (beyond-paper: the paper's stated future work)
# ---------------------------------------------------------------------------

def test_two_bit_closed_form_matches_direct():
    """The quadrant-block-sum score equals a direct full-grid evaluation."""
    m = C.get("mul8u_trunc0_4")
    cfg, val, st = C.two_bit_sweep(m, "mae")
    vals = C.operand_values(8, m.signed)
    A = jnp.asarray(vals)[:, None]
    B = jnp.asarray(vals)[None, :]
    out = C.apply_swapper_two_bit(m, A, B, cfg)
    exact = m.exact_product(A, B)
    direct = float(np.asarray(C.abs_err(out, exact, m.signed)).astype(np.float64).mean())
    assert val == pytest.approx(direct, rel=1e-9)


def test_two_bit_at_least_as_good_as_single_bit():
    """A 2-bit decision function subsumes every single-bit config, so the
    tuned result can only improve on the paper's mechanism."""
    for name in ["mul8u_trunc0_4", "mul8u_bam_v2_h1", "mul8u_perf0_1"]:
        m = C.get(name)
        r1 = C.component_sweep(m, tile=256).reduction("mae")
        _, _, st = C.two_bit_sweep(m, "mae")
        assert st["reduction"] >= r1 - 1e-12, name


def test_two_bit_strictly_better_somewhere():
    m = C.get("mul8u_trunc0_4")
    r1 = C.component_sweep(m, tile=256).reduction("mae")
    _, _, st = C.two_bit_sweep(m, "mae")
    assert st["reduction"] > r1 + 0.01  # 25.1% -> ~31.8%
