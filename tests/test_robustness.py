"""Self-healing adaptation loop: chaos harness determinism, crash-atomic
store publishes, reader degradation (torn/corrupt/pruned CURRENT), audit-log
torn-tail recovery, telemetry quarantine, canaried rollout with
auto-rollback, and scheduler deadlines / load-shedding — the failure-mode
catalogue of docs/robustness.md, each fault injected deterministically via
``fleet.chaos``.

Runs in CI's chaos lane (``-m chaos``) with the unit lane excluding it.
"""
import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.fleet import (BatcherConfig, ContinuousBatcher, PolicyReader,
                         PolicyStore, Request, chaos)
from repro.obs.audit import AuditLog
from repro.runtime.telemetry import TelemetryQuarantine

pytestmark = pytest.mark.chaos


def _policy(cfg=None):
    return R.SwapPolicy("mul8u_trunc0_4", configs={"*": cfg})


# ---------------------------------------------------------------------------
# chaos harness
# ---------------------------------------------------------------------------

def test_fault_plan_seeded_deterministic_and_json_roundtrip(tmp_path):
    a, b = chaos.FaultPlan.seeded(42), chaos.FaultPlan.seeded(42)
    assert a.describe() == b.describe() and len(a.faults) == 6
    assert chaos.FaultPlan.seeded(43).describe() != a.describe()
    path = str(tmp_path / "plan.json")
    a.save(path)
    c = chaos.FaultPlan.load(path)
    assert c.describe() == a.describe() and c.seed == 42


def test_fault_spec_validation():
    with pytest.raises(ValueError):
        chaos.FaultSpec("no.such.site", "torn_current")
    with pytest.raises(ValueError):
        chaos.FaultSpec("store.publish", "poison_nan")  # wrong site


def test_harness_armed_but_idle_fires_nothing():
    # fire() with no harness installed is a free no-op
    assert chaos.fire("store.publish") == []
    plan = chaos.FaultPlan([chaos.FaultSpec("sched.step", "stall_step",
                                            at=10 ** 6)])
    with chaos.active(plan) as h:
        for _ in range(5):
            assert chaos.fire("store.publish") == []
        assert h.visits["store.publish"] == 5 and h.fired == []
    assert chaos.current() is None


def test_harness_fires_at_visit_and_counts():
    plan = chaos.FaultPlan([
        chaos.FaultSpec("reader.poll", "delay_poll", at=1, arg=0.0),
        chaos.FaultSpec("reader.poll", "delay_poll", at=2, arg=0.0),
    ])
    with chaos.active(plan) as h:
        assert chaos.fire("reader.poll") == []
        assert [f.kind for f in chaos.fire("reader.poll")] == ["delay_poll"]
        assert len(chaos.fire("reader.poll")) == 1
        assert h.fired_count("delay_poll") == 2


# ---------------------------------------------------------------------------
# store hardening: crash-atomic publish, torn/corrupt/pruned degradation
# ---------------------------------------------------------------------------

def test_publish_kill_mid_write_is_crash_atomic(tmp_path):
    store = PolicyStore(str(tmp_path))
    store.publish(_policy(C.SwapConfig("A", 3, 0)))
    plan = chaos.FaultPlan([chaos.FaultSpec("store.publish",
                                            "kill_mid_write", at=0)])
    with chaos.active(plan):
        with pytest.raises(chaos.InjectedFault):
            store.publish(_policy(C.SwapConfig("B", 5, 1)))
    # nothing committed: previous version still current, torn temp on disk
    assert store.current_version() == 1 and store.versions() == [1]
    assert any(fn.endswith(".tmp") for fn in os.listdir(str(tmp_path)))
    # recovery sweep at open removes the stale orphan; publishing resumes
    store2 = PolicyStore(str(tmp_path), recover_stale_s=0.0)
    assert not any(fn.endswith(".tmp") for fn in os.listdir(str(tmp_path)))
    v = store2.publish(_policy(C.SwapConfig("B", 5, 1)))
    assert v == 2 and store2.current_version() == 2


def test_publish_torn_current_degrades_to_newest(tmp_path):
    store = PolicyStore(str(tmp_path))
    store.publish(_policy(C.SwapConfig("A", 3, 0)))
    reader = PolicyReader(store, ("mlp",), backoff_s=0.0)
    assert reader.version == 1
    plan = chaos.FaultPlan([chaos.FaultSpec("store.publish",
                                            "torn_current", at=0)])
    with chaos.active(plan):
        with pytest.raises(chaos.InjectedFault):
            store.publish(_policy(C.SwapConfig("B", 5, 1)))
    # CURRENT is garbage but v2 was committed: current_version falls back
    # to the newest on-disk version and the replica adopts it, no crash
    assert store.current_version() == 2
    assert reader.poll() is True and reader.version == 2
    # the writer's next publish allocates past the damage
    store2 = PolicyStore(str(tmp_path))
    assert store2.publish(_policy(C.SwapConfig("A", 1, 1))) == 3


def test_corrupt_policy_reader_falls_back_loadable(tmp_path):
    store = PolicyStore(str(tmp_path))
    store.publish(_policy(C.SwapConfig("A", 3, 0)))
    plan = chaos.FaultPlan([chaos.FaultSpec("store.after_publish",
                                            "corrupt_policy", at=0)])
    with chaos.active(plan):
        store.publish(_policy(C.SwapConfig("B", 5, 1)))   # v2, then corrupted
    reader = PolicyReader(store, ("mlp",), retries=2, backoff_s=0.0)
    # CURRENT says v2 but v2 is garbage JSON: the replica retries, then
    # serves the newest *loadable* version instead of crashing
    assert reader.version == 1 and reader.read_errors >= 1
    assert reader.policy.lookup("mlp") == C.SwapConfig("A", 3, 0)


def test_reader_survives_pruned_current(tmp_path):
    # satellite: CURRENT pointing at a pruned version must degrade, not raise
    store = PolicyStore(str(tmp_path))
    for cfg in (C.SwapConfig("A", 3, 0), C.SwapConfig("B", 5, 1),
                C.SwapConfig("A", 1, 1)):
        store.publish(_policy(cfg))
    os.remove(store._path(3))                  # prune race: file gone,
    reader = PolicyReader(store, ("mlp",),     # CURRENT still says 3
                          retries=2, backoff_s=0.0)
    assert reader.version == 2 and reader.read_errors >= 1
    assert reader.policy.lookup("mlp") == C.SwapConfig("B", 5, 1)


def test_candidate_promote_reject_lifecycle(tmp_path):
    store = PolicyStore(str(tmp_path))
    store.publish(_policy(C.SwapConfig("A", 3, 0)))
    reader = PolicyReader(store, ("mlp",))
    cand = store.publish_candidate(_policy(C.SwapConfig("B", 5, 1)))
    assert cand == 2 and store.candidate_version() == 2
    # candidates are invisible to readers and version listings
    assert store.versions() == [1]
    assert reader.poll() is False and reader.version == 1
    assert store.promote(cand) == 2
    assert reader.poll() is True and reader.version == 2
    # a rejected candidate's number is never reused for a different policy
    c2 = store.publish_candidate(_policy(C.SwapConfig("A", 7, 0)))
    store.reject_candidate(c2)
    assert store.candidate_version() is None
    assert store.publish(_policy(C.SwapConfig("A", 1, 0))) == c2 + 1


def test_rollback_repoints_current_and_allocates_past(tmp_path):
    store = PolicyStore(str(tmp_path))
    store.publish(_policy(C.SwapConfig("A", 3, 0)))
    store.publish(_policy(C.SwapConfig("B", 5, 1)))
    reader = PolicyReader(store, ("mlp",))
    assert reader.version == 2
    assert store.rollback(1) == 1
    assert store.current_version() == 1
    # the backwards heartbeat is adopted (equality compare, not order)
    assert reader.poll() is True and reader.version == 1
    assert reader.policy.lookup("mlp") == C.SwapConfig("A", 3, 0)
    # immutable files survive; the next publish allocates past them
    assert store.versions() == [1, 2]
    assert store.publish(_policy(C.SwapConfig("A", 1, 1))) == 3
    with pytest.raises(FileNotFoundError):
        store.rollback(99)


# ---------------------------------------------------------------------------
# audit log: fsync'd appends, torn-tail seq resume
# ---------------------------------------------------------------------------

def test_audit_torn_final_line_resumes_seq(tmp_path):
    path = str(tmp_path / "audit.jsonl")
    log = AuditLog(path)
    for i in range(3):
        log.append("retune", idx=i)
    with open(path, "rb") as f:
        body = f.read()
    with open(path, "wb") as f:               # injected mid-append kill:
        f.write(body[:-7])                    # torn final line, no newline
    log2 = AuditLog(path)
    events = log2.read()
    assert [e["seq"] for e in events] == [0, 1]   # torn event skipped
    ev = log2.append("retune", idx=99)
    assert ev["seq"] == 2                     # resumes after last COMPLETE
    events = log2.read()
    assert [e["seq"] for e in events] == [0, 1, 2]
    assert events[-1]["idx"] == 99            # not glued onto the wreckage


# ---------------------------------------------------------------------------
# telemetry quarantine
# ---------------------------------------------------------------------------

def _scalar_record(n=128, bits=8):
    rng = np.random.default_rng(0)
    return {
        "bits_a": np.full((1, bits), n / 2, np.float32),
        "bits_b": np.full((1, bits), n / 3, np.float32),
        "neg_a": np.zeros(1, np.float32), "neg_b": np.zeros(1, np.float32),
        "n": np.asarray([n], np.int32),
        "err_lo": np.asarray([n * 10], np.uint32),
        "err_hi": np.zeros(1, np.uint32),
        "err_max": np.asarray([40], np.uint32),
        "err_cnt": np.asarray([n // 2], np.uint32),
        "a_smp": rng.integers(0, 2 ** bits, (1, 64)).astype(np.int32),
        "b_smp": rng.integers(0, 2 ** bits, (1, 64)).astype(np.int32),
    }


def test_quarantine_nonfinite_bounds_and_zero_records():
    q = TelemetryQuarantine(bits=8)
    assert q.check("t", _scalar_record()) is None
    nan = _scalar_record()
    nan["bits_a"] = np.full_like(nan["bits_a"], np.nan)
    assert q.check("t", nan) == "nonfinite"
    inf = _scalar_record()
    inf["neg_b"] = np.full_like(inf["neg_b"], np.inf)
    assert q.check("t", inf) == "nonfinite"
    big = _scalar_record()
    big["bits_a"] = big["bits_a"] * 1000      # counts >> sample size
    assert q.check("t", big) == "bounds"
    wild = _scalar_record()
    wild["a_smp"] = wild["a_smp"] * 10 ** 6   # codes past 2**bits
    assert q.check("t", wild) == "bounds"
    limb = _scalar_record()
    limb["err_lo"] = np.asarray([2 ** 31], np.uint32)  # > n * 0xFFFF
    assert q.check("t", limb) == "bounds"
    # gated-off all-zero records pass untouched (fused decode emits them)
    zero = {k: np.zeros_like(v) for k, v in _scalar_record().items()}
    assert q.check("t", zero) is None


def test_quarantine_robust_z_outlier_keeps_history_clean():
    q = TelemetryQuarantine(bits=8, z_threshold=8.0, min_history=4)
    for _ in range(6):
        assert q.check("t", _scalar_record()) is None
    hot = _scalar_record()
    hot["err_lo"] = np.asarray([128 * 5000], np.uint32)   # ~500x the MAE
    assert q.check("t", hot) == "outlier"
    # the outlier never entered the history: the next honest record passes
    assert q.check("t", _scalar_record()) is None
    admitted, dropped = q.filter({"t": hot})
    assert admitted == {} and dropped == [("t", "outlier")]
    assert q.quarantined == 1 and q.by_reason["outlier"] == 1


def _make_controller(start_cfg, store=None, **kw):
    policy = _policy(start_cfg)
    cfg = dict(decay=0.4, drift_threshold=0.05, min_observe_steps=2,
               cooldown_steps=2, buffer_size=1024)
    cfg.update(kw)
    ctrl = R.AdaptiveController(policy, targets=("stream",),
                                cfg=R.AdaptiveConfig(**cfg), store=store)
    ctrl.warmup()
    return ctrl


def test_poisoned_telemetry_quarantined_no_retune():
    """NaN-poisoned records must neither reach the accumulators nor fire a
    retune — the tentpole's 'one poisoned shard cannot retune the fleet'."""
    rng = np.random.default_rng(3)
    ctrl = _make_controller(C.SwapConfig("A", 3, 0))
    plan = chaos.FaultPlan(
        [chaos.FaultSpec("controller.observe", "poison_nan", at=k)
         for k in range(4, 10)])
    with chaos.active(plan) as h:
        for _ in range(12):
            ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                                  rng.integers(0, 256, 2048))
        assert h.fired_count("poison_nan") == 6
    assert ctrl.quarantine.by_reason.get("nonfinite", 0) >= 6
    assert ctrl.retunes == []                  # poison never looked like drift
    snap = ctrl.telemetry.snapshot()["stream"]
    assert np.isfinite(snap["bit_probs"]).all() and np.isfinite(snap["ew_mae"])


# ---------------------------------------------------------------------------
# canaried rollout + auto-rollback
# ---------------------------------------------------------------------------

def test_canary_rejection_keeps_incumbent(tmp_path):
    """canary_margin=1.0 demands an impossible holdout win: the retune's
    winner must be rejected, the incumbent kept, the store untouched."""
    rng = np.random.default_rng(4)
    store = PolicyStore(str(tmp_path))
    ctrl = _make_controller(None, store=store, canary=True, canary_margin=1.0,
                            min_observe_steps=1, cooldown_steps=0)
    ctrl.resume_from_store()                   # publishes v1
    for _ in range(3):
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    cache = ctrl.scorer_cache_size()
    ev = ctrl.retune("stream")
    assert ev.promoted is False and ev.candidate_version == 2
    assert ctrl.policy.lookup("stream") is None          # incumbent kept
    assert store.current_version() == 1                  # CURRENT untouched
    assert store.candidate_version() is None             # candidate rejected
    assert ctrl.scorer_cache_size() == cache             # zero recompiles
    kinds = [e["kind"] for e in ctrl.audit.read()]
    assert "canary_rejected" in kinds


def test_canary_promotion_arms_then_disarms_guard(tmp_path):
    rng = np.random.default_rng(5)
    store = PolicyStore(str(tmp_path))
    ctrl = _make_controller(None, store=store, canary=True,
                            min_observe_steps=1, cooldown_steps=0,
                            rollback_min_steps=1, rollback_window=4)
    ctrl.resume_from_store()
    for _ in range(3):
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    cache = ctrl.scorer_cache_size()
    ev = ctrl.retune("stream")
    assert ev.promoted is True and store.current_version() == 2
    assert "stream" in ctrl._guards            # guard armed on promotion
    assert ctrl.scorer_cache_size() == cache   # canary scoring precompiled
    for _ in range(6):                         # same regime: no regression
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    assert ctrl._guards == {} and ctrl.rollbacks == []   # survived the window


def test_auto_rollback_restores_last_good_bit_identically(tmp_path):
    """Post-adoption regression past the guard band re-points CURRENT to
    last-good and restores the pre-adoption policy byte-for-byte."""
    rng = np.random.default_rng(6)
    store = PolicyStore(str(tmp_path))
    ctrl = _make_controller(None, store=store, canary=True,
                            drift_threshold=10.0,      # guard, not drift,
                            min_observe_steps=1,       # must do the healing
                            cooldown_steps=0, rollback_guard=0.5,
                            rollback_min_steps=2, rollback_window=32)
    ctrl.resume_from_store()
    for _ in range(4):                         # low-error regime: baseline
        ctrl.observe_operands("stream", rng.integers(0, 64, 2048),
                              rng.integers(0, 64, 2048))
    ev = ctrl.retune("stream")
    assert ev.promoted is True and store.current_version() == 2
    expected = R.SwapPolicy.from_json(store.load(1).to_json())
    for _ in range(12):                        # regressed regime: ew_mae blows
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(128, 256, 2048))
        if ctrl.rollbacks:
            break
    assert len(ctrl.rollbacks) == 1
    rb = ctrl.rollbacks[0]
    assert rb["to_version"] == 1 and rb["observed"] > rb["baseline"] * 1.5
    assert store.current_version() == 1                  # CURRENT re-pointed
    assert ctrl.policy.configs_equal(expected)           # bit-identical
    assert "stream" not in ctrl._guards
    audits = [e for e in ctrl.audit.read() if e["kind"] == "rollback"]
    assert len(audits) == 1 and audits[0]["trigger"] == "rollback"
    assert audits[0]["store_version"] == 1


# ---------------------------------------------------------------------------
# scheduler: deadlines, load-shedding, armed-but-idle bit-identity
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def _tiny_model():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _requests(cfg, n, rng, deadline_s=None):
    return [Request(rid, rng.integers(0, cfg.vocab, 6), max_new=3,
                    deadline_s=deadline_s) for rid in range(n)]


def test_scheduler_sheds_past_bounded_queue():
    cfg, params = _tiny_model()
    bat = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4, max_queue=2))
    rng = np.random.default_rng(0)
    accepted = [bat.submit(r) for r in _requests(cfg, 5, rng)]
    assert accepted == [True, True, False, False, False]
    assert bat.stats["shed"] == 3 and bat.pending() == 2
    done = bat.run()
    assert sorted(c.rid for c in done) == [0, 1]
    assert all(c.status == "ok" for c in done)


def test_scheduler_deadline_times_out_queued_requests():
    cfg, params = _tiny_model()
    bat = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4))
    rng = np.random.default_rng(1)
    for r in _requests(cfg, 2, rng):
        bat.submit(r)                          # no deadline: must complete
    expired = Request(7, rng.integers(0, cfg.vocab, 6), max_new=3,
                      deadline_s=0.0)          # lapses before any wave
    bat.submit(expired)
    done = bat.run()
    by_rid = {c.rid: c for c in done}
    assert by_rid[7].status == "timeout" and len(by_rid[7].tokens) == 0
    assert all(by_rid[r].status == "ok" and len(by_rid[r].tokens) == 3
               for r in (0, 1))
    assert bat.stats["timeouts"] == 1


def test_token_granular_deadline_and_stall_under_chaos():
    """An injected per-step stall plus zero-deadline requests: timeouts are
    reported, the drain completes, the replica never crashes."""
    cfg, params = _tiny_model()
    bat = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4, token_granular=True))
    rng = np.random.default_rng(2)
    for r in _requests(cfg, 3, rng):
        bat.submit(r)
    bat.submit(Request(9, rng.integers(0, cfg.vocab, 6), max_new=3,
                       deadline_s=0.0))
    plan = chaos.FaultPlan([chaos.FaultSpec("sched.step", "stall_step",
                                            at=1, arg=0.01)])
    with chaos.active(plan) as h:
        done = bat.run()
    assert h.fired_count("stall_step") == 1
    by_rid = {c.rid: c for c in done}
    assert by_rid[9].status == "timeout"
    assert all(by_rid[r].status == "ok" for r in (0, 1, 2))
    assert bat.stats["timeouts"] >= 1
    assert bat.stats["decode_retraces_post_warmup"] == 0


def test_armed_idle_token_serving_bit_identical_to_wave():
    """Acceptance: an installed-but-never-firing harness leaves token-
    granular serving bit-identical to the wave oracle, zero retraces."""
    cfg, params = _tiny_model()
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(3, 8)))
               for _ in range(5)]
    budgets = [int(rng.integers(1, 4)) for _ in range(5)]

    def serve(token_granular, armed):
        bat = ContinuousBatcher(
            params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                       new_token_bucket=4,
                                       token_granular=token_granular))
        for rid, (p, m) in enumerate(zip(prompts, budgets)):
            bat.submit(Request(rid, p, max_new=m))
        if armed:
            idle = chaos.FaultPlan([chaos.FaultSpec(
                "sched.step", "crash_replica", at=10 ** 6)])
            with chaos.active(idle) as h:
                out = bat.run()
            assert h.fired == [] and h.visits.get("sched.step", 0) > 0
        else:
            out = bat.run()
        return {c.rid: np.asarray(c.tokens) for c in out}, bat

    oracle, _ = serve(token_granular=False, armed=False)
    got, bat = serve(token_granular=True, armed=True)
    assert set(oracle) == set(got)
    for rid in oracle:
        assert np.array_equal(oracle[rid], got[rid]), rid
    assert bat.stats["decode_retraces_post_warmup"] == 0


def test_replica_crash_supervision_resumes_drain():
    """An injected mid-drain replica kill is caught by the supervisor
    pattern (launch/serve does the same) and the drain resumes: every
    non-expired request still completes exactly once."""
    cfg, params = _tiny_model()
    bat = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4, token_granular=True))
    rng = np.random.default_rng(4)
    for r in _requests(cfg, 4, rng):
        bat.submit(r)
    plan = chaos.FaultPlan([chaos.FaultSpec("sched.step", "crash_replica",
                                            at=2)])
    done = []
    crashes = 0
    with chaos.active(plan) as h:
        while bat.pending() or crashes == 0:
            try:
                done.extend(bat.run())
                break
            except chaos.InjectedFault:
                crashes += 1
    assert crashes == 1 and h.fired_count("crash_replica") == 1
    rids = sorted(c.rid for c in done)
    # in-flight requests at the kill are lost (their slots died with the
    # process); every still-queued request completes after the restart
    assert set(rids) <= {0, 1, 2, 3} and len(rids) == len(set(rids))
    assert bat.pending() == 0
