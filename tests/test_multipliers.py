"""Bit-accuracy and property tests for the AxIC multiplier families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # property tests skip without hypothesis

import repro.core as C


def _rand_ops(bits, signed, n=4096, seed=0):
    rng = np.random.default_rng(seed)
    lo, hi = (-(1 << (bits - 1)), 1 << (bits - 1)) if signed else (0, 1 << bits)
    a = rng.integers(lo, hi, n).astype(np.int32)
    b = rng.integers(lo, hi, n).astype(np.int32)
    return a, b


@pytest.mark.parametrize("bits", [8, 12, 16])
@pytest.mark.parametrize("signed", [False, True])
def test_exact_matches_numpy(bits, signed):
    m = C.exact(bits, signed)
    a, b = _rand_ops(bits, signed)
    got = np.asarray(m.fn(jnp.asarray(a), jnp.asarray(b)))
    if signed:
        ref = (a.astype(np.int64) * b.astype(np.int64)).astype(np.int32)
    else:
        ref = (a.astype(np.uint64) * b.astype(np.uint64)).astype(np.uint32)
    assert np.array_equal(got, ref)


def test_registry_commutativity_flags():
    """Every registry member with a declared flag matches empirical behavior."""
    for name, m in C.REGISTRY.items():
        if m.commutative is not None:
            assert C.is_commutative(m) == m.commutative, name


def test_registry_has_noncommutative_members():
    nc = [n for n, m in C.REGISTRY.items() if m.commutative is False]
    assert len(nc) >= 30  # SWAPPER targets exist at every width/signedness
    for bits in (8, 12, 16):
        for s in ("u", "s"):
            assert any(f"mul{bits}{s}" in n for n in nc)


@pytest.mark.parametrize(
    "name",
    ["mul8u_trunc0_4", "mul8u_bam_v2_h1", "mul8u_drum3_4", "mul8u_mitch13_0",
     "mul16s_trunc0_8", "mul16s_drum5_8"],
)
def test_approximation_is_bounded(name):
    """Approximate product never exceeds the exact product's bit budget and
    the average relative error is sane (<50%)."""
    m = C.get(name)
    a, b = _rand_ops(m.bits, m.signed, 8192)
    p = np.asarray(m.fn(jnp.asarray(a), jnp.asarray(b))).astype(np.int64)
    if not m.signed:
        p = p & 0xFFFFFFFF
    ex = a.astype(np.float64) * b.astype(np.float64)
    rel = np.abs(p - ex) / np.maximum(np.abs(ex), 1)
    assert rel.mean() < 0.5, rel.mean()


def test_mitchell_error_bound():
    """Mitchell's classical bound: relative error < 11.15% (underestimates)."""
    m = C.mitchell(16, 0, 0, False)
    a, b = _rand_ops(16, False, 1 << 16, seed=3)
    a = np.maximum(a, 1)
    b = np.maximum(b, 1)
    p = np.asarray(m.fn(jnp.asarray(a), jnp.asarray(b))).astype(np.float64)
    ex = a.astype(np.float64) * b.astype(np.float64)
    rel = (ex - p) / ex
    assert rel.max() < 0.1115 + 1e-3
    assert rel.min() > -1e-3  # never overestimates (modulo fxp rounding)


def test_trunc_error_closed_form():
    """trunc(ka,kb): error == a_lo*bhi_trunc... exact algebraic identity:
    a*b - (a&~ma)*(b&~mb) == a_lo*b + a_hi*b_lo where splits are exact."""
    ka, kb = 2, 5
    m = C.trunc(8, ka, kb, False)
    a, b = _rand_ops(8, False, 2048, seed=1)
    p = np.asarray(m.fn(jnp.asarray(a), jnp.asarray(b))).astype(np.int64)
    ah = a & ~((1 << ka) - 1)
    bh = b & ~((1 << kb) - 1)
    assert np.array_equal(p, (ah.astype(np.int64) * bh.astype(np.int64)))


def test_lut_roundtrip():
    """A LUT built from a closed-form 8-bit multiplier reproduces it exactly
    (signed and unsigned)."""
    for name in ("mul8u_drum3_4", "mul8s_trunc0_4"):
        m = C.get(name)
        tbl = C.make_lut(m)
        lm = C.lut_mult(m.name + "_lut", tbl, m.signed)
        a, b = _rand_ops(8, m.signed, 4096, seed=2)
        p1 = np.asarray(m.fn(jnp.asarray(a), jnp.asarray(b)))
        p2 = np.asarray(lm.fn(jnp.asarray(a), jnp.asarray(b)))
        assert np.array_equal(p1.astype(np.int64), p2.astype(np.int64))


@settings(max_examples=200, deadline=None)
@given(
    a=st.integers(0, 255),
    b=st.integers(0, 255),
    ka=st.integers(0, 7),
    kb=st.integers(0, 7),
)
def test_trunc_underestimates_property(a, b, ka, kb):
    """Property: operand truncation never overestimates the exact product."""
    m = C.trunc(8, ka, kb, False)
    p = int(np.asarray(m.fn(jnp.int32(a), jnp.int32(b))))
    assert p <= a * b
    assert p >= 0


@settings(max_examples=200, deadline=None)
@given(a=st.integers(-32768, 32767), b=st.integers(-32768, 32767))
def test_signed_envelope_sign_property(a, b):
    """Property: sign-magnitude envelope => sign(approx) in {0, sign(a*b)}."""
    m = C.get("mul16s_drum5_8")
    p = int(np.asarray(m.fn(jnp.int32(a), jnp.int32(b))))
    ex = a * b
    if p != 0 and ex != 0:
        assert (p > 0) == (ex > 0)
