"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode; see DESIGN.md §4 for the TPU-target layout reasoning)."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # property tests skip without hypothesis

import repro.core as C
import repro.kernels as K


def _ops(shape, lo, hi, seed, dtype):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, shape).astype(dtype))


# ---------------------------------------------------------------------------
# ax_matmul
# ---------------------------------------------------------------------------

SHAPES = [
    (8, 8, 8),
    (32, 64, 16),
    (128, 128, 128),
    (256, 64, 32),
    (64, 256, 128),
]
BLOCKS = [(8, 8, 8), (32, 32, 32), (64, 64, 64), (128, 128, 128)]


@pytest.mark.parametrize("shape", SHAPES)
def test_ax_matmul_shapes(shape):
    M, K_, N = shape
    a = _ops((M, K_), -128, 128, 0, np.int8)
    b = _ops((K_, N), -128, 128, 1, np.int8)
    m = C.get("mul8s_bam_v2_h1")
    swap = C.SwapConfig("A", 5, 1)
    got = K.ax_matmul(a, b, m, swap, block_m=32, block_n=32, block_k=8)
    ref = K.ax_matmul_ref(a, b, m, swap)
    assert got.dtype == jnp.int32
    assert np.array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("blocks", BLOCKS)
def test_ax_matmul_block_invariance(blocks):
    """Output must be independent of the VMEM tiling."""
    bm, bn, bk = blocks
    a = _ops((128, 128), -128, 128, 2, np.int8)
    b = _ops((128, 128), -128, 128, 3, np.int8)
    m = C.get("mul8s_drum3_4")
    got = K.ax_matmul(a, b, m, C.SwapConfig("B", 2, 0), block_m=bm, block_n=bn, block_k=bk)
    ref = K.ax_matmul_ref(a, b, m, C.SwapConfig("B", 2, 0))
    assert np.array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize(
    "mname", ["mul8s_exact", "mul8s_trunc0_4", "mul8s_mitch13_0", "mul8s_perf0_1"]
)
def test_ax_matmul_multiplier_families(mname):
    a = _ops((64, 32), -128, 128, 4, np.int8)
    b = _ops((32, 64), -128, 128, 5, np.int8)
    m = C.get(mname)
    for swap in (None, C.SwapConfig("A", 7, 0)):
        got = K.ax_matmul(a, b, m, swap, block_m=32, block_n=32, block_k=16)
        ref = K.ax_matmul_ref(a, b, m, swap)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), (mname, swap)


def test_ax_matmul_unsigned_dtype():
    a = _ops((32, 32), 0, 256, 6, np.uint8)
    b = _ops((32, 32), 0, 256, 7, np.uint8)
    m = C.get("mul8u_trunc0_4")
    got = K.ax_matmul(a, b, m, C.SwapConfig("A", 3, 0), block_m=32, block_n=32, block_k=32)
    ref = K.ax_matmul_ref(a, b, m, C.SwapConfig("A", 3, 0))
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_ax_matmul_exact_equals_mxu_matmul():
    """With the exact multiplier the kernel reproduces the MXU int8 matmul."""
    a = _ops((64, 64), -128, 128, 8, np.int8)
    b = _ops((64, 64), -128, 128, 9, np.int8)
    got = K.ax_matmul(a, b, C.get("mul8s_exact"), None, block_m=32, block_n=32, block_k=32)
    assert np.array_equal(
        np.asarray(got), np.asarray(a.astype(jnp.int32) @ b.astype(jnp.int32))
    )


def test_ax_matmul_dequant_epilogue():
    a = _ops((32, 64), -128, 128, 10, np.int8)
    b = _ops((64, 32), -128, 128, 11, np.int8)
    sa = jnp.asarray(np.random.default_rng(12).uniform(0.001, 0.1, (32, 1)).astype(np.float32))
    sb = jnp.asarray(np.random.default_rng(13).uniform(0.001, 0.1, (1, 32)).astype(np.float32))
    m = C.get("mul8s_exact")
    got = K.ax_matmul_dequant(a, b, sa, sb, m, None, block_m=32, block_n=32, block_k=32)
    ref = (a.astype(jnp.float32) @ b.astype(jnp.float32)) * sa * sb
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([8, 16, 64]),
    n=st.sampled_from([8, 32]),
    bit=st.integers(0, 7),
    value=st.integers(0, 1),
)
def test_ax_matmul_property(m, k, n, bit, value):
    """Property: kernel == oracle for random shapes x swap configs."""
    a = _ops((m, k), -128, 128, m * k + bit, np.int8)
    b = _ops((k, n), -128, 128, k * n + value, np.int8)
    mult = C.get("mul8s_trunc1_5")
    swap = C.SwapConfig("B", bit, value)
    got = K.ax_matmul(a, b, mult, swap, block_m=8, block_n=8, block_k=8)
    ref = K.ax_matmul_ref(a, b, mult, swap)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# ax_matmul_grid (per-tile swap-config grids, scalar prefetch)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mname", ["mul8s_trunc0_4", "mul8u_trunc0_4", "mul8s_drum3_4"])
def test_ax_matmul_grid_matches_ref(mname):
    rng = np.random.default_rng(21)
    lo, hi, dt = (-128, 128, np.int8) if mname.startswith("mul8s") else (0, 256, np.uint8)
    a = jnp.asarray(rng.integers(lo, hi, (64, 32)).astype(dt))
    b = jnp.asarray(rng.integers(lo, hi, (32, 64)).astype(dt))
    m = C.get(mname)
    grid = np.stack([
        np.asarray(rng.integers(0, 2, (2, 2)), np.int32),   # op_is_a
        np.asarray(rng.integers(0, 8, (2, 2)), np.int32),   # bit
        np.asarray(rng.integers(0, 3, (2, 2)), np.int32),   # value (2 => noswap)
    ], axis=-1)
    got = K.ax_matmul_grid(a, b, m, jnp.asarray(grid), block_m=32, block_n=32, block_k=16)
    ref = K.ax_matmul_grid_ref(a, b, m, jnp.asarray(grid))
    assert got.dtype == jnp.int32
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_ax_matmul_grid_uniform_equals_static():
    """A uniform grid reproduces the statically-configured kernel exactly."""
    rng = np.random.default_rng(22)
    a = jnp.asarray(rng.integers(-128, 128, (64, 64)).astype(np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (64, 64)).astype(np.int8))
    m = C.get("mul8s_trunc0_4")
    for cfg, triple in [(C.SwapConfig("A", 5, 1), (1, 5, 1)),
                        (C.SwapConfig("B", 2, 0), (0, 2, 0)),
                        (None, (1, 0, 2))]:
        uni = jnp.broadcast_to(jnp.asarray(triple, jnp.int32), (2, 2, 3))
        got = K.ax_matmul_grid(a, b, m, uni, block_m=32, block_n=32, block_k=32)
        ref = K.ax_matmul(a, b, m, cfg, block_m=32, block_n=32, block_k=32)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), cfg


def test_ax_matmul_grid_change_does_not_recompile():
    """The config grid is a traced operand: per-tile policies update without
    retracing the kernel (the adaptive-runtime contract)."""
    rng = np.random.default_rng(23)
    a = jnp.asarray(rng.integers(-128, 128, (64, 64)).astype(np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (64, 64)).astype(np.int8))
    m = C.get("mul8s_trunc0_4")
    outs = []
    size_after_first = None
    for triple in ((1, 3, 0), (0, 6, 1), (1, 0, 2)):
        grid = jnp.broadcast_to(jnp.asarray(triple, jnp.int32), (2, 2, 3))
        outs.append(np.asarray(K.ax_matmul_grid(a, b, m, grid,
                                                block_m=32, block_n=32, block_k=32)))
        if size_after_first is None:
            size_after_first = K.ax_matmul_grid._cache_size()
    assert K.ax_matmul_grid._cache_size() == size_after_first
    assert any(not np.array_equal(outs[0], o) for o in outs[1:])


# ---------------------------------------------------------------------------
# tuning_sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["mul8u_trunc0_4", "mul8s_drum3_4", "mul8u_mitch13_0"])
@pytest.mark.parametrize("tile", [64, 128, 256])
def test_tuning_sweep_matches_jnp_driver(name, tile):
    m = C.get(name)
    r_jnp = C.component_sweep(m, tile=tile)
    r_pls = K.component_sweep_pallas(m, tile=tile)
    assert r_jnp.noswap.sum_abs == r_pls.noswap.sum_abs
    assert r_jnp.noswap.max_abs == r_pls.noswap.max_abs
    assert r_jnp.oracle.sum_abs == r_pls.oracle.sum_abs
    for cfg in C.all_configs(8):
        s1, s2 = r_jnp.per_config[cfg], r_pls.per_config[cfg]
        assert s1.sum_abs == s2.sum_abs, cfg
        assert s1.max_abs == s2.max_abs, cfg
        assert s1.count_neq == s2.count_neq, cfg
        assert s1.sum_sq == pytest.approx(s2.sum_sq, rel=1e-6), cfg
    assert r_jnp.best("mae") == r_pls.best("mae")


def test_tuning_sweep_sampled_16bit():
    """16-bit sweep with sampled operands stays consistent between drivers."""
    m = C.get("mul16s_drum5_8")
    r_jnp = C.component_sweep(m, tile=128, sample_bits=9, seed=11)
    r_pls = K.component_sweep_pallas(m, tile=128, sample_bits=9, seed=11)
    assert r_jnp.noswap.sum_abs == r_pls.noswap.sum_abs
    assert r_jnp.best("mae") == r_pls.best("mae")
    assert r_pls.reduction("mae") > 0.01  # a useful bit exists
