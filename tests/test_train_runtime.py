"""Distributed-runtime tests: train step, grad accumulation parity, gradient
compression, checkpoint/restart (fault tolerance), straggler watchdog,
serving loop."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as CFG
import repro.models as M
import repro.train as T
from repro.serve import ServeConfig, generate


@pytest.fixture(scope="module")
def small():
    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    return cfg, params


def _stream(cfg, batch=8, seq=32):
    # 'arith' mode: next token = (tok+1) mod vocab — learnable, so loss-drop
    # assertions are meaningful (uniform hash tokens start at the optimum)
    return T.SyntheticStream(T.DataConfig(cfg.vocab, seq, batch, seed=1, mode="arith"))


def test_train_loss_decreases(small):
    cfg, params = small
    opt = T.AdamWConfig(lr=3e-3, warmup=5)
    par = CFG.ParallelConfig(remat="none", grad_accum=1)
    step = jax.jit(T.make_train_step(cfg, par, opt))
    state = T.init_train_state(params, opt)
    stream = _stream(cfg)
    losses = []
    for _ in range(20):
        state, m = step(state, jax.tree.map(jnp.asarray, stream.next()))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses[:3] + losses[-3:]


def test_grad_accum_parity(small):
    """k microbatches == one big batch (same grads up to accumulation fp)."""
    cfg, params = small
    opt = T.AdamWConfig(lr=1e-3)
    batch = _stream(cfg).next()
    batch = jax.tree.map(jnp.asarray, batch)
    outs = {}
    for k in (1, 4):
        par = CFG.ParallelConfig(remat="none", grad_accum=k)
        step = jax.jit(T.make_train_step(cfg, par, opt))
        state = T.init_train_state(params, opt)
        new_state, m = step(state, batch)
        outs[k] = (float(m["loss"]),
                   np.asarray(jax.tree.leaves(new_state["params"])[0], np.float32))
    assert outs[1][0] == pytest.approx(outs[4][0], rel=1e-3)
    np.testing.assert_allclose(outs[1][1], outs[4][1], rtol=2e-2, atol=2e-4)


def test_grad_compression_converges(small):
    """bf16 gradient compression with error feedback still trains."""
    cfg, params = small
    opt = T.AdamWConfig(lr=3e-3, warmup=5, compress="bf16")
    par = CFG.ParallelConfig(remat="none")
    step = jax.jit(T.make_train_step(cfg, par, opt))
    state = T.init_train_state(params, opt)
    stream = _stream(cfg)
    losses = []
    for _ in range(20):
        state, m = step(state, jax.tree.map(jnp.asarray, stream.next()))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1


def test_checkpoint_roundtrip(tmp_path, small):
    cfg, params = small
    opt = T.AdamWConfig()
    state = T.init_train_state(params, opt)
    T.save(str(tmp_path), 7, state, extra={"train_step": 7, "data": {"step": 7}})
    assert T.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, extra = T.restore(str(tmp_path), 7, like)
    assert extra["train_step"] == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fault_restart_resumes_identically(tmp_path, small):
    """Kill the job mid-run; the supervised loop restores the newest
    checkpoint + data state and converges to the same final state as an
    uninterrupted run (bit-identical data resume)."""
    cfg, params0 = small
    opt = T.AdamWConfig(lr=1e-3, warmup=2)
    par = CFG.ParallelConfig(remat="none")
    step = jax.jit(T.make_train_step(cfg, par, opt))

    def step_fn(state, batch):
        return step(state, jax.tree.map(jnp.asarray, batch))

    def make_state():
        return T.init_train_state(params0, opt)

    n = 12
    # uninterrupted reference
    ref_dir = str(tmp_path / "ref")
    s_ref, log_ref = T.run_supervised(
        make_state, step_fn, _stream(cfg), n,
        T.FaultConfig(ckpt_dir=ref_dir, ckpt_every=4),
    )
    assert log_ref["restarts"] == 0

    # chaotic run: dies at step 6 (after the step-4 checkpoint)
    chaos_dir = str(tmp_path / "chaos")
    fired = {"done": False}

    def chaos(i):
        if i == 6 and not fired["done"]:
            fired["done"] = True
            raise T.SimulatedFailure("node died")

    s_chaos, log_chaos = T.run_supervised(
        make_state, step_fn, _stream(cfg), n,
        T.FaultConfig(ckpt_dir=chaos_dir, ckpt_every=4), chaos=chaos,
    )
    assert log_chaos["restarts"] == 1
    a = np.asarray(jax.tree.leaves(s_ref["params"])[0], np.float32)
    b = np.asarray(jax.tree.leaves(s_chaos["params"])[0], np.float32)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_straggler_watchdog():
    w = T.StragglerWatchdog(factor=3.0)
    for _ in range(10):
        assert not w.observe(0.1)
    assert w.observe(1.0)   # 10x median -> flagged
    assert w.flagged == 1


def test_elastic_restore_resharding(tmp_path, small):
    """Restore onto explicit (new) shardings — the elastic-scaling path."""
    cfg, params = small
    state = {"params": params}
    T.save(str(tmp_path), 1, state, extra={"train_step": 1, "data": {"step": 1}})
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    # single-device "new mesh": fully replicated shardings
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shardings = jax.tree.map(lambda x: NamedSharding(mesh, PartitionSpec()), like)
    restored, _ = T.restore(str(tmp_path), 1, like, sharding_tree=shardings)
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding == NamedSharding(mesh, PartitionSpec())


def test_generate_greedy(small):
    cfg, params = small
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab, (2, 12)), jnp.int32)
    out = generate(params, {"tokens": toks}, cfg, ServeConfig(max_new_tokens=6))
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab).all())
    # greedy decode is deterministic
    out2 = generate(params, {"tokens": toks}, cfg, ServeConfig(max_new_tokens=6))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


def test_data_stream_determinism():
    cfg = T.DataConfig(vocab=100, seq_len=8, global_batch=4, seed=3)
    s1 = T.SyntheticStream(cfg)
    for _ in range(5):
        s1.next()
    st = s1.state()
    a = s1.next()
    s2 = T.SyntheticStream(cfg).restore(st)
    b = s2.next()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
