"""The closed per-tile adaptation loop (PR 4): tile-histogram kernel
outputs, per-row-tile dynamic execution on every backend, tile telemetry ->
controller -> ``SwapPolicy.tile_grids`` -> store/reader adoption, the
engine's tile-mode fused decode, and the 8-device psum aggregation of tile
records (subprocess, forced device count)."""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.kernels as K
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.fleet import PolicyReader, PolicyStore
from repro.quant.ax import ax_matmul_int_dyn

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# kernel tile histograms: bit-exact vs the host oracle across slab depths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k_slab", [1, 4, None])
@pytest.mark.parametrize("mname", ["mul8s_trunc0_4", "mul8u_trunc0_4"])
def test_kernel_tile_hist_bitexact(mname, k_slab):
    rng = np.random.default_rng(11)
    lo, hi, dt = (-128, 128, np.int8) if mname.startswith("mul8s") else (0, 256, np.uint8)
    a = jnp.asarray(rng.integers(lo, hi, (64, 48)).astype(dt))
    b = jnp.asarray(rng.integers(lo, hi, (48, 64)).astype(dt))
    m = C.get(mname)
    out, hist = K.ax_matmul(a, b, m, C.SwapConfig("A", 5, 1), block_m=32,
                            block_n=32, block_k=16, k_slab=k_slab,
                            tile_hist=True)
    assert hist.dtype == jnp.int32 and hist.shape == (2, 2, 2, m.bits + 1)
    assert np.array_equal(np.asarray(hist), K.tile_hist_ref(a, b, m.bits, 2, 2))
    # the histogram output must not perturb the matmul result
    ref = K.ax_matmul_ref(a, b, m, C.SwapConfig("A", 5, 1))
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("k_slab", [1, None])
def test_grid_kernel_tile_hist_bitexact(k_slab):
    """The scalar-prefetch grid kernel emits the same histograms — one
    dispatch both applies the per-tile policy and observes the per-tile
    distribution."""
    rng = np.random.default_rng(12)
    a = jnp.asarray(rng.integers(-128, 128, (64, 32)).astype(np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (32, 64)).astype(np.int8))
    m = C.get("mul8s_trunc0_4")
    grid = np.stack([rng.integers(0, 2, (2, 2)), rng.integers(0, 8, (2, 2)),
                     rng.integers(0, 3, (2, 2))], axis=-1).astype(np.int32)
    out, hist = K.ax_matmul_grid(a, b, m, jnp.asarray(grid), block_m=32,
                                 block_n=32, block_k=16, k_slab=k_slab,
                                 tile_hist=True)
    assert np.array_equal(np.asarray(hist), K.tile_hist_ref(a, b, m.bits, 2, 2))
    assert np.array_equal(np.asarray(out),
                          np.asarray(K.ax_matmul_grid_ref(a, b, m, jnp.asarray(grid))))
    # histograms are policy-independent: a different grid, same counts
    grid2 = np.broadcast_to(np.asarray((1, 0, 2), np.int32), (2, 2, 3))
    _, hist2 = K.ax_matmul_grid(a, b, m, jnp.asarray(grid2), block_m=32,
                                block_n=32, block_k=16, k_slab=k_slab,
                                tile_hist=True)
    assert np.array_equal(np.asarray(hist), np.asarray(hist2))


# ---------------------------------------------------------------------------
# per-row-tile dynamic execution: all backends agree on the grid semantics
# ---------------------------------------------------------------------------

def _pol(backend):
    return AxPolicy(backend=backend)


@pytest.mark.parametrize("shape", [(3, 16, 64), (1, 10, 64), (2, 64)])
def test_rowtile_dyn_backends_agree(shape):
    """A-side/NoSwap per-row-tile grids: mxu (single K-stacked matmul),
    kernel (scalar-prefetch grid) and emul produce identical int32 results,
    including uneven last tiles and gm > rows."""
    rng = np.random.default_rng(13)
    a = jnp.asarray(rng.integers(-127, 128, shape).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (64, 96)).astype(np.int8))
    grid = jnp.asarray([[[1, 3, 0]], [[1, 0, 2]], [[1, 6, 1]]], jnp.int32)
    ref = ax_matmul_int_dyn(a, b, _pol("emul"), grid)
    for be in ("mxu", "kernel"):
        got = ax_matmul_int_dyn(a, b, _pol(be), grid)
        assert np.array_equal(np.asarray(got), np.asarray(ref)), be


def test_rowtile_bside_kernel_matches_emul():
    """B-side per-tile decisions are the grid kernel's (and emul's) domain;
    they agree bit-exactly (the mxu row-tile path is A-side-only by
    construction — see quant.ax._mxu_limbs_rowtile)."""
    rng = np.random.default_rng(14)
    a = jnp.asarray(rng.integers(-127, 128, (32, 64)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (64, 96)).astype(np.int8))
    grid = jnp.asarray([[[0, 2, 0]], [[1, 0, 2]], [[0, 7, 1]], [[0, 1, 0]]],
                       jnp.int32)
    assert np.array_equal(
        np.asarray(ax_matmul_int_dyn(a, b, _pol("kernel"), grid)),
        np.asarray(ax_matmul_int_dyn(a, b, _pol("emul"), grid)))


def test_uniform_grid_matches_scalar_triple():
    """A uniform per-tile grid reproduces the scalar dynamic path exactly
    on every backend — INCLUDING a B-side config (the broadcast a scalar
    B-tuned target gets under --tile-rows): scalar and tile-granular
    policies are one continuum, and enabling tile mode never changes the
    numerics of a scalar policy."""
    rng = np.random.default_rng(15)
    a = jnp.asarray(rng.integers(-127, 128, (24, 64)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (64, 32)).astype(np.int8))
    for trip in ((1, 5, 1), (1, 0, 2), (0, 3, 1), (0, 6, 0)):
        t = jnp.asarray(trip, jnp.int32)
        grid = jnp.broadcast_to(t, (4, 1, 3))
        for be in ("mxu", "kernel", "emul"):
            assert np.array_equal(
                np.asarray(ax_matmul_int_dyn(a, b, _pol(be), grid)),
                np.asarray(ax_matmul_int_dyn(a, b, _pol(be), t))), (be, trip)


@pytest.mark.parametrize("grid", [
    # B-side tile first
    [[[0, 3, 1]], [[1, 5, 0]], [[1, 0, 2]], [[0, 3, 1]]],
    # B-side tile NOT first (the representative must be found, not assumed
    # at position 0), mixed with A-side and NoSwap tiles
    [[[1, 5, 0]], [[0, 2, 0]], [[1, 0, 2]], [[0, 2, 0]]],
    # NoSwap-only ahead of a trailing B-side tile
    [[[1, 0, 2]], [[1, 0, 2]], [[1, 0, 2]], [[0, 7, 1]]],
])
def test_mixed_aside_with_uniform_bside_grid_agrees(grid):
    """Grids mixing A-side/NoSwap tiles with ONE shared B-side triple are
    exact on the mxu 4-limb row-tile path wherever the B-side tile sits
    (the expressible B-side family; heterogeneous B-side grids are
    rejected by SwapPolicy.set_tile_grid)."""
    rng = np.random.default_rng(20)
    a = jnp.asarray(rng.integers(-127, 128, (32, 64)).astype(np.int8))
    b = jnp.asarray(rng.integers(-127, 128, (64, 48)).astype(np.int8))
    grid = jnp.asarray(grid, jnp.int32)
    ref = ax_matmul_int_dyn(a, b, _pol("emul"), grid)
    for be in ("mxu", "kernel"):
        assert np.array_equal(
            np.asarray(ax_matmul_int_dyn(a, b, _pol(be), grid)),
            np.asarray(ref)), be


def test_tile_drift_survives_granularity_change():
    """gm follows min(tile_rows, rows): a batch-size change mid-stream
    changes the tile statistic's shape — the drift detector must rebase,
    not crash (and not silently broadcast-compare)."""
    ctrl = R.AdaptiveController(
        R.SwapPolicy("mul8u_trunc0_4", configs={"*": None}),
        targets=("stream",),
        cfg=R.AdaptiveConfig(min_observe_steps=1, cooldown_steps=1,
                             tile_rows=4))
    rng = np.random.default_rng(21)
    for rows in (3, 3, 4, 4, 2, 4):      # granularity flips 3 -> 4 -> 2 -> 4
        ctrl.observe_operands("stream", rng.integers(0, 256, (rows, 64)),
                              rng.integers(0, 256, 256))
    snap = ctrl.telemetry.snapshot()[R.tile_key("stream")]
    assert snap["bit_probs"].shape == (4, 9)


def test_set_tile_grid_rejects_heterogeneous_bside():
    p = R.SwapPolicy("mul8u_trunc0_4")
    # uniform B-side: fine; A-side mix: fine
    p.set_tile_grid("ok", np.asarray([[[0, 3, 1]], [[0, 3, 1]], [[1, 2, 0]]],
                                     np.int32))
    with pytest.raises(AssertionError, match="B-side"):
        p.set_tile_grid("bad", np.asarray([[[0, 3, 1]], [[0, 5, 0]]], np.int32))


# ---------------------------------------------------------------------------
# tile telemetry records
# ---------------------------------------------------------------------------

def test_tile_summary_shapes_and_gate():
    mult = C.get("mul8u_trunc0_4")
    rng = np.random.default_rng(16)
    x = jnp.asarray(rng.integers(0, 256, (16, 128)), jnp.int32)
    w = jnp.asarray(rng.integers(0, 256, (128, 32)), jnp.int32)
    rec = jax.device_get(R.tile_summary(x, w, mult, 4))
    assert rec["tile_bits_a"].shape == (4, mult.bits)
    assert rec["tile_a_smp"].shape == (R.TILE_RETUNE_SAMPLE, 4)
    assert rec["tile_n"].sum() == 4 * R.TILE_TELEMETRY_SAMPLE
    # every field is classified for the fleet reduction
    from repro.runtime.telemetry import MAX_FIELDS, SAMPLE_FIELDS, SUM_FIELDS
    for k in rec:
        assert k in SUM_FIELDS + MAX_FIELDS + SAMPLE_FIELDS, k
    # gate=False produces the all-zero record of identical structure
    off = jax.device_get(R.tile_summary(x, w, mult, 4, gate=jnp.bool_(False)))
    assert set(off) == set(rec)
    assert all(np.all(np.asarray(v) == 0) for v in off.values())
    on = jax.device_get(R.tile_summary(x, w, mult, 4, gate=jnp.bool_(True)))
    for k in rec:
        assert np.array_equal(np.asarray(on[k]), np.asarray(rec[k])), k


def test_tile_summary_rows_smaller_than_granularity():
    mult = C.get("mul8u_trunc0_4")
    rng = np.random.default_rng(17)
    x = jnp.asarray(rng.integers(0, 256, (2, 64)), jnp.int32)
    rec = jax.device_get(R.tile_summary(x, x.T, mult, 8))
    assert rec["tile_bits_a"].shape == (2, mult.bits)   # min(gm, rows) tiles


# ---------------------------------------------------------------------------
# the closed loop: skewed two-tile traffic -> non-uniform published grid ->
# store round-trip -> reader adoption (ISSUE acceptance)
# ---------------------------------------------------------------------------

def test_controller_closes_tile_loop(tmp_path):
    mult = C.get("mul8u_trunc0_4")
    store = PolicyStore(str(tmp_path))
    ctrl = R.AdaptiveController(
        R.SwapPolicy(mult.name, configs={"*": None}), targets=("stream",),
        store=store,
        cfg=R.AdaptiveConfig(decay=0.4, drift_threshold=0.005,
                             min_observe_steps=2, cooldown_steps=2,
                             tile_rows=2, tile_buffer_size=512))
    ctrl.resume_from_store()
    ctrl.warmup()
    cache0 = ctrl.scorer_cache_size()
    reader = PolicyReader(store, ("stream",), tile_rows=2)
    v0 = reader.version
    t0 = reader.dyn_tree()
    assert t0["stream"].shape == (2, 1, 3)

    rng = np.random.default_rng(18)
    K_ = 128
    for step in range(16):
        hi = rng.integers(128, 256, (8, K_))
        lo = (rng.integers(0, 48, (8, K_)) if step >= 6
              else rng.integers(128, 256, (8, K_)))
        ctrl.observe_operands("stream", np.concatenate([hi, lo]),
                              rng.integers(0, 256, 1024))

    # the loop closed: a tile re-tune fired and published a NON-uniform grid
    assert len(ctrl.tile_retunes) >= 1
    grid = ctrl.policy.tile_grids["stream"]
    assert grid.shape == (2, 1, 3)
    assert not np.array_equal(grid[0], grid[1]), grid
    # tile sweep space is backend-portable: A-side or NoSwap only
    assert all(int(t[0]) == 1 for t in grid[:, 0, :]), grid
    # zero recompiles across every tile re-tune (scorers warmed up once)
    assert ctrl.scorer_cache_size() == cache0

    # JSON round-trip preserves the grid bit-exactly
    back = R.SwapPolicy.from_json(ctrl.policy.to_json())
    assert back.configs_equal(ctrl.policy)
    assert np.array_equal(back.tile_grids["stream"], grid)

    # reader: staleness grows, poll adopts, dyn tree keeps shape (no retrace)
    assert reader.staleness() >= 1
    assert reader.poll() and reader.version > v0
    assert reader.staleness() == 0
    t1 = reader.dyn_tree()
    assert jax.tree.structure(t0) == jax.tree.structure(t1)
    assert t1["stream"].shape == (2, 1, 3)
    assert np.array_equal(np.asarray(t1["stream"]), grid)


def test_reader_staleness_from_empty_store(tmp_path):
    """A replica that spun up against an empty store is behind EVERY
    version published afterwards — maximal lag, never zero."""
    store = PolicyStore(str(tmp_path))
    reader = PolicyReader(store, ("mlp",))
    assert reader.version == -1 and reader.staleness() == 0   # nothing exists
    p = R.SwapPolicy("mul8u_trunc0_4")
    store.publish(p)
    store.publish(p)
    assert reader.staleness() == 2
    assert reader.poll() and reader.staleness() == 0


def test_policy_tile_grid_resample():
    p = R.SwapPolicy("mul8u_trunc0_4", configs={"*": C.SwapConfig("A", 3, 0)})
    # no stored grid: scalar config broadcasts to every tile
    g = p.tile_grid("mlp", 4, 1)
    assert g.shape == (4, 1, 3) and np.all(g == np.asarray((1, 3, 0)))
    # stored (2, 1): resamples up (repeat) and down (stride) deterministically
    p.set_tile_grid("mlp", np.asarray([[[1, 7, 1]], [[1, 0, 2]]], np.int32))
    up = p.tile_grid("mlp", 4, 1)
    assert np.array_equal(up[:, 0, 0:3:2], [[1, 1], [1, 1], [1, 2], [1, 2]])
    down = p.tile_grid("mlp", 1, 1)
    assert np.array_equal(down[0, 0], [1, 7, 1])
    # dyn_tree in tile mode serves the resampled grid
    tree = p.dyn_tree(("mlp",), tile_rows=4)
    assert tree["mlp"].shape == (4, 1, 3)


# ---------------------------------------------------------------------------
# engine: tile-mode fused decode == stepwise loop; grid adoption, no retrace
# ---------------------------------------------------------------------------

def _tiny_model():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _tile_controller(cfg):
    return R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10 ** 6, tile_rows=2))


def test_engine_tile_mode_fused_matches_stepwise():
    from repro.serve import ServeConfig, generate
    from repro.serve import engine as E

    cfg, params = _tiny_model()
    rng = np.random.default_rng(19)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 12)),
                                    jnp.int32)}
    cA, cB = _tile_controller(cfg), _tile_controller(cfg)
    kw = dict(max_new_tokens=8)
    o_loop = generate(params, prompt, cfg, ServeConfig(fused=False, **kw),
                      adaptive=cA)
    o_scan = generate(params, prompt, cfg, ServeConfig(fused=True, **kw),
                      adaptive=cB)
    assert np.array_equal(np.asarray(o_loop), np.asarray(o_scan))
    sA, sB = cA.telemetry.snapshot(), cB.telemetry.snapshot()
    tile_keys = {R.tile_key(t) for t in cfg.ax.targets}
    assert tile_keys <= set(sA) and tile_keys <= set(sB)
    for k in tile_keys:
        assert np.allclose(sA[k]["bit_probs"], sB[k]["bit_probs"]), k
    assert set(cB.tile_buffers) == set(cfg.ax.targets)

    # adopting a non-uniform tile grid changes tokens with ZERO retraces
    n0 = {k: f._cache_size() for k, f in E._ADAPTIVE_FNS.items()}
    cB.policy.set_tile_grid("mlp", np.asarray([[[1, 7, 1]], [[1, 0, 2]]],
                                              np.int32))
    o2 = generate(params, prompt, cfg, ServeConfig(fused=True, **kw),
                  adaptive=cB)
    assert all(f._cache_size() == n0[k] for k, f in E._ADAPTIVE_FNS.items())
    assert not np.array_equal(np.asarray(o_scan), np.asarray(o2))


# ---------------------------------------------------------------------------
# 8-device mesh: tile records psum/all-gather bit-exactly (subprocess)
# ---------------------------------------------------------------------------

def _run_sub(code, timeout=540):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(out.stdout[-2000:])


_TILE_PSUM_SCRIPT = r"""
import json
import jax, jax.numpy as jnp, numpy as np
import repro.core as C
import repro.runtime as R
from repro.fleet import make_sharded_summarizer
from repro.launch.mesh import make_fleet_mesh
from repro.runtime.telemetry import combine_records, tile_key

res = {"devices": jax.device_count()}
mesh = make_fleet_mesh(8)
mult = C.get("mul8u_trunc0_4")
dyn = jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)
GM = 2
f = make_sharded_summarizer(mult.name, mesh, tile_rows=GM)
rng = np.random.default_rng(0)
ROWS, K = 16, 128          # per-shard row slice: 16 rows -> 2 row tiles of 8

a = rng.integers(0, 256, (8 * ROWS, K))
b = rng.integers(0, 256, 8 * R.TELEMETRY_SAMPLE)
got = jax.device_get(f(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), dyn))

shard_recs = []
for s in range(8):
    al = jnp.asarray(a[s*ROWS:(s+1)*ROWS], jnp.int32)
    bl = jnp.asarray(b[s*R.TELEMETRY_SAMPLE:(s+1)*R.TELEMETRY_SAMPLE], jnp.int32)
    rec = jax.device_get(R.operand_summary(al, bl, mult, dyn))
    trec = jax.device_get(R.tile_summary(al, bl, mult, GM))
    shard_recs.append({
        "stream": {k: np.asarray(v)[None] for k, v in rec.items()},
        tile_key("stream"): {k: np.asarray(v)[None] for k, v in trec.items()},
    })
ref = combine_records(shard_recs)
res["scalar_bitexact"] = all(
    np.array_equal(got["stream"][k], ref["stream"][k].reshape(got["stream"][k].shape))
    for k in got["stream"])
tk = tile_key("stream")
res["tile_bitexact"] = all(
    np.array_equal(got[tk][k], ref[tk][k].reshape(got[tk][k].shape))
    for k in got[tk])
res["tile_fields"] = sorted(got[tk])
res["tile_smp_shape"] = list(np.asarray(got[tk]["tile_a_smp"]).shape)

# the fleet-aggregated tile records drive a per-tile re-tune on the
# controller exactly like single-host records
ctrl = R.AdaptiveController(
    R.SwapPolicy(mult.name, configs={"*": None}), targets=("stream",),
    cfg=R.AdaptiveConfig(min_observe_steps=10**9, tile_rows=GM))
ctrl.observe(got)
ctrl.retune_tiles("stream")
res["grid_published"] = "stream" in ctrl.policy.tile_grids
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.multidevice
def test_tile_records_psum_bitexact_8dev():
    """ISSUE acceptance: tile histograms psum-aggregate correctly on a
    forced 8-device mesh (bit-exact vs the host combine oracle), and the
    aggregated records feed the controller's per-tile re-tune."""
    r = _run_sub(_TILE_PSUM_SCRIPT)
    assert r["devices"] == 8
    assert r["scalar_bitexact"], r
    assert r["tile_bitexact"], r
    # all-gather concatenated 8 shards' samples along the sample axis
    assert r["tile_smp_shape"] == [1, 8 * R.TILE_RETUNE_SAMPLE, 2], r
    assert r["grid_published"], r
