"""QoR observability (PR 8): per-request error attribution, the SLO
burn-rate engine, push exporter backends (StatsD / OTLP-JSON golden
files), bucket-coverage tooling, scrape-vs-snapshot thread races, and
correlation-id uniqueness across splices and repeated drains.
"""
import dataclasses
import json
import os
import socket
import threading
import urllib.request

import numpy as np
import pytest

from repro import obs

DATA = os.path.join(os.path.dirname(__file__), "data")


# ---------------------------------------------------------------------------
# schema cross-checks: obs stays import-free of the runtime, a test pins
# the mirrored constants/fields in sync instead
# ---------------------------------------------------------------------------

def test_tile_key_suffix_matches_runtime():
    from repro.runtime import telemetry as T

    assert obs.qor.TILE_KEY_SUFFIX == T.TILE_KEY_SUFFIX
    assert T.tile_key("mlp") == "mlp" + obs.qor.TILE_KEY_SUFFIX


def test_step_error_summary_reads_runtime_record_fields():
    """The attributor's field names must match what the runtime's
    telemetry records actually carry (err limbs + tile err limbs)."""
    from repro.runtime import telemetry as T

    assert {"err_lo", "err_hi", "n"} <= set(T.SUM_FIELDS)
    assert {"tile_err_lo", "tile_err_hi", "tile_n"} <= set(T.SUM_FIELDS)


# ---------------------------------------------------------------------------
# step_error_summary / ErrorAttributor unit behaviour
# ---------------------------------------------------------------------------

def _rec(err_lo, err_hi, n):
    return dict(err_lo=np.asarray(err_lo, np.uint32),
                err_hi=np.asarray(err_hi, np.uint32),
                n=np.asarray(n, np.uint32))


def _tile_rec(lo, hi, n):
    return dict(tile_err_lo=np.asarray(lo, np.uint32),
                tile_err_hi=np.asarray(hi, np.uint32),
                tile_n=np.asarray(n, np.uint32))


def test_step_error_summary_limb_arithmetic_and_tiles():
    records = {
        "mlp": _rec([100, 100], [1, 0], [10, 10]),      # (200+65536)/20
        "attn": _rec([50], [0], [50]),                  # 1.0
        "mlp@tiles": _tile_rec([[10, 20], [30, 40]],    # per-call stacked
                               [[0, 0], [0, 0]],
                               [[4, 4], [4, 4]]),
        "skipme": dict(n=np.asarray([1], np.uint32)),   # no limbs: skipped
    }
    scalars, tiles = obs.step_error_summary(records)
    assert scalars["mlp"] == pytest.approx((200 + 65536) / 20)
    assert scalars["attn"] == pytest.approx(1.0)
    assert "skipme" not in scalars
    np.testing.assert_allclose(tiles["mlp"], [(10 + 30) / 8, (20 + 40) / 8])


def test_attributor_request_basis_share_and_top_tile():
    at = obs.ErrorAttributor(top_k=2)
    at.begin("7#0", 7)
    step = {"mlp": _rec([300], [0], [100]),             # 3.0/step
            "attn": _rec([100], [0], [100]),            # 1.0/step
            "mlp@tiles": _tile_rec([[8, 792]], [[0, 0]], [[100, 100]])}
    for _ in range(4):
        at.observe_step(step, live=["7#0"])
    q = at.finish("7#0")
    assert q["basis"] == "request" and q["steps"] == 4
    assert q["ew_mae"]["mlp"] == pytest.approx(3.0)
    assert q["share"]["mlp"] == pytest.approx(0.75)
    assert q["share"]["attn"] == pytest.approx(0.25)
    assert [e["where"] for e in q["top"]] == ["mlp", "attn"]
    assert q["top"][0]["top_tile"] == 1                 # tile 1 dominates
    assert q["top"][0]["tile_share"] == pytest.approx(792 / 800)
    assert q["weighting"] == "step-exposure"
    assert at.finish("7#0") is None                     # already closed


def test_attributor_zero_step_request_falls_back_to_fleet_basis():
    at = obs.ErrorAttributor()
    at.begin("0#0", 0)
    at.observe_step({"mlp": _rec([100], [0], [10])}, live=["0#0"])
    at.begin("1#1", 1)                 # retires without a live step
    q = at.finish("1#1")
    assert q["basis"] == "fleet"
    assert q["top"][0]["where"] == "mlp"
    # the exposed request keeps its own basis
    assert at.finish("0#0")["basis"] == "request"


def test_attributor_unknown_and_stale_corrs_dropped():
    at = obs.ErrorAttributor()
    at.observe_step({"mlp": _rec([10], [0], [10])}, live=["ghost#9"])
    assert at.finish("ghost#9") is None                 # never begun
    assert at.fleet_share() == {"mlp": 1.0}             # fleet still learns


# ---------------------------------------------------------------------------
# SLO engine: burn-rate window edge cases
# ---------------------------------------------------------------------------

def _spec(**kw):
    base = dict(name="s", kind="latency", source="e2e", threshold=1.0,
                objective=0.1, short_window=4, long_window=8,
                burn_alert=2.0, min_events=4)
    base.update(kw)
    return obs.SLOSpec(**base)


def test_slo_spec_validation():
    with pytest.raises(ValueError):
        _spec(kind="nope")
    with pytest.raises(ValueError):
        _spec(objective=0.0)
    with pytest.raises(ValueError):
        _spec(short_window=9)          # > long_window
    with pytest.raises(ValueError):
        obs.SLOEngine([_spec(), _spec()])   # duplicate names


def test_slo_no_alert_below_min_events():
    eng = obs.SLOEngine([_spec()])
    for _ in range(3):                 # all bad, but < min_events
        eng.observe_latency("e2e", 5.0)
    assert eng.alerting() == []
    eng.observe_latency("e2e", 5.0)    # 4th event arms it
    assert [a.slo for a in eng.alerting()] == ["s"]


def test_slo_needs_both_windows_burning():
    """A long window still diluted by good events keeps the alert off even
    when the short window is saturated (blip suppression)."""
    eng = obs.SLOEngine([_spec(short_window=2, long_window=8, min_events=2)])
    for _ in range(6):
        eng.observe_latency("e2e", 0.1)           # good history
    eng.observe_latency("e2e", 5.0)
    eng.observe_latency("e2e", 5.0)
    bs, bl = eng.burn_rate("s")
    assert bs == pytest.approx(10.0)              # short: 2/2 bad / 0.1
    assert bl == pytest.approx(2.5)               # long: 2/8 bad / 0.1
    assert eng.alerting()                          # both >= 2.0 -> alert
    eng2 = obs.SLOEngine([_spec(short_window=2, long_window=8, min_events=2,
                                burn_alert=3.0)])
    for _ in range(6):
        eng2.observe_latency("e2e", 0.1)
    eng2.observe_latency("e2e", 5.0)
    eng2.observe_latency("e2e", 5.0)
    assert eng2.alerting() == []                   # long window vetoes


def test_slo_alert_edges_audited_and_clears(tmp_path):
    from repro.obs.audit import AuditLog

    audit = AuditLog(str(tmp_path / "audit.jsonl"))
    eng = obs.SLOEngine([_spec(short_window=4, long_window=4, min_events=2)],
                        audit=audit)
    for _ in range(4):
        eng.observe_latency("e2e", 5.0)
    assert eng.alerting()
    for _ in range(4):                 # recover: window flushes to good
        eng.observe_latency("e2e", 0.1)
    assert eng.alerting() == []
    kinds = [e["kind"] for e in audit.read()]
    assert kinds == ["slo_alert", "slo_clear"]     # edge-triggered, once each


def test_slo_qor_guard_band_uses_reference():
    eng = obs.SLOEngine([_spec(kind="qor", source="mlp", threshold=1.5,
                               short_window=2, long_window=2, min_events=1)])
    eng.set_reference("mlp", 100.0)
    eng.observe_qor("mlp", 140.0)      # inside 1.5x band: good
    assert eng.burn_rate("s") == (0.0, 0.0)
    eng.observe_qor("mlp", 160.0)      # past the band: bad
    assert eng.burn_rate("s")[0] > 0
    eng.observe_qor("other", 10 ** 9)  # different target: ignored
    assert eng.events("s") == 2


def test_slo_veto_only_from_veto_bearing_specs():
    eng = obs.SLOEngine([
        _spec(name="lat", short_window=2, long_window=2, min_events=1),
        _spec(name="qor", kind="qor", source="mlp", threshold=0.0,
              short_window=2, long_window=2, min_events=1,
              veto_promotion=True)])
    eng.observe_latency("e2e", 9.0)
    eng.observe_latency("e2e", 9.0)
    assert eng.alerting() and eng.vetoes_promotion() is None
    eng.observe_qor("mlp", 1.0)
    eng.observe_qor("mlp", 1.0)
    assert eng.vetoes_promotion() == "qor"


# ---------------------------------------------------------------------------
# exporter backends: golden files + wire behaviour
# ---------------------------------------------------------------------------

def _golden_registry() -> obs.MetricsRegistry:
    reg = obs.MetricsRegistry()
    c = reg.counter("repro_demo_total", "a counter with labels")
    c.inc(3, mode="wave")
    c.inc(1.5, mode="token")
    g = reg.gauge("repro_demo_occupancy", 'quoted "help" with\nnewline')
    g.set(0.75)
    h = reg.histogram("repro_demo_seconds", "a histogram",
                      buckets=(0.1, 1.0, 10.0))
    h.observe(0.05, path="a")
    h.observe(0.5, path="a")
    h.observe(99.0, path="a")
    return reg


def test_statsd_lines_match_golden_file():
    lines = obs.statsd_lines(_golden_registry())
    with open(os.path.join(DATA, "metrics_golden.statsd")) as f:
        assert "\n".join(lines) + "\n" == f.read()


def test_otlp_json_matches_golden_file():
    payload = obs.otlp_json(_golden_registry(), time_unix_nano=0)
    with open(os.path.join(DATA, "metrics_golden_otlp.json")) as f:
        assert payload == json.load(f)


def test_otlp_bucket_counts_are_non_cumulative():
    payload = obs.otlp_json(_golden_registry(), time_unix_nano=0)
    metrics = payload["resourceMetrics"][0]["scopeMetrics"][0]["metrics"]
    hist = next(m for m in metrics if m["name"] == "repro_demo_seconds")
    pt = hist["histogram"]["dataPoints"][0]
    assert pt["bucketCounts"] == ["1", "1", "0", "1"]   # differenced
    assert pt["explicitBounds"] == [0.1, 1.0, 10.0]     # inf excluded
    assert pt["count"] == "3"


def test_statsd_udp_push_and_mirror(tmp_path):
    rx = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
    rx.bind(("127.0.0.1", 0))
    rx.settimeout(5.0)
    mirror = str(tmp_path / "m.statsd")
    ex = obs.StatsdExporter("127.0.0.1", rx.getsockname()[1], mirror=mirror,
                            mtu=120)
    n = ex.push(_golden_registry())
    assert n == len(obs.statsd_lines(_golden_registry()))
    assert ex.packets_sent >= 2        # mtu=120 forces multiple datagrams
    got = []
    for _ in range(ex.packets_sent):
        got.extend(rx.recv(4096).decode().splitlines())
    rx.close()
    ex.close()
    assert got == obs.statsd_lines(_golden_registry())
    with open(mirror) as f:
        assert f.read().splitlines() == got
    assert all(len(line) <= 120 for line in got)


def test_statsd_from_spec_and_unreachable_is_silent():
    ex = obs.StatsdExporter.from_spec("127.0.0.1:1")    # nothing listens
    assert ex.addr == ("127.0.0.1", 1)
    assert ex.push(_golden_registry()) > 0              # no raise
    ex.close()


def test_otlp_file_push_appends_jsonl(tmp_path):
    path = str(tmp_path / "otlp.jsonl")
    ex = obs.OtlpJsonExporter(path)
    assert ex.push(_golden_registry(), time_unix_nano=1) == 1
    assert ex.push(_golden_registry(), time_unix_nano=2) == 1
    lines = open(path).read().splitlines()
    assert len(lines) == 2
    p0 = json.loads(lines[0])
    assert p0["resourceMetrics"][0]["resource"]["attributes"][0][
        "value"]["stringValue"] == "repro-swapper"


def test_otlp_http_collector_down_degrades(tmp_path):
    ex = obs.OtlpJsonExporter("http://127.0.0.1:9/v1/metrics", timeout_s=0.2)
    assert ex.push(_golden_registry()) == 0
    assert ex.errors == 1              # counted, not raised


def test_push_all_totals_units(tmp_path):
    ex1 = obs.OtlpJsonExporter(str(tmp_path / "a.jsonl"))
    ex2 = obs.StatsdExporter("127.0.0.1", 1)
    total = obs.push_all([ex1, ex2], _golden_registry())
    assert total == 1 + len(obs.statsd_lines(_golden_registry()))
    ex2.close()


# ---------------------------------------------------------------------------
# percentiles + bucket coverage
# ---------------------------------------------------------------------------

def test_interpolated_percentile_and_resolution():
    reg = obs.MetricsRegistry()
    h = reg.histogram("w", "h", buckets=(1.0, 2.0, 4.0))
    for _ in range(100):
        h.observe(1.5)                 # all in the (1, 2] bucket
    assert h.percentile(0.5) == 2.0                      # bucket ceiling
    assert h.percentile(0.5, interpolate=True) == pytest.approx(1.5)
    assert h.percentile_resolution(0.5) == pytest.approx(1.0)
    h.observe(100.0)                   # +Inf bucket
    assert h.percentile(1.0, interpolate=True) == 4.0    # clamped to edge
    assert h.percentile_resolution(1.0) == float("inf")


def test_bucket_percentile_offline_twin():
    samples = [0.5, 1.5, 1.5, 3.0]
    v, res = obs.bucket_percentile(samples, (1.0, 2.0, 4.0), 0.5)
    assert 1.0 <= v <= 2.0 and res == pytest.approx(1.0)
    assert obs.bucket_percentile([], (1.0,), 0.5) == (None, None)


def test_bucket_coverage_flags_inf_heavy_series():
    reg = obs.MetricsRegistry()
    h = reg.histogram("cov", "h", buckets=(1.0, 2.0))
    for _ in range(90):
        h.observe(0.5, path="ok")
    for _ in range(80):
        h.observe(0.5, path="bad")
    for _ in range(20):
        h.observe(9.0, path="bad")     # 20% beyond the top edge
    findings = reg.bucket_coverage(threshold=0.05, min_count=20)
    assert len(findings) == 1
    f = findings[0]
    assert f["name"] == "cov" and f["inf_fraction"] == pytest.approx(0.2)
    with pytest.warns(UserWarning, match="cov"):
        reg.check_bucket_coverage(threshold=0.05, min_count=20)
    # sparse series never flag (a lone cold-compile outlier is fine)
    reg2 = obs.MetricsRegistry()
    h2 = reg2.histogram("cov2", "h", buckets=(1.0,))
    h2.observe(9.0)
    assert reg2.bucket_coverage(min_count=20) == []


def test_tuned_families_cover_recorded_serving_walls():
    """The BENCH-derived bucket families must cover the distributions they
    were tuned from (smoke-container p99s sit inside the top edge)."""
    assert max(obs.TTFT_BUCKETS) >= 12.0
    assert max(obs.E2E_BUCKETS) >= 18.0
    assert max(obs.DISPATCH_BUCKETS) >= 5.0
    for fam in (obs.TTFT_BUCKETS, obs.E2E_BUCKETS, obs.DISPATCH_BUCKETS,
                obs.QOR_MAE_BUCKETS):
        assert list(fam) == sorted(fam) and len(set(fam)) == len(fam)


# ---------------------------------------------------------------------------
# scrape + snapshot under concurrent metric writes (daemon-thread race)
# ---------------------------------------------------------------------------

def test_scrape_and_snapshot_race_with_writers(tmp_path):
    reg = obs.MetricsRegistry()
    c = reg.counter("race_total", "h")
    h = reg.histogram("race_seconds", "h", buckets=(0.1, 1.0))
    stop = threading.Event()

    def writer(i):
        k = 0
        while not stop.is_set():
            c.inc(1, worker=str(i))
            h.observe(0.05 if k % 2 else 5.0, worker=str(i))
            k += 1

    threads = [threading.Thread(target=writer, args=(i,), daemon=True)
               for i in range(4)]
    for t in threads:
        t.start()
    path = str(tmp_path / "race.jsonl")
    try:
        with obs.start_metrics_server(0, reg, host="127.0.0.1") as srv:
            url = f"http://127.0.0.1:{srv.port}/metrics"
            for _ in range(20):
                body = urllib.request.urlopen(url, timeout=10).read().decode()
                assert "race_total" in body
                obs.write_snapshot(path, reg, run="race")
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=5)
    # every snapshot line parses and is internally consistent: cumulative
    # bucket counts monotone, +Inf bucket == count (no torn histogram rows)
    lines = [json.loads(s) for s in open(path).read().splitlines()]
    assert len(lines) == 20
    for snap in lines:
        for series in snap["metrics"]["race_seconds"]["series"].values():
            counts = [n for _, n in series["buckets"]]
            assert counts == sorted(counts)
            assert counts[-1] == series["count"]


# ---------------------------------------------------------------------------
# correlation ids + attribution through the real scheduler
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_serve():
    import jax

    import repro.configs as CFG
    from repro.configs.base import AxPolicy
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(
        cfg, n_layers=2, ax=AxPolicy(mult_name="mul8s_trunc0_4",
                                     backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _batcher(cfg, params, **kw):
    import repro.runtime as R
    from repro.fleet import BatcherConfig, ContinuousBatcher

    ctrl = R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10 ** 6,
                             tile_rows=kw.pop("tile_rows", 0)))
    return ContinuousBatcher(
        params, cfg,
        BatcherConfig(n_slots=2, prompt_buckets=(8,), new_token_bucket=4,
                      token_granular=True, **kw),
        adaptive=ctrl)


def _submit_n(bat, cfg, n, rng, max_new=3):
    from repro.fleet import Request

    for rid in range(n):
        bat.submit(Request(rid, rng.integers(0, cfg.vocab, 6),
                           max_new=max_new))


def test_corr_ids_unique_across_splices_and_drains(tiny_serve):
    """rids recur across drains (and splice mid-flight within one); the
    arrival-stamped correlation ids must never collide."""
    cfg, params = tiny_serve
    bat = _batcher(cfg, params)
    rng = np.random.default_rng(0)
    _submit_n(bat, cfg, 5, rng)        # 5 requests on 2 slots: splices
    done1 = bat.run()
    _submit_n(bat, cfg, 5, rng)        # SAME rids, second drain
    done2 = bat.run()
    assert bat.stats["splices"] >= 1
    corrs = [c.corr for c in done1 + done2]
    assert all(c is not None for c in corrs)
    assert len(set(corrs)) == len(corrs) == 10
    rids = {c.rid for c in done1 + done2}
    assert rids == set(range(5))       # rid reuse is real, corr saved us


def test_every_completion_carries_qor_summary(tiny_serve):
    cfg, params = tiny_serve
    bat = _batcher(cfg, params, tile_rows=2)
    rng = np.random.default_rng(1)
    _submit_n(bat, cfg, 4, rng)
    done = bat.run()
    assert len(done) == 4
    for c in done:
        assert c.qor is not None and c.qor["corr"] == c.corr
        assert c.qor["basis"] == "request" and c.qor["steps"] >= 1
        tops = c.qor["top"]
        assert tops and abs(sum(e["share"] for e in tops) - 1.0) < 1e-6
        assert all("top_tile" in e and 0.0 < e["tile_share"] <= 1.0
                   for e in tops)
        # per-target tile vectors: list-of-float, tile count > 1
        assert all(len(v) > 1 for v in c.qor["tiles"].values())
    assert bat.qor.describe().startswith("qor finished=4")


def test_one_token_requests_get_fleet_basis(tiny_serve):
    cfg, params = tiny_serve
    bat = _batcher(cfg, params)
    rng = np.random.default_rng(2)
    _submit_n(bat, cfg, 2, rng, max_new=3)     # build fleet profile
    bat.run()
    _submit_n(bat, cfg, 2, rng, max_new=1)     # decode 1 step then retire
    done = bat.run()
    for c in done:
        assert c.qor is not None
        assert c.qor["basis"] in ("request", "fleet")
        assert c.qor["top"]


def test_wave_mode_carries_corr_but_no_qor(tiny_serve):
    from repro.fleet import BatcherConfig, ContinuousBatcher, Request

    cfg, params = tiny_serve
    bat = ContinuousBatcher(
        params, cfg, BatcherConfig(n_slots=2, prompt_buckets=(8,),
                                   new_token_bucket=4))
    rng = np.random.default_rng(3)
    bat.submit(Request(0, rng.integers(0, cfg.vocab, 6), max_new=3))
    done = bat.run()
    assert done[0].corr is not None
    assert done[0].qor is None         # the oracle stays uninstrumented


def test_latency_summary_bucketed_twins(tiny_serve):
    cfg, params = tiny_serve
    bat = _batcher(cfg, params)
    rng = np.random.default_rng(4)
    _submit_n(bat, cfg, 3, rng)
    bat.run()
    s = bat.latency_summary()
    for k in ("e2e_p50", "e2e_p99", "ttft_p50", "ttft_p99"):
        assert k in s                  # exact order statistics stay
        assert f"{k}_bucketed" in s and f"{k}_resolution" in s
        if s[f"{k}_resolution"] != float("inf"):
            # the bucket read sits within one stated resolution of exact
            assert abs(s[f"{k}_bucketed"] - s[k]) <= s[f"{k}_resolution"]


# ---------------------------------------------------------------------------
# SLO engine wired to scheduler + controller (veto + re-arm paths)
# ---------------------------------------------------------------------------

def test_scheduler_feeds_latency_slos(tiny_serve):
    cfg, params = tiny_serve
    bat = _batcher(cfg, params)
    eng = obs.SLOEngine(obs.default_serving_slos())
    bat.attach_slo(eng)
    rng = np.random.default_rng(5)
    _submit_n(bat, cfg, 3, rng)
    bat.run()
    assert eng.events("ttft") == 3 and eng.events("e2e") == 3


def test_controller_slo_veto_blocks_canary_promotion(tmp_path):
    import repro.runtime as R
    from repro.fleet import PolicyStore

    store = PolicyStore(str(tmp_path))
    ctrl = R.AdaptiveController(
        R.SwapPolicy("mul8u_trunc0_4", configs={"*": None}),
        targets=("stream",),
        cfg=R.AdaptiveConfig(decay=0.4, drift_threshold=10.0,
                             min_observe_steps=1, cooldown_steps=0,
                             buffer_size=1024, canary=True),
        store=store)
    ctrl.warmup()
    ctrl.resume_from_store()
    eng = obs.SLOEngine([obs.SLOSpec(
        name="qor_stream", kind="qor", source="stream", threshold=0.0,
        objective=0.1, short_window=4, long_window=4, min_events=2,
        veto_promotion=True)], audit=ctrl.audit)
    ctrl.attach_slo(eng)
    rng = np.random.default_rng(5)
    for _ in range(4):
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    assert eng.vetoes_promotion() == "qor_stream"
    ev = ctrl.retune("stream")
    assert ev.promoted is False
    assert ctrl.policy.lookup("stream") is None          # incumbent kept
    assert store.current_version() == 1                  # CURRENT untouched
    assert store.candidate_version() is None             # candidate dropped
    events = ctrl.audit.read()
    veto = [e for e in events if e["kind"] == "slo_veto"]
    assert veto and veto[0]["vetoed_by"] == "qor_stream"
    assert any(e["kind"] == "slo_alert" for e in events)
