"""Token-granular serving (PR 5): per-slot cache positions, pad-mask
prefill, done-flag gating, and mid-flight admission.

Layers: vector ``cache_index`` must be value-identical to the scalar path
and per-slot writes maskable.  Engine: pad-masked prompts must generate
bit-identically to the same prompt served unpadded, the fused scans must
honor per-slot budgets, and the fused/stepwise paths must stay mutual
oracles.  Scheduler: token-granular draining must reproduce the wave
oracle's per-request tokens bit-exactly on mixed-length traces with zero
recompiles across splices and policy updates; idle wave slots must
backfill from the next FIFO bucket.  The forced-8-device mesh variant runs
in a subprocess (multidevice lane).
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.fleet import BatcherConfig, ContinuousBatcher, Request

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _model(backend="mxu", n_layers=2):
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=n_layers,
                              ax=AxPolicy(backend=backend))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _controller(cfg, **kw):
    kw.setdefault("cfg", R.AdaptiveConfig(min_observe_steps=10 ** 6))
    return R.AdaptiveController(R.SwapPolicy.from_ax_policy(cfg.ax),
                                targets=cfg.ax.targets, **kw)


# ---------------------------------------------------------------------------
# layers: vector cache_index == scalar path; write_mask keeps slots inert
# ---------------------------------------------------------------------------

def test_vector_cache_index_matches_scalar():
    from repro.models import decode_step, prefill

    cfg, params = _model()
    rng = np.random.default_rng(0)
    B, S = 3, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    _, cache = prefill(params, {"tokens": toks}, cfg, max_cache_len=S + 4)
    t = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    l_s, c_s = decode_step(params, cache, t, jnp.int32(S), cfg)
    l_v, c_v = decode_step(params, cache, t, jnp.full((B,), S, jnp.int32),
                           cfg, write_mask=jnp.ones((B,), bool))
    assert np.array_equal(np.asarray(l_s), np.asarray(l_v))
    assert jax.tree.all(jax.tree.map(
        lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)), c_s, c_v))


def test_write_mask_keeps_retired_slot_cache_inert():
    from repro.models import decode_step, prefill

    cfg, params = _model()
    rng = np.random.default_rng(1)
    B, S = 3, 8
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    _, cache = prefill(params, {"tokens": toks}, cfg, max_cache_len=S + 4)
    t = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    mask = jnp.asarray([True, False, True])
    _, c_m = decode_step(params, cache, t, jnp.full((B,), S, jnp.int32),
                         cfg, write_mask=mask)
    for (path, old), new in zip(
            jax.tree_util.tree_flatten_with_path(cache)[0],
            jax.tree.leaves(c_m)):
        bdim = 1 if getattr(path[0], "key", None) == "stack" else 0
        old, new = np.asarray(old), np.asarray(new)
        assert np.array_equal(old.take(1, bdim), new.take(1, bdim)), path
        assert not np.array_equal(old.take(0, bdim), new.take(0, bdim)), path


# ---------------------------------------------------------------------------
# pad-mask prefill: bit-identical logits at every bucket size, all backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["mxu", "emul", "kernel"])
def test_padmask_prefill_bit_identical_all_buckets(backend):
    """ISSUE satellite: a padded prompt's logits at its real positions must
    equal the unpadded run bit-for-bit, at every bucket size, on all three
    SWAPPER backends."""
    from repro.models import prefill

    cfg, params = _model(backend=backend, n_layers=1 if backend == "kernel" else 2)
    rng = np.random.default_rng(2)
    B, L = 2, 5
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32)
    max_len = 24
    lg_ref, _ = prefill(params, {"tokens": prompt}, cfg, max_cache_len=max_len,
                        prompt_lens=jnp.full((B,), L, jnp.int32))
    buckets = (8, 16) if backend == "kernel" else (6, 8, 12, 16)
    for bucket in buckets:
        padded = jnp.concatenate(
            [prompt, jnp.broadcast_to(prompt[:, -1:], (B, bucket - L))], axis=1)
        lg, _ = prefill(params, {"tokens": padded}, cfg, max_cache_len=max_len,
                        prompt_lens=jnp.full((B,), L, jnp.int32))
        assert np.array_equal(np.asarray(lg_ref), np.asarray(lg[:, :L])), (
            backend, bucket)


def test_padmask_generate_matches_unpadded_per_request():
    """Mixed-length padded batch: every slot's generation equals the same
    prompt served alone and unpadded (greedy)."""
    from repro.serve import ServeConfig, generate

    cfg, params = _model()
    rng = np.random.default_rng(3)
    B, bucket, T = 4, 12, 6
    lens = np.asarray([4, 7, 12, 9], np.int32)
    prompts = [rng.integers(0, cfg.vocab, int(L)).astype(np.int32)
               for L in lens]
    batch = np.stack([np.concatenate([p, np.full(bucket - len(p), p[-1],
                                                 np.int32)])
                      for p in prompts])
    max_len = bucket + T + 1
    out = np.asarray(generate(
        params, {"tokens": jnp.asarray(batch)}, cfg,
        ServeConfig(max_new_tokens=T), prompt_lens=lens,
        max_cache_len=max_len))
    for i, p in enumerate(prompts):
        solo = np.asarray(generate(
            params, {"tokens": jnp.asarray(p[None])}, cfg,
            ServeConfig(max_new_tokens=T),
            prompt_lens=np.asarray([len(p)], np.int32),
            max_cache_len=max_len))
        assert np.array_equal(out[i], solo[0]), i


def test_slot_budgets_freeze_and_match_oracle():
    """Per-slot done-flags: a retired slot's token freezes; active prefixes
    are unaffected; fused and stepwise paths agree bit-for-bit."""
    from repro.serve import ServeConfig, generate

    cfg, params = _model()
    rng = np.random.default_rng(4)
    B, S, T = 3, 8, 7
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                    jnp.int32)}
    budgets = np.asarray([2, T, 5], np.int32)
    scfg = ServeConfig(max_new_tokens=T)
    full = np.asarray(generate(params, prompt, cfg, scfg))
    out_f = np.asarray(generate(params, prompt, cfg, scfg,
                                slot_new_tokens=budgets))
    out_s = np.asarray(generate(
        params, prompt, cfg, dataclasses.replace(scfg, fused=False),
        slot_new_tokens=budgets))
    assert np.array_equal(out_f, out_s)
    for b in range(B):
        n = int(budgets[b])
        assert np.array_equal(out_f[b, :n], full[b, :n]), b   # live prefix
        assert (out_f[b, n:] == out_f[b, n - 1]).all(), b     # frozen tail


def test_adaptive_fused_with_budgets_matches_stepwise():
    """The adaptive scan's telemetry gating under per-slot budgets mirrors
    the stepwise loop (tokens + telemetry bit-identical)."""
    from repro.serve import ServeConfig, generate

    cfg, params = _model()
    rng = np.random.default_rng(5)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 8)),
                                    jnp.int32)}
    budgets = np.asarray([3, 6], np.int32)
    cA, cB = _controller(cfg), _controller(cfg)
    kw = dict(max_new_tokens=6, observe_every=2)
    o_loop = generate(params, prompt, cfg, ServeConfig(fused=False, **kw),
                      adaptive=cA, slot_new_tokens=budgets)
    o_scan = generate(params, prompt, cfg, ServeConfig(fused=True, **kw),
                      adaptive=cB, slot_new_tokens=budgets)
    assert np.array_equal(np.asarray(o_loop), np.asarray(o_scan))
    sA, sB = cA.telemetry.snapshot(), cB.telemetry.snapshot()
    assert set(sA) == set(sB)
    for t in sA:
        for f in ("mae", "wce", "ep", "n", "n_steps"):
            assert sA[t][f] == sB[t][f], (t, f)
        assert np.array_equal(sA[t]["bit_probs"], sB[t]["bit_probs"]), t


# ---------------------------------------------------------------------------
# scheduler: token-granular vs wave oracle, backfill, zero recompiles
# ---------------------------------------------------------------------------

def _mixed_trace(cfg, n_req, seed=7, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid, rng.integers(0, cfg.vocab, int(rng.integers(3, 17))),
                    max_new=int(rng.integers(1, max_new + 1)))
            for rid in range(n_req)]


def _serve(params, cfg, token_granular, trace, adaptive, n_slots=3, T=6):
    bcfg = BatcherConfig(n_slots=n_slots, prompt_buckets=(8, 16),
                         new_token_bucket=T, token_granular=token_granular)
    bat = ContinuousBatcher(params, cfg, bcfg, adaptive=adaptive)
    for r in trace:
        bat.submit(Request(r.rid, np.asarray(r.tokens).copy(), r.max_new))
    done = bat.run()
    return {c.rid: c.tokens.tolist() for c in done}, bat


def test_token_granular_matches_wave_oracle_bit_exact():
    """ISSUE acceptance: same prompts, same seeds => identical per-request
    tokens between token-granular and wave-granular modes on a mixed-length
    trace, with mid-flight admissions actually happening and occupancy at
    least the wave mode's."""
    from repro.serve import engine as E

    cfg, params = _model()
    trace = _mixed_trace(cfg, 10)
    wave, wave_bat = _serve(params, cfg, False, trace, _controller(cfg))
    n_fns0 = len(E._TOKEN_FNS)
    tok, tok_bat = _serve(params, cfg, True, trace, _controller(cfg))
    assert set(wave) == set(tok) == {r.rid for r in trace}
    for rid in wave:
        assert wave[rid] == tok[rid], rid
    assert tok_bat.stats["splices"] > 0          # admission was mid-flight
    assert tok_bat.occupancy() >= wave_bat.occupancy()
    # one compiled step program for the whole trace (splices retrace nothing)
    new_fns = list(E._TOKEN_FNS.values())[n_fns0:]
    assert len(new_fns) == 1 and new_fns[0]._cache_size() == 1

    # a policy update between traces also reuses the program
    ctrl = _controller(cfg)
    ctrl.policy.set_config("mlp", C.SwapConfig("B", 5, 1))
    tok2, _ = _serve(params, cfg, True, trace, ctrl)
    assert new_fns[0]._cache_size() == 1
    assert any(tok2[r] != tok[r] for r in tok)   # the policy actually bites


def test_token_granular_without_adaptive():
    """The non-adaptive token step (static policy) drains correctly too."""
    cfg, params = _model()
    trace = _mixed_trace(cfg, 6, seed=9)
    wave, _ = _serve(params, cfg, False, trace, None)
    tok, bat = _serve(params, cfg, True, trace, None)
    assert wave == tok
    assert bat.stats["requests"] == 6


def test_wave_backfills_idle_slots_from_next_fifo_bucket():
    """ISSUE satellite: idle slots admit the next FIFO requests from other
    buckets (outputs kept) instead of cycling already-admitted prompts."""
    cfg, params = _model()
    rng = np.random.default_rng(11)
    bcfg = BatcherConfig(n_slots=4, prompt_buckets=(8, 16),
                         new_token_bucket=4)
    bat = ContinuousBatcher(params, cfg, bcfg, adaptive=_controller(cfg))
    # one long request (bucket 16) then three short ones (bucket 8): the
    # first wave picks bucket 16 and backfills its 3 idle slots with the
    # short requests, draining everything in ONE wave
    bat.submit(Request(0, rng.integers(0, cfg.vocab, 12), max_new=3))
    for rid in (1, 2, 3):
        bat.submit(Request(rid, rng.integers(0, cfg.vocab, 5), max_new=2))
    done = bat.run()
    assert sorted(c.rid for c in done) == [0, 1, 2, 3]
    assert bat.stats["waves"] == 1
    assert bat.stats["backfilled"] == 3
    assert bat.stats["filler_tokens"] == 0
    # backfilled outputs are real: rid 1 equals its solo-served tokens
    solo = ContinuousBatcher(params, cfg,
                             BatcherConfig(n_slots=4, prompt_buckets=(8, 16),
                                           new_token_bucket=4),
                             adaptive=_controller(cfg))
    rng = np.random.default_rng(11)
    rng.integers(0, cfg.vocab, 12)
    p1 = rng.integers(0, cfg.vocab, 5)
    solo.submit(Request(1, p1, max_new=2))
    (c1,) = solo.run()
    got = {c.rid: c.tokens for c in done}
    assert np.array_equal(got[1], c1.tokens)


def test_wave_retire_order_and_budget_assert():
    cfg, params = _model()
    bat = ContinuousBatcher(
        params, cfg,
        BatcherConfig(n_slots=2, prompt_buckets=(8,), new_token_bucket=4),
        adaptive=_controller(cfg))
    rng = np.random.default_rng(2)
    for rid in range(5):
        bat.submit(Request(rid, rng.integers(0, cfg.vocab,
                                             int(rng.integers(2, 9))),
                           max_new=int(rng.integers(1, 5))))
    with pytest.raises(AssertionError):
        bat.submit(Request(99, np.zeros(4, np.int32), max_new=5))
    done = bat.run()
    assert [c.rid for c in done] == list(range(5))


# ---------------------------------------------------------------------------
# 8-device mesh: token-granular splicing under shard_map
# ---------------------------------------------------------------------------

def _run_sub(code, timeout=540):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(out.stdout[-2000:])


_TOKEN_MESH_SCRIPT = r"""
import dataclasses, json
import jax, numpy as np
import repro.configs as CFG
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.fleet import BatcherConfig, ContinuousBatcher, Request
from repro.launch.mesh import make_fleet_mesh
from repro.models import init_params
from repro.serve import engine as E

cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_fleet_mesh(8)

def ctrl():
    return R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10**6))

def trace():
    rng = np.random.default_rng(7)
    return [Request(rid, rng.integers(0, cfg.vocab, int(rng.integers(3, 17))),
                    max_new=int(rng.integers(1, 5)))
            for rid in range(12)]

def serve(token, mesh_):
    bcfg = BatcherConfig(n_slots=8, prompt_buckets=(8, 16),
                         new_token_bucket=4, token_granular=token)
    bat = ContinuousBatcher(params, cfg, bcfg, adaptive=ctrl(), mesh=mesh_)
    for r in trace():
        bat.submit(Request(r.rid, np.asarray(r.tokens).copy(), r.max_new))
    return {c.rid: c.tokens.tolist() for c in bat.run()}, bat

res = {"devices": jax.device_count()}
wave, _ = serve(False, None)              # single-host wave oracle
tokm, bat = serve(True, mesh)             # sharded token-granular
res["tokens_identical"] = bool(wave == tokm)
res["splices"] = bat.stats["splices"]
sizes0 = {k: f._cache_size() for k, f in E._TOKEN_FNS.items()}
c2 = ctrl()
c2.policy.set_config("mlp", __import__("repro.core", fromlist=["x"]).SwapConfig("B", 5, 1))
bcfg = BatcherConfig(n_slots=8, prompt_buckets=(8, 16), new_token_bucket=4,
                     token_granular=True)
bat2 = ContinuousBatcher(params, cfg, bcfg, adaptive=c2, mesh=mesh)
for r in trace():
    bat2.submit(Request(r.rid, np.asarray(r.tokens).copy(), r.max_new))
bat2.run()
res["retrace_free"] = all(f._cache_size() == sizes0[k]
                          for k, f in E._TOKEN_FNS.items())
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.multidevice
def test_token_granular_sharded_matches_wave_oracle_8dev():
    """ISSUE acceptance: on a forced 8-device mesh the token-granular
    batcher (sharded step + mid-flight splices) reproduces the single-host
    wave oracle's per-request tokens bit-exactly with zero recompiles
    across splices and a policy update."""
    r = _run_sub(_TOKEN_MESH_SCRIPT)
    assert r["devices"] == 8
    assert r["tokens_identical"], r
    assert r["splices"] > 0, r
    assert r["retrace_free"], r
