"""Unified observability layer (PR 6): metrics registry semantics,
Prometheus exposition (golden file), trace-span JSON well-formedness, the
retune audit trail, the policy-store heartbeat fast-path, and — the one
that guards the serving guarantees — a regression test that the recompile
gauge stays 0 across token-granular splices and a policy update WITH the
instrumentation live (metrics + trace recorder + compile listener all on),
and that tokens stay bit-identical to the uninstrumented wave oracle.
"""
import dataclasses
import json
import os
import urllib.request

import jax
import numpy as np
import pytest

import repro.core as C
import repro.runtime as R
from repro import obs
from repro.configs.base import AxPolicy
from repro.fleet import (BatcherConfig, ContinuousBatcher, PolicyReader,
                         PolicyStore, Request)

DATA = os.path.join(os.path.dirname(__file__), "data")


# ---------------------------------------------------------------------------
# registry: label-set semantics, declaration rules
# ---------------------------------------------------------------------------

def test_counter_label_sets_and_totals():
    reg = obs.MetricsRegistry()
    c = reg.counter("x_total", "help")
    c.inc(1, mode="wave")
    c.inc(2, mode="token")
    c.inc(3, mode="wave")
    assert c.value(mode="wave") == 4
    assert c.value(mode="token") == 2
    assert c.value(mode="absent") == 0
    assert c.total() == 6
    with pytest.raises(AssertionError):
        c.inc(-1)


def test_label_order_never_matters():
    reg = obs.MetricsRegistry()
    c = reg.counter("y_total", "h")
    c.inc(1, a="1", b="2")
    c.inc(1, b="2", a="1")
    assert c.value(a="1", b="2") == 2
    assert len(c.series()) == 1


def test_declaration_get_or_create_and_mismatch():
    reg = obs.MetricsRegistry()
    c1 = reg.counter("z_total", "same help")
    c2 = reg.counter("z_total", "same help")     # get-or-create: same object
    assert c1 is c2
    with pytest.raises(AssertionError):
        reg.gauge("z_total", "same help")         # type mismatch
    with pytest.raises(AssertionError):
        reg.counter("z_total", "different help")  # help mismatch
    h1 = reg.histogram("h_seconds", "h", buckets=(1.0, 2.0))
    assert reg.histogram("h_seconds", "h", buckets=(2.0, 1.0)) is h1
    with pytest.raises(AssertionError):
        reg.histogram("h_seconds", "h", buckets=(1.0, 3.0))


def test_gauge_set_and_inc():
    reg = obs.MetricsRegistry()
    g = reg.gauge("g", "h")
    g.set(2.5, target="mlp")
    g.inc(0.5, target="mlp")
    g.set(7, target="attn")
    assert g.value(target="mlp") == 3.0
    assert g.value(target="attn") == 7.0


# ---------------------------------------------------------------------------
# histogram: bucket-edge semantics (v <= le), percentiles
# ---------------------------------------------------------------------------

def test_histogram_bucket_edges_inclusive():
    reg = obs.MetricsRegistry()
    h = reg.histogram("lat", "h", buckets=(1.0, 2.0, 5.0))
    for v in (0.5, 1.0, 1.5, 2.0, 5.0, 9.0):     # edge values land IN the
        h.observe(v)                              # edge's bucket (v <= le)
    cum = dict(h.cumulative())
    assert cum[1.0] == 2          # 0.5, 1.0
    assert cum[2.0] == 4          # + 1.5, 2.0
    assert cum[5.0] == 5          # + 5.0
    assert cum[float("inf")] == 6  # + 9.0
    snap = h.snapshot()
    assert snap["count"] == 6 and snap["sum"] == pytest.approx(19.0)


def test_histogram_percentile_bucket_resolution():
    reg = obs.MetricsRegistry()
    h = reg.histogram("p", "h", buckets=(0.01, 0.1, 1.0))
    assert h.percentile(0.5) is None              # empty series
    for _ in range(98):
        h.observe(0.005)
    h.observe(0.5)
    h.observe(50.0)                               # +Inf bucket
    assert h.percentile(0.5) == 0.01
    assert h.percentile(0.99) == 1.0
    assert h.percentile(1.0) == 1.0               # +Inf reports last edge


# ---------------------------------------------------------------------------
# Prometheus exposition: golden file
# ---------------------------------------------------------------------------

def _golden_registry() -> obs.MetricsRegistry:
    reg = obs.MetricsRegistry()
    c = reg.counter("repro_demo_total", "a counter with labels")
    c.inc(3, mode="wave")
    c.inc(1.5, mode="token")
    g = reg.gauge("repro_demo_occupancy", 'quoted "help" with\nnewline')
    g.set(0.75)
    h = reg.histogram("repro_demo_seconds", "a histogram",
                      buckets=(0.1, 1.0, 10.0))
    h.observe(0.05, path="a")
    h.observe(0.5, path="a")
    h.observe(99.0, path="a")
    return reg


def test_prometheus_exposition_matches_golden_file():
    text = obs.prometheus_text(_golden_registry())
    golden = os.path.join(DATA, "metrics_golden.prom")
    with open(golden) as f:
        assert text == f.read()


def test_prometheus_text_deterministic_and_escaped():
    a = obs.prometheus_text(_golden_registry())
    b = obs.prometheus_text(_golden_registry())
    assert a == b
    assert r'quoted \"help\" with\nnewline' in a
    assert 'le="+Inf"' in a
    # cumulative bucket counts, sum/count per series
    assert 'repro_demo_seconds_bucket{path="a",le="0.1"} 1' in a
    assert 'repro_demo_seconds_bucket{path="a",le="+Inf"} 3' in a
    assert 'repro_demo_seconds_count{path="a"} 3' in a


# ---------------------------------------------------------------------------
# /metrics scrape endpoint
# ---------------------------------------------------------------------------

def test_metrics_server_serves_prometheus_text():
    reg = _golden_registry()
    with obs.start_metrics_server(0, reg, host="127.0.0.1") as srv:
        url = f"http://127.0.0.1:{srv.port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
        assert body == obs.prometheus_text(reg)
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{srv.port}/nope", timeout=10)


def test_jsonl_snapshot_roundtrip(tmp_path):
    path = str(tmp_path / "m.jsonl")
    obs.write_snapshot(path, _golden_registry(), run="first")
    obs.write_snapshot(path, _golden_registry(), run="second")
    lines = [json.loads(s) for s in open(path).read().splitlines()]
    assert [s["run"] for s in lines] == ["first", "second"]
    m = lines[0]["metrics"]["repro_demo_seconds"]
    assert m["kind"] == "histogram"
    assert m["series"]["path=a"]["count"] == 3
    assert m["series"]["path=a"]["buckets"][-1] == ["+Inf", 3]


# ---------------------------------------------------------------------------
# trace spans: Chrome-trace JSON well-formedness
# ---------------------------------------------------------------------------

def test_trace_recorder_chrome_format(tmp_path):
    rec = obs.TraceRecorder()
    prev = obs.install_recorder(rec)
    try:
        obs.async_begin("request", 7, prompt_len=5)
        with obs.span("prefill", cat="engine", rid=7):
            with obs.span("inner"):
                pass
        obs.instant("splice", slot=2)
        obs.async_end("request", 7)
    finally:
        obs.install_recorder(prev)
    path = str(tmp_path / "trace.json")
    rec.save(path)
    doc = json.loads(open(path).read())          # well-formed JSON
    evs = doc["traceEvents"]
    assert [e["ph"] for e in evs] == ["b", "X", "X", "i", "e"]
    for e in evs:
        assert {"name", "ph", "cat", "pid", "tid", "ts"} <= set(e)
        json.dumps(e)                             # every event serializable
    (b_ev, inner, outer, inst, e_ev) = evs
    assert b_ev["id"] == e_ev["id"] == "7"
    assert b_ev["args"]["prompt_len"] == 5
    # nested span closed first, and sits inside the outer span's interval
    assert inner["name"] == "inner" and outer["name"] == "prefill"
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-3
    assert e_ev["ts"] >= b_ev["ts"]


def test_span_without_recorder_is_noop():
    prev = obs.install_recorder(None)
    try:
        with obs.span("anything", rid=1):         # must not raise or record
            obs.instant("x")
            obs.async_begin("r", 1)
            obs.async_end("r", 1)
    finally:
        obs.install_recorder(prev)


# ---------------------------------------------------------------------------
# audit trail
# ---------------------------------------------------------------------------

def test_audit_log_roundtrip_and_seq_resume(tmp_path):
    path = str(tmp_path / "audit.jsonl")
    log = obs.AuditLog(path)
    ev0 = log.append("retune", target="mlp", drift=0.05, store_version=1)
    ev1 = log.append("tile_retune", target="attn_out",
                     grid_digest=obs.grid_digest(np.arange(12).reshape(4, 1, 3)))
    assert (ev0["seq"], ev1["seq"]) == (0, 1)
    got = log.read()
    assert [e["kind"] for e in got] == ["retune", "tile_retune"]
    assert got[0]["drift"] == 0.05 and got[0]["store_version"] == 1
    # a reopened log continues the sequence; a torn tail line is skipped
    with open(path, "a") as f:
        f.write('{"seq": 2, "kind": "torn...')
    log2 = obs.AuditLog(path)
    ev2 = log2.append("retune", target="mlp")
    assert ev2["seq"] == 2
    assert len(log2.read()) == 3                  # torn line dropped


def test_grid_digest_stable_and_shape_sensitive():
    g = np.arange(12, dtype=np.int32).reshape(4, 1, 3)
    assert obs.grid_digest(g) == obs.grid_digest(g.copy())
    assert obs.grid_digest(g) != obs.grid_digest(g.reshape(2, 2, 3))
    assert len(obs.grid_digest(g)) == 12


def test_controller_retune_writes_audit_event(tmp_path):
    """A store-backed controller's re-tune appends one structured audit
    event carrying the published store version."""
    store = PolicyStore(str(tmp_path / "store"))
    policy = R.SwapPolicy(mult_name="mul8s_trunc0_4")
    ctrl = R.AdaptiveController(policy, targets=("mlp",), store=store)
    rng = np.random.default_rng(0)
    ctrl.buffers["mlp"].add(rng.integers(-100, 100, 512),
                            rng.integers(-100, 100, 512))
    ev = ctrl.retune("mlp", drift=0.123)
    events = ctrl.audit.read()
    assert len(events) == 1
    e = events[0]
    assert e["kind"] == "retune" and e["target"] == "mlp"
    assert e["drift"] == pytest.approx(0.123)
    assert e["store_version"] == store.current_version()
    assert e["predicted_gain"] == pytest.approx(ev.old_score - ev.new_score)
    assert os.path.exists(os.path.join(store.root, obs.AUDIT_FILENAME))


# ---------------------------------------------------------------------------
# store heartbeat fast-path + staleness disambiguation
# ---------------------------------------------------------------------------

def test_heartbeat_mtime_is_version_and_monotonic(tmp_path):
    store = PolicyStore(str(tmp_path / "s"))
    p = R.SwapPolicy(mult_name="mul8s_trunc0_4")
    assert store.heartbeat_ns() is None           # nothing published
    v1 = store.publish(p)
    assert store.heartbeat_ns() == v1
    v2 = store.publish(p)                         # same-instant publishes
    assert store.heartbeat_ns() == v2 == v1 + 1   # still distinct signals


def test_reader_poll_fast_paths_on_heartbeat(tmp_path, monkeypatch):
    store = PolicyStore(str(tmp_path / "s"))
    p = R.SwapPolicy(mult_name="mul8s_trunc0_4")
    store.publish(p)
    reader = PolicyReader(store, targets=("mlp",), name="r0")
    assert reader.version == 1
    calls = {"n": 0}
    orig = store.current_version

    def counting():
        calls["n"] += 1
        return orig()

    monkeypatch.setattr(store, "current_version", counting)
    for _ in range(5):
        assert reader.poll() is False             # heartbeat unchanged:
    assert calls["n"] == 0                        # CURRENT never read
    store.publish(p)
    assert reader.poll() is True                  # heartbeat moved: full poll
    assert calls["n"] >= 1
    assert reader.version == 2


def test_reader_without_heartbeat_still_polls(tmp_path):
    """Pre-heartbeat store layouts (no HEARTBEAT file) keep working: every
    poll takes the full path."""
    store = PolicyStore(str(tmp_path / "s"))
    p = R.SwapPolicy(mult_name="mul8s_trunc0_4")
    store.publish(p)
    os.remove(os.path.join(store.root, "HEARTBEAT"))
    reader = PolicyReader(store, targets=("mlp",), name="r0")
    assert reader.version == 1
    store.publish(p)
    os.remove(os.path.join(store.root, "HEARTBEAT"))
    assert reader.poll() is True
    assert reader.version == 2


def test_staleness_distinguishes_empty_store_from_behind(tmp_path):
    reg = obs.default_registry()
    published = reg.get("repro_policy_store_published")
    store = PolicyStore(str(tmp_path / "s"))
    reader = PolicyReader(store, targets=("mlp",), name="rx")
    # empty store: staleness 0 is vacuous; the published gauge says WHY
    assert reader.staleness() == 0
    assert reg.get("repro_replica_staleness").value(replica="rx") == 0
    p = R.SwapPolicy(mult_name="mul8s_trunc0_4")
    v1 = store.publish(p)
    assert published.value() == v1
    assert reader.staleness() == 1                # now genuinely behind
    store.publish(p)
    assert reader.staleness() == 2
    reader.poll()
    assert reader.staleness() == 0
    assert reg.get("repro_replica_staleness").value(replica="rx") == 0


# ---------------------------------------------------------------------------
# recompile accounting: the gauge guards the serving guarantees
# ---------------------------------------------------------------------------

def _tiny_model():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(
        cfg, n_layers=2,
        ax=AxPolicy(mult_name="mul8s_trunc0_4", backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _ctrl(cfg):
    return R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10 ** 6))


def _serve(params, cfg, token_granular, trace, adaptive):
    bcfg = BatcherConfig(n_slots=2, prompt_buckets=(8, 16),
                         new_token_bucket=4, token_granular=token_granular)
    bat = ContinuousBatcher(params, cfg, bcfg, adaptive=adaptive)
    for r in trace:
        bat.submit(Request(r.rid, np.asarray(r.tokens).copy(), r.max_new))
    done = bat.run()
    return {c.rid: c.tokens.tolist() for c in done}, bat


def test_recompile_gauge_zero_across_splices_and_policy_update():
    """ISSUE acceptance: with ALL instrumentation live (metrics, trace
    recorder, jax.monitoring compile listener), a token-granular drain with
    mid-flight splices followed by a policy-update drain keeps the
    recompile gauge at zero post-warmup — and per-request tokens stay
    bit-identical to the wave oracle, proving instrumentation is host-side
    only."""
    cfg, params = _tiny_model()
    rng = np.random.default_rng(7)
    trace = [Request(rid, rng.integers(0, cfg.vocab, int(rng.integers(3, 17))),
                     max_new=int(rng.integers(1, 5)))
             for rid in range(8)]

    wave, _ = _serve(params, cfg, False, trace, _ctrl(cfg))

    obs.install_jax_compile_listener()
    rec = obs.TraceRecorder()
    prev = obs.install_recorder(rec)
    try:
        tok, bat = _serve(params, cfg, True, trace, _ctrl(cfg))
    finally:
        obs.install_recorder(prev)
    assert wave == tok                       # bit-identity with obs live
    assert bat.stats["splices"] > 0
    assert bat.stats["decode_retraces_post_warmup"] == 0
    reg = obs.default_registry()
    assert reg.get("repro_decode_retraces_post_warmup").value() == 0
    assert reg.get("repro_splices_total").total() >= 1
    # the drain's timeline actually recorded spans
    names = {e["name"] for e in rec.events()}
    assert {"admit", "token_step", "request"} <= names

    # a policy update between drains must not move the retrace counter
    before = obs.retrace_total("token_step")
    ctrl = _ctrl(cfg)
    ctrl.policy.set_config("mlp", C.SwapConfig("B", 5, 1))
    tok2, bat2 = _serve(params, cfg, True, trace, ctrl)
    assert obs.retrace_total("token_step") == before
    assert bat2.stats["decode_retraces_post_warmup"] == 0
    assert any(tok2[r] != tok[r] for r in tok)   # the policy actually bites


def test_latency_log_and_summary_populated():
    cfg, params = _tiny_model()
    rng = np.random.default_rng(3)
    trace = [Request(rid, rng.integers(0, cfg.vocab, 6), max_new=3)
             for rid in range(4)]
    _, bat = _serve(params, cfg, True, trace, _ctrl(cfg))
    assert len(bat.request_log) == 4
    for r in bat.request_log:
        assert r["ttft"] is not None and 0 <= r["ttft"] <= r["e2e"]
    s = bat.latency_summary()
    assert s["requests"] == 4
    assert s["ttft_p50"] <= s["ttft_p99"]
    assert s["e2e_p50"] <= s["e2e_p99"]
