"""Docs integrity: every relative markdown link in README/docs resolves,
and every fenced ``python`` snippet in docs/ actually runs (the snippets
are the documentation's executable examples — this is what keeps them from
rotting silently; CI additionally runs examples/quickstart.py)."""
import os
import re

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_MD_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(ROOT, "docs"))
    if f.endswith(".md")
)

# [text](target) — inline markdown links
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _links(md_path):
    with open(os.path.join(ROOT, md_path)) as f:
        text = f.read()
    # drop fenced code blocks: link syntax inside code is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return _LINK.findall(text)


@pytest.mark.parametrize("md", _MD_FILES)
def test_markdown_links_resolve(md):
    base = os.path.dirname(os.path.join(ROOT, md))
    missing = []
    for target in _links(md):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#")[0]
        if not path:
            continue
        if not os.path.exists(os.path.join(base, path)):
            missing.append(target)
    assert not missing, f"{md}: broken relative links {missing}"


def _snippets():
    out = []
    for md in _MD_FILES:
        if not md.startswith("docs"):
            continue
        with open(os.path.join(ROOT, md)) as f:
            for i, block in enumerate(_FENCE.findall(f.read())):
                out.append(pytest.param(block, id=f"{os.path.basename(md)}-{i}"))
    return out


@pytest.mark.parametrize("code", _snippets())
def test_docs_snippets_run(code):
    """Each docs/ snippet is self-contained and executable as written."""
    exec(compile(code, "<docs-snippet>", "exec"), {"__name__": "__docs__"})
