"""Optional-hypothesis shim: re-exports ``given``/``settings``/``st`` when the
dependency is installed; otherwise provides stand-ins that mark property tests
as skipped so the rest of the suite still runs (tier-1 must not require dev
extras)."""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _Strategy:
        """Inert placeholder accepted by the stub ``given``."""

    class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
        @staticmethod
        def sampled_from(*_a, **_k):
            return _Strategy()

        @staticmethod
        def integers(*_a, **_k):
            return _Strategy()

        @staticmethod
        def booleans(*_a, **_k):
            return _Strategy()
