"""Fleet subsystem: versioned policy store, continuous-batching scheduler,
fused adaptive (telemetry-through-scan-carry) decode, and the sharded psum
telemetry aggregation path.

Multi-device cases run in a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (jax locks the device
count at first init); single-device logic tests run in-process.
"""
import dataclasses
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.fleet import (BatcherConfig, ContinuousBatcher, PolicyReader,
                         PolicyStore, Request)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# policy store: versions, atomicity, single-writer, reader sync
# ---------------------------------------------------------------------------

def _policy(cfg=None):
    return R.SwapPolicy("mul8u_trunc0_4", configs={"*": cfg})


def test_store_versions_monotonic_and_current(tmp_path):
    store = PolicyStore(str(tmp_path))
    assert store.current_version() is None and store.load_current() is None
    p = _policy(C.SwapConfig("A", 3, 0))
    assert store.publish(p) == 1
    p.set_config("mlp", C.SwapConfig("B", 5, 1))
    assert store.publish(p) == 2
    assert store.versions() == [1, 2]
    v, got = store.load_current()
    assert v == 2 and got.version == 2
    assert got.configs_equal(p)
    # version 1 is immutable history
    old = store.load(1)
    assert old.lookup("mlp") == C.SwapConfig("A", 3, 0)   # fallback to "*"


def test_store_single_writer_guard(tmp_path):
    a = PolicyStore(str(tmp_path))
    b = PolicyStore(str(tmp_path))
    a.publish(_policy())
    b.publish(_policy())          # b now owns version 2
    with pytest.raises(RuntimeError, match="single-writer"):
        a.publish(_policy())      # a's view is stale -> split brain detected


def test_store_prune_keeps_current(tmp_path):
    store = PolicyStore(str(tmp_path))
    p = _policy()
    for _ in range(6):
        store.publish(p)
    dropped = store.prune(keep_last=2)
    assert dropped == [1, 2, 3, 4]
    assert store.versions() == [5, 6]
    assert store.load_current()[0] == 6


def test_reader_polls_and_adopts(tmp_path):
    store = PolicyStore(str(tmp_path))
    p = _policy(C.SwapConfig("A", 3, 0))
    store.publish(p)
    reader = PolicyReader(store, ("mlp", "attn_out"))
    assert reader.version == 1
    t1 = reader.dyn_tree()
    assert not reader.poll()                        # no-op: nothing newer
    p.set_config("mlp", C.SwapConfig("B", 1, 1))
    store.publish(p)
    assert reader.poll()
    assert reader.version == 2 and reader.policy.configs_equal(p)
    t2 = reader.dyn_tree()
    # engine contract: same tree structure, values only
    assert jax.tree.structure(t1) == jax.tree.structure(t2)
    assert not np.array_equal(np.asarray(t1["mlp"]), np.asarray(t2["mlp"]))
    assert reader.observe({"mlp": {}}) == []        # replicas drop records


def test_controller_publishes_and_resumes(tmp_path):
    store = PolicyStore(str(tmp_path))
    ctrl = R.AdaptiveController(
        _policy(C.SwapConfig("A", 3, 0)), targets=("stream",), store=store,
        cfg=R.AdaptiveConfig(buffer_size=512))
    assert not ctrl.resume_from_store()             # empty store: publish v1
    assert store.current_version() == 1
    rng = np.random.default_rng(0)
    ctrl.observe_operands("stream", rng.integers(0, 256, 2048),
                          rng.integers(0, 256, 2048))
    ctrl.retune("stream")                           # publishes v2
    assert store.current_version() == 2
    # elastic restart: a fresh controller resumes the adapted policy
    ctrl2 = R.AdaptiveController(_policy(C.SwapConfig("A", 3, 0)),
                                 targets=("stream",), store=store)
    assert ctrl2.resume_from_store()
    assert ctrl2.policy.configs_equal(ctrl.policy)
    assert store.current_version() == 2             # resume never re-publishes


# ---------------------------------------------------------------------------
# host combine oracle == in-graph aggregation (1-shard identity in-process)
# ---------------------------------------------------------------------------

def test_combine_records_sums_max_and_concat():
    from repro.runtime.telemetry import combine_records

    mult = C.get("mul8u_trunc0_4")
    rng = np.random.default_rng(3)
    dyn = jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)
    recs = []
    for s in range(3):
        a = jnp.asarray(rng.integers(0, 256, R.TELEMETRY_SAMPLE), jnp.int32)
        b = jnp.asarray(rng.integers(0, 256, R.TELEMETRY_SAMPLE), jnp.int32)
        rec = jax.device_get(R.operand_summary(a, b, mult, dyn))
        recs.append({"t": {k: np.asarray(v)[None] for k, v in rec.items()}})
    got = combine_records(recs)["t"]
    for k in ("bits_a", "bits_b", "neg_a", "neg_b", "n", "err_lo", "err_hi",
              "err_cnt"):
        expect = sum(np.asarray(r["t"][k]) for r in recs)
        assert np.array_equal(got[k], expect), k
    assert int(got["err_max"][0]) == max(int(r["t"]["err_max"][0]) for r in recs)
    assert got["a_smp"].shape == (3, R.RETUNE_SAMPLE)


def test_sharded_summarizer_single_shard_identity():
    """On a 1-device mesh the psum/pmax/all_gather aggregation must be the
    identity (modulo the call axis) — the bit-exactness base case."""
    from repro.fleet import make_sharded_summarizer

    mesh = jax.make_mesh((1,), ("data",))
    mult = C.get("mul8u_trunc0_4")
    rng = np.random.default_rng(4)
    a = jnp.asarray(rng.integers(0, 256, R.TELEMETRY_SAMPLE), jnp.int32)
    b = jnp.asarray(rng.integers(0, 256, R.TELEMETRY_SAMPLE), jnp.int32)
    dyn = jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)
    f = make_sharded_summarizer(mult.name, mesh)
    got = jax.device_get(f(a, b, dyn))
    ref = jax.device_get(R.operand_summary(a, b, mult, dyn))
    for k, v in ref.items():
        assert np.array_equal(got[k], np.asarray(v)[None]), k


# ---------------------------------------------------------------------------
# fused adaptive decode: scan-carry telemetry == unrolled adaptive loop
# ---------------------------------------------------------------------------

def _tiny_model():
    import repro.configs as CFG
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def _controller(cfg, **kw):
    kw.setdefault("cfg", R.AdaptiveConfig(min_observe_steps=10 ** 6))
    return R.AdaptiveController(R.SwapPolicy.from_ax_policy(cfg.ax),
                                targets=cfg.ax.targets, **kw)


@pytest.mark.parametrize("k_obs", [1, 3])
def test_fused_adaptive_matches_unrolled_loop(k_obs):
    """ISSUE acceptance: the telemetry-through-scan-carry decode produces the
    same tokens AND the same telemetry as the stepwise adaptive loop."""
    from repro.serve import ServeConfig, generate

    cfg, params = _tiny_model()
    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)),
                                    jnp.int32)}
    cA, cB = _controller(cfg), _controller(cfg)
    kw = dict(max_new_tokens=10, observe_every=k_obs)
    o_loop = generate(params, prompt, cfg, ServeConfig(fused=False, **kw),
                      adaptive=cA)
    o_scan = generate(params, prompt, cfg, ServeConfig(fused=True, **kw),
                      adaptive=cB)
    assert np.array_equal(np.asarray(o_loop), np.asarray(o_scan))
    sA, sB = cA.telemetry.snapshot(), cB.telemetry.snapshot()
    assert set(sA) == set(sB) == set(cfg.ax.targets)
    for t in sA:
        for f in ("mae", "wce", "ep", "n", "n_steps", "ew_mae"):
            assert sA[t][f] == sB[t][f], (t, f)
        assert np.array_equal(sA[t]["bit_probs"], sB[t]["bit_probs"]), t


def test_fused_adaptive_policy_update_no_retrace():
    """One compiled scan serves every policy (re-tunes between generations
    change traced int32 values only)."""
    from repro.serve import ServeConfig, generate
    from repro.serve import engine as E

    cfg, params = _tiny_model()
    rng = np.random.default_rng(1)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 12)),
                                    jnp.int32)}
    ctrl = _controller(cfg)
    scfg = ServeConfig(max_new_tokens=8)
    before = len(E._ADAPTIVE_FNS)
    o1 = generate(params, prompt, cfg, scfg, adaptive=ctrl)
    ctrl.policy.set_config("mlp", C.SwapConfig("B", 5, 1))
    o2 = generate(params, prompt, cfg, scfg, adaptive=ctrl)
    new = [f for k, f in E._ADAPTIVE_FNS.items()][before:]
    assert len(new) == 1 and new[0]._cache_size() == 1
    assert not np.array_equal(np.asarray(o1), np.asarray(o2))  # policy bites


# ---------------------------------------------------------------------------
# continuous-batching scheduler
# ---------------------------------------------------------------------------

def test_scheduler_bucketing_and_padding():
    bat = ContinuousBatcher.__new__(ContinuousBatcher)   # logic-only instance
    bat.queues = {8: __import__("collections").deque(),
                  16: __import__("collections").deque()}
    assert bat.bucket_of(3) == 8 and bat.bucket_of(8) == 8
    assert bat.bucket_of(9) == 16
    with pytest.raises(ValueError):
        bat.bucket_of(17)
    padded = bat._pad(np.asarray([5, 6, 7], np.int32), 8)
    assert padded.tolist() == [5, 6, 7, 7, 7, 7, 7, 7]   # repeat-last padding


def test_scheduler_serves_all_requests_fifo():
    cfg, params = _tiny_model()
    bat = ContinuousBatcher(
        params, cfg,
        BatcherConfig(n_slots=2, prompt_buckets=(8,), new_token_bucket=4),
        adaptive=_controller(cfg))
    rng = np.random.default_rng(2)
    for rid in range(5):
        bat.submit(Request(rid, rng.integers(0, cfg.vocab, int(rng.integers(2, 9))),
                           max_new=int(rng.integers(1, 5))))
    with pytest.raises(AssertionError):                  # over token budget
        bat.submit(Request(99, np.zeros(4, np.int32), max_new=5))
    done = bat.run()
    assert sorted(c.rid for c in done) == list(range(5))
    assert [c.rid for c in done] == sorted(c.rid for c in done)  # FIFO retire
    for c in done:
        assert c.tokens.shape[0] <= 4
    assert bat.stats["waves"] == 3                        # ceil(5/2)
    assert bat.stats["filler_tokens"] > 0                 # odd request padded


# ---------------------------------------------------------------------------
# perf regression gate
# ---------------------------------------------------------------------------

def test_regress_gate_detects_counter_regressions():
    from benchmarks.regress import check

    base = {"matmul_dispatch": {"static_stacked": {"dot_generals": 1},
                                "dyn_stacked": {"dot_generals": 1}},
            "kernel_reduction": {"slab8_reduction_steps_per_tile": 16},
            "decode": {"bit_identical": True}}
    good = json.loads(json.dumps(base))
    good["fleet"] = {"adaptive_decode": {
        "fused_dispatch_per_gen": 1, "bit_identical": True,
        "telemetry_identical": True, "retrace_free": True}}
    failures, notes = check(good, base)
    assert failures == [] and notes            # fleet keys absent in base: ok
    bad = json.loads(json.dumps(good))
    bad["matmul_dispatch"]["dyn_stacked"]["dot_generals"] = 2
    bad["fleet"]["adaptive_decode"]["telemetry_identical"] = False
    failures, _ = check(bad, base)
    assert len(failures) == 2
    assert any("dyn_stacked" in f for f in failures)
    assert any("telemetry_identical" in f for f in failures)


# ---------------------------------------------------------------------------
# 8-device subprocess: psum bit-exactness + sharded decode identity + the
# drift-on-one-shard -> fleet re-tune -> replica adoption loop
# ---------------------------------------------------------------------------

def _run_sub(code, timeout=540):
    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(out.stdout[-2000:])


_PSUM_AND_RETUNE_SCRIPT = r"""
import json
import jax, jax.numpy as jnp, numpy as np
import repro.core as C
import repro.runtime as R
from repro.fleet import PolicyReader, PolicyStore, make_sharded_summarizer
from repro.launch.mesh import make_fleet_mesh
from repro.runtime.telemetry import combine_records
import tempfile

res = {"devices": jax.device_count()}
mesh = make_fleet_mesh(8)
mult = C.get("mul8u_trunc0_4")
dyn = jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)
f = make_sharded_summarizer(mult.name, mesh)
rng = np.random.default_rng(0)
N = R.TELEMETRY_SAMPLE

# (1) psum'd record == host-side sum of the 8 per-shard records, bit-exact
a = rng.integers(0, 256, 8 * N)
b = rng.integers(0, 256, 8 * N)
got = jax.device_get(f(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), dyn))
shard_recs = []
for s in range(8):
    rec = jax.device_get(R.operand_summary(
        jnp.asarray(a[s*N:(s+1)*N], jnp.int32),
        jnp.asarray(b[s*N:(s+1)*N], jnp.int32), mult, dyn))
    shard_recs.append({"t": {k: np.asarray(v)[None] for k, v in rec.items()}})
ref = combine_records(shard_recs)["t"]
res["psum_bitexact"] = all(
    np.array_equal(got[k], ref[k].reshape(got[k].shape)) for k in got)
res["fields"] = sorted(got)

# (2) drift injected on ONE shard -> fleet-global re-tune -> store publish ->
#     replica adoption; scorer stays on one compiled program throughout
tmp = tempfile.mkdtemp()
store = PolicyStore(tmp)
ctrl = R.AdaptiveController(
    R.SwapPolicy(mult.name, configs={"*": C.SwapConfig("A", 3, 0)}),
    targets=("stream",), store=store,
    cfg=R.AdaptiveConfig(decay=0.4, drift_threshold=0.01,
                         min_observe_steps=2, cooldown_steps=2,
                         buffer_size=8 * R.RETUNE_SAMPLE))
ctrl.resume_from_store()
ctrl.warmup()
cache0 = ctrl.scorer_cache_size()
reader = PolicyReader(store, ("stream",))
v0 = reader.version

def shard_stream(step):
    # shard 3 collapses to a low-A regime after step 8; others stationary
    a_parts, b_parts = [], []
    for s in range(8):
        r = np.random.default_rng(1000 * step + s)
        if s == 3 and step >= 8:
            a_parts.append(r.integers(0, 48, N))
        else:
            a_parts.append(r.integers(128, 256, N))
        b_parts.append(r.integers(0, 256, N))
    return np.concatenate(a_parts), np.concatenate(b_parts)

retune_at = None
for step in range(20):
    a, b = shard_stream(step)
    t = jnp.asarray(R.triple_of(ctrl.policy.lookup("stream")), jnp.int32)
    rec = jax.device_get(f(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), t))
    ctrl.observe({"stream": rec})
    if ctrl.retunes and retune_at is None:
        retune_at = step
res["retune_at"] = retune_at
res["retunes"] = len(ctrl.retunes)
res["store_version"] = store.current_version()
res["reader_advanced"] = bool(reader.poll() and reader.version > v0)
res["reader_matches_writer"] = reader.policy.configs_equal(ctrl.policy)
res["scorer_recompiles"] = ctrl.scorer_cache_size() - cache0
res["summarizer_cache"] = None
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.multidevice
def test_sharded_psum_and_fleet_retune_8dev():
    r = _run_sub(_PSUM_AND_RETUNE_SCRIPT)
    assert r["devices"] == 8
    assert r["psum_bitexact"], r
    assert r["retunes"] >= 1 and r["retune_at"] >= 8, r   # fired post-drift
    assert r["store_version"] >= 2, r
    assert r["reader_advanced"] and r["reader_matches_writer"], r
    assert r["scorer_recompiles"] == 0, r


_SHARDED_DECODE_SCRIPT = r"""
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
import repro.configs as CFG
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.launch.mesh import make_fleet_mesh
from repro.models import init_params
from repro.serve import ServeConfig, generate
from repro.serve import engine as E

cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
params = init_params(jax.random.PRNGKey(0), cfg)
mesh = make_fleet_mesh(8)
rng = np.random.default_rng(0)
B, T = 8, 6
prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, 12)), jnp.int32)}

def ctrl():
    return R.AdaptiveController(
        R.SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(min_observe_steps=10**6))

res = {"devices": jax.device_count()}
# sharded fused adaptive decode vs the single-host *unrolled* adaptive loop
cS, cU = ctrl(), ctrl()
o_shard = generate(params, prompt, cfg, ServeConfig(max_new_tokens=T),
                   adaptive=cS, mesh=mesh)
o_unroll = generate(params, prompt, cfg,
                    ServeConfig(max_new_tokens=T, fused=False), adaptive=cU)
res["tokens_identical"] = bool(np.array_equal(np.asarray(o_shard),
                                              np.asarray(o_unroll)))

# telemetry sums: fleet aggregate == exact sum over 8 independent per-shard
# runs (each shard's slice decoded alone reproduces its local records)
agree = True
for t in cfg.ax.targets:
    n = wce = neq = 0
    sa = 0
    for s in range(8):
        c1 = ctrl()
        generate(params, {"tokens": prompt["tokens"][s:s+1]}, cfg,
                 ServeConfig(max_new_tokens=T), adaptive=c1)
        st = c1.telemetry.targets[t].stats
        n += st.n; sa += st.sum_abs; wce = max(wce, st.max_abs)
        neq += st.count_neq
    stS = cS.telemetry.targets[t].stats
    agree &= (stS.n == n and stS.sum_abs == sa and stS.max_abs == wce
              and stS.count_neq == neq)
res["telemetry_sums_identical"] = bool(agree)

# zero recompiles across a policy update on the sharded program
n_progs0 = {k: f._cache_size() for k, f in E._ADAPTIVE_FNS.items()}
cS.policy.set_config("mlp", __import__("repro.core", fromlist=["x"]).SwapConfig("B", 5, 1))
generate(params, prompt, cfg, ServeConfig(max_new_tokens=T), adaptive=cS, mesh=mesh)
res["retrace_free"] = all(f._cache_size() == n_progs0[k]
                          for k, f in E._ADAPTIVE_FNS.items())
print("RESULT:" + json.dumps(res))
"""


@pytest.mark.multidevice
def test_sharded_adaptive_decode_bit_identical_8dev():
    """ISSUE acceptance: sharded adaptive decode == single-host unrolled
    adaptive loop (tokens + telemetry sums) with zero recompiles."""
    r = _run_sub(_SHARDED_DECODE_SCRIPT)
    assert r["devices"] == 8
    assert r["tokens_identical"], r
    assert r["telemetry_sums_identical"], r
    assert r["retrace_free"], r
