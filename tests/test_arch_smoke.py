"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step on CPU, asserting output shapes and no NaNs (the FULL
configs are exercised only via the dry-run — ShapeDtypeStruct, no alloc)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as CFG
import repro.models as M
from repro.configs.base import AxPolicy


def _batch(cfg, B=2, S=64, key=None):
    key = key or jax.random.PRNGKey(7)
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
            "tokens": jax.random.randint(key, (B, 16), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, 16), 0, cfg.vocab),
        }
    if cfg.family == "vlm":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
            "pos": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
        }
    return {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }


@pytest.mark.parametrize("name", sorted(CFG.ARCHS))
def test_arch_smoke_train_step(name):
    cfg = CFG.reduced(CFG.ARCHS[name])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, b: M.train_loss(p, b, cfg), has_aux=True)
    )(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all()), name


@pytest.mark.parametrize("name", sorted(CFG.ARCHS))
def test_arch_smoke_forward_shapes(name):
    cfg = CFG.reduced(CFG.ARCHS[name])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    mod = __import__("repro.models.registry", fromlist=["_mod"])
    logits, _, _ = mod._mod(cfg).forward(params, batch, cfg, mode="train")
    B = 2
    S_out = 16 if cfg.family == "encdec" else 64
    assert logits.shape == (B, S_out, cfg.vocab), (name, logits.shape)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name


@pytest.mark.parametrize(
    "name", ["qwen2-72b", "deepseek-moe-16b", "recurrentgemma-2b", "mamba2-370m",
             "whisper-base"]
)
def test_arch_smoke_prefill_decode(name):
    """Prefill + 3 decode steps agree with the full forward pass."""
    cfg = CFG.reduced(CFG.ARCHS[name])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    key = jax.random.PRNGKey(3)
    if cfg.family == "encdec":
        frames = jax.random.normal(key, (B, 32, cfg.d_model), jnp.bfloat16)
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        pre = {"frames": frames, "tokens": toks[:, : S - 3]}
        full_batch = {"frames": frames, "tokens": toks}
    else:
        toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
        pre = {"tokens": toks[:, : S - 3]}
        full_batch = {"tokens": toks}

    mod = __import__("repro.models.registry", fromlist=["_mod"])
    full, _, _ = mod._mod(cfg).forward(params, full_batch, cfg, mode="train")

    logits, cache = M.prefill(params, pre, cfg, max_cache_len=S + 2)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1].astype(jnp.float32)),
        np.asarray(full[:, S - 4].astype(jnp.float32)),
        rtol=0.1, atol=0.15,
    )
    for i in range(3):
        pos = S - 3 + i
        logits, cache = M.decode_step(params, cache, toks[:, pos : pos + 1],
                                      jnp.int32(pos), cfg)
        a = np.asarray(full[:, pos].astype(jnp.float32))
        b = np.asarray(logits[:, 0].astype(jnp.float32))
        denom = max(float(np.abs(a).max()), 1e-6)
        assert np.abs(a - b).max() / denom < 0.15, (name, i)


def test_ax_mode_trains():
    """SWAPPER approximate matmuls (mxu backend) as a first-class train-time
    feature: one step runs and the loss stays finite."""
    cfg = dataclasses.replace(
        CFG.reduced(CFG.ARCHS["qwen2-72b"]),
        ax=AxPolicy(mult_name="mul8s_trunc0_4", backend="mxu",
                    targets=("mlp", "attn_out")),
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    (loss, _), grads = jax.jit(
        jax.value_and_grad(lambda p, b: M.train_loss(p, b, cfg), has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss))
    # and the approximate path actually changes the forward value
    cfg0 = dataclasses.replace(cfg, ax=None)
    loss0, _ = M.train_loss(params, batch, cfg0)
    assert float(loss) != pytest.approx(float(loss0), rel=1e-6)
