"""Adaptive SWAPPER runtime: policy maps, drift detection, dynamic-config
execution paths, and the telemetry -> drift -> re-tune loop (zero-recompile
guarantees checked via jit cache sizes)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as C
import repro.runtime as R
from repro.configs.base import AxPolicy
from repro.quant.ax import ax_dense, ax_dense_dyn, ax_matmul_int, ax_matmul_int_dyn


def _policy_static(backend, cfg):
    if cfg is None:
        return AxPolicy(backend=backend, swap_enabled=False)
    return AxPolicy(backend=backend, swap_operand=cfg.operand,
                    swap_bit=cfg.bit, swap_value=cfg.value)


def _dyn(cfg):
    return jnp.asarray(R.triple_of(cfg), jnp.int32)


# ---------------------------------------------------------------------------
# SwapPolicy
# ---------------------------------------------------------------------------

def test_policy_hierarchical_lookup():
    p = R.SwapPolicy("mul8u_trunc0_4", configs={
        "*": C.SwapConfig("A", 3, 0),
        "mlp": C.SwapConfig("B", 5, 1),
        "layer2/mlp": None,
    })
    assert p.lookup("layer2/mlp") is None                 # exact key wins
    assert p.lookup("layer7/mlp") == C.SwapConfig("B", 5, 1)   # suffix fallback
    assert p.lookup("mlp") == C.SwapConfig("B", 5, 1)
    assert p.lookup("attn_out") == C.SwapConfig("A", 3, 0)     # global fallback


def test_policy_json_roundtrip():
    p = R.SwapPolicy("mul8s_trunc0_4", configs={
        "*": C.SwapConfig("A", 3, 0), "mlp": None,
    }, meta={"tuned_on": np.ones((2, 8)) * 0.5})
    p.set_tile_grid("attn_out", np.zeros((4, 1, 3), np.int32))
    q = R.SwapPolicy.from_json(p.to_json())
    assert q.mult_name == p.mult_name
    assert q.lookup("mlp") is None
    assert q.lookup("attn_qkv") == C.SwapConfig("A", 3, 0)
    assert q.tile_grids["attn_out"].shape == (4, 1, 3)
    assert np.asarray(q.meta["tuned_on"]).shape == (2, 8)


def test_policy_tile_grid_broadcast():
    p = R.SwapPolicy("mul8u_trunc0_4", configs={"*": C.SwapConfig("B", 6, 1)})
    g = p.tile_grid("mlp", 3, 5)
    assert g.shape == (3, 5, 3)
    assert (g == np.asarray([0, 6, 1])).all()
    # stored per-row-tile grid broadcast over columns
    rows = np.stack([[1, i % 8, 0] for i in range(3)])[:, None, :]
    p.set_tile_grid("mlp", rows)
    g2 = p.tile_grid("mlp", 3, 4)
    assert g2.shape == (3, 4, 3)
    assert (g2[2, :, 1] == 2).all()


def test_dyn_tree_structure_stable_across_updates():
    p = R.SwapPolicy("mul8u_trunc0_4", configs={"*": C.SwapConfig("A", 3, 0)})
    t1 = p.dyn_tree(("mlp", "attn_out"))
    p.set_config("mlp", C.SwapConfig("B", 1, 1))
    t2 = p.dyn_tree(("mlp", "attn_out"))
    assert jax.tree.structure(t1) == jax.tree.structure(t2)
    assert not np.array_equal(np.asarray(t1["mlp"]), np.asarray(t2["mlp"]))


# ---------------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------------

def test_drift_detector_fires_only_on_shift():
    det = R.DriftDetector(R.DriftConfig(threshold=0.05, min_steps=2))
    ref = np.full((2, 8), 0.5)
    det.rebase("t", ref)
    for _ in range(3):
        assert det.check({"t": {"bit_probs": ref + 0.01}}) == []
    shifted = ref.copy()
    shifted[0] += 0.4
    out = det.check({"t": {"bit_probs": shifted}})
    assert len(out) == 1 and out[0][0] == "t" and out[0][1] > 0.05


def test_drift_score_is_mean_abs_diff():
    a = np.zeros((2, 8))
    b = np.full((2, 8), 0.25)
    assert R.drift_score(a, b) == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# dynamic-config execution paths == static paths, all backends
# ---------------------------------------------------------------------------

CFGS = [None, C.SwapConfig("A", 3, 0), C.SwapConfig("A", 7, 1),
        C.SwapConfig("B", 0, 0), C.SwapConfig("B", 6, 1)]


@pytest.mark.parametrize("backend", ["mxu", "emul", "kernel"])
def test_ax_matmul_int_dyn_matches_static(backend):
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.integers(-128, 128, (32, 64)).astype(np.int8))
    b = jnp.asarray(rng.integers(-128, 128, (64, 48)).astype(np.int8))
    for cfg in CFGS:
        pol = _policy_static(backend, cfg)
        ref = ax_matmul_int(a, b, pol)
        got = ax_matmul_int_dyn(a, b, pol, _dyn(cfg))
        assert np.array_equal(np.asarray(ref), np.asarray(got)), (backend, cfg)


def test_ax_dense_dyn_matches_static_and_grads():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.1, (64, 48)).astype(np.float32))
    pol = AxPolicy(backend="mxu")
    dyn = _dyn(pol.swap)
    np.testing.assert_allclose(np.asarray(ax_dense(x, w, pol)),
                               np.asarray(ax_dense_dyn(x, w, pol, dyn)))
    gs = jax.grad(lambda x, w: ax_dense(x, w, pol).sum(), (0, 1))(x, w)
    gd = jax.grad(lambda x, w: ax_dense_dyn(x, w, pol, dyn).sum(), (0, 1))(x, w)
    for p, q in zip(gs, gd):
        np.testing.assert_allclose(np.asarray(p), np.asarray(q))


def test_dyn_config_change_does_not_recompile():
    """The zero-recompile contract: one compiled fn serves every config."""
    pol = AxPolicy(backend="mxu")
    f = jax.jit(lambda x, w, dyn: ax_dense_dyn(x, w, pol, dyn))
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(0, 1, (8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(0, 0.1, (64, 48)).astype(np.float32))
    outs = []
    for cfg in CFGS:
        outs.append(np.asarray(f(x, w, _dyn(cfg))))
    assert f._cache_size() == 1
    # configs genuinely change the result (not a constant-folded swap)
    assert any(not np.allclose(outs[0], o) for o in outs[1:])


# ---------------------------------------------------------------------------
# telemetry
# ---------------------------------------------------------------------------

def test_telemetry_limb_exact_and_bit_probs():
    mult = C.get("mul8u_trunc0_4")
    rng = np.random.default_rng(5)
    a = rng.integers(0, 256, R.TELEMETRY_SAMPLE)
    b = rng.integers(0, 256, R.TELEMETRY_SAMPLE)
    rec = jax.device_get(R.operand_summary(
        jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32), mult,
        jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)))
    # limb recombination == exact numpy error sum
    e = np.abs(np.asarray(mult.fn(jnp.asarray(a, jnp.int32),
                                  jnp.asarray(b, jnp.int32))).astype(np.int64)
               - a * b)
    assert int(rec["err_lo"]) + (int(rec["err_hi"]) << 16) == int(e.sum())
    assert int(rec["err_max"]) == int(e.max())
    # bit occupancy
    expect = np.stack([((a[:, None] >> np.arange(8)) & 1).sum(0),
                       ((b[:, None] >> np.arange(8)) & 1).sum(0)])
    got = np.stack([rec["bits_a"], rec["bits_b"]])
    assert np.array_equal(got, expect.astype(np.float32))

    tel = R.Telemetry(bits=8, decay=0.5)
    tel.update({"t": {k: np.asarray(v)[None] for k, v in rec.items()}})
    snap = tel.snapshot()["t"]
    assert snap["mae"] == pytest.approx(e.mean())
    # magnitude bits + trailing sign-frequency column per operand
    assert snap["bit_probs"].shape == (2, 9)
    assert snap["bit_probs"][:, -1] == pytest.approx([0.0, 0.0])  # unsigned


def test_telemetry_sees_symmetric_signed_shrinkage():
    """Raw two's-complement bit occupancy is blind to a symmetric signed
    distribution shrinking toward zero (high bits of negatives sign-extend to
    one); the magnitude-bit statistic must expose it."""
    mult = C.get("mul8s_trunc0_4")
    rng = np.random.default_rng(9)
    dyn = jnp.asarray(R.NO_SWAP_TRIPLE, jnp.int32)

    def probs_of(lo, hi):
        a = rng.integers(lo, hi, R.TELEMETRY_SAMPLE)
        rec = jax.device_get(R.operand_summary(
            jnp.asarray(a, jnp.int32), jnp.asarray(a, jnp.int32), mult, dyn))
        tel = R.Telemetry(bits=8, decay=1.0)
        tel.update({"t": {k: np.asarray(v)[None] for k, v in rec.items()}})
        return tel.snapshot()["t"]["bit_probs"]

    wide = probs_of(-128, 128)
    narrow = probs_of(-6, 7)
    assert R.drift_score(wide, narrow) > 0.2


# ---------------------------------------------------------------------------
# controller: drift -> re-tune loop
# ---------------------------------------------------------------------------

def _make_controller(start_cfg, **kw):
    policy = R.SwapPolicy("mul8u_trunc0_4", configs={"*": start_cfg})
    cfg = dict(decay=0.4, drift_threshold=0.05, min_observe_steps=2,
               cooldown_steps=2, buffer_size=1024)
    cfg.update(kw)
    ctrl = R.AdaptiveController(policy, targets=("stream",),
                                cfg=R.AdaptiveConfig(**cfg))
    ctrl.warmup()
    return ctrl


def test_controller_retunes_under_drift_zero_recompiles():
    rng = np.random.default_rng(6)
    mult = C.get("mul8u_trunc0_4")
    start = C.component_sweep(mult, tile=256).best("mae")
    ctrl = _make_controller(start)
    cache_after_warmup = None

    for step in range(20):
        if step < 8:
            a = rng.integers(128, 256, 2048)    # tuned-on regime
        else:
            a = rng.integers(0, 96, 2048)       # drifted regime
        b = rng.integers(0, 256, 2048)
        ctrl.observe_operands("stream", a, b)
        if step == 0:
            cache_after_warmup = ctrl.scorer_cache_size()

    assert len(ctrl.retunes) >= 1
    first = ctrl.retunes[0]
    assert first.step >= 8                       # fired after the shift
    assert first.new_score <= first.old_score    # re-tune can only help (on buffer)
    assert ctrl.policy.version >= 1
    # the vmapped scorer never recompiled across re-tunes
    assert ctrl.scorer_cache_size() == cache_after_warmup
    # telemetry streamed throughout
    assert ctrl.telemetry.snapshot()["stream"]["n"] > 0


def test_controller_quiet_without_drift():
    rng = np.random.default_rng(7)
    ctrl = _make_controller(C.SwapConfig("A", 3, 0))
    for _ in range(15):
        ctrl.observe_operands("stream", rng.integers(128, 256, 2048),
                              rng.integers(0, 256, 2048))
    assert ctrl.retunes == []


def test_adaptive_beats_static_under_drift():
    """The acceptance-criterion property in miniature: after drift, the
    adaptive policy's live MAE is below the stale static config's."""
    from repro.runtime.controller import _score_configs

    rng = np.random.default_rng(8)
    mult = C.get("mul8u_trunc0_4")
    ctrl = _make_controller(None)
    # phase 0: high-A regime; tune statically on it via a forced retune
    a0, b0 = rng.integers(128, 256, 2048), rng.integers(0, 256, 2048)
    for _ in range(3):
        ctrl.observe_operands("stream", a0, b0)
    ctrl.retune("stream")
    static_cfg = ctrl.policy.lookup("stream")

    # phase 1: drifted regime
    a1 = rng.integers(0, 96, 2048)
    b1 = rng.integers(0, 256, 2048)
    for _ in range(8):
        ctrl.observe_operands("stream", rng.integers(0, 96, 2048),
                              rng.integers(0, 256, 2048))
    assert len(ctrl.retunes) >= 2                # re-tuned after the drift
    adapt_cfg = ctrl.policy.lookup("stream")
    t3 = jnp.asarray(np.stack([R.triple_of(static_cfg), R.triple_of(adapt_cfg)]),
                     jnp.int32)
    maes = np.asarray(_score_configs(mult, jnp.asarray(a1, jnp.int32),
                                     jnp.asarray(b1, jnp.int32), t3, "mae"))
    assert maes[1] < maes[0]


def test_adaptive_train_step_telemetry_and_no_retrace():
    """make_train_step(adaptive=True): telemetry arrives via the loss aux,
    loss stays finite, and a policy (dyn) change does not retrace the step."""
    import repro.configs as CFG
    from repro.train import AdamWConfig, init_train_state, make_train_step
    from repro.configs.base import ParallelConfig
    from repro.models import init_params

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    par = ParallelConfig(fsdp=False, seq_shard=False, scan_layers=False,
                         remat="none")
    step = jax.jit(make_train_step(cfg, par, AdamWConfig(lr=1e-3), adaptive=True))

    state = init_train_state(init_params(jax.random.PRNGKey(0), cfg),
                             AdamWConfig(lr=1e-3))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32),
    }
    policy = R.SwapPolicy.from_ax_policy(cfg.ax)
    state, m1 = step(state, batch, policy.dyn_tree(cfg.ax.targets))
    policy.set_config("mlp", C.SwapConfig("B", 5, 1))
    state, m2 = step(state, batch, policy.dyn_tree(cfg.ax.targets))
    assert step._cache_size() == 1                 # dyn change never retraces
    for m in (m1, m2):
        assert np.isfinite(float(m["loss"]))
        for t in cfg.ax.targets:
            assert float(np.sum(m["ax_telemetry"][t]["n"])) > 0


# ---------------------------------------------------------------------------
# end-to-end: adaptive serving on a tiny model
# ---------------------------------------------------------------------------

def test_adaptive_generate_end_to_end():
    import repro.configs as CFG
    from repro.models import init_params
    from repro.serve import ServeConfig, generate

    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    params = init_params(jax.random.PRNGKey(0), cfg)

    policy = R.SwapPolicy.from_ax_policy(cfg.ax)
    ctrl = R.AdaptiveController(
        policy, targets=cfg.ax.targets,
        cfg=R.AdaptiveConfig(drift_threshold=0.02, min_observe_steps=1,
                             cooldown_steps=1, buffer_size=1024))
    ctrl.warmup()

    def hook(step, params):
        if step != 3:
            return params

        def perturb(w):
            if w.ndim < 2:
                return w
            return jnp.where(jnp.arange(w.shape[-1]) % 2 == 0, w * 0.05, w)

        return jax.tree.map(perturb, params)

    rng = np.random.default_rng(0)
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)), jnp.int32)}
    out = generate(params, prompt, cfg, ServeConfig(max_new_tokens=10),
                   adaptive=ctrl, param_hook=hook)
    assert out.shape == (2, 10)
    # telemetry streamed for every approximate target
    snap = ctrl.telemetry.snapshot()
    for t in cfg.ax.targets:
        assert snap[t]["n"] > 0
    assert len(ctrl.retunes) >= 1                # injected drift was caught
