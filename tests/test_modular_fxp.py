"""Eq. 6 modular 32-bit multiply + Q16.16 fixed-point library tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st  # property tests skip without hypothesis

import repro.core as C

I32 = st.integers(-(2**31), 2**31 - 1)


def _ref_fxp_mul(a, b):
    """int64 oracle: Q16.16 product = (a*b) >> 16, truncated to int32."""
    p = (np.int64(a) * np.int64(b)) >> 16
    return np.int32((int(p) + 2**31) % 2**32 - 2**31)


@settings(max_examples=500, deadline=None)
@given(a=I32, b=I32)
def test_precise_modular_matches_int64(a, b):
    got = int(np.asarray(C.ax_fxp_mul(jnp.int32(a), jnp.int32(b))))
    assert got == int(_ref_fxp_mul(a, b))


@settings(max_examples=200, deadline=None)
@given(a=I32, b=I32)
def test_lsb_fix_with_exact_mult_is_bit_exact(a, b):
    """Beyond-paper lsb_fix + exact 16-bit parts == int64 reference even when
    every part goes through the 'approximate' (here exact) path."""
    cfg = C.AxMul32Config(C.exact(16, True), parts=C.PART_ALL, lsb_fix=True)
    got = int(np.asarray(C.ax_fxp_mul(jnp.int32(a), jnp.int32(b), cfg)))
    assert got == int(_ref_fxp_mul(a, b))


def test_paper_shift_protocol_loses_lsb_rows():
    """Faithful paper protocol (no lsb_fix): exact 16-bit parts still differ
    from the true product exactly by the dropped LSB rows."""
    cfg = C.AxMul32Config(C.exact(16, True), parts=C.PART_ALL, lsb_fix=False)
    rng = np.random.default_rng(0)
    a = rng.integers(-(2**31), 2**31, 2000).astype(np.int32)
    b = rng.integers(-(2**31), 2**31, 2000).astype(np.int32)
    got = np.asarray(C.ax_fxp_mul(jnp.asarray(a), jnp.asarray(b), cfg)).astype(np.int64)
    ref = np.array([_ref_fxp_mul(x, y) for x, y in zip(a, b)], np.int64)
    # error exists but is bounded by the dropped rows: the MD fixes contribute
    # up to |AH| + |BH| <= 2^16 raw each (~1 unit of Q16.16 integer part),
    # the LO fix contributes <= ~2 after the >>16.
    err = np.abs(got - ref)
    assert err.max() <= (1 << 17) + 4
    assert (err > 0).any()  # the protocol does drop information


def test_md_lo_vs_all_error_ordering():
    """Approximating HI injects much larger error than MD+LO (paper §III.B:
    'approximating HI ... inserts an absolute error of at least 2^2n')."""
    mult = C.get("mul16s_trunc0_8")
    rng = np.random.default_rng(1)
    a = rng.integers(-(2**24), 2**24, 4000).astype(np.int32)
    b = rng.integers(-(2**24), 2**24, 4000).astype(np.int32)
    ref = np.array([_ref_fxp_mul(x, y) for x, y in zip(a, b)], np.float64)
    out = {}
    for parts, nm in [(C.PART_MD_LO, "mdlo"), (C.PART_ALL, "all")]:
        cfg = C.AxMul32Config(mult, parts=parts)
        got = np.asarray(C.ax_fxp_mul(jnp.asarray(a), jnp.asarray(b), cfg)).astype(np.float64)
        out[nm] = np.abs(got - ref).mean()
    assert out["all"] > out["mdlo"]


@pytest.mark.parametrize("mname,min_red", [("mul16s_drum5_8", 0.3), ("mul16s_bam_v4_h1", 0.15)])
def test_swap_config_threads_through_modular(mname, min_red):
    """A SWAPPER config on the 16-bit parts improves the modular product
    error for non-commutative part multipliers (some circuits see ~0% like
    several Table I rows; these two reproduce the large-gain regime)."""
    mult = C.get(mname)
    rng = np.random.default_rng(2)
    a = rng.integers(-(2**26), 2**26, 8000).astype(np.int32)
    b = rng.integers(-(2**26), 2**26, 8000).astype(np.int32)
    ref = np.array([_ref_fxp_mul(x, y) for x, y in zip(a, b)], np.float64)

    def mae_for(swap):
        cfg = C.AxMul32Config(mult, parts=C.PART_MD_LO, swap=swap)
        got = np.asarray(C.ax_fxp_mul(jnp.asarray(a), jnp.asarray(b), cfg)).astype(np.float64)
        return np.abs(got - ref).mean()

    base = mae_for(None)
    best = min(mae_for(c) for c in C.all_configs(16))
    assert (base - best) / base > min_red


def test_dyn_modular_matches_static():
    mult = C.get("mul16s_drum5_8")
    cfg = C.AxMul32Config(mult, parts=C.PART_ALL, swap=C.SwapConfig("B", 11, 1))
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.integers(-(2**30), 2**30, 512).astype(np.int32))
    b = jnp.asarray(rng.integers(-(2**30), 2**30, 512).astype(np.int32))
    ref = np.asarray(C.ax_fxp_mul(a, b, cfg))
    got = np.asarray(C.ax_fxp_mul_dyn(a, b, cfg, *C.cfg_to_dyn(cfg.swap)))
    assert np.array_equal(ref, got)


# ---------------------------------------------------------------------------
# Q16.16 math library accuracy (precise multiply installed)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def F():
    return C.FxpMath(C.make_mul(None))


def test_fxp_div(F):
    rng = np.random.default_rng(4)
    a = rng.uniform(-1000, 1000, 500).astype(np.float32)
    b = rng.uniform(0.1, 100, 500).astype(np.float32) * np.sign(rng.normal(size=500)).astype(np.float32)
    got = np.asarray(C.from_fxp(F.div(C.to_fxp(a), C.to_fxp(b))))
    rel = np.abs(got - a / b) / np.maximum(np.abs(a / b), 1.0)
    assert rel.max() < 5e-4


def test_fxp_sqrt(F):
    x = np.linspace(0.01, 3000, 700).astype(np.float32)
    got = np.asarray(C.from_fxp(F.sqrt(C.to_fxp(x))))
    assert np.abs(got - np.sqrt(x)).max() < 2e-3


def test_fxp_exp_log(F):
    x = np.linspace(-6, 9, 400).astype(np.float32)
    got = np.asarray(C.from_fxp(F.exp(C.to_fxp(x))))
    assert (np.abs(got - np.exp(x)) / np.maximum(np.exp(x), 1.0)).max() < 1e-3
    y = np.linspace(0.05, 5000, 400).astype(np.float32)
    got = np.asarray(C.from_fxp(F.log(C.to_fxp(y))))
    assert np.abs(got - np.log(y)).max() < 1e-3


def test_fxp_trig(F):
    x = np.linspace(-7, 7, 500).astype(np.float32)
    assert np.abs(np.asarray(C.from_fxp(F.sin(C.to_fxp(x)))) - np.sin(x)).max() < 5e-4
    assert np.abs(np.asarray(C.from_fxp(F.cos(C.to_fxp(x)))) - np.cos(x)).max() < 5e-4
    z = np.linspace(-0.999, 0.999, 301).astype(np.float32)
    assert np.abs(np.asarray(C.from_fxp(F.acos(C.to_fxp(z)))) - np.arccos(z)).max() < 2e-3
    y = np.linspace(-5, 5, 101).astype(np.float32)
    xs = np.linspace(-5, 5, 101)[::-1].astype(np.float32).copy()
    got = np.asarray(C.from_fxp(F.atan2(C.to_fxp(y), C.to_fxp(xs))))
    assert np.abs(got - np.arctan2(y, xs)).max() < 2e-3
