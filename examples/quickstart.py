"""Quickstart: the whole SWAPPER pipeline on one non-commutative multiplier.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core as C

# 1. pick a non-commutative approximate multiplier from the library
mult = C.get("mul8u_trunc0_4")
print(f"{mult.name}: commutative={C.is_commutative(mult)}")

# 2. error depends on operand order
a, b = jnp.int32(200), jnp.int32(13)
print(f"m({int(a)},{int(b)})={int(mult.fn(a, b))}  "
      f"m({int(b)},{int(a)})={int(mult.fn(b, a))}  exact={int(a)*int(b)}")

# 3. component-level tuning: explore all 4M single-bit decisions exhaustively
res = C.component_sweep(mult, tile=256)
best = res.best("mae")
print(f"NoSwap MAE={res.noswap.mae:.2f}")
print(f"SWAPPER best bit {best.short()}: MAE={res.per_config[best].mae:.2f} "
      f"(-{100*res.reduction('mae'):.1f}%)")
print(f"Oracle bound: MAE={res.oracle.mae:.2f} "
      f"(-{100*res.theoretical_reduction('mae'):.1f}%)")

# 4. deploy: a swapped multiplier is just another AxMult
swapped = C.swapped_mult(mult, best)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.integers(0, 256, 10000).astype(np.int32))
y = jnp.asarray(rng.integers(0, 256, 10000).astype(np.int32))
e_base = np.abs(np.asarray(mult.fn(x, y)).astype(float) - np.asarray(x * y).astype(float)).mean()
e_swap = np.abs(np.asarray(swapped.fn(x, y)).astype(float) - np.asarray(x * y).astype(float)).mean()
print(f"random-input MAE: NoSwap={e_base:.2f} SWAPPER={e_swap:.2f}")
