"""Serve a small model with batched requests: prefill + decode with KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as CFG
import repro.models as M
from repro.serve import ServeConfig, generate

cfg = CFG.reduced(CFG.ARCHS["gemma3-27b"])   # local:global attention family
params = M.init_params(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 48)), jnp.int32)

out = generate(params, {"tokens": prompts}, cfg,
               ServeConfig(max_new_tokens=24, temperature=0.0))
print(f"arch family: {cfg.family}, pattern: {cfg.pattern}")
print("generated token ids:")
print(np.asarray(out))
