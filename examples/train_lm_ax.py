"""Train a ~100M-class LM for a few hundred steps with SWAPPER approximate
matmuls (MXU-factorized backend) as a first-class feature, with checkpointing
and fault-tolerant supervision.

    PYTHONPATH=src python examples/train_lm_ax.py [--steps 300]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as CFG
import repro.models as M
import repro.train as T
from repro.configs.base import AxPolicy

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=512)
ap.add_argument("--layers", type=int, default=8)
args = ap.parse_args()

base = CFG.reduced(CFG.ARCHS["qwen2-72b"])
cfg = dataclasses.replace(
    base, name="qwen2-100m-ax", d_model=args.d_model, n_layers=args.layers,
    d_ff=args.d_model * 4, n_heads=8, n_kv_heads=4, head_dim=args.d_model // 8,
    vocab=8192,
    ax=AxPolicy(mult_name="mul8s_trunc0_4", backend="mxu", targets=("mlp",)),
)
params = M.init_params(jax.random.PRNGKey(0), cfg)
print(f"params: {sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M, "
      f"ax={cfg.ax.mult_name} swap={cfg.ax.swap.short()}")

opt = T.AdamWConfig(lr=1e-3, warmup=20)
par = CFG.ParallelConfig(remat="none", fsdp=False, seq_shard=False)
step = jax.jit(T.make_train_step(cfg, par, opt), donate_argnums=(0,))
stream = T.SyntheticStream(T.DataConfig(cfg.vocab, 128, 16, seed=0, mode="arith"))

state, log = T.run_supervised(
    lambda: T.init_train_state(params, opt),
    lambda s, b: step(s, jax.tree.map(jnp.asarray, b)),
    stream, args.steps,
    T.FaultConfig(ckpt_dir="/tmp/repro_ax_train", ckpt_every=100),
    on_step=lambda i, m: (i + 1) % 25 == 0 and print(
        f"step {i+1}: loss={float(m['loss']):.4f}"),
)
print("done:", log)
