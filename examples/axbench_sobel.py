"""End-to-end AxBench application demo: sobel under approximate multipliers,
reproducing the paper's NoSwap -> SWAPPER(App) -> oracle progression.

    PYTHONPATH=src python examples/axbench_sobel.py
"""
import numpy as np

import repro.apps as A
import repro.core as C

app = A.ALL_APPS["sobel"]
mult = C.get("mul16s_mitch10_13")

v_fxp, _ = A.evaluate(app, "fxp", n=96, seed=1234)
v_nosw, out_ns = A.evaluate(app, None, mult=mult, n=96, seed=1234)
cfg, train_val, table = A.tune_app(app, mult, n=96, seed=42)
v_app, out_sw = A.evaluate(app, cfg, mult=mult, n=96, seed=1234)
v_orc, _ = A.evaluate(app, "oracle", mult=mult, n=96, seed=1234)

print(f"sobel SSIM (higher better), multiplier={mult.name}")
print(f"  precise FxP       : {v_fxp:.4f}")
print(f"  NoSwap            : {v_nosw:.4f}")
print(f"  SWAPPER app-tuned : {v_app:.4f}   (chose {cfg.short() if cfg else 'NoSwap'})")
print(f"  oracle            : {v_orc:.4f}")
np.savez("sobel_outputs.npz", noswap=np.asarray(out_ns), swapper=np.asarray(out_sw))
print("outputs saved to sobel_outputs.npz")
