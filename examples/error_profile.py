"""Fig. 1 analog: error-surface heat maps (text rendering + .npz dump) for a
non-commutative multiplier, without swap / with SWAPPER / oracle.

    PYTHONPATH=src python examples/error_profile.py
"""
import jax.numpy as jnp
import numpy as np

import repro.core as C

mult = C.get("mul8u_drum2_6")
res = C.component_sweep(mult, tile=256)
best = res.best("mae")

vals = jnp.asarray(np.arange(256, dtype=np.int32))
A, B = jnp.meshgrid(vals, vals, indexing="ij")
exact = mult.exact_product(A, B)

surfaces = {
    "noswap": mult.fn(A, B),
    "swapper": C.apply_swapper(mult, A, B, best),
    "oracle": C.oracle_mult(mult).fn(A, B),
}
np.savez("error_profile.npz", **{
    k: np.asarray(C.abs_err(v, exact, mult.signed)) for k, v in surfaces.items()
})
print(f"{mult.name}, best bit {best.short()} — coarse error maps (16x16 blocks,"
      " '.' low error .. '#' high):")
for name, surf in surfaces.items():
    e = np.asarray(C.abs_err(surf, exact, mult.signed)).astype(float).reshape(16, 16, 16, 16)
    blk = e.mean((1, 3))
    mx = blk.max() or 1.0
    chars = " .:-=+*#%@"
    print(f"\n[{name}] MAE={e.mean():.1f}")
    for row in blk:
        print("".join(chars[min(int(v / mx * 9.999), 9)] for v in row))
print("\nfull surfaces saved to error_profile.npz")
