"""Adaptive SWAPPER runtime on a drifting synthetic operand stream.

    PYTHONPATH=src python examples/adaptive_drift.py

The controller starts from an offline-tuned config, watches streaming
bit-occupancy telemetry, detects the distribution shift, and re-tunes from
its live operand buffer — all without recompiling the (jitted) scorer.
"""
import numpy as np

import repro.core as C
from repro.runtime import AdaptiveConfig, AdaptiveController, SwapPolicy

mult = C.get("mul8u_trunc0_4")

# offline tuning on the deployment-time distribution: high operand A
res = C.component_sweep(mult, tile=256)
policy = SwapPolicy(mult.name, configs={"*": res.best("mae")})
print(f"offline-tuned: {policy.describe()}")

ctrl = AdaptiveController(
    policy, targets=("stream",),
    cfg=AdaptiveConfig(decay=0.3, min_observe_steps=2, cooldown_steps=2,
                       buffer_size=1024),
    log_fn=print,
)
ctrl.warmup()

rng = np.random.default_rng(0)
for step in range(24):
    if step < 12:     # tuned-on regime
        a = rng.integers(128, 256, 2048)
    else:             # drifted regime: low-A traffic
        a = rng.integers(0, 96, 2048)
    b = rng.integers(0, 256, 2048)
    ctrl.observe_operands("stream", a, b)

print(f"final: {ctrl.policy.describe()}")
print(ctrl.telemetry.describe())
print(f"re-tunes: {len(ctrl.retunes)}, scorer jit entries: {ctrl.scorer_cache_size()}")
