"""Fleet-scale adaptive serving demo: 8 replicas, one global policy.

Forces an 8-device CPU mesh, serves continuous-batching waves of
variable-length requests through the fused adaptive decode (ONE dispatch per
wave, telemetry psum'd across the mesh inside the compiled scan), then
injects an operand-distribution drift on a SINGLE shard's traffic.  The
fleet controller — which only ever sees the in-graph-aggregated records —
detects the diluted global shift, re-tunes from its all-gathered operand
buffers, and publishes the new policy to the versioned ``PolicyStore``;
read-only serve replicas poll the store and adopt the same version, all with
zero recompilations.

    PYTHONPATH=src python examples/fleet_serve.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import tempfile

import jax
import numpy as np

import repro.configs as CFG
from repro.configs.base import AxPolicy
from repro.fleet import (BatcherConfig, ContinuousBatcher, PolicyReader,
                         PolicyStore, Request)
from repro.launch.mesh import make_fleet_mesh
from repro.models import init_params
from repro.runtime import AdaptiveConfig, AdaptiveController, SwapPolicy
from repro.serve import engine as engine_mod

N_SHARDS = 8
DRIFT_SHARD = 3
N_WAVES = 7
WARMUP_WAVES = 3        # detector disarmed while the EW telemetry converges
DRIFT_WAVE = 3          # waves >= this route degenerate traffic to one shard
FLEET_THRESHOLD = 0.0023  # ~1/N_SHARDS of a single-host threshold (see below)


def main():
    assert jax.device_count() >= N_SHARDS, (
        f"need {N_SHARDS} devices (XLA_FLAGS not applied early enough?)")
    cfg = CFG.reduced(CFG.ARCHS["qwen2-72b"])
    cfg = dataclasses.replace(cfg, n_layers=2, ax=AxPolicy(backend="mxu"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    mesh = make_fleet_mesh(N_SHARDS)

    store_dir = tempfile.mkdtemp(prefix="fleet_policy_")
    store = PolicyStore(store_dir)
    # Disarmed (huge threshold) during warm-up; after WARMUP_WAVES the
    # reference is rebased to the converged snapshot and the detector armed
    # with the fleet threshold.  A single-shard anomaly reaches the
    # controller diluted by the psum over N_SHARDS shards, so the fleet
    # threshold scales ~1/N of a single-host setting (0.02-ish); the low EW
    # decay keeps the stationary wave-to-wave score well under it.
    controller = AdaptiveController(
        SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=AdaptiveConfig(decay=0.12, drift_threshold=10.0,
                           min_observe_steps=2, cooldown_steps=2),
        store=store, log_fn=lambda line: print(f"  [controller] {line}"))
    controller.resume_from_store()
    controller.warmup()
    replicas = [PolicyReader(store, cfg.ax.targets) for _ in range(2)]
    print(f"mesh={mesh.shape} store={store_dir}")
    print(f"start: {controller.policy.describe()}\n")

    bat = ContinuousBatcher(
        params, cfg,
        BatcherConfig(n_slots=N_SHARDS, prompt_buckets=(16,),
                      new_token_bucket=8),
        adaptive=controller, mesh=mesh)

    rng = np.random.default_rng(0)
    rid = 0
    for wave in range(N_WAVES):
        # one request per slot; slot i lives on shard i of the 1-D mesh
        for slot in range(N_SHARDS):
            if wave >= DRIFT_WAVE and slot == DRIFT_SHARD:
                # drifted shard: degenerate single-token traffic (extreme
                # bit-occupancy shift in its quantized activations)
                toks = np.full(16, 7, np.int32)
            else:
                toks = rng.integers(0, cfg.vocab, 16).astype(np.int32)
            bat.submit(Request(rid, toks, max_new=8))
            rid += 1
        tag = f" <- drift on shard {DRIFT_SHARD}" if wave >= DRIFT_WAVE else ""
        print(f"wave {wave}{tag}")
        bat.step()
        if wave == WARMUP_WAVES - 1:
            controller.rebase_reference(threshold=FLEET_THRESHOLD)
            print(f"  [controller] warm-up done: reference rebased, detector "
                  f"armed at {FLEET_THRESHOLD}")
        for i, r in enumerate(replicas):
            if r.poll():
                print(f"  [replica {i}] adopted policy v{r.version}: "
                      f"{r.policy.describe()}")

    print(f"\n{bat.describe()}")
    print(f"controller: {len(controller.retunes)} re-tune(s), "
          f"store v{store.current_version()}")
    print(f"final: {controller.policy.describe()}")
    for i, r in enumerate(replicas):
        same = r.policy.configs_equal(controller.policy)
        print(f"replica {i}: v{r.version} configs_equal(writer)={same}")
    sizes = [f._cache_size() for f in engine_mod._ADAPTIVE_FNS.values()]
    print(f"compiled adaptive programs: {sizes} (zero recompiles across "
          f"waves, drift, and re-tunes)")


if __name__ == "__main__":
    main()
