"""SWAPPER — the paper's contribution: single-bit online operand swapping.

A :class:`SwapConfig` names (operand in {A,B}, bit position, reference value).
At execution time the selected bit of the selected operand is compared to the
reference value; on a match the multiplier is evaluated as ``m(b, a)`` instead
of ``m(a, b)``.  On TPU the x86 ``xchg`` of the paper becomes a *branch-free
pair of vector selects* fused ahead of the multiply (see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .metrics import abs_err
from .multipliers import AxMult

__all__ = [
    "SwapConfig",
    "swap_mask",
    "swap_mask_dyn",
    "apply_swapper",
    "apply_swapper_dyn",
    "NO_SWAP_TRIPLE",
    "cfg_to_triple",
    "cfg_to_dyn",
    "swapped_mult",
    "oracle_mult",
    "all_configs",
]

# (op_is_a, bit, value): value=2 never matches a bit => NoSwap.  This module
# owns the triple encoding; everything else (runtime.policy, the grid kernel
# callers) builds on cfg_to_triple / cfg_to_dyn.
NO_SWAP_TRIPLE = (1, 0, 2)


@dataclasses.dataclass(frozen=True)
class SwapConfig:
    operand: str  # 'A' or 'B'
    bit: int      # 0 .. M-1 within the M-bit representation
    value: int    # 0 or 1

    def __post_init__(self):
        assert self.operand in ("A", "B")
        assert self.value in (0, 1)

    def short(self) -> str:
        return f"{self.operand}[{self.bit}]=={self.value}"


def all_configs(bits: int):
    """The 4M-entry exploration space of the tuning phase."""
    return [
        SwapConfig(op, i, v) for op in ("A", "B") for i in range(bits) for v in (0, 1)
    ]


def swap_mask(a, b, cfg: SwapConfig):
    """True where the operands must be swapped.  Operands may be signed; the
    bit is taken from the M-bit two's-complement representation."""
    src = a if cfg.operand == "A" else b
    bit = (src.astype(jnp.int32) >> cfg.bit) & 1
    return bit == cfg.value


def apply_swapper(mult: AxMult, a, b, cfg: Optional[SwapConfig]):
    """Evaluate ``mult`` with the SWAPPER decision applied (branch-free)."""
    if cfg is None:
        return mult.fn(a, b)
    m = swap_mask(a, b, cfg)
    aa = jnp.where(m, b, a)
    bb = jnp.where(m, a, b)
    return mult.fn(aa, bb)


def swap_mask_dyn(a, b, op_is_a, bit, value):
    """Dynamic-config variant: ``op_is_a``/``bit``/``value`` are traced scalars
    so a single compiled program can evaluate every tuning configuration
    (used by the application-level tuner to avoid 4M recompiles)."""
    a_bit = (a.astype(jnp.int32) >> bit) & 1
    b_bit = (b.astype(jnp.int32) >> bit) & 1
    src = jnp.where(op_is_a, a_bit, b_bit)
    return src == value


def apply_swapper_dyn(mult: AxMult, a, b, op_is_a, bit, value):
    m = swap_mask_dyn(a, b, op_is_a, bit, value)
    aa = jnp.where(m, b, a)
    bb = jnp.where(m, a, b)
    return mult.fn(aa, bb)


def cfg_to_triple(cfg: Optional[SwapConfig]):
    """SwapConfig -> host-side (op_is_a, bit, value) int triple; None -> the
    no-swap encoding."""
    if cfg is None:
        return NO_SWAP_TRIPLE
    return (1 if cfg.operand == "A" else 0, cfg.bit, cfg.value)


def cfg_to_dyn(cfg: Optional[SwapConfig]):
    """SwapConfig -> (op_is_a, bit, value) int32 scalar triple for the
    dynamic (traced) execution paths."""
    return tuple(jnp.int32(v) for v in cfg_to_triple(cfg))


def swapped_mult(mult: AxMult, cfg: Optional[SwapConfig]) -> AxMult:
    """A new AxMult whose circuit is `mult` + the SWAPPER front-end."""
    if cfg is None:
        return mult
    return AxMult(
        name=f"{mult.name}+swap({cfg.short()})",
        bits=mult.bits,
        signed=mult.signed,
        fn=lambda a, b: apply_swapper(mult, a, b, cfg),
        commutative=mult.commutative,
    )


def oracle_mult(mult: AxMult) -> AxMult:
    """The theoretical oracle of the paper (Fig. 1c / 'Theor.' rows): per
    multiplication, pick whichever operand order yields the smaller absolute
    error.  Not implementable in hardware — used as the bound."""

    def fn(a, b):
        p0 = mult.fn(a, b)
        p1 = mult.fn(b, a)
        exact = mult.exact_product(a, b)
        e0 = abs_err(p0, exact, mult.signed)
        e1 = abs_err(p1, exact, mult.signed)
        return jnp.where(e0 <= e1, p0, p1)

    return AxMult(f"{mult.name}+oracle", mult.bits, mult.signed, fn, None)
