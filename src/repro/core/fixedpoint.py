"""Q16.16 fixed-point math library with an injectable (approximable) multiply.

The AxBench CPU benchmarks are floating-point; the paper converts them to
32-bit fixed point via libfixmath and routes **every multiplication** through
the Eq. 6 modular approximate multiplier.  This module is our libfixmath
analog: all derived operations (div, sqrt, exp, log, sin, cos, atan, acos)
are built on top of a single Q16.16 ``mul`` callable, so installing an
approximate multiply automatically approximates the whole math library —
matching the paper's "all multiplications are approximate" protocol.

Division and the transcendental seeds use float32 only for *initial guesses*
(and integer range reduction); the refining arithmetic is fixed point through
``mul``, keeping the error model faithful.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

from .modular import AxMul32Config, ax_fxp_mul, ax_fxp_mul_dyn

__all__ = ["FX_ONE", "FxpMath", "to_fxp", "from_fxp", "make_mul"]

FX_ONE = 1 << 16
_LN2 = int(round(np.log(2) * FX_ONE))
_PI = int(round(np.pi * FX_ONE))
_HALF_PI = int(round(np.pi / 2 * FX_ONE))
_QUARTER_PI = int(round(np.pi / 4 * FX_ONE))
_I32 = jnp.int32


def to_fxp(x) -> jnp.ndarray:
    """float -> Q16.16 (round to nearest), saturating to int32 range."""
    v = jnp.clip(jnp.round(jnp.asarray(x, jnp.float32) * FX_ONE), -(2.0**31), 2.0**31 - 1)
    return v.astype(jnp.int32)


def from_fxp(x) -> jnp.ndarray:
    return x.astype(jnp.float32) / FX_ONE


def make_mul(cfg: Optional[AxMul32Config] = None, dyn=None) -> Callable:
    """A Q16.16 multiply closure: precise (cfg=None), statically-configured
    approximate, or dynamic-swap-config approximate (dyn = traced triple)."""
    if cfg is None:
        return lambda a, b: ax_fxp_mul(a, b, None)
    if dyn is None:
        return lambda a, b: ax_fxp_mul(a, b, cfg)
    return lambda a, b: ax_fxp_mul_dyn(a, b, cfg, *dyn)


class FxpMath:
    """Q16.16 math built exclusively on ``self.mul`` (plus exact add/shift)."""

    def __init__(self, mul: Callable):
        self.mul = mul

    # -- helpers ------------------------------------------------------
    def const(self, x: float):
        return jnp.int32(int(round(x * FX_ONE)))

    def _poly(self, x, coeffs):
        """Horner evaluation; coeffs are floats, highest degree first."""
        acc = jnp.full_like(x, self.const(coeffs[0]))
        for c in coeffs[1:]:
            acc = self.mul(acc, x) + self.const(c)
        return acc

    # -- division (normalized-reciprocal Newton; float32 seed) ----------
    def div(self, a, b):
        """q = a/b.  The divisor is normalized to m in [1,2) by exact shifts
        so the Q16.16 reciprocal keeps full relative precision for any
        divisor magnitude; the Newton refinements go through self.mul and are
        therefore approximated along with everything else."""
        import jax.lax as lax

        neg = jnp.logical_xor(a < 0, b < 0)
        aa = jnp.abs(a)
        bb = jnp.maximum(jnp.abs(b), 1)
        e = (31 - lax.clz(bb.astype(jnp.uint32))).astype(jnp.int32) - 16
        m = jnp.where(e >= 0, bb >> jnp.maximum(e, 0), bb << jnp.maximum(-e, 0))
        r = to_fxp(1.0 / jnp.maximum(from_fxp(m), 0.5))  # seed, m in [1,2)
        two = jnp.int32(2 * FX_ONE)
        for _ in range(2):                                # r <- r*(2 - m*r)
            r = self.mul(r, two - self.mul(m, r))
        q = self.mul(aa, r)
        q = jnp.where(e >= 0, q >> jnp.maximum(e, 0), q << jnp.maximum(-e, 0))
        q = jnp.where(neg, -q, q)
        return jnp.where(b == 0, jnp.int32(0), q)

    # -- sqrt via normalized rsqrt Newton ---------------------------------
    def sqrt(self, x):
        """x = m * 4^(e/2) with m in [1,4) (exact shifts); sqrt(m) via rsqrt
        Newton in Q16.16 keeps full relative precision at any magnitude."""
        import jax.lax as lax

        xs = jnp.maximum(x, 1)
        e = ((31 - lax.clz(xs.astype(jnp.uint32))).astype(jnp.int32) - 16) & ~1
        m = jnp.where(e >= 0, xs >> jnp.maximum(e, 0), xs << jnp.maximum(-e, 0))
        r = to_fxp(1.0 / jnp.sqrt(jnp.maximum(from_fxp(m), 0.25)))  # seed
        half = self.const(0.5)
        three = jnp.int32(3 * FX_ONE)
        for _ in range(2):                           # r <- r*(3 - m r^2)/2
            r = self.mul(r, self.mul(half, three - self.mul(m, self.mul(r, r))))
        s = self.mul(m, r)                           # sqrt(m) in [1,2)
        h = e >> 1
        s = jnp.where(h >= 0, s << jnp.maximum(h, 0), s >> jnp.maximum(-h, 0))
        return jnp.where(x <= 0, jnp.int32(0), s)

    # -- exp: x = k ln2 + t, e^x = 2^k e^t --------------------------------
    def exp(self, x):
        k = jnp.round(from_fxp(x) / float(np.log(2))).astype(jnp.int32)
        k = jnp.clip(k, -17, 13)  # Q16.16 representable range of 2^k * e^t
        t = x - k * _LN2
        # e^t on |t| <= ln2/2: 6-term Taylor (|err| < 3e-6)
        e = self._poly(t, [1 / 720, 1 / 120, 1 / 24, 1 / 6, 1 / 2, 1.0, 1.0])
        e_shift = jnp.where(k >= 0, e << jnp.maximum(k, 0), e >> jnp.maximum(-k, 0))
        return e_shift

    # -- log: x = 2^k m, ln x = k ln2 + 2 atanh((m-1)/(m+1)) ---------------
    def log(self, x):
        import jax.lax as lax

        xs = jnp.maximum(x, 1)
        msb = (31 - lax.clz(xs.astype(jnp.uint32))).astype(jnp.int32)
        k = msb - 16
        m = jnp.where(k >= 0, xs >> jnp.maximum(k, 0), xs << jnp.maximum(-k, 0))
        t = self.div(m - FX_ONE, m + FX_ONE)
        t2 = self.mul(t, t)
        # 2*(t + t^3/3 + t^5/5 + t^7/7)
        s = self._poly(t2, [2 / 7, 2 / 5, 2 / 3, 2.0])
        ln_m = self.mul(t, s)
        out = k * _LN2 + ln_m
        return jnp.where(x <= 0, jnp.int32(-(1 << 31)), out)

    # -- sin/cos with pi/2 folding ----------------------------------------
    def _sin_core(self, r):
        r2 = self.mul(r, r)
        # r - r^3/6 + r^5/120 - r^7/5040 on |r| <= pi/4
        s = self._poly(r2, [-1 / 5040, 1 / 120, -1 / 6, 1.0])
        return self.mul(r, s)

    def _cos_core(self, r):
        r2 = self.mul(r, r)
        # 1 - r^2/2 + r^4/24 - r^6/720
        return self._poly(r2, [-1 / 720, 1 / 24, -1 / 2, 1.0])

    def _fold(self, x):
        k = jnp.round(from_fxp(x) / float(np.pi / 2)).astype(jnp.int32)
        r = x - k * _HALF_PI
        return k & 3, r

    def sin(self, x):
        q, r = self._fold(x)
        s, c = self._sin_core(r), self._cos_core(r)
        return jnp.where(
            q == 0, s, jnp.where(q == 1, c, jnp.where(q == 2, -s, -c))
        )

    def cos(self, x):
        q, r = self._fold(x)
        s, c = self._sin_core(r), self._cos_core(r)
        return jnp.where(
            q == 0, c, jnp.where(q == 1, -s, jnp.where(q == 2, -c, s))
        )

    # -- atan / atan2 / acos -----------------------------------------------
    def _atan_small(self, z):
        """atan on |z| <= 0.5 via 7-term odd series."""
        z2 = self.mul(z, z)
        s = self._poly(z2, [-1 / 15, 1 / 13, -1 / 11, 1 / 9, -1 / 7, 1 / 5, -1 / 3, 1.0])
        return self.mul(z, s)

    def atan(self, z):
        neg = z < 0
        za = jnp.where(neg, -z, z)
        inv = za > FX_ONE
        zb = jnp.where(inv, self.div(jnp.int32(FX_ONE), jnp.maximum(za, 1)), za)
        mid = zb > (FX_ONE // 2)
        zc = jnp.where(mid, self.div(zb - FX_ONE, zb + FX_ONE), zb)
        a = self._atan_small(zc)
        a = jnp.where(mid, _QUARTER_PI + a, a)
        a = jnp.where(inv, _HALF_PI - a, a)
        return jnp.where(neg, -a, a)

    def atan2(self, y, x):
        base = self.atan(self.div(y, jnp.where(x == 0, 1, x)))
        out = jnp.where(
            x > 0,
            base,
            jnp.where(
                x < 0,
                jnp.where(y >= 0, base + _PI, base - _PI),
                jnp.where(y > 0, _HALF_PI, jnp.where(y < 0, -_HALF_PI, 0)),
            ),
        )
        return out.astype(jnp.int32)

    def acos(self, x):
        xc = jnp.clip(x, -FX_ONE, FX_ONE)
        one_minus = FX_ONE - self.mul(xc, xc)
        return self.atan2(self.sqrt(one_minus), xc)
