"""SWAPPER tuning framework (the paper's exploration phase).

Component level
---------------
The paper stimulates the circuit ``4M * 2^(2M)`` times (3 h for 16-bit,
single-threaded).  We reduce this to **O(2^(2M)) total work** with a rank-1
observation: the swap mask of configuration (A, i, v) depends only on operand
A — constant along each row of the (a, b) error grid — so the masked error sum
is a *mask-weighted combination of row sums* of the two error surfaces

    E0(a,b) = |m(a,b) - a*b|      (no swap)
    E1(a,b) = |m(b,a) - a*b|      (swapped)

and symmetrically (B, i, v) configs read *column* sums.  One pass computes
row/col sums, maxima, nonzero counts, squared and relative sums of E0/E1 plus
the pointwise oracle min(E0, E1); every one of the 4M configurations is then
scored for all five paper metrics with a cheap host-side contraction.

All integer accumulation is exact: per-tile sums are carried as 16-bit limb
pairs in uint32 (see core/metrics.py) and recombined in python ints.

Application level
-----------------
``tune_application`` scores every configuration by running the application on
representative inputs with a *dynamic* (traced) swap configuration, so one
compilation serves the whole sweep (paper: one run per configuration).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .metrics import ErrorStats, abs_err
from .multipliers import AxMult
from .swapper import SwapConfig, all_configs

__all__ = [
    "tile_stats_jnp",
    "ComponentResult",
    "component_sweep",
    "operand_values",
    "tune_application",
    "TwoBitConfig",
    "two_bit_sweep",
    "swap_mask_two_bit",
    "apply_swapper_two_bit",
]

MINIMIZE = {"mae": True, "wce": True, "are": True, "mse": True, "ep": True}


# ---------------------------------------------------------------------------
# component level
# ---------------------------------------------------------------------------

def operand_values(bits: int, signed: bool, sample_bits: Optional[int] = None,
                   seed: int = 0) -> np.ndarray:
    """The operand population: exhaustive for small widths, a fixed-seed
    random subset of 2^sample_bits distinct values otherwise (all bit
    positions remain exercised, unlike strided subsampling)."""
    lo, hi = (-(1 << (bits - 1)), 1 << (bits - 1)) if signed else (0, 1 << bits)
    vals = np.arange(lo, hi, dtype=np.int64)
    if sample_bits is not None and sample_bits < bits:
        rng = np.random.default_rng(seed)
        vals = rng.choice(vals, size=1 << sample_bits, replace=False)
        vals.sort()
    return vals.astype(np.int32)


def _row_stats(e, exact_abs_f, axis):
    """Exact limb sums + max + nonzero count + float sq/rel sums along axis."""
    lo = (e & jnp.uint32(0xFFFF)).astype(jnp.uint32)
    hi = (e >> jnp.uint32(16)).astype(jnp.uint32)
    ef = e.astype(jnp.float32)
    rel = ef / jnp.maximum(exact_abs_f, 1.0)
    return dict(
        lo=jnp.sum(lo, axis=axis, dtype=jnp.uint32),
        hi=jnp.sum(hi, axis=axis, dtype=jnp.uint32),
        mx=jnp.max(e, axis=axis),
        cnt=jnp.sum((e != 0).astype(jnp.int32), axis=axis, dtype=jnp.int32),
        sq=jnp.sum(ef * ef, axis=axis, dtype=jnp.float32),
        rel=jnp.sum(rel, axis=axis, dtype=jnp.float32),
    )


@partial(jax.jit, static_argnums=(0,))
def tile_stats_jnp(mult: AxMult, a_vals, b_vals):
    """Pure-jnp tile oracle (the Pallas `tuning_sweep` kernel mirrors this —
    see src/repro/kernels/).  Returns row (per-a) stats of the E0/E1 surfaces
    plus row stats of the oracle surface min(E0,E1).

    Column stats come for free from the transpose identity
    ``E1(a,b) = |m(b,a) - ab| = E0(b,a)``: the two error surfaces are
    transposes of each other, so the per-b column sums of E0 equal the per-b
    row sums of E1 and vice versa.  The sweep driver exploits this — only row
    stats are ever computed (2x tile-compute saving vs the naive framework).
    """
    A = a_vals[:, None]
    B = b_vals[None, :]
    p0 = mult.fn(A, B)
    p1 = mult.fn(B, A)
    exact = mult.exact_product(A, B)
    e0 = abs_err(p0, exact, mult.signed)
    e1 = abs_err(p1, exact, mult.signed)
    emin = jnp.minimum(e0, e1)
    if mult.signed:
        exact_abs = jnp.abs(exact.astype(jnp.float32))
    else:
        exact_abs = exact.astype(jnp.float32)
    return dict(
        r0=_row_stats(e0, exact_abs, 1),
        r1=_row_stats(e1, exact_abs, 1),
        orc=_row_stats(emin, exact_abs, 1),
    )


class _Acc:
    """Host-side exact accumulator for one stats family over tiles."""

    def __init__(self, n_vals):
        self.sum = np.zeros(n_vals, np.int64)
        self.mx = np.zeros(n_vals, np.int64)
        self.cnt = np.zeros(n_vals, np.int64)
        self.sq = np.zeros(n_vals, np.float64)
        self.rel = np.zeros(n_vals, np.float64)

    def add(self, sl, st):
        self.sum[sl] += np.asarray(st["lo"], np.int64) + (np.asarray(st["hi"], np.int64) << 16)
        self.mx[sl] = np.maximum(self.mx[sl], np.asarray(st["mx"], np.int64))
        self.cnt[sl] += np.asarray(st["cnt"], np.int64)
        self.sq[sl] += np.asarray(st["sq"], np.float64)
        self.rel[sl] += np.asarray(st["rel"], np.float64)

    def stats_where(self, mask, n_each) -> ErrorStats:
        s = ErrorStats()
        s.n = int(mask.sum()) * n_each
        s.sum_abs = int(self.sum[mask].sum())
        s.max_abs = int(self.mx[mask].max()) if mask.any() else 0
        s.count_neq = int(self.cnt[mask].sum())
        s.sum_sq = float(self.sq[mask].sum())
        s.sum_rel = float(self.rel[mask].sum())
        return s


def _merge(s1: ErrorStats, s2: ErrorStats) -> ErrorStats:
    out = ErrorStats()
    out.n = s1.n + s2.n
    out.sum_abs = s1.sum_abs + s2.sum_abs
    out.max_abs = max(s1.max_abs, s2.max_abs)
    out.count_neq = s1.count_neq + s2.count_neq
    out.sum_sq = s1.sum_sq + s2.sum_sq
    out.sum_rel = s1.sum_rel + s2.sum_rel
    return out


@dataclasses.dataclass
class ComponentResult:
    """Full component-level tuning output: NoSwap / every config / oracle."""

    mult_name: str
    bits: int
    noswap: ErrorStats
    oracle: ErrorStats
    per_config: Dict[SwapConfig, ErrorStats]

    def best(self, metric: str = "mae") -> SwapConfig:
        return min(self.per_config, key=lambda c: self.per_config[c].metric(metric))

    def reduction(self, metric: str = "mae", cfg: Optional[SwapConfig] = None) -> float:
        """Relative reduction vs NoSwap (the paper's 'SWAPPER' rows)."""
        cfg = cfg or self.best(metric)
        base = self.noswap.metric(metric)
        if base == 0:
            return 0.0
        return (base - self.per_config[cfg].metric(metric)) / base

    def theoretical_reduction(self, metric: str = "mae") -> float:
        """Oracle bound (the paper's 'Theoretical' rows)."""
        base = self.noswap.metric(metric)
        if base == 0:
            return 0.0
        return (base - self.oracle.metric(metric)) / base


def component_sweep(
    mult: AxMult,
    tile: int = 256,
    sample_bits: Optional[int] = None,
    seed: int = 0,
    tile_fn: Callable = tile_stats_jnp,
) -> ComponentResult:
    """Exhaustive (or fixed-seed sampled) component-level SWAPPER tuning."""
    vals = operand_values(mult.bits, mult.signed, sample_bits, seed)
    n = len(vals)
    tile = min(tile, n)
    assert n % tile == 0, (n, tile)
    nt = n // tile

    r0, r1 = _Acc(n), _Acc(n)
    orc = _Acc(n)
    dvals = jnp.asarray(vals)

    for ti in range(nt):
        sa = slice(ti * tile, (ti + 1) * tile)
        for tj in range(nt):
            sb = slice(tj * tile, (tj + 1) * tile)
            st = jax.device_get(tile_fn(mult, dvals[sa], dvals[sb]))
            r0.add(sa, st["r0"])
            r1.add(sa, st["r1"])
            orc.add(sa, st["orc"])

    return result_from_accs(mult, vals, r0, r1, orc)


def result_from_accs(mult: AxMult, vals: np.ndarray, r0: "_Acc", r1: "_Acc",
                     orc: "_Acc") -> ComponentResult:
    """Score NoSwap, all 4M configurations, and the oracle from accumulated
    row statistics (shared by the jnp driver and the Pallas sweep kernel)."""
    n = len(vals)
    all_true = np.ones(n, bool)
    noswap = r0.stats_where(all_true, n)
    oracle = orc.stats_where(all_true, n)

    per_config: Dict[SwapConfig, ErrorStats] = {}
    bitvals = vals.astype(np.int64) & ((1 << mult.bits) - 1)
    for cfg in all_configs(mult.bits):
        sel = ((bitvals >> cfg.bit) & 1) == cfg.value
        if cfg.operand == "A":
            # rows with the bit match use the swapped surface E1
            stats = _merge(r1.stats_where(sel, n), r0.stats_where(~sel, n))
        else:
            # transpose identity: col sums of E1/E0 == row sums of E0/E1
            stats = _merge(r0.stats_where(sel, n), r1.stats_where(~sel, n))
        per_config[cfg] = stats

    return ComponentResult(mult.name, mult.bits, noswap, oracle, per_config)


def accs_from_row_stats(vals: np.ndarray, stats: dict):
    """Build (_Acc r0, r1, orc) from full-length row-stat arrays as returned
    by ``kernels.tuning_sweep.tuning_sweep_pallas``."""
    n = len(vals)
    accs = []
    for surf in ("r0", "r1", "orc"):
        acc = _Acc(n)
        acc.add(slice(None), stats[surf])
        accs.append(acc)
    return tuple(accs)


# ---------------------------------------------------------------------------
# two-bit decisions (beyond-paper: the paper's stated future work,
# "more fine-grained decisions with the goal of further reducing the error")
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoBitConfig:
    """Swap decided by an arbitrary boolean function of TWO operand bits:
    swap <=> table[(bit_p << 1) | bit_q] where p = (op_p, bit_p),
    q = (op_q, bit_q) and table is 4 bools (16 truth tables).  Hardware cost:
    a 4-entry LUT instead of a wire — still O(1)."""

    op_p: str
    bit_p: int
    op_q: str
    bit_q: int
    table: int  # 4-bit truth table, bit (vp*2+vq) set => swap

    def short(self):
        return (f"f({self.op_p}[{self.bit_p}],{self.op_q}[{self.bit_q}])"
                f"=t{self.table:04b}")


def swap_mask_two_bit(a, b, cfg: TwoBitConfig):
    pa = a if cfg.op_p == "A" else b
    qa = a if cfg.op_q == "A" else b
    vp = (pa.astype(jnp.int32) >> cfg.bit_p) & 1
    vq = (qa.astype(jnp.int32) >> cfg.bit_q) & 1
    idx = (vp << 1) | vq
    tbl = jnp.asarray([(cfg.table >> i) & 1 for i in range(4)], jnp.int32)
    return jnp.take(tbl, idx) == 1


def apply_swapper_two_bit(mult: AxMult, a, b, cfg: TwoBitConfig):
    m = swap_mask_two_bit(a, b, cfg)
    return mult.fn(jnp.where(m, b, a), jnp.where(m, a, b))


def two_bit_sweep(mult: AxMult, metric: str = "mae",
                  sample_bits: Optional[int] = None, seed: int = 0):
    """Exhaustive two-bit tuning (sum-metrics: mae/mse/ep/are).

    The masked error sum for a bit pair factorizes over the 4 bit-value
    quadrants: with indicator matrices U (n x 2M_bits) over operand values,
    the conditional block sums are just M_s = U^T E_s U (tiny 2Mx2M
    matrices), after which all pairs x 16 truth tables are scored in closed
    form — the 2-D generalization of the paper's 4M exploration, still
    O(2^(2M)) total work.  Returns (best TwoBitConfig, best_value, stats
    dict with single-bit and noswap references)."""
    assert metric in ("mae", "mse", "ep", "are")
    vals = operand_values(mult.bits, mult.signed, sample_bits, seed)
    n = len(vals)
    M = mult.bits
    dvals = jnp.asarray(vals)

    A = dvals[:, None]
    B = dvals[None, :]
    p0 = mult.fn(A, B)
    p1 = mult.fn(B, A)
    exact = mult.exact_product(A, B)
    e0 = abs_err(p0, exact, mult.signed).astype(jnp.float32)
    e1 = abs_err(p1, exact, mult.signed).astype(jnp.float32)
    if metric == "mse":
        e0, e1 = e0 * e0, e1 * e1
    elif metric == "ep":
        e0, e1 = (e0 != 0).astype(jnp.float32), (e1 != 0).astype(jnp.float32)
    elif metric == "are":
        den = jnp.maximum(jnp.abs(exact.astype(jnp.float32)), 1.0)
        e0, e1 = e0 / den, e1 / den

    # indicator matrix over values: U[v, 2*i + bitval]
    bits = ((vals.astype(np.int64)[:, None] & ((1 << M) - 1)) >> np.arange(M)) & 1
    U = np.zeros((n, 2 * M), np.float32)
    U[np.arange(n)[:, None], 2 * np.arange(M) + bits] = 1.0
    Uj = jnp.asarray(U)

    # conditional block sums: M_s[(i,vi),(j,vj)] = sum over quadrant of E_s
    M0 = np.asarray(Uj.T @ e0 @ Uj, np.float64)   # rows: A-side bit/val
    M1 = np.asarray(Uj.T @ e1 @ Uj, np.float64)
    total0 = float(np.asarray(jnp.sum(e0, dtype=jnp.float32)))

    best = None
    best_val = np.inf
    # pair kinds: (A-bit, B-bit) uses M_s directly; (A,A)/(B,B) pairs reduce
    # to row/col sums with compound masks — cover them by scoring (A,B)
    # pairs plus same-operand pairs via the same quadrant algebra on rows.
    for pi in range(M):
        for qi in range(M):
            for table in range(1, 15):  # skip never/always-swap
                s = 0.0
                for vp in (0, 1):
                    for vq in (0, 1):
                        use1 = (table >> ((vp << 1) | vq)) & 1
                        Msel = M1 if use1 else M0
                        s += Msel[2 * pi + vp, 2 * qi + vq]
                if s < best_val:
                    best_val = s
                    best = TwoBitConfig("A", pi, "B", qi, table)
    stats = {
        "noswap": total0 / (n * n),
        "two_bit": best_val / (n * n),
        "reduction": (total0 - best_val) / total0 if total0 else 0.0,
    }
    return best, best_val / (n * n), stats


# ---------------------------------------------------------------------------
# application level
# ---------------------------------------------------------------------------

def tune_application(
    run_app: Callable,
    bits: int,
    minimize: bool = True,
    configs: Optional[Sequence[Optional[SwapConfig]]] = None,
    include_noswap: bool = True,
):
    """Application-level tuning (paper §II / §III.B).

    ``run_app(op_is_a, bit, value)`` -> scalar application metric, with the
    swap configuration passed as **traced** int32 scalars (one compile for the
    whole sweep; pass value=2 for the NoSwap reference).  NoSwap itself is a
    candidate (the framework keeps the original order when no single bit
    helps).  Returns (best_cfg_or_None, best_metric, table).
    """
    if configs is None:
        configs = all_configs(bits)
        if include_noswap:
            configs = [None] + configs
    else:
        configs = list(configs)
    table: Dict[Optional[SwapConfig], float] = {}
    for cfg in configs:
        if cfg is None:
            v = run_app(jnp.int32(1), jnp.int32(0), jnp.int32(2))
        else:
            v = run_app(
                jnp.int32(1 if cfg.operand == "A" else 0),
                jnp.int32(cfg.bit),
                jnp.int32(cfg.value),
            )
        table[cfg] = float(v)
    key = min if minimize else max
    best = key(configs, key=lambda c: table[c])
    return best, table[best], table
