"""SWAPPER core — the paper's contribution as a composable JAX module.

Layers:
  multipliers  — bit-accurate approximate multiplier families (AxICs)
  swapper      — single-bit dynamic operand swapping (the paper's mechanism)
  metrics      — MAE / WCE / ARE / MSE / EP (paper Eqs. 1-5)
  tuning       — component- and application-level exploration framework
  modular      — Eq. 6: 32-bit multiply from 16-bit approximate parts
  fixedpoint   — Q16.16 math library with injectable approximate multiply
"""
from .metrics import METRICS, ErrorStats, abs_err, are, ep, mae, mse, wce
from .modular import (
    PART_ALL,
    PART_MD_LO,
    PART_NONE,
    AxMul32Config,
    ax_fxp_mul,
    ax_fxp_mul_dyn,
)
from .multipliers import (
    REGISTRY,
    AxMult,
    broken_array,
    drum,
    exact,
    get,
    is_commutative,
    lut_mult,
    make_lut,
    mitchell,
    perforate,
    trunc,
)
from .swapper import (
    SwapConfig,
    all_configs,
    apply_swapper,
    apply_swapper_dyn,
    cfg_to_dyn,
    oracle_mult,
    swap_mask,
    swap_mask_dyn,
    swapped_mult,
)
from .tiling import rowtile_count, rowtile_index, rowtile_span
from .tuning import (
    ComponentResult,
    TwoBitConfig,
    apply_swapper_two_bit,
    component_sweep,
    operand_values,
    swap_mask_two_bit,
    tile_stats_jnp,
    tune_application,
    two_bit_sweep,
)
from .fixedpoint import FX_ONE, FxpMath, from_fxp, make_mul, to_fxp

__all__ = [n for n in dir() if not n.startswith("_")]
