"""Eq. 6 of the paper: 32-bit multiplication composed from 16-bit multiplies.

    A*B = (AH*2^16 + AL) * (BH*2^16 + BL)
        = AH*BH*2^32  (HI)  +  (AH*BL + AL*BH)*2^16  (MD)  +  AL*BL  (LO)

The paper plugs 16-bit *signed* EvoApprox multipliers (mul16s) into the three
partial products; because AL/BL are unsigned 16-bit values, MD/LO operands are
shifted right by one position to fit the signed range ("we shift the input
values to one position right for MD and LO multiplications"), and the partial
result is shifted back (the dropped LSB row is part of the approximation).
The HI part can be kept precise (the paper's "MD and LO" configuration) or
approximated too ("ALL").

``lsb_fix=True`` is a **beyond-paper** accuracy option: it re-adds the exact
LSB partial-product rows lost to the fit-to-signed shifts
(AL*BL = 4ab + rb*(AL&~1) + ra*(BL&~1) + (ra&rb) for AL=2a+ra, BL=2b+rb),
costing three selects + two adds per multiply.

Everything is carried in int32/uint32 lanes with well-defined modular
wraparound — no 64-bit types are needed (DESIGN.md §4): for a Q16.16
fixed-point multiply the result is bits [16:48) of the 64-bit product,

    (A*B) >> 16  ==  (HI << 16) + MD + (LO_u >> 16)        (mod 2^32)

which is exact because LO is the only term that is not a multiple of 2^16.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from .multipliers import AxMult
from .swapper import SwapConfig, apply_swapper, apply_swapper_dyn

__all__ = [
    "AxMul32Config",
    "PART_ALL",
    "PART_MD_LO",
    "PART_NONE",
    "ax_fxp_mul",
    "ax_fxp_mul_dyn",
]

PART_ALL = ("HI", "MD", "LO")
PART_MD_LO = ("MD", "LO")
PART_NONE = ()


@dataclasses.dataclass(frozen=True)
class AxMul32Config:
    """Which 16-bit partial products are approximated, with which multiplier,
    and which SWAPPER configuration (None = NoSwap)."""

    mult: AxMult                       # 16-bit signed multiplier
    parts: tuple = PART_MD_LO          # subset of {"HI","MD","LO"}
    swap: Optional[SwapConfig] = None
    lsb_fix: bool = False              # beyond-paper LSB-row restoration

    def __post_init__(self):
        assert self.mult.bits == 16 and self.mult.signed, "paper uses mul16s"


def _u32(x):
    return x.astype(jnp.uint32)


def _split(x):
    """int32 -> (high signed 16, low unsigned 16)."""
    xh = (x >> 16).astype(jnp.int32)
    xl = (x & 0xFFFF).astype(jnp.int32)
    return xh, xl


def _ax(cfg: AxMul32Config, a, b, dyn):
    if dyn is not None:
        return apply_swapper_dyn(cfg.mult, a, b, *dyn).astype(jnp.int32)
    return apply_swapper(cfg.mult, a, b, cfg.swap).astype(jnp.int32)


def _mul32_body(A, B, cfg: Optional[AxMul32Config], dyn):
    A = A.astype(jnp.int32)
    B = B.astype(jnp.int32)
    AH, AL = _split(A)
    BH, BL = _split(B)
    parts = cfg.parts if cfg is not None else PART_NONE
    fix = cfg.lsb_fix if cfg is not None else False

    # ---- HI: signed x signed — native mul16s domain -------------------
    if "HI" in parts:
        hi = _u32(_ax(cfg, AH, BH, dyn))
    else:
        hi = _u32(AH * BH)  # |AH*BH| <= 2^30, fits int32

    # ---- MD: signed x unsigned -----------------------------------------
    if "MD" in parts:
        md1 = _u32(_ax(cfg, AH, BL >> 1, dyn)) << 1
        md2 = _u32(_ax(cfg, BH, AL >> 1, dyn)) << 1
        if fix:  # AH*BL = 2*AH*(BL>>1) + AH*(BL&1)
            md1 = md1 + _u32(jnp.where((BL & 1) != 0, AH, 0))
            md2 = md2 + _u32(jnp.where((AL & 1) != 0, BH, 0))
    else:
        md1 = _u32(AH * BL)  # in (-2^31, 2^31), fits int32 exactly
        md2 = _u32(BH * AL)

    # ---- LO: unsigned x unsigned ----------------------------------------
    if "LO" in parts:
        lo = _u32(_ax(cfg, AL >> 1, BL >> 1, dyn)) << 2
        if fix:  # AL*BL = 4ab + rb*(AL&~1) + ra*(BL&~1) + (ra & rb)
            ra = AL & 1
            rb = BL & 1
            lo = (
                lo
                + _u32(jnp.where(rb != 0, AL & ~1, 0))
                + _u32(jnp.where(ra != 0, BL & ~1, 0))
                + _u32(ra & rb)
            )
    else:
        lo = _u32(AL) * _u32(BL)  # < 2^32, exact in uint32

    # ---- Q16.16 recombination: (product >> 16) mod 2^32 ------------------
    res = (hi << 16) + md1 + md2 + (lo >> 16)
    return res.astype(jnp.int32)


def ax_fxp_mul(A, B, cfg: Optional[AxMul32Config] = None):
    """Q16.16 fixed-point multiply via Eq. 6.  ``cfg=None`` (or empty parts)
    -> bit-exact vs the int64 reference (see tests)."""
    return _mul32_body(A, B, cfg, None)


def ax_fxp_mul_dyn(A, B, cfg: AxMul32Config, op_is_a, bit, value):
    """Dynamic-swap-config variant for the application-level tuner: the
    SWAPPER (operand, bit, value) triple is traced, so one compiled
    application scores the whole 4M-configuration sweep."""
    return _mul32_body(A, B, cfg, (op_is_a, bit, value))
