"""The row -> row-tile map shared by everything per-tile.

Per-tile semantics only hold if the rows a config tile is *applied to* are
exactly the rows whose telemetry that tile *observed*.  Three layers
consume the same partition — execution (``quant.ax``: the mxu per-row path
and the Pallas grid-kernel block alignment), telemetry
(``runtime.telemetry.tile_summary``) and the controller's buffers — so the
partition lives here once: ``gm`` requested tiles become
``rowtile_count = min(gm, M)`` actual tiles of ``rowtile_span =
floor(M / count)`` rows, the LAST tile absorbing the remainder (up to
``span - 1`` extra rows).  The floor span guarantees every tile is
occupied by real rows — a ceil span would leave trailing "ghost" tiles
whose telemetry could only be fabricated and whose published configs no
row would ever execute.  All host-side numpy: tile *membership* is a
compile-time constant everywhere; only the config values are traced.
"""
from __future__ import annotations

import numpy as np

__all__ = ["rowtile_count", "rowtile_span", "rowtile_index",
           "largest_divisor_leq"]


def largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1).  The block/slab
    sizing primitive shared by the Pallas reduction schedule
    (``kernels.ax_matmul._pick_k_slab``) and the tile-aligned block choice
    of the quant layer (``quant.ax._block_of``)."""
    d = max(1, min(n, cap))
    while n % d:
        d -= 1
    return d


def rowtile_count(M: int, gm: int) -> int:
    """Actual number of row tiles: ``gm`` capped by the row count."""
    return max(1, min(gm, M))


def rowtile_span(M: int, gm: int) -> int:
    """Rows per tile, ``floor(M / rowtile_count)`` (the last tile absorbs
    the remainder, so every tile holds at least ``span`` real rows)."""
    return max(1, M // rowtile_count(M, gm))


def rowtile_index(M: int, gm: int) -> np.ndarray:
    """(M,) int array: the tile index of every row (last tile absorbs the
    remainder when the span does not divide ``M``)."""
    return np.minimum(np.arange(M) // rowtile_span(M, gm),
                      rowtile_count(M, gm) - 1)
