"""Error metrics from the paper (Eqs. 1-5): MAE, WCE, ARE, MSE, EP.

Two forms are provided:

* direct array metrics (``mae(approx, precise)`` ...) used by tests and the
  application-level tuner;
* an exact *streaming accumulator* (:class:`ErrorStats`) used by the
  component-level tuner, which must aggregate over up to 2^32 input pairs
  without precision loss.  Absolute errors of 16-bit multipliers reach
  ~1.5 * 2^31, so sums are carried as split 16-bit limb partial sums (exact
  in uint32 per tile, recombined on the host in int64/float64).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

__all__ = ["abs_err", "mae", "wce", "are", "mse", "ep", "METRICS", "ErrorStats"]


def abs_err(approx, precise, signed: bool):
    """Exact |approx - precise| in uint32 lanes (handles the int32-overflowing
    signed case: both values in (-2^31, 2^31) so |diff| < 2^32 fits uint32)."""
    au = approx.astype(jnp.uint32)
    pu = precise.astype(jnp.uint32)
    if signed:
        big = approx.astype(jnp.int32) >= precise.astype(jnp.int32)
    else:
        big = au >= pu
    return jnp.where(big, au - pu, pu - au)


def _err_f64(approx, precise, signed):
    e = np.asarray(abs_err(approx, precise, signed))
    return e.astype(np.float64)


def mae(approx, precise, signed: bool) -> float:
    return float(_err_f64(approx, precise, signed).mean())


def wce(approx, precise, signed: bool) -> float:
    return float(_err_f64(approx, precise, signed).max())


def are(approx, precise, signed: bool) -> float:
    """Average relative error; zero-denominator inputs use denominator 1
    (the AxBench qos convention of still counting an error when the
    reference is 0)."""
    e = _err_f64(approx, precise, signed)
    p = np.abs(np.asarray(precise).astype(np.float64))
    return float((e / np.maximum(p, 1.0)).mean())


def mse(approx, precise, signed: bool) -> float:
    e = _err_f64(approx, precise, signed)
    return float((e * e).mean())


def ep(approx, precise, signed: bool) -> float:
    e = _err_f64(approx, precise, signed)
    return float((e != 0).mean())


METRICS = {"mae": mae, "wce": wce, "are": are, "mse": mse, "ep": ep}


@dataclasses.dataclass
class ErrorStats:
    """Exact streaming accumulator for one error population.

    Partial sums arrive from tile kernels as uint32 limb sums (see
    ``core/tuning.py``) and are recombined here in int64/float64.
    """

    n: int = 0
    sum_abs: int = 0            # exact, int64 semantics (python int)
    max_abs: int = 0
    count_neq: int = 0
    sum_sq: float = 0.0         # float64 (MSE tolerated at ~1e-6 relative)
    sum_rel: float = 0.0        # float64

    def add_limbs(self, n, lo_sum, hi_sum, max_abs, count_neq, sum_sq, sum_rel):
        self.n += int(n)
        self.sum_abs += int(lo_sum) + (int(hi_sum) << 16)
        self.max_abs = max(self.max_abs, int(max_abs))
        self.count_neq += int(count_neq)
        self.sum_sq += float(sum_sq)
        self.sum_rel += float(sum_rel)

    # -- metric views -------------------------------------------------
    @property
    def mae(self) -> float:
        return self.sum_abs / max(self.n, 1)

    @property
    def wce(self) -> float:
        return float(self.max_abs)

    @property
    def mse(self) -> float:
        return self.sum_sq / max(self.n, 1)

    @property
    def ep(self) -> float:
        return self.count_neq / max(self.n, 1)

    @property
    def are(self) -> float:
        return self.sum_rel / max(self.n, 1)

    def metric(self, name: str) -> float:
        return getattr(self, name)
