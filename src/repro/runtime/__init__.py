"""Online adaptive SWAPPER runtime (DESIGN: telemetry -> drift -> re-tune).

Closes the loop between tuning and execution for the paper's *online* error
reduction claim:

  scope      — trace-time dynamic-policy context: swap configs enter compiled
               steps as traced int32 triples; telemetry summaries leave as
               ordinary outputs (zero recompiles on policy change)
  telemetry  — streaming, exponentially-decayed operand/error statistics on
               the limb-exact accumulators of ``core/metrics.py``
  policy     — granular, serializable SwapPolicy maps (global / per-tensor /
               per-layer / per-row-tile grids for the scalar-prefetch kernel)
  drift      — bit-occupancy distribution-shift scoring vs the tuned-on
               reference snapshot
  controller — drift-triggered incremental re-tune: one vmapped jitted call
               scores NoSwap + all 4M configs over buffered live operands
"""
from .controller import (
    AdaptiveConfig,
    AdaptiveController,
    RetuneEvent,
    TileRetuneEvent,
    all_triples,
    tile_triples,
)
from .drift import DriftConfig, DriftDetector, drift_score
from .policy import NO_SWAP_TRIPLE, SwapPolicy, triple_of, triple_short
from .scope import AxRuntimeScope, active_scope, ax_scope, fallback_chain
from .telemetry import (
    RETUNE_SAMPLE,
    TELEMETRY_SAMPLE,
    TILE_RETUNE_SAMPLE,
    TILE_TELEMETRY_SAMPLE,
    TargetTelemetry,
    TargetTileTelemetry,
    Telemetry,
    base_target,
    is_tile_key,
    operand_summary,
    tile_key,
    tile_summary,
)

__all__ = [
    "AdaptiveConfig",
    "AdaptiveController",
    "RetuneEvent",
    "TileRetuneEvent",
    "all_triples",
    "tile_triples",
    "DriftConfig",
    "DriftDetector",
    "drift_score",
    "NO_SWAP_TRIPLE",
    "SwapPolicy",
    "triple_of",
    "triple_short",
    "AxRuntimeScope",
    "active_scope",
    "ax_scope",
    "fallback_chain",
    "Telemetry",
    "TargetTelemetry",
    "TargetTileTelemetry",
    "operand_summary",
    "tile_summary",
    "tile_key",
    "is_tile_key",
    "base_target",
    "TELEMETRY_SAMPLE",
    "RETUNE_SAMPLE",
    "TILE_TELEMETRY_SAMPLE",
    "TILE_RETUNE_SAMPLE",
]
