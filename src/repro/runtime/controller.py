"""The adaptive SWAPPER controller: closes the loop between telemetry and
policy.

Per observed step it (1) folds the step's telemetry records into the
streaming accumulators, (2) refreshes per-target operand ring buffers from
the exported samples, (3) scores distribution drift against the snapshot the
current policy was tuned on, and (4) on drift, re-tunes the affected targets
by scoring **all 4M+1 configurations in one vmapped call** of a jitted
scorer built on ``apply_swapper_dyn`` (the one-compile dynamic sweep of
``core/tuning.py``) over the buffered live operands.  The scorer and the
serving step both take the config as traced int32 inputs, so adaptation
costs **zero recompilations** after warm-up.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import multipliers as M
from repro.core.metrics import abs_err
from repro.core.swapper import SwapConfig, all_configs, apply_swapper_dyn

from .drift import DriftConfig, DriftDetector
from .policy import NO_SWAP_TRIPLE, SwapPolicy, triple_of, triple_short
from .telemetry import (Telemetry, TelemetryQuarantine, base_target,
                        is_tile_key, operand_summary, tile_key, tile_summary)


def _chaos():
    """Lazy import of the fleet chaos harness (module-level would cycle:
    fleet.store imports runtime.policy)."""
    from repro.fleet import chaos

    return chaos

__all__ = ["AdaptiveConfig", "RetuneEvent", "TileRetuneEvent",
           "AdaptiveController", "all_triples", "tile_triples"]

# host-side observability (repro.obs): re-tune counters/latency/gain plus
# the append-only audit trail next to the PolicyStore (obs.audit) — every
# policy mutation is a structured event carrying its published store
# version, so the policy history is replayable after the fact.
_REG = obs.default_registry()
_RETUNES = _REG.counter(
    "repro_retunes_total",
    "controller re-tunes by kind (scalar target vs per-row-tile grid)")
_RETUNE_WALL = _REG.histogram(
    "repro_retune_seconds",
    "host wall of one re-tune (vmapped sweep scoring + policy publish)")
_RETUNE_GAIN = _REG.gauge(
    "repro_retune_predicted_gain",
    "per-target predicted error reduction of the last re-tune "
    "(incumbent score - winner score, re-tune metric units)")
_CANARY = _REG.counter(
    "repro_canary_total",
    "candidate policies canaried against the ring-buffer holdout, by outcome "
    "(promoted / rejected)")
_ROLLBACKS = _REG.counter(
    "repro_rollbacks_total",
    "post-adoption guard-band trips: CURRENT re-pointed to last-good")


def all_triples(bits: int) -> np.ndarray:
    """(4M+1, 3) int32 sweep space: NoSwap first, then every single-bit
    config in ``all_configs`` order."""
    rows = [NO_SWAP_TRIPLE] + [triple_of(c) for c in all_configs(bits)]
    return np.asarray(rows, np.int32)


def tile_triples(bits: int) -> np.ndarray:
    """(2M+1, 3) int32 per-row-tile sweep space: NoSwap first, then every
    A-side single-bit config.  Row tiles partition the *A* (activation)
    operand, so the decision that can vary per row tile is A's; B-side
    decisions mask the weight operand shared by every row tile, which the
    single-dispatch mxu factorization cannot vary per output row (see
    ``quant.ax._mxu_limbs_rowtile``).  Restricting the tile sweep to this
    family keeps published tile grids backend-portable."""
    rows = [NO_SWAP_TRIPLE] + [triple_of(c) for c in all_configs(bits)
                               if c.operand == "A"]
    return np.asarray(rows, np.int32)


@functools.partial(jax.jit, static_argnums=(0, 4))
def _score_configs(mult, a, b, triples, metric: str = "mae"):
    """Mean error of every (op_is_a, bit, value) triple over the operand
    sample — one compile serves every re-tune."""
    exact = mult.exact_product(a, b)

    def one(t):
        p = apply_swapper_dyn(mult, a, b, t[0], t[1], t[2])
        e = abs_err(p, exact, mult.signed).astype(jnp.float32)
        if metric == "mse":
            e = e * e
        elif metric == "ep":
            e = (e != 0).astype(jnp.float32)
        return jnp.mean(e)

    return jax.vmap(one)(triples)


@functools.partial(jax.jit, static_argnums=(0, 4))
def _score_configs_tiled(mult, a_tiles, b_tiles, triples, metric: str = "mae"):
    """(gm, n_triples) mean error of every candidate triple over each row
    tile's operand sample — the whole per-tile sweep is one vmapped call of
    the scalar scorer, so tile re-tunes stay zero-recompile after warm-up."""
    return jax.vmap(
        lambda a, b: _score_configs(mult, a, b, triples, metric)
    )(a_tiles, b_tiles)


@functools.partial(jax.jit, static_argnums=(0,))
def _summarize_pair(mult, a, b, dyn):
    """Telemetry record for a raw operand pair stream (benchmarks/tests feed
    the controller without a serving engine)."""
    return operand_summary(a, b, mult, dyn)


@functools.partial(jax.jit, static_argnums=(0, 4))
def _summarize_pair_tiled(mult, a, b, dyn, gm: int):
    """Scalar + per-row-tile records for a raw 2-D operand stream (``a``
    rows are the tiled dimension)."""
    return (operand_summary(a, b, mult, dyn),
            tile_summary(a, b, mult, gm, dyn=dyn))


@dataclasses.dataclass
class AdaptiveConfig:
    decay: float = 0.2             # telemetry EW decay per observed step
    drift_threshold: float = 0.04  # mean bit-probability shift triggering re-tune
    min_observe_steps: int = 4     # warm-up before drift can fire
    cooldown_steps: int = 4        # steps between re-tunes (buffer refresh time)
    buffer_size: int = 2048        # per-target operand ring-buffer elements
    metric: str = "mae"            # re-tune objective
    # per-row-tile adaptation: 0 = off; N > 0 = collect tile telemetry and
    # serve per-row-tile config grids at N row tiles per projection (drift
    # confined to one tile reaches the detector diluted by ~1/N — scale
    # drift_threshold accordingly, as with the fleet's 1/N shard dilution)
    tile_rows: int = 0
    tile_buffer_size: int = 512    # per-(target, tile) operand ring buffer
    # guarded rollout (canary + auto-rollback; docs/robustness.md).  Off by
    # default: single-host experiments keep the direct adopt-on-retune
    # behavior; the fleet driver and chaos paths turn it on.
    canary: bool = False           # publish winners as candidates, canary them
    canary_holdout: int = 256      # newest ring-buffer elements held out
    canary_margin: float = 0.0     # winner must beat incumbent by this frac
    rollback_guard: float = 0.5    # post-adoption ew_mae regression fraction
    rollback_min_steps: int = 2    # observed steps before the guard can fire
    rollback_window: int = 32      # guard watch window (steps) per adoption
    # telemetry admission control (always constructed; `quarantine=False`
    # disables even the NaN/Inf + bounds checks)
    quarantine: bool = True
    quarantine_z: Optional[float] = None   # robust-z MAE outlier threshold


@dataclasses.dataclass
class RetuneEvent:
    step: int
    target: str
    drift: float
    old: Optional[SwapConfig]
    new: Optional[SwapConfig]
    old_score: float
    new_score: float
    promoted: bool = True                   # False: canary rejected the winner
    candidate_version: Optional[int] = None  # store version the attempt holds

    def describe(self) -> str:
        fmt = lambda c: "noswap" if c is None else c.short()
        verdict = "" if self.promoted else " [canary REJECTED, kept incumbent]"
        return (f"retune[{self.target}] step={self.step} drift={self.drift:.3f} "
                f"{fmt(self.old)} ({self.old_score:.2f}) -> "
                f"{fmt(self.new)} ({self.new_score:.2f}){verdict}")


@dataclasses.dataclass
class TileRetuneEvent:
    """One per-row-tile re-tune: the controller scored every candidate in
    ``tile_triples`` per row tile and published the winning grid."""

    step: int
    target: str
    drift: float
    grid: np.ndarray               # (gm, 1, 3) published tile grid
    old_score: float               # mean over tiles, incumbent per-tile cfg
    new_score: float               # mean over tiles, winning per-tile cfg

    def describe(self) -> str:
        cfgs = ",".join(triple_short(t) for t in self.grid[:, 0, :])
        return (f"tile-retune[{self.target}] step={self.step} "
                f"drift={self.drift:.3f} -> ({cfgs}) "
                f"({self.old_score:.2f} -> {self.new_score:.2f})")


class _RingBuffer:
    """Host-side operand ring buffer (recency-biased re-tune sample)."""

    def __init__(self, size: int):
        self.a = np.zeros(size, np.int32)
        self.b = np.zeros(size, np.int32)
        self.pos = 0
        self.filled = 0

    def add(self, a: np.ndarray, b: np.ndarray) -> None:
        a = np.asarray(a, np.int32).reshape(-1)
        b = np.asarray(b, np.int32).reshape(-1)
        n = min(len(a), len(b), len(self.a))
        idx = (self.pos + np.arange(n)) % len(self.a)
        self.a[idx] = a[:n]
        self.b[idx] = b[:n]
        self.pos = int((self.pos + n) % len(self.a))
        self.filled = min(self.filled + n, len(self.a))

    def operands(self):
        """Fixed-shape views (partially-filled slots repeat the newest data
        so the jitted scorer sees one static shape)."""
        if self.filled >= len(self.a):
            return self.a, self.b
        n = max(self.filled, 1)
        reps = -(-len(self.a) // n)
        return (np.tile(self.a[:n], reps)[: len(self.a)],
                np.tile(self.b[:n], reps)[: len(self.a)])

    def recent(self, n: int):
        """The ``n`` most recently written elements as fixed-shape (n,)
        arrays (cyclically tiled when fewer were ever written) — the canary
        holdout: the freshest slice of the live distribution, scored but
        never what the full-buffer sweep optimized on alone."""
        m = min(max(self.filled, 1), n)
        idx = (self.pos - m + np.arange(m)) % len(self.a)
        a, b = self.a[idx], self.b[idx]
        if m < n:
            reps = -(-n // m)
            a = np.tile(a, reps)[:n]
            b = np.tile(b, reps)[:n]
        return a, b


class AdaptiveController:
    """Owns the telemetry, drift detector, operand buffers and the policy."""

    def __init__(
        self,
        policy: SwapPolicy,
        targets: Sequence[str],
        cfg: Optional[AdaptiveConfig] = None,
        log_fn: Optional[Callable[[str], None]] = None,
        store=None,
    ):
        """``store`` — optional ``fleet.PolicyStore`` this controller writes:
        every re-tune is published as a new monotonic version so serve
        replicas (``fleet.PolicyReader``) and elastic restarts
        (:meth:`resume_from_store`) pick the adapted policy up."""
        self.policy = policy
        self.store = store
        self.targets = tuple(targets)
        self.cfg = cfg or AdaptiveConfig()
        self.mult = M.get(policy.mult_name)
        self.telemetry = Telemetry(self.mult.bits, self.cfg.decay)
        self.detector = DriftDetector(DriftConfig(
            threshold=self.cfg.drift_threshold,
            min_steps=self.cfg.min_observe_steps,
        ))
        self.buffers: Dict[str, _RingBuffer] = {
            t: _RingBuffer(self.cfg.buffer_size) for t in self.targets
        }
        self.triples = jnp.asarray(all_triples(self.mult.bits))
        # per-row-tile state (cfg.tile_rows > 0): one ring buffer per
        # (target, row tile), created lazily at the granularity the first
        # tile record reports (min(tile_rows, projection rows))
        self.tile_sweep = jnp.asarray(tile_triples(self.mult.bits))
        self.tile_buffers: Dict[str, List[_RingBuffer]] = {}
        self.tile_retunes: List[TileRetuneEvent] = []
        self.step = 0
        self._dyn_cache = None            # (policy.version, built tree)
        self._last_retune_step = -(10 ** 9)
        self.retunes: List[RetuneEvent] = []
        self.log: List[str] = []
        self._log_fn = log_fn
        # audit trail rides next to the store (obs.audit): store-less
        # controllers (unit tests, single-host experiments) skip it
        self.audit = obs.audit_for_store(store) if store is not None else None
        # telemetry admission control (docs/robustness.md): NaN/Inf + bounds
        # always when enabled; robust-z outliers only with quarantine_z set
        self.quarantine = (TelemetryQuarantine(
            self.mult.bits, z_threshold=self.cfg.quarantine_z)
            if self.cfg.quarantine else None)
        # post-adoption rollback guard state, one slot per promoted target:
        # {target: dict(baseline, version, last_good, last_good_policy,
        #               adopted_step, steps)}
        self._guards: Dict[str, dict] = {}
        self.rollbacks: List[dict] = []
        # QoR SLO engine (obs.slo; optional, attach_slo): fed the per-target
        # ew_mae stream every observed step; an alerting veto-bearing SLO
        # blocks canary promotion, and any alert on a target whose guarded
        # adoption already disarmed re-arms its rollback guard
        self.slo = None
        self._last_adoptions: Dict[str, dict] = {}

    @property
    def tile_rows(self) -> int:
        """Per-row-tile granularity the serving engine should open scopes
        with (0 = scalar mode); mirrored by ``fleet.PolicyReader``."""
        return self.cfg.tile_rows

    # -- plumbing ------------------------------------------------------
    def _emit(self, line: str) -> None:
        self.log.append(line)
        if self._log_fn is not None:
            self._log_fn(line)

    def dyn_tree(self) -> Dict[str, jnp.ndarray]:
        """Traced-input triples — or (tile_rows, 1, 3) per-row-tile grids in
        tile mode — for the serving/training step (stable pytree structure
        AND leaf shapes: policy updates, including tile-grid publishes,
        change values only).  Cached on the policy version so the per-step
        hot path pays no rebuild between re-tunes."""
        if self._dyn_cache is None or self._dyn_cache[0] != self.policy.version:
            self._dyn_cache = (self.policy.version,
                               self.policy.dyn_tree(self.targets,
                                                    self.cfg.tile_rows))
        return self._dyn_cache[1]

    def adopt(self, policy: SwapPolicy) -> None:
        """Replace the live policy (store restore / reader sync).  The dyn
        tree structure is keyed on ``self.targets``, so adoption changes
        traced int32 values only — no retrace downstream."""
        assert policy.mult_name == self.policy.mult_name, (
            policy.mult_name, self.policy.mult_name)
        self.policy = policy
        self._dyn_cache = None

    def resume_from_store(self) -> bool:
        """Elastic-restart protocol: adopt the store's current policy when
        one exists (True), else publish the starting policy as version 1 so a
        crash before the first re-tune still restores deterministically."""
        if self.store is None:
            return False
        got = self.store.load_current()
        if got is not None:
            version, policy = got
            self.adopt(policy)
            self._emit(f"resumed policy v{version} from store")
            return True
        self.store.publish(self.policy)
        return False

    def rebase_reference(self, threshold: Optional[float] = None) -> None:
        """End-of-warm-up freeze: rebase every target's drift reference to
        the *converged* telemetry snapshot (the first-sighting reference is
        still mid-EW-convergence and inflates stationary scores), optionally
        arming the detector with its production ``threshold`` at the same
        time.  Fleet note: a single-shard anomaly reaches this controller
        diluted by the psum over N shards, so fleet thresholds scale ~1/N of
        their single-host settings."""
        for target, snap in self.telemetry.snapshot().items():
            if snap.get("bit_probs") is not None:
                self.detector.rebase(target, snap["bit_probs"])
            if (self.slo is not None and not is_tile_key(target)
                    and snap.get("ew_mae") is not None):
                self.slo.set_reference(target, float(snap["ew_mae"]))
        if threshold is not None:
            self.detector.cfg.threshold = threshold
            self.cfg.drift_threshold = threshold

    def attach_slo(self, engine) -> None:
        """Attach an :class:`repro.obs.slo.SLOEngine`: every observed step
        feeds the per-target ``ew_mae`` stream to its qor specs, the current
        drift-reference MAE seeds the guard bands, alerting veto-bearing
        specs block canary promotion, and qor alerts re-arm the rollback
        guard on that target's most recent promoted adoption."""
        self.slo = engine
        for target, snap in self.telemetry.snapshot().items():
            if not is_tile_key(target) and snap.get("ew_mae") is not None:
                engine.set_reference(target, float(snap["ew_mae"]))

    def warmup(self) -> None:
        """Pre-compile the re-tune scorers (scalar, and per-tile when tile
        mode is on) so later re-tunes cost zero compilations (verified in
        tests via the jit cache size)."""
        zeros = jnp.zeros(self.cfg.buffer_size, jnp.int32)
        _score_configs(self.mult, zeros, zeros, self.triples,
                       self.cfg.metric).block_until_ready()
        if self.cfg.tile_rows > 0:
            tz = jnp.zeros((self.cfg.tile_rows, self.cfg.tile_buffer_size),
                           jnp.int32)
            _score_configs_tiled(self.mult, tz, tz, self.tile_sweep,
                                 self.cfg.metric).block_until_ready()
        if self.cfg.canary:
            # the canary's (2, 3)-triple holdout scoring shape — precompiled
            # here so canaried retunes stay zero-recompile like everything
            # else (tests pin scorer_cache_size across retunes)
            hz = jnp.zeros(self.cfg.canary_holdout, jnp.int32)
            _score_configs(self.mult, hz, hz,
                           jnp.zeros((2, 3), jnp.int32),
                           self.cfg.metric).block_until_ready()

    def scorer_cache_size(self) -> int:
        return _score_configs._cache_size()

    # -- observation ---------------------------------------------------
    def observe(self, records: Dict[str, Dict[str, np.ndarray]]) -> List[str]:
        """Fold one step's scope-collected telemetry in; re-tune on drift.
        Records keyed ``<target>@tiles`` feed the per-row-tile loop (tile
        accumulators + per-tile ring buffers; drift on them triggers
        :meth:`retune_tiles`).  Returns the log lines emitted for this
        step."""
        mark = len(self.log)
        faults = _chaos().fire("controller.observe", step=self.step)
        if faults:
            records = _chaos().poison_records(faults, records)
        if self.quarantine is not None:
            records, dropped = self.quarantine.filter(records)
            for target, reason in dropped:
                self._emit(f"quarantined {target} record ({reason})")
                if self.audit is not None:
                    self.audit.append("quarantine", step=self.step,
                                      target=target, reason=reason)
        self.telemetry.update(records)
        for target, rec in records.items():
            if is_tile_key(target):
                self._tile_buffer_add(base_target(target), rec)
                continue
            buf = self.buffers.get(target)
            if buf is not None:
                buf.add(rec["a_smp"], rec["b_smp"])
        self.step += 1
        if self.slo is not None:
            for target, snap in self.telemetry.snapshot().items():
                if not is_tile_key(target) and snap.get("ew_mae") is not None:
                    self.slo.observe_qor(target, float(snap["ew_mae"]))
            for al in self.slo.alerting():
                # a qor alert on a target whose guarded adoption already
                # disarmed re-arms the rollback guard on that adoption
                la = self._last_adoptions.get(al.source)
                if al.kind != "qor" or al.source in self._guards or la is None:
                    continue
                self._emit(f"slo alert [{al.slo}] re-arming rollback guard "
                           f"on {al.source}")
                self._arm_guard(al.source, la["version"], la["last_good"],
                                la["last_good_policy"], la["ev"])
        # rollback guard BEFORE drift: a regressed adoption must roll back
        # to last-good within one sweep, not race a fresh retune for it
        self._check_guards()

        if self.step - self._last_retune_step > self.cfg.cooldown_steps:
            drifted = self.detector.check(self.telemetry.snapshot())
            for target, score in drifted:
                if is_tile_key(target):
                    if base_target(target) in self.tile_buffers:
                        self.retune_tiles(base_target(target), drift=score)
                elif target in self.buffers:
                    self.retune(target, drift=score)
        return self.log[mark:]

    def _tile_buffer_add(self, target: str, rec: Dict[str, np.ndarray]) -> None:
        """Refresh the per-(target, tile) ring buffers from a stacked tile
        record (samples are (ncalls, S, gm) — tiles on the last axis)."""
        a = np.asarray(rec["tile_a_smp"])
        b = np.asarray(rec["tile_b_smp"])
        gm = a.shape[-1]
        bufs = self.tile_buffers.get(target)
        if bufs is None or len(bufs) != gm:
            bufs = self.tile_buffers[target] = [
                _RingBuffer(self.cfg.tile_buffer_size) for _ in range(gm)]
        for t in range(gm):
            bufs[t].add(a[..., t].reshape(-1), b[..., t].reshape(-1))

    def observe_operands(self, target: str, a, b) -> List[str]:
        """Feed a raw int operand pair batch (no engine required); used by
        benchmarks and synthetic drift streams.  In tile mode a 2-D ``a``
        also produces the per-row-tile record (rows are the tiled dim)."""
        dyn = jnp.asarray(triple_of(self.policy.lookup(target)), jnp.int32)
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if self.cfg.tile_rows > 0 and a.ndim >= 2:
            rec, trec = jax.device_get(_summarize_pair_tiled(
                self.mult, a, b, dyn, self.cfg.tile_rows))
            return self.observe({
                target: {k: np.asarray(v)[None] for k, v in rec.items()},
                tile_key(target): {k: np.asarray(v)[None]
                                   for k, v in trec.items()},
            })
        rec = jax.device_get(_summarize_pair(self.mult, a, b, dyn))
        stacked = {k: np.asarray(v)[None] for k, v in rec.items()}
        return self.observe({target: stacked})

    # -- re-tuning -----------------------------------------------------
    def retune(self, target: str, drift: float = 0.0) -> RetuneEvent:
        """Incremental re-tune of one target over its live operand buffer:
        one vmapped call scores NoSwap + all 4M configs; zero recompiles.

        With ``cfg.canary`` the winner is NOT adopted directly: it is
        published as a store *candidate*, scored head-to-head against the
        incumbent on the holdout (the newest ``canary_holdout`` buffer
        elements — one extra vmapped call of the precompiled scorer), and
        only a confirmed predicted gain promotes it to CURRENT; a rejected
        winner keeps the incumbent serving.  Every promotion arms the
        post-adoption rollback guard (:meth:`_check_guards`)."""
        t0 = time.perf_counter()
        _chaos().maybe_stall(_chaos().fire("controller.retune",
                                           target=target), default=0.05)
        with obs.span("retune", cat="adapt", target=target, drift=drift):
            a, b = self.buffers[target].operands()
            scores = np.asarray(_score_configs(
                self.mult, jnp.asarray(a), jnp.asarray(b), self.triples,
                self.cfg.metric))
            best = int(np.argmin(scores))
            old = self.policy.lookup(target)
            old_idx = int(np.nonzero(
                (np.asarray(self.triples)
                 == np.asarray(triple_of(old))).all(1))[0][0])
            new = None if best == 0 else all_configs(self.mult.bits)[best - 1]
            ev = RetuneEvent(self.step, target, drift, old, new,
                             float(scores[old_idx]), float(scores[best]))
            guarded = self.cfg.canary and best != old_idx
            last_good_policy = self._policy_copy() if guarded else None
            last_good = (self.store.current_version()
                         if guarded and self.store is not None else None)
            self.policy.set_config(target, new)
            veto = None
            canary_scores = None
            if guarded:
                if self.store is not None:
                    ev.candidate_version = self.store.publish_candidate(
                        self.policy)
                ok, canary_scores = self._canary(target, old_idx, best)
                if ok and self.slo is not None:
                    # an alerting veto-bearing SLO pre-empts promotion: a
                    # degraded QoR stream means the holdout score cannot be
                    # trusted to represent live traffic
                    veto = self.slo.vetoes_promotion()
                    if veto is not None:
                        ok = False
                        self._emit(f"canary[{target}] promotion VETOED by "
                                   f"alerting SLO [{veto}]")
                if not ok:
                    # keep the incumbent serving: revert, drop the candidate
                    self.policy.set_config(target, old)
                    if self.store is not None:
                        self.store.reject_candidate(ev.candidate_version)
                    ev.promoted = False
                    _CANARY.inc(1, outcome="slo_veto" if veto else "rejected")
                else:
                    _CANARY.inc(1, outcome="promoted")
            snap = self.telemetry.snapshot().get(target)
            if snap is not None and snap.get("bit_probs") is not None:
                self.detector.rebase(target, snap["bit_probs"])
            self._last_retune_step = self.step
            self.retunes.append(ev)
            self._emit(ev.describe())
            version = None
            if self.store is not None and ev.promoted:
                if guarded:
                    version = self.store.promote(ev.candidate_version)
                else:
                    version = self.store.publish(self.policy)
                self._emit(f"published policy v{version}")
            if guarded and ev.promoted:
                self._arm_guard(target, version, last_good, last_good_policy,
                                ev)
        _RETUNES.inc(1, kind="scalar")
        _RETUNE_WALL.observe(time.perf_counter() - t0)
        _RETUNE_GAIN.set(ev.old_score - ev.new_score, target=target)
        if self.audit is not None:
            kind = ("retune" if ev.promoted
                    else "slo_veto" if veto is not None
                    else "canary_rejected")
            # canary scores ride along on PROMOTED guarded events too: the
            # holdout incumbent-vs-winner delta is the *realized* gain that
            # benchmarks/audit_report.py compares against predicted_gain
            extra = {} if canary_scores is None else dict(canary=canary_scores)
            if veto is not None:
                extra["vetoed_by"] = veto
            self.audit.append(
                kind, step=self.step, target=target, drift=float(drift),
                old="noswap" if old is None else old.short(),
                new="noswap" if new is None else new.short(),
                old_score=ev.old_score, new_score=ev.new_score,
                predicted_gain=ev.old_score - ev.new_score,
                store_version=version,
                candidate_version=ev.candidate_version, **extra)
        return ev

    # -- guarded rollout (canary + auto-rollback) ----------------------
    def _policy_copy(self) -> SwapPolicy:
        """Deep, bit-identical snapshot of the live policy via the same JSON
        round-trip the store uses — a rollback restores *exactly* what the
        replicas were serving before the regressed adoption."""
        return SwapPolicy.from_json(self.policy.to_json())

    def _canary(self, target: str, old_idx: int, best: int):
        """Score incumbent vs winner head-to-head on the canary holdout (the
        ``canary_holdout`` newest ring-buffer elements) with one call of the
        precompiled scorer (shape warmed in :meth:`warmup` — zero
        recompiles).  Confirms when the winner's holdout score beats the
        incumbent's by at least ``canary_margin`` (fraction)."""
        a, b = self.buffers[target].recent(self.cfg.canary_holdout)
        pair = jnp.stack([self.triples[old_idx], self.triples[best]])
        s = np.asarray(_score_configs(self.mult, jnp.asarray(a),
                                      jnp.asarray(b), pair, self.cfg.metric))
        incumbent, winner = float(s[0]), float(s[1])
        ok = winner <= incumbent * (1.0 - self.cfg.canary_margin) + 1e-12
        self._emit(f"canary[{target}] incumbent={incumbent:.3f} "
                   f"winner={winner:.3f} -> "
                   f"{'CONFIRMED' if ok else 'REJECTED'}")
        obs.instant("canary", cat="adapt", target=target,
                    incumbent=incumbent, winner=winner, confirmed=ok)
        return ok, dict(incumbent=incumbent, winner=winner,
                        margin=self.cfg.canary_margin)

    def _arm_guard(self, target: str, version: Optional[int],
                   last_good: Optional[int],
                   last_good_policy: SwapPolicy, ev: RetuneEvent) -> None:
        """Watch a just-promoted adoption: if the target's live ``ew_mae``
        regresses past ``baseline * (1 + rollback_guard)`` within
        ``rollback_window`` observed steps, :meth:`_rollback` fires."""
        snap = self.telemetry.snapshot().get(target) or {}
        base = snap.get("ew_mae")
        self._guards[target] = dict(
            baseline=float(base) if base is not None else float(ev.new_score),
            version=version, last_good=last_good,
            last_good_policy=last_good_policy,
            adopted_step=self.step, steps=0)
        # kept after the guard disarms: an SLO alert on this target re-arms
        # the guard on this (most recent) adoption
        self._last_adoptions[target] = dict(
            version=version, last_good=last_good,
            last_good_policy=last_good_policy, ev=ev)

    def _check_guards(self) -> None:
        """Post-adoption rollback guard sweep (every observed step, before
        drift): disarm guards that survive their window, roll back targets
        whose telemetry MAE regressed past the guard band."""
        if not self._guards:
            return
        snaps = self.telemetry.snapshot()
        for target in list(self._guards):
            g = self._guards[target]
            g["steps"] += 1
            if g["steps"] > self.cfg.rollback_window:
                del self._guards[target]          # adoption survived
                continue
            snap = snaps.get(target)
            if (g["steps"] < self.cfg.rollback_min_steps or snap is None
                    or snap.get("ew_mae") is None):
                continue
            band = g["baseline"] * (1.0 + self.cfg.rollback_guard)
            observed = float(snap["ew_mae"])
            if observed > band:
                self._rollback(target, g, observed=observed, band=band)

    def _rollback(self, target: str, g: dict, observed: float,
                  band: float) -> None:
        """Re-point serving to last-good: restore the pre-adoption policy
        snapshot bit-identically, re-point the store's CURRENT at the
        last-good version (readers adopt on their next poll), rebase the
        drift reference and start a cooldown so the bad window's telemetry
        can't immediately re-trigger the same retune."""
        with obs.span("rollback", cat="adapt", target=target):
            self.policy = g["last_good_policy"]
            self._dyn_cache = None
            version = None
            if self.store is not None and g["last_good"] is not None:
                version = self.store.rollback(g["last_good"])
            snap = self.telemetry.snapshot().get(target)
            if snap is not None and snap.get("bit_probs") is not None:
                self.detector.rebase(target, snap["bit_probs"])
            self._last_retune_step = self.step
            del self._guards[target]
            info = dict(step=self.step, target=target,
                        from_version=g["version"],
                        to_version=(version if version is not None
                                    else g["last_good"]),
                        baseline=g["baseline"], observed=observed)
            self.rollbacks.append(info)
            _ROLLBACKS.inc(1)
            self._emit(
                f"ROLLBACK[{target}] step={self.step} ew_mae={observed:.3f} "
                f"> band={band:.3f} -> restored "
                f"v{info['to_version']}" if info["to_version"] is not None
                else f"ROLLBACK[{target}] step={self.step} "
                     f"ew_mae={observed:.3f} > band={band:.3f}")
        if self.audit is not None:
            self.audit.append(
                "rollback", trigger="rollback", step=self.step, target=target,
                observed_mae=observed, baseline_mae=g["baseline"],
                guard=self.cfg.rollback_guard, from_version=g["version"],
                store_version=version)

    def retune_tiles(self, target: str, drift: float = 0.0) -> TileRetuneEvent:
        """Per-row-tile re-tune of one target: ONE vmapped call scores the
        backend-portable candidate family (NoSwap + every A-side config,
        ``tile_triples``) over every tile's live operand buffer, and the
        per-tile winners are published as the target's
        ``SwapPolicy.tile_grids`` entry — which serve replicas adopt with
        zero recompiles exactly like scalar configs (grids enter compiled
        steps as traced int32 values)."""
        t0 = time.perf_counter()
        with obs.span("retune_tiles", cat="adapt", target=target, drift=drift):
            bufs = self.tile_buffers[target]
            gm = len(bufs)
            a_tiles = np.stack([b.operands()[0] for b in bufs])
            b_tiles = np.stack([b.operands()[1] for b in bufs])
            scores = np.asarray(_score_configs_tiled(
                self.mult, jnp.asarray(a_tiles), jnp.asarray(b_tiles),
                self.tile_sweep, self.cfg.metric))          # (gm, 2M+1)
            best = np.argmin(scores, axis=1)                # per-tile winner
            sweep = np.asarray(self.tile_sweep)
            grid = sweep[best][:, None, :]                  # (gm, 1, 3)

            # incumbent per-tile score (for the event log): the currently
            # published grid resampled to this granularity, mapped into the
            # tile sweep (B-side incumbents fall back to NoSwap = index 0,
            # matching their per-row-tile execution semantics)
            old_grid = self.policy.tile_grid(target, gm, 1)
            old_idx = np.zeros(gm, np.int64)
            for t in range(gm):
                hit = np.nonzero((sweep == old_grid[t, 0]).all(1))[0]
                old_idx[t] = hit[0] if len(hit) else 0
            old_score = float(np.mean(scores[np.arange(gm), old_idx]))
            new_score = float(np.mean(scores[np.arange(gm), best]))

            self.policy.set_tile_grid(target, grid)
            snap = self.telemetry.snapshot().get(tile_key(target))
            if snap is not None and snap.get("bit_probs") is not None:
                self.detector.rebase(tile_key(target), snap["bit_probs"])
            self._last_retune_step = self.step
            ev = TileRetuneEvent(self.step, target, drift, grid,
                                 old_score, new_score)
            self.tile_retunes.append(ev)
            self._emit(ev.describe())
            version = None
            if self.store is not None:
                version = self.store.publish(self.policy)
                self._emit(f"published policy v{version}")
        _RETUNES.inc(1, kind="tile")
        _RETUNE_WALL.observe(time.perf_counter() - t0)
        _RETUNE_GAIN.set(old_score - new_score, target=target)
        if self.audit is not None:
            self.audit.append(
                "tile_retune", step=self.step, target=target,
                drift=float(drift), tile_rows=gm,
                grid_digest=obs.grid_digest(grid),
                old_score=old_score, new_score=new_score,
                predicted_gain=old_score - new_score, store_version=version)
        return ev
