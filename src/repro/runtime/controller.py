"""The adaptive SWAPPER controller: closes the loop between telemetry and
policy.

Per observed step it (1) folds the step's telemetry records into the
streaming accumulators, (2) refreshes per-target operand ring buffers from
the exported samples, (3) scores distribution drift against the snapshot the
current policy was tuned on, and (4) on drift, re-tunes the affected targets
by scoring **all 4M+1 configurations in one vmapped call** of a jitted
scorer built on ``apply_swapper_dyn`` (the one-compile dynamic sweep of
``core/tuning.py``) over the buffered live operands.  The scorer and the
serving step both take the config as traced int32 inputs, so adaptation
costs **zero recompilations** after warm-up.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import multipliers as M
from repro.core.metrics import abs_err
from repro.core.swapper import SwapConfig, all_configs, apply_swapper_dyn

from .drift import DriftConfig, DriftDetector
from .policy import NO_SWAP_TRIPLE, SwapPolicy, triple_of
from .telemetry import Telemetry, operand_summary

__all__ = ["AdaptiveConfig", "RetuneEvent", "AdaptiveController", "all_triples"]


def all_triples(bits: int) -> np.ndarray:
    """(4M+1, 3) int32 sweep space: NoSwap first, then every single-bit
    config in ``all_configs`` order."""
    rows = [NO_SWAP_TRIPLE] + [triple_of(c) for c in all_configs(bits)]
    return np.asarray(rows, np.int32)


@functools.partial(jax.jit, static_argnums=(0, 4))
def _score_configs(mult, a, b, triples, metric: str = "mae"):
    """Mean error of every (op_is_a, bit, value) triple over the operand
    sample — one compile serves every re-tune."""
    exact = mult.exact_product(a, b)

    def one(t):
        p = apply_swapper_dyn(mult, a, b, t[0], t[1], t[2])
        e = abs_err(p, exact, mult.signed).astype(jnp.float32)
        if metric == "mse":
            e = e * e
        elif metric == "ep":
            e = (e != 0).astype(jnp.float32)
        return jnp.mean(e)

    return jax.vmap(one)(triples)


@functools.partial(jax.jit, static_argnums=(0,))
def _summarize_pair(mult, a, b, dyn):
    """Telemetry record for a raw operand pair stream (benchmarks/tests feed
    the controller without a serving engine)."""
    return operand_summary(a, b, mult, dyn)


@dataclasses.dataclass
class AdaptiveConfig:
    decay: float = 0.2             # telemetry EW decay per observed step
    drift_threshold: float = 0.04  # mean bit-probability shift triggering re-tune
    min_observe_steps: int = 4     # warm-up before drift can fire
    cooldown_steps: int = 4        # steps between re-tunes (buffer refresh time)
    buffer_size: int = 2048        # per-target operand ring-buffer elements
    metric: str = "mae"            # re-tune objective


@dataclasses.dataclass
class RetuneEvent:
    step: int
    target: str
    drift: float
    old: Optional[SwapConfig]
    new: Optional[SwapConfig]
    old_score: float
    new_score: float

    def describe(self) -> str:
        fmt = lambda c: "noswap" if c is None else c.short()
        return (f"retune[{self.target}] step={self.step} drift={self.drift:.3f} "
                f"{fmt(self.old)} ({self.old_score:.2f}) -> "
                f"{fmt(self.new)} ({self.new_score:.2f})")


class _RingBuffer:
    """Host-side operand ring buffer (recency-biased re-tune sample)."""

    def __init__(self, size: int):
        self.a = np.zeros(size, np.int32)
        self.b = np.zeros(size, np.int32)
        self.pos = 0
        self.filled = 0

    def add(self, a: np.ndarray, b: np.ndarray) -> None:
        a = np.asarray(a, np.int32).reshape(-1)
        b = np.asarray(b, np.int32).reshape(-1)
        n = min(len(a), len(b), len(self.a))
        idx = (self.pos + np.arange(n)) % len(self.a)
        self.a[idx] = a[:n]
        self.b[idx] = b[:n]
        self.pos = int((self.pos + n) % len(self.a))
        self.filled = min(self.filled + n, len(self.a))

    def operands(self):
        """Fixed-shape views (partially-filled slots repeat the newest data
        so the jitted scorer sees one static shape)."""
        if self.filled >= len(self.a):
            return self.a, self.b
        n = max(self.filled, 1)
        reps = -(-len(self.a) // n)
        return (np.tile(self.a[:n], reps)[: len(self.a)],
                np.tile(self.b[:n], reps)[: len(self.a)])


class AdaptiveController:
    """Owns the telemetry, drift detector, operand buffers and the policy."""

    def __init__(
        self,
        policy: SwapPolicy,
        targets: Sequence[str],
        cfg: Optional[AdaptiveConfig] = None,
        log_fn: Optional[Callable[[str], None]] = None,
        store=None,
    ):
        """``store`` — optional ``fleet.PolicyStore`` this controller writes:
        every re-tune is published as a new monotonic version so serve
        replicas (``fleet.PolicyReader``) and elastic restarts
        (:meth:`resume_from_store`) pick the adapted policy up."""
        self.policy = policy
        self.store = store
        self.targets = tuple(targets)
        self.cfg = cfg or AdaptiveConfig()
        self.mult = M.get(policy.mult_name)
        self.telemetry = Telemetry(self.mult.bits, self.cfg.decay)
        self.detector = DriftDetector(DriftConfig(
            threshold=self.cfg.drift_threshold,
            min_steps=self.cfg.min_observe_steps,
        ))
        self.buffers: Dict[str, _RingBuffer] = {
            t: _RingBuffer(self.cfg.buffer_size) for t in self.targets
        }
        self.triples = jnp.asarray(all_triples(self.mult.bits))
        self.step = 0
        self._dyn_cache = None            # (policy.version, built tree)
        self._last_retune_step = -(10 ** 9)
        self.retunes: List[RetuneEvent] = []
        self.log: List[str] = []
        self._log_fn = log_fn

    # -- plumbing ------------------------------------------------------
    def _emit(self, line: str) -> None:
        self.log.append(line)
        if self._log_fn is not None:
            self._log_fn(line)

    def dyn_tree(self) -> Dict[str, jnp.ndarray]:
        """Traced-input triples for the serving/training step (stable pytree
        structure: policy updates change values only, never keys).  Cached on
        the policy version so the per-step hot path pays no rebuild between
        re-tunes."""
        if self._dyn_cache is None or self._dyn_cache[0] != self.policy.version:
            self._dyn_cache = (self.policy.version,
                               self.policy.dyn_tree(self.targets))
        return self._dyn_cache[1]

    def adopt(self, policy: SwapPolicy) -> None:
        """Replace the live policy (store restore / reader sync).  The dyn
        tree structure is keyed on ``self.targets``, so adoption changes
        traced int32 values only — no retrace downstream."""
        assert policy.mult_name == self.policy.mult_name, (
            policy.mult_name, self.policy.mult_name)
        self.policy = policy
        self._dyn_cache = None

    def resume_from_store(self) -> bool:
        """Elastic-restart protocol: adopt the store's current policy when
        one exists (True), else publish the starting policy as version 1 so a
        crash before the first re-tune still restores deterministically."""
        if self.store is None:
            return False
        got = self.store.load_current()
        if got is not None:
            version, policy = got
            self.adopt(policy)
            self._emit(f"resumed policy v{version} from store")
            return True
        self.store.publish(self.policy)
        return False

    def rebase_reference(self, threshold: Optional[float] = None) -> None:
        """End-of-warm-up freeze: rebase every target's drift reference to
        the *converged* telemetry snapshot (the first-sighting reference is
        still mid-EW-convergence and inflates stationary scores), optionally
        arming the detector with its production ``threshold`` at the same
        time.  Fleet note: a single-shard anomaly reaches this controller
        diluted by the psum over N shards, so fleet thresholds scale ~1/N of
        their single-host settings."""
        for target, snap in self.telemetry.snapshot().items():
            if snap.get("bit_probs") is not None:
                self.detector.rebase(target, snap["bit_probs"])
        if threshold is not None:
            self.detector.cfg.threshold = threshold
            self.cfg.drift_threshold = threshold

    def warmup(self) -> None:
        """Pre-compile the re-tune scorer so later re-tunes cost zero
        compilations (verified in tests via the jit cache size)."""
        zeros = jnp.zeros(self.cfg.buffer_size, jnp.int32)
        _score_configs(self.mult, zeros, zeros, self.triples,
                       self.cfg.metric).block_until_ready()

    def scorer_cache_size(self) -> int:
        return _score_configs._cache_size()

    # -- observation ---------------------------------------------------
    def observe(self, records: Dict[str, Dict[str, np.ndarray]]) -> List[str]:
        """Fold one step's scope-collected telemetry in; re-tune on drift.
        Returns the log lines emitted for this step."""
        mark = len(self.log)
        self.telemetry.update(records)
        for target, rec in records.items():
            buf = self.buffers.get(target)
            if buf is not None:
                buf.add(rec["a_smp"], rec["b_smp"])
        self.step += 1

        if self.step - self._last_retune_step > self.cfg.cooldown_steps:
            drifted = self.detector.check(self.telemetry.snapshot())
            for target, score in drifted:
                if target in self.buffers:
                    self.retune(target, drift=score)
        return self.log[mark:]

    def observe_operands(self, target: str, a, b) -> List[str]:
        """Feed a raw int operand pair batch (no engine required); used by
        benchmarks and synthetic drift streams."""
        dyn = jnp.asarray(triple_of(self.policy.lookup(target)), jnp.int32)
        rec = jax.device_get(_summarize_pair(self.mult, jnp.asarray(a),
                                             jnp.asarray(b), dyn))
        stacked = {k: np.asarray(v)[None] for k, v in rec.items()}
        return self.observe({target: stacked})

    # -- re-tuning -----------------------------------------------------
    def retune(self, target: str, drift: float = 0.0) -> RetuneEvent:
        """Incremental re-tune of one target over its live operand buffer:
        one vmapped call scores NoSwap + all 4M configs; zero recompiles."""
        a, b = self.buffers[target].operands()
        scores = np.asarray(_score_configs(
            self.mult, jnp.asarray(a), jnp.asarray(b), self.triples,
            self.cfg.metric))
        best = int(np.argmin(scores))
        old = self.policy.lookup(target)
        old_idx = int(np.nonzero(
            (np.asarray(self.triples) == np.asarray(triple_of(old))).all(1))[0][0])
        new = None if best == 0 else all_configs(self.mult.bits)[best - 1]
        self.policy.set_config(target, new)
        snap = self.telemetry.snapshot().get(target)
        if snap is not None and snap.get("bit_probs") is not None:
            self.detector.rebase(target, snap["bit_probs"])
        self._last_retune_step = self.step
        ev = RetuneEvent(self.step, target, drift, old, new,
                         float(scores[old_idx]), float(scores[best]))
        self.retunes.append(ev)
        self._emit(ev.describe())
        if self.store is not None:
            v = self.store.publish(self.policy)
            self._emit(f"published policy v{v}")
        return ev
