"""Streaming operand/error telemetry for the adaptive SWAPPER runtime.

Two halves:

* **In-graph summaries** (:func:`operand_summary`) — tiny fixed-shape
  statistics computed on sampled int8 operands inside the compiled step:
  per-bit occupancy counts of both operands, exact absolute-error limb sums
  of the *live* policy (same 16-bit-limb scheme as ``core/metrics.py``), and
  a small operand sample that feeds the controller's re-tune buffer.  Cheap
  enough to leave on in serving: a handful of shifts/masks and reductions
  over ≤ ``TELEMETRY_SAMPLE`` elements per projection.

* **Host accumulators** (:class:`Telemetry`) — exponentially-decayed bit
  occupancy probabilities (the drift signal) plus an exact cumulative
  :class:`~repro.core.metrics.ErrorStats` window recombined from the limb
  sums, per target.

* **Admission control** (:class:`TelemetryQuarantine`) — sanitization in
  front of the accumulators: NaN/Inf records, records violating the
  summary's structural invariants (counts bounded by the sample size,
  operand codes bounded by the multiplier width), and — optionally —
  robust-z step-MAE outliers are quarantined BEFORE they can reach ring
  buffers or drift scores, so one poisoned shard cannot trigger (or skew)
  a fleet retune.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.metrics import ErrorStats, abs_err
from repro.core.multipliers import AxMult
from repro.core.swapper import NO_SWAP_TRIPLE, apply_swapper_dyn

__all__ = [
    "TELEMETRY_SAMPLE",
    "RETUNE_SAMPLE",
    "TILE_TELEMETRY_SAMPLE",
    "TILE_RETUNE_SAMPLE",
    "TILE_KEY_SUFFIX",
    "SUM_FIELDS",
    "MAX_FIELDS",
    "SAMPLE_FIELDS",
    "tile_key",
    "is_tile_key",
    "base_target",
    "operand_summary",
    "tile_summary",
    "combine_records",
    "TargetTelemetry",
    "TargetTileTelemetry",
    "Telemetry",
    "TelemetryQuarantine",
]

TELEMETRY_SAMPLE = 2048   # elements of each operand entering the bit/error stats
RETUNE_SAMPLE = 512       # operand sample exported per call for the re-tune buffer
TILE_TELEMETRY_SAMPLE = 512  # per-row-tile elements entering the tile bit stats
TILE_RETUNE_SAMPLE = 256     # per-row-tile operand sample for the tile buffers

# Tile records travel the same scope -> controller -> fleet plumbing as the
# scalar operand summaries, keyed by ``<target>@tiles`` (no "/" so the
# hierarchical fallback chain of runtime.scope never strips it).
TILE_KEY_SUFFIX = "@tiles"

# Cross-shard reduction classes of the summary fields (consumed by
# ``fleet.collect``): occupancy/error/limb counters are plain sums (psum over
# the mesh batch axes is exact), the worst-case error is a max, and operand
# samples concatenate (all-gather).  With TELEMETRY_SAMPLE=2048 the uint32
# limb sums stay overflow-free up to 32 shards (32 * 2048 * 0xFFFF < 2^32).
# The tile_* fields are the per-row-tile record (``tile_summary``): counts
# psum like their scalar counterparts; the per-tile samples are stored
# *sample-major* — (TILE_RETUNE_SAMPLE, gm), tiles on the LAST axis — so the
# shared axis-(-2) concatenation rule of combine_records / fleet.collect
# extends each tile's sample column instead of inventing new tiles.  The
# per-tile error limbs (tile_err_lo/hi, one uint32 per tile over a
# TILE_TELEMETRY_SAMPLE-element sample) psum with the same 32-shard
# headroom (32 * 512 * 0xFFFF < 2^32).
SUM_FIELDS = ("bits_a", "bits_b", "neg_a", "neg_b", "n",
              "err_lo", "err_hi", "err_cnt",
              "tile_bits_a", "tile_neg_a", "tile_n",
              "tile_err_lo", "tile_err_hi")
MAX_FIELDS = ("err_max",)
SAMPLE_FIELDS = ("a_smp", "b_smp", "tile_a_smp", "tile_b_smp")


def tile_key(target: str) -> str:
    """Record key the per-tile summary of ``target`` is collected under."""
    return target + TILE_KEY_SUFFIX


def is_tile_key(key: str) -> bool:
    return key.endswith(TILE_KEY_SUFFIX)


def base_target(key: str) -> str:
    """Inverse of :func:`tile_key` (identity for non-tile keys)."""
    return key[:-len(TILE_KEY_SUFFIX)] if is_tile_key(key) else key


def _flat_sample(x, n: int):
    """First ``n`` elements of ``x`` flattened, tiled cyclically when the
    tensor is smaller (keeps shapes static and stackable across call sites
    without zero-padding that would bias the statistics)."""
    flat = x.reshape(-1)
    if flat.shape[0] < n:
        reps = -(-n // flat.shape[0])
        flat = jnp.concatenate([flat] * reps)
    return flat[:n]


def _bit_counts(v_i32, bits: int):
    """(bits,) float32 count of set **magnitude** bits per position.  Raw
    two's-complement bits are a poor drift statistic for signed operands: a
    symmetric distribution shrinking toward zero keeps every high bit at
    ~P(0.5) (negative values sign-extend to ones), hiding the shift.  The
    sign frequency is tracked separately in the summary."""
    shifts = jnp.arange(bits, dtype=jnp.int32)
    mag = jnp.abs(v_i32)
    return jnp.sum((mag[:, None] >> shifts) & 1, axis=0).astype(jnp.float32)


def operand_summary(xq, wq, mult: AxMult, dyn, gate=None) -> dict:
    """Fixed-shape telemetry record for one approximate projection call.

    ``xq``/``wq`` are the quantized integer operands, ``dyn`` the traced
    (op_is_a, bit, value) triple currently applied.  All outputs are scalars
    or small vectors so the host transfer stays negligible.

    ``gate`` — optional traced boolean scalar (telemetry decimation): when
    False at runtime the whole summary compute is skipped via ``lax.cond``
    and an all-zero record of identical structure is produced instead.  The
    host only observes gated-on steps, so the zeros never reach the
    accumulators.
    """
    if gate is not None:
        import jax

        impl = lambda: operand_summary(xq, wq, mult, dyn)
        shapes = jax.eval_shape(impl)
        zeros = lambda: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return jax.lax.cond(gate, impl, zeros)
    bits = mult.bits
    a = _flat_sample(xq, TELEMETRY_SAMPLE).astype(jnp.int32)
    b = _flat_sample(wq, TELEMETRY_SAMPLE).astype(jnp.int32)

    # live-policy error sample (exact limb sums, as in core/tuning._row_stats)
    approx = apply_swapper_dyn(mult, a, b, dyn[0], dyn[1], dyn[2])
    e = abs_err(approx, mult.exact_product(a, b), mult.signed)
    lo = jnp.sum(e & jnp.uint32(0xFFFF), dtype=jnp.uint32)
    hi = jnp.sum(e >> jnp.uint32(16), dtype=jnp.uint32)

    return dict(
        bits_a=_bit_counts(a, bits),
        bits_b=_bit_counts(b, bits),
        neg_a=jnp.sum((a < 0).astype(jnp.int32)).astype(jnp.float32),
        neg_b=jnp.sum((b < 0).astype(jnp.int32)).astype(jnp.float32),
        n=jnp.int32(TELEMETRY_SAMPLE),
        err_lo=lo,
        err_hi=hi,
        err_max=jnp.max(e),
        err_cnt=jnp.sum((e != 0).astype(jnp.int32)),
        a_smp=_flat_sample(xq, RETUNE_SAMPLE),
        b_smp=_flat_sample(wq, RETUNE_SAMPLE),
    )


def tile_summary(xq, wq, mult: AxMult, gm: int, gate=None, dyn=None) -> dict:
    """Per-row-tile telemetry record for one approximate projection call —
    the host-side twin of the kernels' in-reduction ``tile_hist`` output,
    shaped for the adaptive loop rather than the physical block layout.

    The flattened row space of ``xq`` (tokens) is split into ``gm`` row
    tiles by the SAME partition the execution paths apply config tiles with
    (``core.tiling.rowtile_*`` — observed rows and configured rows must
    coincide; ``min(gm, rows)`` tiles are emitted when the call is smaller
    than the granularity, and when the floor span does not divide the row
    count the last tile's few absorbed remainder rows are left unsampled —
    shapes stay static and no tile's statistic is ever fabricated from
    another tile's rows).  Per tile: magnitude-bit occupancy
    counts + sign count of a ``TILE_TELEMETRY_SAMPLE``-element sample (the
    per-tile drift statistic) and a ``TILE_RETUNE_SAMPLE``-element operand
    sample feeding the controller's per-tile re-tune buffers.  ``wq`` is
    shared by every row tile of a projection, so its sample is emitted once
    and broadcast — tile re-tunes pair each tile's A sample against it.

    Samples are laid out (sample, tile) — tiles on the last axis — so the
    fleet's axis-(-2) all-gather/concat rule applies unchanged.  ``gate`` is
    the same traced decimation boolean as :func:`operand_summary`.

    ``dyn`` — the traced live config: a (3,) triple, a (gm, 1, 3) row-tile
    grid, or None (no-swap).  It selects the per-tile triple the exact
    error-limb sums (``tile_err_lo``/``tile_err_hi``, one uint32 pair per
    tile) are computed under, so per-tile QoR attribution sees the error of
    the policy actually applied to each tile.
    """
    if gate is not None:
        import jax

        impl = lambda: tile_summary(xq, wq, mult, gm, dyn=dyn)
        shapes = jax.eval_shape(impl)
        zeros = lambda: jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), shapes)
        return jax.lax.cond(gate, impl, zeros)
    import jax

    from repro.core.tiling import rowtile_count, rowtile_span

    bits = mult.bits
    x2d = xq.reshape(-1, xq.shape[-1])
    M = x2d.shape[0]
    g = rowtile_count(M, gm)
    rows_per = rowtile_span(M, gm)
    # g * rows_per <= M (floor span): the last tile's absorbed remainder
    # rows fall outside the equal reshape and go unsampled
    tiles = x2d[:g * rows_per].reshape(g, rows_per * x2d.shape[-1])
    a_t = jax.vmap(lambda v: _flat_sample(v, TILE_TELEMETRY_SAMPLE))(tiles)
    a_i32 = a_t.astype(jnp.int32)
    smp = jax.vmap(lambda v: _flat_sample(v, TILE_RETUNE_SAMPLE))(tiles)
    b_smp = _flat_sample(wq, TILE_RETUNE_SAMPLE)

    # per-tile exact error limbs of the live policy: each tile's A sample
    # against the shared B sample under the triple configured FOR that tile
    if dyn is None:
        trip = jnp.broadcast_to(
            jnp.asarray(NO_SWAP_TRIPLE, jnp.int32), (g, 3))
    else:
        dyn = jnp.asarray(dyn, jnp.int32)
        if dyn.ndim == 3:
            # row-tile grid: telemetry tiles and config tiles share the
            # rowtile_* partition, so tile i observes config row i (clamped
            # when the call emits fewer tiles than the grid)
            trip = dyn[:, 0, :][jnp.minimum(jnp.arange(g), dyn.shape[0] - 1)]
        else:
            trip = jnp.broadcast_to(dyn.reshape(3), (g, 3))
    b_i32 = _flat_sample(wq, TILE_TELEMETRY_SAMPLE).astype(jnp.int32)

    def _tile_err(a_row, t):
        approx = apply_swapper_dyn(mult, a_row, b_i32, t[0], t[1], t[2])
        e = abs_err(approx, mult.exact_product(a_row, b_i32), mult.signed)
        return (jnp.sum(e & jnp.uint32(0xFFFF), dtype=jnp.uint32),
                jnp.sum(e >> jnp.uint32(16), dtype=jnp.uint32))

    tile_err_lo, tile_err_hi = jax.vmap(_tile_err)(a_i32, trip)
    return dict(
        tile_bits_a=jax.vmap(lambda v: _bit_counts(v, bits))(a_i32),  # (g, bits)
        tile_neg_a=jnp.sum((a_i32 < 0), axis=1).astype(jnp.float32),  # (g,)
        tile_n=jnp.full((g,), TILE_TELEMETRY_SAMPLE, jnp.int32),
        tile_err_lo=tile_err_lo,                                      # (g,)
        tile_err_hi=tile_err_hi,                                      # (g,)
        tile_a_smp=smp.T,                                             # (S, g)
        tile_b_smp=jnp.broadcast_to(b_smp[:, None],
                                    (TILE_RETUNE_SAMPLE, g)),         # (S, g)
    )


def combine_records(shard_records) -> Dict[str, Dict[str, np.ndarray]]:
    """Host-side reference combiner: fold per-shard record trees into the
    fleet record (sum/max/concat per the field classes above).  This is the
    oracle the in-graph ``fleet.collect.aggregate_records`` psum path is
    tested bit-exactly against."""
    out: Dict[str, Dict[str, np.ndarray]] = {}
    for records in shard_records:
        for target, rec in records.items():
            acc = out.get(target)
            if acc is None:
                out[target] = {k: np.asarray(v).copy() for k, v in rec.items()}
                continue
            for k, v in rec.items():
                v = np.asarray(v)
                if k in MAX_FIELDS:
                    acc[k] = np.maximum(acc[k], v)
                elif k in SAMPLE_FIELDS:
                    acc[k] = np.concatenate([acc[k], v], axis=-2)
                else:
                    acc[k] = acc[k] + v
    return out


# ---------------------------------------------------------------------------
# host side
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TargetTelemetry:
    """Decayed + exact accumulators for one projection target."""

    bits: int
    decay: float
    n_steps: int = 0
    # (2, bits+1) EW occupancy: per-operand magnitude-bit P(bit==1) columns
    # plus a trailing sign-frequency column (the drift statistic)
    bit_probs: Optional[np.ndarray] = None
    ew_mae: Optional[float] = None             # EW-decayed per-step MAE
    stats: ErrorStats = dataclasses.field(default_factory=ErrorStats)

    def update(self, rec: Dict[str, np.ndarray]) -> None:
        """``rec`` holds stacked per-call arrays for one step (leading axis =
        calls of this target inside the step)."""
        n = float(np.sum(rec["n"]))
        probs = np.stack([
            np.concatenate([np.sum(rec["bits_a"], axis=0),
                            np.sum(np.atleast_1d(rec["neg_a"]), keepdims=True)]),
            np.concatenate([np.sum(rec["bits_b"], axis=0),
                            np.sum(np.atleast_1d(rec["neg_b"]), keepdims=True)]),
        ]) / max(n, 1.0)

        step = ErrorStats()
        for lo, hi, mx, cnt, cn in zip(
            np.atleast_1d(rec["err_lo"]), np.atleast_1d(rec["err_hi"]),
            np.atleast_1d(rec["err_max"]), np.atleast_1d(rec["err_cnt"]),
            np.atleast_1d(rec["n"]),
        ):
            step.add_limbs(int(cn), int(lo), int(hi), int(mx), int(cnt), 0.0, 0.0)
        self.stats.n += step.n
        self.stats.sum_abs += step.sum_abs
        self.stats.max_abs = max(self.stats.max_abs, step.max_abs)
        self.stats.count_neq += step.count_neq

        d = self.decay
        if self.bit_probs is None:
            self.bit_probs = probs
            self.ew_mae = step.mae
        else:
            self.bit_probs = (1.0 - d) * self.bit_probs + d * probs
            self.ew_mae = (1.0 - d) * self.ew_mae + d * step.mae
        self.n_steps += 1

    def snapshot(self) -> dict:
        return dict(
            bit_probs=None if self.bit_probs is None else self.bit_probs.copy(),
            ew_mae=self.ew_mae,
            mae=self.stats.mae,
            wce=self.stats.wce,
            ep=self.stats.ep,
            n=self.stats.n,
            n_steps=self.n_steps,
        )


@dataclasses.dataclass
class TargetTileTelemetry:
    """Decayed per-row-tile accumulators for one projection target's
    ``tile_summary`` records (collected under ``tile_key(target)``).

    ``bit_probs`` is a (gm, bits+1) matrix — per row tile, the EW-decayed
    magnitude-bit P(bit==1) columns plus the trailing sign frequency; the
    same sufficient statistic the scalar drift detector uses, one row per
    tile.  The generic :class:`~repro.runtime.drift.DriftDetector` scores it
    unchanged (mean |delta| over the matrix), so a shift confined to one of
    ``gm`` tiles reaches the threshold diluted by ~1/gm — size tile drift
    thresholds accordingly (mirrors the fleet's 1/N shard dilution)."""

    bits: int
    decay: float
    n_steps: int = 0
    bit_probs: Optional[np.ndarray] = None      # (gm, bits+1)
    ew_mae: Optional[np.ndarray] = None         # (gm,) EW per-tile step MAE

    def update(self, rec: Dict[str, np.ndarray]) -> None:
        """``rec`` holds stacked per-call arrays (leading axis = calls of
        this target inside the observed step)."""
        bits_a = np.sum(np.asarray(rec["tile_bits_a"]), axis=0)    # (gm, bits)
        neg_a = np.sum(np.asarray(rec["tile_neg_a"]), axis=0)      # (gm,)
        n = np.maximum(np.sum(np.asarray(rec["tile_n"]), axis=0), 1.0)
        probs = np.concatenate([bits_a, neg_a[:, None]], axis=-1) / n[:, None]
        if self.bit_probs is None or self.bit_probs.shape != probs.shape:
            self.bit_probs = probs
            self.ew_mae = None
        else:
            d = self.decay
            self.bit_probs = (1.0 - d) * self.bit_probs + d * probs
        if "tile_err_lo" in rec:
            lo = np.sum(np.asarray(rec["tile_err_lo"], np.float64), axis=0)
            hi = np.sum(np.asarray(rec["tile_err_hi"], np.float64), axis=0)
            mae = (lo + hi * 65536.0) / n
            if self.ew_mae is None or self.ew_mae.shape != mae.shape:
                self.ew_mae = mae
            else:
                self.ew_mae = (1.0 - self.decay) * self.ew_mae \
                    + self.decay * mae
        self.n_steps += 1

    def snapshot(self) -> dict:
        return dict(
            bit_probs=None if self.bit_probs is None else self.bit_probs.copy(),
            ew_mae=None if self.ew_mae is None else self.ew_mae.copy(),
            n_steps=self.n_steps,
        )


class Telemetry:
    """Per-target streaming telemetry over the records a scope collected.
    Records keyed ``<target>@tiles`` route to per-row-tile accumulators
    (:class:`TargetTileTelemetry`); everything else to the scalar
    :class:`TargetTelemetry`."""

    def __init__(self, bits: int, decay: float = 0.2):
        self.bits = bits
        self.decay = decay
        self.targets: Dict[str, TargetTelemetry] = {}
        self.tile_targets: Dict[str, TargetTileTelemetry] = {}

    def update(self, records: Dict[str, Dict[str, np.ndarray]]) -> None:
        for target, rec in records.items():
            if is_tile_key(target):
                tt = self.tile_targets.get(target)
                if tt is None:
                    tt = self.tile_targets[target] = TargetTileTelemetry(
                        self.bits, self.decay)
                tt.update(rec)
                continue
            tt = self.targets.get(target)
            if tt is None:
                tt = self.targets[target] = TargetTelemetry(self.bits, self.decay)
            tt.update(rec)

    def snapshot(self) -> Dict[str, dict]:
        out = {t: tt.snapshot() for t, tt in self.targets.items()}
        out.update({t: tt.snapshot() for t, tt in self.tile_targets.items()})
        return out

    def describe(self) -> str:
        parts = []
        for t, tt in sorted(self.targets.items()):
            parts.append(f"{t}: ew_mae={tt.ew_mae:.2f} mae={tt.stats.mae:.2f} "
                         f"n={tt.stats.n}")
        for t, tt in sorted(self.tile_targets.items()):
            gm = 0 if tt.bit_probs is None else tt.bit_probs.shape[0]
            parts.append(f"{t}: tiles={gm} steps={tt.n_steps}")
        return "telemetry " + " | ".join(parts) if parts else "telemetry <empty>"


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------

_QUARANTINED = obs.default_registry().counter(
    "repro_telemetry_quarantined_total",
    "telemetry records quarantined before the accumulators, by target and "
    "reason (nonfinite / bounds / outlier)")


class TelemetryQuarantine:
    """Record sanitization in front of the accumulators and ring buffers.

    Three independent checks, cheapest first:

    1. **nonfinite** — any NaN/Inf in a float field (corrupt shard math,
       torn transfers);
    2. **bounds** — structural invariants every honest ``operand_summary``
       / ``tile_summary`` record satisfies by construction: per-bit
       occupancy counts cannot exceed the total sample count, error-limb
       sums are bounded by ``n * 0xFFFF``, the nonzero-error count by
       ``n``, and exported operand codes by the multiplier's ``2**bits``
       magnitude range;
    3. **outlier** (``z_threshold`` set) — robust z-score of the record's
       step MAE against the trailing per-target history (median/MAD):
       finite, in-bounds, but absurd records — the "one shard went insane"
       case.  Quarantined records are NOT appended to the history, so a
       poison burst cannot drag the baseline toward itself.

    Records with ``n == 0`` pass untouched: the fused decode's gated-off
    slots legitimately emit all-zero records, and vetoing them would change
    accumulator trajectories for honest traffic.
    """

    REASONS = ("nonfinite", "bounds", "outlier")

    def __init__(self, bits: int, z_threshold: Optional[float] = None,
                 history: int = 64, min_history: int = 8):
        self.bits = int(bits)
        self.z_threshold = z_threshold
        self.history = int(history)
        self.min_history = int(min_history)
        self._mae_hist: Dict[str, collections.deque] = {}
        self.quarantined = 0
        self.by_reason: Dict[str, int] = {}

    # -- checks --------------------------------------------------------
    def check(self, target: str, rec: Dict[str, np.ndarray]) -> Optional[str]:
        """The quarantine reason for this record, or None when admissible."""
        for v in rec.values():
            v = np.asarray(v)
            if np.issubdtype(v.dtype, np.floating) and not bool(
                    np.all(np.isfinite(v))):
                return "nonfinite"
        tile = is_tile_key(target)
        n = float(np.sum(np.asarray(rec["tile_n" if tile else "n"],
                                    np.float64)))
        if n <= 0:
            return None                      # gated-off zero record: vacuous
        lim = float(2 ** self.bits)
        for k in ("bits_a", "bits_b") if not tile else ("tile_bits_a",):
            if k in rec:
                counts = np.asarray(rec[k], np.float64)
                counts = counts.reshape(-1, counts.shape[-1]).sum(axis=0)
                if float(counts.max(initial=0.0)) > n + 0.5:
                    return "bounds"
        for k in ("a_smp", "b_smp", "tile_a_smp", "tile_b_smp"):
            if k in rec and np.abs(
                    np.asarray(rec[k], np.float64)).max(initial=0.0) > lim:
                return "bounds"
        if tile and "tile_err_lo" in rec:
            tn = np.asarray(rec["tile_n"], np.float64)
            tn = tn.reshape(-1, tn.shape[-1]).sum(axis=0)
            for k in ("tile_err_lo", "tile_err_hi"):
                limb = np.asarray(rec[k], np.float64)
                limb = limb.reshape(-1, limb.shape[-1]).sum(axis=0)
                if np.any(limb > tn * 0xFFFF + 0.5):
                    return "bounds"
        if not tile:
            lo = float(np.sum(np.asarray(rec["err_lo"], np.float64)))
            hi = float(np.sum(np.asarray(rec["err_hi"], np.float64)))
            cnt = float(np.sum(np.asarray(rec["err_cnt"], np.float64)))
            if lo > n * 0xFFFF or hi > n * 0xFFFF or cnt > n + 0.5:
                return "bounds"
            if self.z_threshold is not None:
                mae = (lo + hi * 65536.0) / n
                hist = self._mae_hist.setdefault(
                    target, collections.deque(maxlen=self.history))
                if len(hist) >= self.min_history:
                    arr = np.asarray(hist, np.float64)
                    med = float(np.median(arr))
                    mad = float(np.median(np.abs(arr - med)))
                    # the 0.05*med floor keeps a near-zero-MAD history from
                    # flagging ordinary drift as an outlier (scale-relative)
                    z = abs(mae - med) / (1.4826 * mad + 0.05 * med + 1e-9)
                    if z > self.z_threshold:
                        return "outlier"     # and keep it OUT of the history
                hist.append(mae)
        return None

    def filter(self, records: Dict[str, Dict[str, np.ndarray]]
               ) -> Tuple[Dict[str, Dict[str, np.ndarray]],
                          List[Tuple[str, str]]]:
        """(admitted records, [(target, reason) dropped]) — the controller
        feeds only the admitted half to accumulators/buffers/drift."""
        admitted, dropped = {}, []
        for target, rec in records.items():
            reason = self.check(target, rec)
            if reason is None:
                admitted[target] = rec
            else:
                dropped.append((target, reason))
                self.quarantined += 1
                self.by_reason[reason] = self.by_reason.get(reason, 0) + 1
                _QUARANTINED.inc(1, target=target, reason=reason)
        return admitted, dropped
