"""Trace-time dynamic-policy scope.

The adaptive runtime must change the SWAPPER configuration of a *compiled*
serving/training step without recompiling it.  The host wraps its jit'd step
so the per-target swap triples enter as ordinary traced inputs, and opens an
:class:`AxRuntimeScope` around the model call; ``models.layers.dense`` looks
the scope up at trace time and routes matching projections through the
dynamic approximate path (``quant.ax.ax_dense_dyn``).

The scope is only consulted while JAX traces the step — on cached executions
the compiled program already contains the dynamic-config inputs and the
telemetry outputs, so no Python-level state is involved.

Config keys are hierarchical: a projection target ``"layer3/mlp"`` falls back
to ``"mlp"`` and then to the global key ``"*"`` (see ``runtime.policy``).
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional

import jax

__all__ = ["AxRuntimeScope", "active_scope", "ax_scope", "fallback_chain"]

GLOBAL_KEY = "*"

_ACTIVE: Optional["AxRuntimeScope"] = None


def fallback_chain(key: str) -> List[str]:
    """Lookup order for a hierarchical config key: the exact key, then each
    suffix after stripping a leading path segment, then the global key."""
    chain = [key]
    while "/" in key:
        key = key.split("/", 1)[1]
        chain.append(key)
    chain.append(GLOBAL_KEY)
    return chain


class AxRuntimeScope:
    """Holds the traced (op_is_a, bit, value) triples for the current step and
    collects per-target telemetry summaries emitted during tracing.

    ``gate`` — optional *traced* boolean scalar implementing telemetry
    decimation: when False at runtime, every summary in the step is replaced
    by a ``lax.cond`` branch of zeros, so off-steps skip the summary compute
    entirely while the compiled program (and the record pytree structure)
    stays identical.  None means always-on (the pre-decimation behavior).

    ``tile_rows`` — per-tile granularity (static at trace time): when > 0,
    the dyn-tree values are (tile_rows, 1, 3) per-row-tile config *grids*
    instead of (3,) triples, and ``quant.ax.ax_dense_dyn`` additionally
    emits a ``telemetry.tile_summary`` record under ``tile_key(target)``
    for every matching projection (same gate).  0 disables (scalar mode)."""

    def __init__(self, dyn_tree: Optional[Dict[str, jax.Array]], collect: bool = False,
                 gate: Optional[jax.Array] = None, tile_rows: int = 0):
        self.dyn = dict(dyn_tree or {})
        self.collect = collect
        self.gate = gate
        self.tile_rows = int(tile_rows)
        self._records: Dict[str, List[dict]] = {}

    def triple_for(self, target: str) -> Optional[jax.Array]:
        for key in fallback_chain(target):
            if key in self.dyn:
                return self.dyn[key]
        return None

    def record(self, target: str, summary: dict) -> None:
        self._records.setdefault(target, []).append(summary)

    def collected(self) -> Dict[str, dict]:
        """Stack the per-call summaries of each target into one pytree of
        arrays with a leading call axis (exact limb sums must be recombined
        per call on the host — summing uint32 limbs across calls could
        overflow in-graph)."""
        import jax.numpy as jnp

        out = {}
        for target, records in self._records.items():
            keys = records[0].keys()
            out[target] = {
                k: jnp.stack([r[k] for r in records]) for k in keys
            }
        return out


def active_scope() -> Optional[AxRuntimeScope]:
    return _ACTIVE


@contextlib.contextmanager
def ax_scope(dyn_tree: Optional[Dict[str, jax.Array]], collect: bool = False,
             gate: Optional[jax.Array] = None, tile_rows: int = 0):
    """Open a dynamic-policy scope (used inside the function being jitted).
    ``gate`` is an optional traced observe-every-k boolean: False-at-runtime
    steps skip the telemetry summary compute; ``tile_rows > 0`` switches the
    scope to per-row-tile mode (grid-valued dyn tree + tile telemetry) —
    see :class:`AxRuntimeScope`."""
    global _ACTIVE
    prev = _ACTIVE
    _ACTIVE = AxRuntimeScope(dyn_tree, collect=collect, gate=gate,
                             tile_rows=tile_rows)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev
