"""Distribution-drift detection over streaming bit-occupancy telemetry.

The component-level tuning result is a function of the operand distribution
(Vasicek et al., arXiv:1903.04188): when live traffic drifts away from the
distribution the current policy was tuned on, the tuned bit may stop helping.
The drift signal used here is the per-bit occupancy probability vector of
both operands — exactly the sufficient statistic of the single-bit decision
family: if no bit's occupancy moved, no single-bit config changed its mask
population.

Score: mean absolute difference between the current exponentially-decayed
bit-probability matrix (2 x M) and the reference matrix captured when the
policy was last tuned.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["DriftConfig", "DriftDetector", "drift_score"]

# live per-target drift score (repro.obs): exported every detector sweep,
# not only when the threshold trips — dashboards see drift build up before
# a re-tune fires
_DRIFT_SCORE = obs.default_registry().gauge(
    "repro_drift_score",
    "mean |bit-probability shift| vs the tuned-on reference, per target")


def drift_score(ref: np.ndarray, cur: np.ndarray) -> float:
    """Mean |P_ref(bit=1) - P_cur(bit=1)| over both operands' bits."""
    return float(np.mean(np.abs(np.asarray(ref) - np.asarray(cur))))


@dataclasses.dataclass
class DriftConfig:
    threshold: float = 0.04    # mean bit-probability shift that triggers re-tune
    min_steps: int = 4         # observations required before scoring


class DriftDetector:
    """Per-target drift scoring against the tuned-on reference snapshot."""

    def __init__(self, cfg: Optional[DriftConfig] = None):
        self.cfg = cfg or DriftConfig()
        self.reference: Dict[str, np.ndarray] = {}
        self._steps_since_rebase: Dict[str, int] = {}

    def rebase(self, target: str, bit_probs: np.ndarray) -> None:
        """Capture the distribution the current policy is tuned for."""
        self.reference[target] = np.asarray(bit_probs).copy()
        self._steps_since_rebase[target] = 0

    def score(self, target: str, bit_probs: Optional[np.ndarray]) -> float:
        if bit_probs is None:
            return 0.0
        bit_probs = np.asarray(bit_probs)
        ref = self.reference.get(target)
        if ref is None or ref.shape != bit_probs.shape:
            # first sighting — or the statistic changed shape (a per-tile
            # target whose tile count follows the call's row count, e.g. a
            # different batch size): the old reference is not comparable,
            # adopt the new snapshot and restart the warm-up
            self.rebase(target, bit_probs)
            return 0.0
        self._steps_since_rebase[target] = self._steps_since_rebase.get(target, 0) + 1
        return drift_score(ref, bit_probs)

    def check(self, snapshot: Dict[str, dict]) -> List[Tuple[str, float]]:
        """Score every target; returns [(target, score)] for those over the
        threshold and past the warm-up period."""
        drifted = []
        for target, snap in snapshot.items():
            s = self.score(target, snap.get("bit_probs"))
            _DRIFT_SCORE.set(s, target=target)
            if (s > self.cfg.threshold
                    and self._steps_since_rebase.get(target, 0) >= self.cfg.min_steps):
                drifted.append((target, s))
        return drifted
