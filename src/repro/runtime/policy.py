"""Granular SWAPPER policies: the generalization of a single global
``SwapConfig`` into hierarchical config maps.

The paper applies its framework "at different granularities"; here a
:class:`SwapPolicy` maps hierarchical keys to single-bit configs:

* ``"*"``            — global default (the paper's single tuned config)
* ``"mlp"``          — per-tensor / per-projection-target
* ``"layer3/mlp"``   — per-layer (keys fall back suffix-wise: ``layer3/mlp``
  → ``mlp`` → ``*``)
* tile grids         — per-row-tile (gm, gn) int32 triple grids consumed by
  the scalar-prefetch ``kernels.ax_matmul_grid`` kernel

Policies serialize to JSON so a tuned policy can be checkpointed alongside
model weights and shipped to serving.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import AxPolicy
from repro.core.swapper import NO_SWAP_TRIPLE, SwapConfig, cfg_to_triple

from .scope import GLOBAL_KEY, fallback_chain

__all__ = ["SwapPolicy", "triple_of", "NO_SWAP_TRIPLE"]

# the triple encoding is owned by core.swapper; re-exported here for the
# runtime-facing API surface
triple_of = cfg_to_triple


def _cfg_from_triple(t) -> Optional[SwapConfig]:
    op_is_a, bit, value = (int(v) for v in t)
    if value not in (0, 1):
        return None
    return SwapConfig("A" if op_is_a else "B", bit, value)


@dataclasses.dataclass
class SwapPolicy:
    """A granular, serializable SWAPPER configuration map."""

    mult_name: str
    configs: Dict[str, Optional[SwapConfig]] = dataclasses.field(default_factory=dict)
    tile_grids: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)
    version: int = 0

    # -- lookups ------------------------------------------------------
    def lookup(self, key: str) -> Optional[SwapConfig]:
        for k in fallback_chain(key):
            if k in self.configs:
                return self.configs[k]
        return None

    def set_config(self, key: str, cfg: Optional[SwapConfig]) -> None:
        self.configs[key] = cfg
        self.version += 1

    def dyn_tree(self, keys: Sequence[str]) -> Dict[str, jnp.ndarray]:
        """Per-key traced-input triples for ``runtime.scope.ax_scope``.  The
        tree structure (keys) is fixed by the caller so the jit cache stays
        warm across policy updates — only the int32 values change."""
        return {
            k: jnp.asarray(triple_of(self.lookup(k)), jnp.int32) for k in keys
        }

    # -- per-row-tile grids -------------------------------------------
    def set_tile_grid(self, key: str, grid: np.ndarray) -> None:
        grid = np.asarray(grid, np.int32)
        assert grid.ndim == 3 and grid.shape[-1] == 3, grid.shape
        self.tile_grids[key] = grid
        self.version += 1

    def tile_grid(self, key: str, gm: int, gn: int) -> np.ndarray:
        """(gm, gn, 3) int32 config grid for the scalar-prefetch kernel.
        A stored grid is broadcast over rows/cols as needed; otherwise the
        hierarchical single-config lookup is broadcast to every tile."""
        if key in self.tile_grids:
            g = self.tile_grids[key]
            assert g.shape[0] in (1, gm) and g.shape[1] in (1, gn), (g.shape, gm, gn)
            return np.broadcast_to(g, (gm, gn, 3)).astype(np.int32)
        t = np.asarray(triple_of(self.lookup(key)), np.int32)
        return np.broadcast_to(t, (gm, gn, 3)).astype(np.int32).copy()

    # -- constructors --------------------------------------------------
    @classmethod
    def from_ax_policy(cls, ax: AxPolicy) -> "SwapPolicy":
        """Lift the static (globally-tuned) AxPolicy into a policy map."""
        return cls(mult_name=ax.mult_name, configs={GLOBAL_KEY: ax.swap})

    # -- serialization -------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(dict(
            mult_name=self.mult_name,
            version=self.version,
            configs={k: (None if c is None else list(triple_of(c)))
                     for k, c in self.configs.items()},
            tile_grids={k: g.tolist() for k, g in self.tile_grids.items()},
            meta=_jsonable(self.meta),
        ), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "SwapPolicy":
        d = json.loads(text)
        return cls(
            mult_name=d["mult_name"],
            configs={k: (None if t is None else _cfg_from_triple(t))
                     for k, t in d["configs"].items()},
            tile_grids={k: np.asarray(g, np.int32)
                        for k, g in d.get("tile_grids", {}).items()},
            meta=d.get("meta", {}),
            version=int(d.get("version", 0)),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "SwapPolicy":
        with open(path) as f:
            return cls.from_json(f.read())

    def configs_equal(self, other: "SwapPolicy") -> bool:
        """True when both policies resolve identically (same multiplier, same
        config map, bit-equal tile grids) — version/meta excluded, so a
        replica that adopted a published policy compares equal to the
        writer's live one.  (Dataclass ``==`` is unusable here: ndarray tile
        grids make it raise.)"""
        if self.mult_name != other.mult_name or self.configs != other.configs:
            return False
        if set(self.tile_grids) != set(other.tile_grids):
            return False
        return all(np.array_equal(g, other.tile_grids[k])
                   for k, g in self.tile_grids.items())

    def describe(self) -> str:
        parts = [f"policy[{self.mult_name} v{self.version}]"]
        for k, c in sorted(self.configs.items()):
            parts.append(f"{k}={'noswap' if c is None else c.short()}")
        return " ".join(parts)


def _jsonable(meta: Dict[str, object]):
    out = {}
    for k, v in meta.items():
        out[k] = v.tolist() if isinstance(v, np.ndarray) else v
    return out
