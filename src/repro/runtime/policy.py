"""Granular SWAPPER policies: the generalization of a single global
``SwapConfig`` into hierarchical config maps.

The paper applies its framework "at different granularities"; here a
:class:`SwapPolicy` maps hierarchical keys to single-bit configs:

* ``"*"``            — global default (the paper's single tuned config)
* ``"mlp"``          — per-tensor / per-projection-target
* ``"layer3/mlp"``   — per-layer (keys fall back suffix-wise: ``layer3/mlp``
  → ``mlp`` → ``*``)
* tile grids         — per-row-tile (gm, gn) int32 triple grids consumed by
  the scalar-prefetch ``kernels.ax_matmul_grid`` kernel

Policies serialize to JSON so a tuned policy can be checkpointed alongside
model weights and shipped to serving.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.configs.base import AxPolicy
from repro.core.swapper import NO_SWAP_TRIPLE, SwapConfig, cfg_to_triple

from .scope import GLOBAL_KEY, fallback_chain

__all__ = ["SwapPolicy", "triple_of", "triple_short", "NO_SWAP_TRIPLE"]

# the triple encoding is owned by core.swapper; re-exported here for the
# runtime-facing API surface
triple_of = cfg_to_triple


def _cfg_from_triple(t) -> Optional[SwapConfig]:
    op_is_a, bit, value = (int(v) for v in t)
    if value not in (0, 1):
        return None
    return SwapConfig("A" if op_is_a else "B", bit, value)


def triple_short(t) -> str:
    """Canonical compact rendering of one (op_is_a, bit, value) triple —
    ``"ns"`` for the NoSwap encoding, else ``"A[b]==v"`` / ``"B[b]==v"``.
    The single formatter shared by policy/controller/benchmark output."""
    cfg = _cfg_from_triple(t)
    return "ns" if cfg is None else cfg.short()


@dataclasses.dataclass
class SwapPolicy:
    """A granular, serializable SWAPPER configuration map."""

    mult_name: str
    configs: Dict[str, Optional[SwapConfig]] = dataclasses.field(default_factory=dict)
    tile_grids: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)
    version: int = 0

    # -- lookups ------------------------------------------------------
    def lookup(self, key: str) -> Optional[SwapConfig]:
        for k in fallback_chain(key):
            if k in self.configs:
                return self.configs[k]
        return None

    def set_config(self, key: str, cfg: Optional[SwapConfig]) -> None:
        self.configs[key] = cfg
        self.version += 1

    def dyn_tree(self, keys: Sequence[str],
                 tile_rows: int = 0) -> Dict[str, jnp.ndarray]:
        """Per-key traced-input values for ``runtime.scope.ax_scope``.

        ``tile_rows == 0`` (scalar mode): each key maps to its resolved
        (op_is_a, bit, value) int32 triple.  ``tile_rows > 0`` (per-tile
        mode): each key maps to a (tile_rows, 1, 3) int32 per-row-tile grid
        — stored ``tile_grids`` resampled to that shape, keys without a
        stored grid broadcast their scalar config (see :meth:`tile_grid`).

        Either way the tree structure (keys) AND the leaf shapes are fixed
        by the caller's ``(keys, tile_rows)``, so the jit cache stays warm
        across policy updates — re-tunes, including tile-grid publishes,
        change int32 values only."""
        if tile_rows > 0:
            return {k: jnp.asarray(self.tile_grid(k, tile_rows, 1), jnp.int32)
                    for k in keys}
        return {
            k: jnp.asarray(triple_of(self.lookup(k)), jnp.int32) for k in keys
        }

    # -- per-row-tile grids -------------------------------------------
    def set_tile_grid(self, key: str, grid: np.ndarray) -> None:
        """Install a (gm, gn, 3) int32 per-tile config grid for ``key``
        (bumps the policy version like :meth:`set_config`).  Consumers
        resample it to whatever physical tiling they run at, so the stored
        granularity is a *logical* choice, not a kernel block constraint.

        Backend-portability guard: a grid may mix A-side and NoSwap tiles
        freely and may use B-side tiles only if every B-side tile carries
        the *same* triple — the one family the mxu single-dispatch row-tile
        factorization cannot express is heterogeneous B-side decisions
        (``quant.ax._mxu_limbs_rowtile``), so such grids are rejected here,
        at the source, instead of silently diverging on one backend.
        (Controller-produced grids are A-side/NoSwap by construction —
        ``controller.tile_triples``.)"""
        grid = np.asarray(grid, np.int32)
        assert grid.ndim == 3 and grid.shape[-1] == 3, grid.shape
        b_side = grid.reshape(-1, 3)
        b_side = b_side[(b_side[:, 0] == 0) & (b_side[:, 2] <= 1)]
        assert len(np.unique(b_side, axis=0)) <= 1, (
            f"tile grid for {key!r} mixes different B-side triples "
            f"({np.unique(b_side, axis=0).tolist()}): not expressible by the "
            f"single-dispatch mxu row-tile factorization — use one B-side "
            f"config uniformly, or A-side/NoSwap per tile")
        self.tile_grids[key] = grid
        self.version += 1

    def tile_grid(self, key: str, gm: int, gn: int) -> np.ndarray:
        """(gm, gn, 3) int32 config grid for the scalar-prefetch kernel and
        the per-row-tile mxu path.  A stored grid is resampled to the
        requested tiling (tile i reads stored tile ``i * stored_gm // gm``
        — exact broadcast when the shapes divide); keys without a stored
        grid broadcast the hierarchical single-config lookup to every tile,
        which is what makes scalar and tile-granular policies one
        continuum."""
        if key in self.tile_grids:
            g = self.tile_grids[key]
            ri = (np.arange(gm) * g.shape[0]) // gm
            ci = (np.arange(gn) * g.shape[1]) // gn
            return np.ascontiguousarray(g[ri][:, ci]).astype(np.int32)
        t = np.asarray(triple_of(self.lookup(key)), np.int32)
        return np.broadcast_to(t, (gm, gn, 3)).astype(np.int32).copy()

    # -- constructors --------------------------------------------------
    @classmethod
    def from_ax_policy(cls, ax: AxPolicy) -> "SwapPolicy":
        """Lift the static (globally-tuned) AxPolicy into a policy map."""
        return cls(mult_name=ax.mult_name, configs={GLOBAL_KEY: ax.swap})

    # -- serialization -------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(dict(
            mult_name=self.mult_name,
            version=self.version,
            configs={k: (None if c is None else list(triple_of(c)))
                     for k, c in self.configs.items()},
            tile_grids={k: g.tolist() for k, g in self.tile_grids.items()},
            meta=_jsonable(self.meta),
        ), indent=2)

    @classmethod
    def from_json(cls, text: str) -> "SwapPolicy":
        d = json.loads(text)
        return cls(
            mult_name=d["mult_name"],
            configs={k: (None if t is None else _cfg_from_triple(t))
                     for k, t in d["configs"].items()},
            tile_grids={k: np.asarray(g, np.int32)
                        for k, g in d.get("tile_grids", {}).items()},
            meta=d.get("meta", {}),
            version=int(d.get("version", 0)),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "SwapPolicy":
        with open(path) as f:
            return cls.from_json(f.read())

    def configs_equal(self, other: "SwapPolicy") -> bool:
        """True when both policies resolve identically (same multiplier, same
        config map, bit-equal tile grids) — version/meta excluded, so a
        replica that adopted a published policy compares equal to the
        writer's live one.  (Dataclass ``==`` is unusable here: ndarray tile
        grids make it raise.)"""
        if self.mult_name != other.mult_name or self.configs != other.configs:
            return False
        if set(self.tile_grids) != set(other.tile_grids):
            return False
        return all(np.array_equal(g, other.tile_grids[k])
                   for k, g in self.tile_grids.items())

    def describe(self) -> str:
        parts = [f"policy[{self.mult_name} v{self.version}]"]
        for k, c in sorted(self.configs.items()):
            parts.append(f"{k}={'noswap' if c is None else c.short()}")
        for k, g in sorted(self.tile_grids.items()):
            short = ",".join(triple_short(t) for t in g.reshape(-1, 3))
            parts.append(f"{k}[tiles {g.shape[0]}x{g.shape[1]}]=({short})")
        return " ".join(parts)


def _jsonable(meta: Dict[str, object]):
    out = {}
    for k, v in meta.items():
        out[k] = v.tolist() if isinstance(v, np.ndarray) else v
    return out
