"""AxBench `jpeg`: 8x8 block DCT -> quantize -> dequantize -> IDCT.

Unlike the other benchmarks, jpeg is implemented with **16-bit integer
arithmetic directly** (paper §III.B: "Jpeg is implemented with 16-bit integer
arithmetic") — every multiply is a single mul16s call, no Eq. 6 modular
composition.  The DCT matrix is scaled by 2^7 (operands stay within the
signed-16 input domain) and quantization uses reciprocal multiplies, as in
integer libjpeg implementations.  Metric: SSIM of the reconstructed image.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .common import AxApp, smooth_image
from .ssim import ssim

_S = 7  # DCT matrix scale = 2^7


def _dct_matrix():
    M = np.zeros((8, 8))
    for u in range(8):
        cu = np.sqrt(0.125) if u == 0 else 0.5
        for x in range(8):
            M[u, x] = cu * np.cos((2 * x + 1) * u * np.pi / 16)
    return M


_M_INT = np.round(_dct_matrix() * (1 << _S)).astype(np.int32)       # |m| <= 64
_Q50 = np.array(  # JPEG luminance quantization table (quality 50)
    [[16, 11, 10, 16, 24, 40, 51, 61],
     [12, 12, 14, 19, 26, 58, 60, 55],
     [14, 13, 16, 24, 40, 57, 69, 56],
     [14, 17, 22, 29, 51, 87, 80, 62],
     [18, 22, 37, 56, 68, 109, 103, 77],
     [24, 35, 55, 64, 81, 104, 113, 92],
     [49, 64, 78, 87, 103, 121, 120, 101],
     [72, 92, 95, 98, 112, 100, 103, 99]], np.int32)
_RECIP_Q = np.round((1 << 15) / _Q50).astype(np.int32)              # <= 2048


def gen_inputs(n, seed):
    side = max(32, int(n))
    side -= side % 8
    return {"img": smooth_image(side, side, seed)}  # [0,255]


def _blocks(img):
    h, w = img.shape
    return img.reshape(h // 8, 8, w // 8, 8).transpose(0, 2, 1, 3).reshape(-1, 8, 8)


def _unblocks(blk, h, w):
    return blk.reshape(h // 8, w // 8, 8, 8).transpose(0, 2, 1, 3).reshape(h, w)


def _matmul16(mul16, A, B):
    """(..., 8, 8) x (..., 8, 8) int matmul with every scalar product routed
    through mul16 (int16-domain operands)."""
    prod = mul16(A[..., :, :, None], B[..., None, :, :])  # (..., 8, 8k, 8)
    return prod.sum(axis=-2)


def run_fxp(inputs, mul16):
    img = jnp.asarray(inputs["img"], jnp.float32)
    h, w = img.shape
    x = _blocks(jnp.round(img).astype(jnp.int32) - 128)              # (B,8,8)
    M = jnp.asarray(_M_INT)
    # forward DCT: Y = (M X M^T) >> 2S  — staged to keep operands 16-bit
    t = _matmul16(mul16, M[None], x) >> _S                           # (B,8,8)
    y = _matmul16(mul16, t, M.T[None]) >> _S
    # quantize / dequantize (reciprocal multiply, then restore)
    q = mul16(y, jnp.asarray(_RECIP_Q)[None]) >> 15
    yq = mul16(q, jnp.asarray(_Q50)[None])
    # inverse DCT: X' = (M^T Y M) >> 2S
    t2 = _matmul16(mul16, M.T[None], yq) >> _S
    x2 = _matmul16(mul16, t2, M[None]) >> _S
    out = jnp.clip(x2 + 128, 0, 255).astype(jnp.float32)
    return _unblocks(out, h, w)


def reference(inputs):
    """Same integer pipeline with precise multiplies (the 'original' 16-bit
    integer implementation, as in AxBench's jpeg)."""
    img = np.asarray(inputs["img"], np.float64)
    h, w = img.shape
    x = np.round(img).astype(np.int64) - 128
    blk = x.reshape(h // 8, 8, w // 8, 8).transpose(0, 2, 1, 3).reshape(-1, 8, 8)
    M = _M_INT.astype(np.int64)
    t = (M[None] @ blk) >> _S
    y = (t @ M.T[None]) >> _S
    q = (y * _RECIP_Q[None]) >> 15
    yq = q * _Q50[None]
    t2 = (M.T[None] @ yq) >> _S
    x2 = (t2 @ M[None]) >> _S
    out = np.clip(x2 + 128, 0, 255).astype(np.float32)
    out = out.reshape(h // 8, w // 8, 8, 8).transpose(0, 2, 1, 3).reshape(h, w)
    return out


def metric(out, ref):
    return ssim(out, ref)


APP = AxApp(
    name="jpeg",
    metric_name="ssim",
    minimize=False,
    kind="int16",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
