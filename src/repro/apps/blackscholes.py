"""AxBench `blackscholes`: European option pricing, Q16.16, ARE metric."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, to_fxp

from .common import AxApp

# Abramowitz & Stegun 26.2.17 CND polynomial constants
_A = (0.319381530, -0.356563782, 1.781477937, -1.821255978, 1.330274429)
_GAMMA = 0.2316419
_INV_SQRT_2PI = 0.3989422804014327


def gen_inputs(n, seed):
    rng = np.random.default_rng(seed)
    n = max(64, int(n))
    return {
        "S": rng.uniform(10.0, 60.0, n),       # spot
        "K": rng.uniform(10.0, 60.0, n),       # strike
        "T": rng.uniform(0.2, 2.0, n),         # expiry (years)
        "r": rng.uniform(0.01, 0.08, n),       # rate
        "v": rng.uniform(0.15, 0.6, n),        # volatility
    }


def _cnd_fxp(F, x):
    """Cumulative normal via A&S polynomial, all arithmetic through F."""
    neg = x < 0
    xa = jnp.where(neg, -x, x)
    k = F.div(to_fxp(1.0), to_fxp(1.0) + F.mul(F.const(_GAMMA), xa))
    poly = jnp.zeros_like(x)
    for a in reversed(_A):
        poly = F.mul(poly + F.const(a), k)
    # pdf = inv_sqrt_2pi * exp(-x^2/2)
    pdf = F.mul(F.const(_INV_SQRT_2PI), F.exp(-(F.mul(xa, xa) >> 1)))
    cnd = to_fxp(1.0) - F.mul(pdf, poly)
    return jnp.where(neg, to_fxp(1.0) - cnd, cnd)


def run_fxp(inputs, mul):
    F = FxpMath(mul)
    S = to_fxp(jnp.asarray(inputs["S"], jnp.float32))
    Kk = to_fxp(jnp.asarray(inputs["K"], jnp.float32))
    T = to_fxp(jnp.asarray(inputs["T"], jnp.float32))
    r = to_fxp(jnp.asarray(inputs["r"], jnp.float32))
    v = to_fxp(jnp.asarray(inputs["v"], jnp.float32))

    sqrtT = F.sqrt(T)
    vsqrtT = F.mul(v, sqrtT)
    d1 = F.div(
        F.log(F.div(S, Kk)) + F.mul(r + (F.mul(v, v) >> 1), T),
        vsqrtT,
    )
    d2 = d1 - vsqrtT
    disc = F.exp(-F.mul(r, T))
    call = F.mul(S, _cnd_fxp(F, d1)) - F.mul(Kk, F.mul(disc, _cnd_fxp(F, d2)))
    return from_fxp(call)


def _cnd_np(x):
    neg = x < 0
    xa = np.abs(x)
    k = 1.0 / (1.0 + _GAMMA * xa)
    poly = np.zeros_like(x)
    for a in reversed(_A):
        poly = (poly + a) * k
    pdf = _INV_SQRT_2PI * np.exp(-0.5 * xa * xa)
    cnd = 1.0 - pdf * poly
    return np.where(neg, 1.0 - cnd, cnd)


def reference(inputs):
    """float64 oracle."""
    S, K, T = inputs["S"], inputs["K"], inputs["T"]
    r, v = inputs["r"], inputs["v"]
    d1 = (np.log(S / K) + (r + 0.5 * v * v) * T) / (v * np.sqrt(T))
    d2 = d1 - v * np.sqrt(T)
    call = S * _cnd_np(d1) - K * np.exp(-r * T) * _cnd_np(d2)
    return call.astype(np.float32)


def metric(out, ref):
    err = jnp.abs(out - ref)
    den = jnp.maximum(jnp.abs(ref), 1.0)  # AxBench qos zero-guard
    return jnp.mean(err / den)


APP = AxApp(
    name="blackscholes",
    metric_name="are",
    minimize=True,
    kind="fxp32",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
