"""AxBench `kmeans`: RGB image segmentation (k=6, fixed Lloyd iterations),
Q16.16 distance arithmetic, SSIM metric on the clustered image."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, to_fxp

from .common import AxApp, smooth_image
from .ssim import ssim

K = 6
ITERS = 4


def gen_inputs(n, seed):
    """Segmentation-friendly image: Voronoi regions of distinct base colors +
    mild noise/shading (photo-like color statistics; a smooth gradient field
    would put most pixels on cluster boundaries, which no fixed-point
    implementation — ours or libfixmath's — can classify stably)."""
    side = max(32, int(n))
    rng = np.random.default_rng(seed)
    colors = rng.uniform(0.1, 0.9, (8, 3))
    sites = rng.uniform(0, side, (8, 2))
    y, x = np.mgrid[0:side, 0:side]
    d = (x[..., None] - sites[:, 0]) ** 2 + (y[..., None] - sites[:, 1]) ** 2
    img = colors[d.argmin(-1)]
    img += rng.normal(0, 0.015, img.shape)  # sensor-ish noise
    img = np.clip(img, 0.0, 1.0)
    # deterministic spread-out initial centroids (same for fxp and reference)
    init = np.linspace(0.08, 0.92, K)[:, None] * np.ones((K, 3))
    return {"img": img, "init": init}


def _assign_fxp(F, px, cents):
    """px (P,3) fxp; cents (K,3) fxp -> (P,) argmin distance^2."""
    d = px[:, None, :] - cents[None, :, :]              # (P,K,3)
    d2 = F.mul(d, d).sum(axis=-1)                       # fxp squares
    return jnp.argmin(d2, axis=1)


def run_fxp(inputs, mul):
    F = FxpMath(mul)
    img = jnp.asarray(inputs["img"], jnp.float32)
    h, w, _ = img.shape
    px = to_fxp(img.reshape(-1, 3))
    cents = to_fxp(jnp.asarray(inputs["init"], jnp.float32))

    def body(cents, _):
        idx = _assign_fxp(F, px, cents)
        onehot = (idx[:, None] == jnp.arange(K)[None, :]).astype(jnp.int32)
        counts = onehot.sum(axis=0)                      # (K,)
        sums = (px[:, None, :] * onehot[:, :, None]).sum(axis=0)  # fxp sums
        new = F.div(sums, jnp.maximum(counts, 1)[:, None] << 16)  # fxp mean
        new = jnp.where((counts > 0)[:, None], new, cents)
        return new, None

    cents, _ = jax.lax.scan(body, cents, None, length=ITERS)
    idx = _assign_fxp(F, px, cents)
    out = jnp.take(cents, idx, axis=0).reshape(h, w, 3)
    return from_fxp(out) * 255.0


def reference(inputs):
    img = np.asarray(inputs["img"], np.float64)
    h, w, _ = img.shape
    px = img.reshape(-1, 3)
    cents = np.asarray(inputs["init"], np.float64)
    for _ in range(ITERS):
        d2 = ((px[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
        idx = d2.argmin(1)
        for k in range(K):
            sel = idx == k
            if sel.any():
                cents[k] = px[sel].mean(0)
    d2 = ((px[:, None, :] - cents[None, :, :]) ** 2).sum(-1)
    idx = d2.argmin(1)
    return (cents[idx].reshape(h, w, 3) * 255.0).astype(np.float32)


def metric(out, ref):
    return ssim(out, ref)


APP = AxApp(
    name="kmeans",
    metric_name="ssim",
    minimize=False,
    kind="fxp32",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
