"""AxBench `fft`: radix-2 DIT FFT, Q16.16 butterflies, ARE metric."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, to_fxp

from .common import AxApp

N_DEFAULT = 1024


def gen_inputs(n, seed):
    n = int(n) if int(n) >= 64 else N_DEFAULT
    n = 1 << int(np.log2(n))
    rng = np.random.default_rng(seed)
    # bounded, structured signal (sum of tones + noise), |x| < 1
    t = np.arange(n)
    sig = np.zeros(n)
    for _ in range(4):
        sig += rng.uniform(0.05, 0.2) * np.sin(2 * np.pi * rng.uniform(1, n / 4) * t / n)
    sig += rng.normal(0, 0.02, n)
    return {"re": sig.astype(np.float64), "im": np.zeros(n)}


def _bitrev_perm(n):
    bits = int(np.log2(n))
    idx = np.arange(n)
    rev = np.zeros(n, np.int64)
    for b in range(bits):
        rev |= ((idx >> b) & 1) << (bits - 1 - b)
    return rev


def run_fxp(inputs, mul):
    F = FxpMath(mul)
    re_in = jnp.asarray(inputs["re"], jnp.float32)
    n = re_in.shape[0]
    rev = _bitrev_perm(n)
    re = to_fxp(re_in)[rev]
    im = to_fxp(jnp.asarray(inputs["im"], jnp.float32))[rev]

    stages = int(np.log2(n))
    for s in range(1, stages + 1):
        m = 1 << s
        half = m >> 1
        # twiddles for this stage, replicated across groups (precise constants)
        k = np.arange(n // 2) % half
        ang = -2.0 * np.pi * k / m
        wr = to_fxp(jnp.asarray(np.cos(ang), jnp.float32))
        wi = to_fxp(jnp.asarray(np.sin(ang), jnp.float32))
        # butterfly index sets
        idx = np.arange(n // 2)
        grp = idx // half
        pos = idx % half
        top = (grp * m + pos).astype(np.int64)
        bot = top + half
        ur, ui = re[top], im[top]
        vr, vi = re[bot], im[bot]
        # t = w * v (4 fxp multiplies)
        tr = F.mul(wr, vr) - F.mul(wi, vi)
        ti = F.mul(wr, vi) + F.mul(wi, vr)
        re = re.at[top].set(ur + tr).at[bot].set(ur - tr)
        im = im.at[top].set(ui + ti).at[bot].set(ui - ti)
    return jnp.stack([from_fxp(re), from_fxp(im)])


def reference(inputs):
    x = np.asarray(inputs["re"]) + 1j * np.asarray(inputs["im"])
    X = np.fft.fft(x)
    return np.stack([X.real, X.imag]).astype(np.float32)


def metric(out, ref):
    """ARE with the AxBench qos convention: zero-reference entries still
    count (denominator clamped to 1e-6 of the scale)."""
    err = jnp.abs(out - ref)
    den = jnp.maximum(jnp.abs(ref), 1e-3)
    return jnp.mean(err / den)


APP = AxApp(
    name="fft",
    metric_name="are",
    minimize=True,
    kind="fxp32",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
