"""AxBench-in-JAX: the paper's application-level evaluation suite."""
from . import blackscholes, fft, inversek2j, jmeint, jpeg, kmeans, sobel
from .common import AxApp, evaluate, smooth_image, tune_app
from .ssim import ssim

ALL_APPS = {
    m.APP.name: m.APP
    for m in (blackscholes, fft, inversek2j, jmeint, kmeans, sobel, jpeg)
}

__all__ = ["AxApp", "evaluate", "tune_app", "smooth_image", "ssim", "ALL_APPS"]
