"""AxBench `jmeint`: 3-D triangle-triangle intersection (separating-axis
test), Q16.16 dot/cross products, miss-rate metric."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, to_fxp

from .common import AxApp


def gen_inputs(n, seed):
    rng = np.random.default_rng(seed)
    n = max(64, int(n))
    # pairs with nearby centers => a healthy mix of hits and misses
    c1 = rng.uniform(-0.5, 0.5, (n, 1, 3))
    c2 = c1 + rng.normal(0, 0.18, (n, 1, 3))
    t1 = c1 + rng.uniform(-0.55, 0.55, (n, 3, 3))
    t2 = c2 + rng.uniform(-0.55, 0.55, (n, 3, 3))
    return {"t1": t1, "t2": t2}


def _sat_intersect(tri1, tri2, dot, cross, zero):
    """Branchless separating-axis test.  tri (N,3,3).  Returns bool (N,)."""
    e1 = jnp.stack([tri1[:, 1] - tri1[:, 0], tri1[:, 2] - tri1[:, 1],
                    tri1[:, 0] - tri1[:, 2]], axis=1)           # (N,3,3)
    e2 = jnp.stack([tri2[:, 1] - tri2[:, 0], tri2[:, 2] - tri2[:, 1],
                    tri2[:, 0] - tri2[:, 2]], axis=1)
    n1 = cross(e1[:, 0], e1[:, 1])[:, None, :]                  # (N,1,3)
    n2 = cross(e2[:, 0], e2[:, 1])[:, None, :]
    # 9 edge-pair axes
    ee = cross(
        jnp.repeat(e1, 3, axis=1).reshape(-1, 3),
        jnp.tile(e2, (1, 3, 1)).reshape(-1, 3),
    ).reshape(tri1.shape[0], 9, 3)
    axes = jnp.concatenate([n1, n2, ee], axis=1)                # (N,11,3)

    def project(tri):
        # (N, 11, 3 verts)
        return dot(axes[:, :, None, :], tri[:, None, :, :])

    p1 = project(tri1)
    p2 = project(tri2)
    min1, max1 = p1.min(-1), p1.max(-1)
    min2, max2 = p2.min(-1), p2.max(-1)
    sep = (max1 < min2) | (max2 < min1)                         # (N,11)
    degenerate = jnp.all(jnp.abs(axes) <= zero, axis=-1)        # ignore null axes
    return ~jnp.any(sep & ~degenerate, axis=1)


def run_fxp(inputs, mul):
    F = FxpMath(mul)
    t1 = to_fxp(jnp.asarray(inputs["t1"], jnp.float32))
    t2 = to_fxp(jnp.asarray(inputs["t2"], jnp.float32))

    def dot(a, b):
        return F.mul(a, b).sum(axis=-1)

    def cross(a, b):
        ax, ay, az = a[..., 0], a[..., 1], a[..., 2]
        bx, by, bz = b[..., 0], b[..., 1], b[..., 2]
        return jnp.stack(
            [F.mul(ay, bz) - F.mul(az, by),
             F.mul(az, bx) - F.mul(ax, bz),
             F.mul(ax, by) - F.mul(ay, bx)], axis=-1)

    return _sat_intersect(t1, t2, dot, cross, zero=jnp.int32(2))


def reference(inputs):
    t1 = jnp.asarray(inputs["t1"], jnp.float32)
    t2 = jnp.asarray(inputs["t2"], jnp.float32)

    def dot(a, b):
        return (a * b).sum(axis=-1)

    def cross(a, b):
        return jnp.cross(a, b)

    out = _sat_intersect(t1, t2, dot, cross, zero=jnp.float32(1e-12))
    return np.asarray(out)


def metric(out, ref):
    return jnp.mean((out != ref).astype(jnp.float32))  # miss rate


APP = AxApp(
    name="jmeint",
    metric_name="miss_rate",
    minimize=True,
    kind="fxp32",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
