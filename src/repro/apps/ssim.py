"""Structural Similarity Index (SSIM) — the paper's image-quality metric
(replacing AxBench's raw image diff, per §III.B).  Uniform 8x8 window variant
on a 0..255 dynamic range; jit-friendly (used inside the app-level tuner)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["ssim"]

_C1 = (0.01 * 255.0) ** 2
_C2 = (0.03 * 255.0) ** 2


def _window_mean(x, w):
    """Mean over w x w windows via a separable cumulative trick ('valid')."""
    k = jnp.ones((w,), x.dtype) / w
    # separable 1-D convolutions along the two trailing axes
    x = jnp.apply_along_axis if False else x  # keep jit-friendly: use conv
    import jax

    def conv1d(v, axis):
        moved = jnp.moveaxis(v, axis, -1)
        flat = moved.reshape(-1, moved.shape[-1])
        out = jax.vmap(lambda r: jnp.convolve(r, k, mode="valid"))(flat)
        return jnp.moveaxis(out.reshape(moved.shape[:-1] + (out.shape[-1],)), -1, axis)

    return conv1d(conv1d(x, -2), -1)


def ssim(img_a, img_b, window: int = 8) -> jnp.ndarray:
    """Mean SSIM between two images (H, W) or (H, W, C), float, 0..255."""
    a = img_a.astype(jnp.float32)
    b = img_b.astype(jnp.float32)
    if a.ndim == 3:  # channel-wise mean
        vals = [ssim(a[..., c], b[..., c], window) for c in range(a.shape[-1])]
        return jnp.mean(jnp.stack(vals))
    mu_a = _window_mean(a, window)
    mu_b = _window_mean(b, window)
    aa = _window_mean(a * a, window) - mu_a * mu_a
    bb = _window_mean(b * b, window) - mu_b * mu_b
    ab = _window_mean(a * b, window) - mu_a * mu_b
    num = (2 * mu_a * mu_b + _C1) * (2 * ab + _C2)
    den = (mu_a**2 + mu_b**2 + _C1) * (aa + bb + _C2)
    return jnp.mean(num / den)
