"""AxBench `inversek2j`: 2-joint arm inverse kinematics, Q16.16, ARE metric."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, to_fxp

from .common import AxApp

L1 = 0.5
L2 = 0.5


def gen_inputs(n, seed):
    rng = np.random.default_rng(seed)
    n = max(64, int(n))
    # reachable targets: radius in (0.15, 0.95), angle in (-pi, pi)
    rad = rng.uniform(0.15, 0.95, n)
    ang = rng.uniform(-np.pi, np.pi, n)
    return {"x": rad * np.cos(ang), "y": rad * np.sin(ang)}


def run_fxp(inputs, mul):
    F = FxpMath(mul)
    x = to_fxp(jnp.asarray(inputs["x"], jnp.float32))
    y = to_fxp(jnp.asarray(inputs["y"], jnp.float32))
    l1 = F.const(L1)
    l2 = F.const(L2)

    r2 = F.mul(x, x) + F.mul(y, y)
    num = r2 - F.mul(l1, l1) - F.mul(l2, l2)
    den = F.mul(to_fxp(2.0), F.mul(l1, l2))
    c2 = jnp.clip(F.div(num, den), to_fxp(-1.0), to_fxp(1.0))
    th2 = F.acos(c2)
    s2 = F.sin(th2)
    th1 = F.atan2(y, x) - F.atan2(F.mul(l2, s2), l1 + F.mul(l2, c2))
    return jnp.stack([from_fxp(th1), from_fxp(th2)])


def reference(inputs):
    x, y = np.asarray(inputs["x"]), np.asarray(inputs["y"])
    r2 = x * x + y * y
    c2 = np.clip((r2 - L1 * L1 - L2 * L2) / (2 * L1 * L2), -1.0, 1.0)
    th2 = np.arccos(c2)
    th1 = np.arctan2(y, x) - np.arctan2(L2 * np.sin(th2), L1 + L2 * c2)
    return np.stack([th1, th2]).astype(np.float32)


def metric(out, ref):
    err = jnp.abs(out - ref)
    den = jnp.maximum(jnp.abs(ref), 0.1)  # qos zero-guard on angles
    return jnp.mean(err / den)


APP = AxApp(
    name="inversek2j",
    metric_name="are",
    minimize=True,
    kind="fxp32",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
