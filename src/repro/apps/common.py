"""Common harness for the AxBench-in-JAX applications.

Each application provides:
  gen_inputs(n, seed)          — deterministic synthetic inputs (train/test
                                 split = different seeds, paper protocol)
  reference(inputs)            — float32/float64 'Original' pipeline (numpy)
  run_fxp(inputs, mul)         — Q16.16 pipeline, every multiply via ``mul``
                                 (jpeg overrides with a direct int16 pipeline)
  metric(out, ref)             — ARE / miss-rate / SSIM, jit-friendly

The harness evaluates any app under:
  'fp'      — the float original               (paper Table II 'Original')
  'fxp'     — precise fixed point              (paper Table II 'FxP')
  NoSwap    — approximate, no swapping         (paper Table III 'NoSwap')
  SwapConfig— approximate + SWAPPER            ('Comp.' / 'App.' columns)
  'oracle'  — per-multiply oracle order        ('Theor.' column)
and drives the application-level tuning with a *dynamic* swap configuration
(one compile for the whole 4M sweep).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, make_mul, to_fxp
from repro.core.modular import AxMul32Config, PART_MD_LO
from repro.core.multipliers import AxMult
from repro.core.swapper import (
    SwapConfig,
    apply_swapper,
    apply_swapper_dyn,
    oracle_mult,
)
from repro.core.tuning import tune_application

__all__ = ["AxApp", "evaluate", "tune_app", "smooth_image", "Mode"]

Mode = Union[str, None, SwapConfig]  # 'fp' | 'fxp' | None(=NoSwap) | cfg | 'oracle'


@dataclasses.dataclass
class AxApp:
    name: str
    metric_name: str            # 'are' | 'miss_rate' | 'ssim'
    minimize: bool
    kind: str                   # 'fxp32' (Eq.6 modular) | 'int16' (direct mul16s)
    gen_inputs: Callable        # (n, seed) -> pytree of np arrays
    reference: Callable         # inputs -> np.ndarray (float pipeline)
    run_fxp: Callable           # (inputs, mul_or_mult16) -> jnp output
    metric: Callable            # (out, ref) -> scalar


def _mul16_closure(mult: AxMult, swap, dyn):
    """Direct 16-bit multiply injection for 'int16' apps (jpeg)."""
    if mult is None:
        return lambda a, b: a.astype(jnp.int32) * b.astype(jnp.int32)
    if dyn is not None:
        return lambda a, b: apply_swapper_dyn(mult, a, b, *dyn).astype(jnp.int32)
    return lambda a, b: apply_swapper(mult, a, b, swap).astype(jnp.int32)


def _build_mul(app: AxApp, mult: Optional[AxMult], parts, swap, dyn):
    if app.kind == "int16":
        return _mul16_closure(mult, swap, dyn)
    if mult is None:
        return make_mul(None)
    cfg = AxMul32Config(mult, parts=parts, swap=swap)
    return make_mul(cfg, dyn)


def evaluate(
    app: AxApp,
    mode: Mode = "fxp",
    mult: Optional[AxMult] = None,
    parts: tuple = PART_MD_LO,
    n: int = 256,
    seed: int = 1234,      # test split (train split uses a different seed)
    inputs=None,
):
    """Run one configuration end to end; returns (metric_value, output)."""
    if inputs is None:
        inputs = app.gen_inputs(n, seed)
    ref = app.reference(inputs)
    if mode == "fp":
        return app.metric(jnp.asarray(ref), jnp.asarray(ref)), ref
    if mode == "fxp":
        mul = _build_mul(app, None, parts, None, None)
    elif mode == "oracle":
        assert mult is not None
        mul = _build_mul(app, oracle_mult(mult), parts, None, None)
    else:  # None (NoSwap) or a SwapConfig
        assert mult is not None
        mul = _build_mul(app, mult, parts, mode, None)
    out = app.run_fxp(inputs, mul)
    return float(jax.device_get(app.metric(out, jnp.asarray(ref)))), out


def tune_app(
    app: AxApp,
    mult: AxMult,
    parts: tuple = PART_MD_LO,
    n: int = 256,
    seed: int = 42,        # train split
    inputs=None,
):
    """Application-level SWAPPER tuning (paper §III.B): score all 4M configs
    on representative (train) inputs with the app's own metric."""
    if inputs is None:
        inputs = app.gen_inputs(n, seed)
    ref = jnp.asarray(app.reference(inputs))
    dev_inputs = jax.tree.map(jnp.asarray, inputs)

    @jax.jit
    def run_cfg(op_is_a, bit, value):
        mul = _build_mul(app, mult, parts, None, (op_is_a, bit, value))
        out = app.run_fxp(dev_inputs, mul)
        return app.metric(out, ref)

    best, best_val, table = tune_application(
        run_cfg, bits=mult.bits, minimize=app.minimize
    )
    return best, best_val, table


# ---------------------------------------------------------------------------
# shared synthetic-input helpers
# ---------------------------------------------------------------------------

def smooth_image(h, w, seed, channels: Optional[int] = None) -> np.ndarray:
    """Structured synthetic image in [0, 255]: random smooth cosine field +
    rectangles + gradient (SSIM needs structure, not noise)."""
    rng = np.random.default_rng(seed)
    y, x = np.mgrid[0:h, 0:w].astype(np.float64)
    c = channels or 1
    img = np.zeros((h, w, c))
    for ch in range(c):
        f = np.zeros((h, w))
        for _ in range(6):
            fx, fy = rng.uniform(0.2, 4.0, 2)
            ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
            f += rng.uniform(0.3, 1.0) * np.cos(2 * np.pi * fx * x / w + ph1) * np.cos(
                2 * np.pi * fy * y / h + ph2
            )
        f += (x / w) * rng.uniform(0.5, 2.0)
        for _ in range(4):  # hard edges
            x0, y0 = rng.integers(0, w - 8), rng.integers(0, h - 8)
            dw, dh = rng.integers(4, max(5, w // 3)), rng.integers(4, max(5, h // 3))
            f[y0 : y0 + dh, x0 : x0 + dw] += rng.uniform(-1.5, 1.5)
        f = (f - f.min()) / max(f.max() - f.min(), 1e-9)
        img[..., ch] = f * 255.0
    return img if channels else img[..., 0]
