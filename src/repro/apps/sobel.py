"""AxBench `sobel`: 3x3 Sobel edge detection, Q16.16, SSIM metric."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.fixedpoint import FxpMath, from_fxp, to_fxp

from .common import AxApp, smooth_image
from .ssim import ssim

_GX = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], np.float64)
_GY = _GX.T


def gen_inputs(n, seed):
    """n is interpreted as image side length (min 32)."""
    side = max(32, int(n))
    return {"img": smooth_image(side, side, seed) / 255.0}  # [0,1]


def _conv3(img, kernel, mul_const):
    h, w = img.shape
    out = jnp.zeros((h - 2, w - 2), img.dtype)
    for di in range(3):
        for dj in range(3):
            c = float(kernel[di, dj])
            if c == 0.0:
                continue
            out = out + mul_const(img[di : h - 2 + di, dj : w - 2 + dj], c)
    return out


def run_fxp(inputs, mul):
    F = FxpMath(mul)
    img = to_fxp(jnp.asarray(inputs["img"], jnp.float32))

    def mul_const(x, c):
        return F.mul(x, F.const(c))

    gx = _conv3(img, _GX, mul_const)
    gy = _conv3(img, _GY, mul_const)
    mag = F.sqrt(F.mul(gx, gx) + F.mul(gy, gy))
    mag = jnp.clip(mag, 0, to_fxp(1.0))
    return from_fxp(mag) * 255.0


def reference(inputs):
    img = np.asarray(inputs["img"], np.float64)
    h, w = img.shape
    gx = np.zeros((h - 2, w - 2))
    gy = np.zeros((h - 2, w - 2))
    for di in range(3):
        for dj in range(3):
            sl = img[di : h - 2 + di, dj : w - 2 + dj]
            gx += _GX[di, dj] * sl
            gy += _GY[di, dj] * sl
    mag = np.minimum(np.sqrt(gx * gx + gy * gy), 1.0)
    return (mag * 255.0).astype(np.float32)


def metric(out, ref):
    return ssim(out, ref)


APP = AxApp(
    name="sobel",
    metric_name="ssim",
    minimize=False,
    kind="fxp32",
    gen_inputs=gen_inputs,
    reference=reference,
    run_fxp=run_fxp,
    metric=metric,
)
