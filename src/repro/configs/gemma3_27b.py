"""gemma3-27b [dense] — 5:1 local:global interleaved attention, 128k context
[hf:google/gemma-3-1b-pt pattern; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab=262144,
    head_dim=128,
    rope_theta=1e6,
    act="silu",
    local_window=1024,
    pattern=("local", "local", "local", "local", "local", "global"),
    tie_embeddings=True,
)
