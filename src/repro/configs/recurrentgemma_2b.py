"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2 recurrent : 1 attn
[arXiv:2402.19427; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    rope_theta=1e4,
    act="silu",
    local_window=2048,
    pattern=("recurrent", "recurrent", "local"),
    d_rnn=2560,
    tie_embeddings=True,
)
