"""deepseek-moe-16b [moe] — fine-grained experts: 2 shared + 64 routed top-6,
first layer dense [arXiv:2401.06066; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,          # dense first-layer FFN width
    vocab=102400,
    head_dim=128,
    rope_theta=1e4,
    act="silu",
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    first_dense=1,
)
