"""qwen2-vl-72b [vlm] — qwen2-72b backbone + M-RoPE; the vision frontend is a
STUB: input_specs() provides precomputed patch embeddings (per assignment).
[arXiv:2409.12191; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1e6,
    act="silu",
    mrope=True,
)
