"""whisper-base [audio] — encoder-decoder; the conv/mel frontend is a STUB:
input_specs() provides precomputed frame embeddings (per assignment)
[arXiv:2212.04356; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,           # decoder layers
    n_enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    head_dim=64,
    act="gelu",
    tie_embeddings=True,
)
