"""Assigned-architecture configs (--arch <id>) + shapes + parallel config."""
from .base import SHAPES, AxPolicy, ModelConfig, ParallelConfig, ShapeConfig
from .deepseek_moe_16b import CONFIG as deepseek_moe_16b
from .gemma3_27b import CONFIG as gemma3_27b
from .granite_moe_1b import CONFIG as granite_moe_1b_a400m
from .mamba2_370m import CONFIG as mamba2_370m
from .qwen15_110b import CONFIG as qwen15_110b
from .qwen2_72b import CONFIG as qwen2_72b
from .qwen2_vl_72b import CONFIG as qwen2_vl_72b
from .recurrentgemma_2b import CONFIG as recurrentgemma_2b
from .starcoder2_15b import CONFIG as starcoder2_15b
from .whisper_base import CONFIG as whisper_base

ARCHS = {
    c.name: c
    for c in (
        qwen2_72b,
        gemma3_27b,
        starcoder2_15b,
        qwen15_110b,
        qwen2_vl_72b,
        deepseek_moe_16b,
        granite_moe_1b_a400m,
        recurrentgemma_2b,
        whisper_base,
        mamba2_370m,
    )
}

# long_500k requires a sub-quadratic path; pure full-attention archs skip it
# (DESIGN.md §6).
LONG_CONTEXT_OK = {"gemma3-27b", "recurrentgemma-2b", "mamba2-370m"}


def get_config(name: str) -> ModelConfig:
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A small same-family config for CPU smoke tests (shapes asserted, no
    NaNs; the FULL config is exercised only via the dry-run)."""
    import dataclasses

    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        head_dim=32,
    )
    if cfg.n_heads:
        kw["n_heads"] = 4
        kw["n_kv_heads"] = min(max(1, cfg.n_kv_heads * 4 // max(cfg.n_heads, 1)), 4) or 1
    if cfg.n_experts:
        kw["n_experts"] = 8
        kw["top_k"] = min(cfg.top_k, 2)
        kw["moe_d_ff"] = 64
        kw["n_shared_experts"] = min(cfg.n_shared_experts, 1)
        kw["moe_capacity"] = 16.0  # no token drops => decode == train
    if cfg.local_window:
        kw["local_window"] = 64
    if cfg.d_rnn:
        kw["d_rnn"] = 128
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = 2
    if cfg.family == "ssm":
        kw["ssm_state"] = 32
        kw["ssm_head_dim"] = 32
        kw["ssm_chunk"] = 32
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)
