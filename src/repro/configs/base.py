"""Model / shape / parallelism configuration dataclasses.

Every assigned architecture is a ``ModelConfig`` in this package; input-shape
cells are ``ShapeConfig``s; ``ParallelConfig`` captures the distribution
strategy knobs that the perf loop (EXPERIMENTS.md §Perf) iterates on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "ParallelConfig", "AxPolicy", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class AxPolicy:
    """SWAPPER approximate-matmul policy (the paper's technique as a
    first-class framework feature; DESIGN.md §5).

    backend:
      'mxu'    — closed-form factorization of the truncation family into two
                 exact int8 matmuls (MXU-friendly; production path at scale)
      'kernel' — the Pallas ax_matmul VPU kernel (arbitrary families)
      'emul'   — pure-jnp reference (tests)
    """

    mult_name: str = "mul8s_trunc0_4"
    swap_operand: str = "A"        # flattened SwapConfig (keeps dataclass hashable)
    swap_bit: int = 3
    swap_value: int = 0
    swap_enabled: bool = True
    backend: str = "mxu"
    targets: Tuple[str, ...] = ("mlp", "attn_out")  # which projections to approximate

    @property
    def swap(self):
        from repro.core.swapper import SwapConfig

        if not self.swap_enabled:
            return None
        return SwapConfig(self.swap_operand, self.swap_bit, self.swap_value)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    act: str = "silu"           # silu (swiglu) | gelu (plain 2-mat mlp)
    tie_embeddings: bool = False
    # --- local/global attention pattern (gemma3 / recurrentgemma) -------
    local_window: int = 0       # sliding-window size for local layers
    pattern: Tuple[str, ...] = ()  # per-period layer kinds, e.g. 5x local + global
    # --- MoE --------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    first_dense: int = 0        # leading dense layers (deepseek-moe)
    moe_capacity: float = 1.25  # capacity factor (reduced configs use a high
    #                             value so train/decode paths drop no tokens)
    # --- RG-LRU hybrid ----------------------------------------------------
    d_rnn: int = 0
    # --- SSM (mamba2) -------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # --- encoder-decoder (whisper) -----------------------------------------
    n_enc_layers: int = 0
    # --- VLM (qwen2-vl) -----------------------------------------------------
    mrope: bool = False
    # --- numerics -----------------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    ax: Optional[AxPolicy] = None
    # pad the embedding/logits vocab dim to a multiple (perf knob: enables
    # vocab-parallel logits when the raw vocab does not divide the mesh;
    # padded ids are masked to -inf in the loss)
    pad_vocab_multiple: int = 1

    @property
    def padded_vocab(self) -> int:
        m = self.pad_vocab_multiple
        return -(-self.vocab // m) * m if m > 1 else self.vocab

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Resolved per-layer kind list of length n_layers."""
        if self.family == "ssm":
            return ("ssm",) * self.n_layers
        kinds = []
        if self.first_dense:
            kinds += ["dense_ffn"] * self.first_dense
        period = self.pattern or ("global",)
        i = 0
        while len(kinds) < self.n_layers:
            kinds.append(period[i % len(period)])
            i += 1
        return tuple(kinds[: self.n_layers])


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """Distribution strategy — the §Perf hillclimbing surface."""

    fsdp: bool = True            # shard weight d_model dim over 'data'
    seq_shard: bool = True       # Megatron-style sequence parallel residual
    remat: str = "layer"         # 'none' | 'layer' | 'dots'
    grad_accum: int = 1
    donate: bool = True
    grad_compress: str = "none"  # 'none' | 'bf16' (all-reduce compression)
    scan_layers: bool = True
    ep: bool = True              # expert parallelism over 'model'
    dp_only: bool = False        # no TP: 'model' axis joins the batch (small models)
