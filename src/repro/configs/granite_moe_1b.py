"""granite-moe-1b-a400m [moe] — 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    rope_theta=1e4,
    act="silu",
    n_experts=32,
    n_shared_experts=0,
    top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
)
