"""AdamW with global-norm clipping and an optional gradient-compression path
(bf16 moment/gradient storage with float32 error feedback) — the
distributed-optimization tricks the train loop composes with grad
accumulation and FSDP sharding."""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    compress: str = "none"   # 'none' | 'bf16' (grads+moments in bf16 + error feedback)


def adamw_init(params, cfg: AdamWConfig):
    mdtype = jnp.bfloat16 if cfg.compress == "bf16" else jnp.float32
    zeros_like = lambda p: jnp.zeros(p.shape, mdtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
    }
    if cfg.compress == "bf16":
        # error-feedback accumulator keeps the quantization residual in f32
        state["ef"] = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(grads, state, params, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cfg.lr * jnp.minimum(1.0, step.astype(jnp.float32) / max(cfg.warmup, 1))

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    if cfg.compress == "bf16":
        # error feedback: g_q = bf16(g + ef); ef' = (g + ef) - g_q
        summed = jax.tree.map(lambda g, e: g + e, grads, state["ef"])
        gq = jax.tree.map(lambda s: s.astype(jnp.bfloat16), summed)
        new_ef = jax.tree.map(lambda s, q: s - q.astype(jnp.float32), summed, gq)
        grads = jax.tree.map(lambda q: q.astype(jnp.float32), gq)

    def upd(g, m, v, p):
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v_new / (1 - cfg.b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], params)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))

    new_state = {"step": step, "m": new_m, "v": new_v}
    if cfg.compress == "bf16":
        new_state["ef"] = new_ef
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
