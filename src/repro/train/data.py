"""Deterministic synthetic data pipeline with checkpointable state.

Tokens are a position-hashed stream (splittable: any (step, index) cell is
computable without materializing history), so a restarted job resumes
*bit-identically* mid-epoch from the step counter alone — the fault-tolerance
property the checkpoint tests exercise.  A binary-file-backed reader with the
same interface covers the "real data" path.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "SyntheticStream", "FileStream", "make_batch_specs"]


@dataclasses.dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "hash"   # 'hash' (uniform, for perf/scale runs) | 'arith'
    #                      ('arith': next = (tok+1) mod vocab — learnable,
    #                       used by convergence tests)


def _hash_tokens(step, cfg: DataConfig) -> np.ndarray:
    """(B, S+1) deterministic pseudo-tokens for a global step (splitmix64;
    uint64 wraparound is intentional)."""
    B, S = cfg.global_batch, cfg.seq_len
    with np.errstate(over="ignore"):
        idx = (
            np.uint64(step) * np.uint64(B * (S + 1))
            + np.arange(B * (S + 1), dtype=np.uint64)
            + np.uint64(cfg.seed) * np.uint64(0x9E3779B97F4A7C15)
        )
        # splitmix64
        z = idx + np.uint64(0x9E3779B97F4A7C15)
        z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        z = z ^ (z >> np.uint64(31))
    toks = (z % np.uint64(cfg.vocab)).astype(np.int32).reshape(B, S + 1)
    if cfg.mode == "arith":
        start = toks[:, :1]
        toks = (start + np.arange(S + 1, dtype=np.int32)[None]) % cfg.vocab
    return toks


class SyntheticStream:
    """state = just the step counter (stored in checkpoints)."""

    def __init__(self, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.step = step

    def next(self) -> dict:
        toks = _hash_tokens(self.step, self.cfg)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
        return self


class FileStream:
    """Flat binary int32 token file, sequential epochs, same interface."""

    def __init__(self, path: str, cfg: DataConfig, step: int = 0):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.step = step
        self.per_step = cfg.global_batch * (cfg.seq_len + 1)

    def next(self) -> dict:
        n = len(self.tokens) - self.per_step
        off = (self.step * self.per_step) % max(n, 1)
        flat = np.asarray(self.tokens[off : off + self.per_step])
        self.step += 1
        toks = flat.reshape(self.cfg.global_batch, self.cfg.seq_len + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, state: dict):
        self.step = int(state["step"])
        return self


def make_batch_specs(cfg: DataConfig):
    shp = (cfg.global_batch, cfg.seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shp, jnp.int32),
        "labels": jax.ShapeDtypeStruct(shp, jnp.int32),
    }
