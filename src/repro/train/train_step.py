"""The jit'd training step: loss + grad + AdamW, with microbatch gradient
accumulation, buffer donation, and logical-axis sharded state."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import train_loss

from .optimizer import AdamWConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "init_train_state"]


def init_train_state(params, opt_cfg: AdamWConfig):
    return {"params": params, "opt": adamw_init(params, opt_cfg)}


def make_train_step(cfg: ModelConfig, par: ParallelConfig, opt_cfg: AdamWConfig,
                    adaptive: bool = False, tile_rows: int = 0):
    """Returns step(state, batch) -> (state, metrics).  With
    par.grad_accum = k, the global batch is split into k microbatches and
    gradients are accumulated in f32 (collectives overlap with compute under
    GSPMD since the accumulation is a scan).

    With ``adaptive=True`` the step instead takes (state, batch, ax_dyn)
    where ``ax_dyn`` is the controller's traced swap-triple tree; the SWAPPER
    forward runs under the dynamic policy and the step's telemetry records
    come back in ``metrics['ax_telemetry']`` (policy updates between steps
    never retrace — only the int32 triples change).  ``tile_rows > 0``
    matches a tile-granular controller: ``ax_dyn`` leaves are per-row-tile
    grids and the telemetry additionally carries the per-tile records."""

    def loss_fn(params, batch):
        loss, metrics = train_loss(params, batch, cfg, par)
        return loss, metrics

    def _step(state, batch):
        params = state["params"]
        k = par.grad_accum
        if k <= 1:
            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def micro(c, mb):
                g_acc, l_acc = c
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, jax.tree.map(
                    lambda x: x.astype(jnp.float32), g)), l_acc + l), None

            def split(x):
                return x.reshape((k, x.shape[0] // k) + x.shape[1:])

            micro_batches = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(micro, (g0, 0.0), micro_batches)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss_sum / k
            metrics = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    if not adaptive:
        return _step

    # telemetry records must be outer-trace outputs: no microbatch scan, no
    # layer scan, no rematerialized bodies around the tapped projections
    assert par.grad_accum <= 1, "adaptive SWAPPER training requires grad_accum=1"
    assert not par.scan_layers, "adaptive SWAPPER training requires scan_layers=False"
    assert par.remat == "none", "adaptive SWAPPER training requires remat='none'"
    from repro.runtime import ax_scope

    def adaptive_step(state, batch, ax_dyn):
        params = state["params"]

        def loss_fn_dyn(params, batch):
            # telemetry must leave through the loss aux: the records are
            # created inside this (differentiated) trace
            with ax_scope(ax_dyn, collect=True, tile_rows=tile_rows) as sc:
                loss, metrics = train_loss(params, batch, cfg, par)
            return loss, dict(metrics, ax_telemetry=sc.collected())

        (loss, metrics), grads = jax.value_and_grad(loss_fn_dyn, has_aux=True)(
            params, batch
        )
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt}, metrics

    return adaptive_step
