"""Fault tolerance: restart supervision, straggler watchdog, elastic resume.

On a real cluster the runtime signals (preemption notice, missing heartbeat,
slow-step detection) come from the orchestration layer; this module provides
the *framework side*: a supervised run loop that checkpoints periodically,
survives worker death (simulated or real exceptions), restores the newest
checkpoint — potentially onto a different mesh (elastic) — and resumes the
data pipeline bit-identically.  The straggler watchdog flags steps exceeding
a deadline multiple of the trailing median so schedulers can rebalance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from . import checkpoint as ckpt_lib

__all__ = ["FaultConfig", "StragglerWatchdog", "SimulatedFailure", "run_supervised"]


class SimulatedFailure(RuntimeError):
    """Raised by tests / chaos hooks to simulate a worker crash."""


@dataclasses.dataclass
class FaultConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 10
    max_restarts: int = 3
    step_deadline_factor: float = 3.0   # straggler threshold vs trailing median


class StragglerWatchdog:
    def __init__(self, factor: float = 3.0, history: int = 32):
        self.factor = factor
        self.times = []
        self.history = history
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        """Record a step time; True if this step straggled."""
        import statistics

        slow = False
        if len(self.times) >= 5:
            med = statistics.median(self.times[-self.history:])
            slow = dt > self.factor * med
            if slow:
                self.flagged += 1
        self.times.append(dt)
        return slow


def run_supervised(
    make_state: Callable[[], dict],
    step_fn: Callable,
    stream,
    n_steps: int,
    fcfg: FaultConfig,
    chaos: Optional[Callable[[int], None]] = None,
    on_step=None,
):
    """Run n_steps with periodic checkpoints; on failure, restore and resume.

    ``chaos(step)`` may raise SimulatedFailure to exercise the recovery path.
    Returns (state, log) where log records restarts and straggler flags.
    """
    log = {"restarts": 0, "stragglers": 0, "steps_run": 0}
    saver = ckpt_lib.AsyncCheckpointer()
    watchdog = StragglerWatchdog(fcfg.step_deadline_factor)

    state = None
    restarts = 0
    while True:
        try:
            if state is None:
                state = make_state()
                last = ckpt_lib.latest_step(fcfg.ckpt_dir)
                start = 0
                if last is not None:
                    state, extra = ckpt_lib.restore(fcfg.ckpt_dir, last, state)
                    stream.restore(extra["data"])
                    start = int(extra["train_step"])
            else:
                start = log["steps_run"]

            for i in range(start, n_steps):
                if chaos is not None:
                    chaos(i)
                t0 = time.monotonic()
                batch = stream.next()
                state, metrics = step_fn(state, batch)
                dt = time.monotonic() - t0
                if watchdog.observe(dt):
                    log["stragglers"] += 1
                log["steps_run"] = i + 1
                if on_step is not None:
                    on_step(i, metrics)
                if (i + 1) % fcfg.ckpt_every == 0:
                    saver.save_async(
                        fcfg.ckpt_dir, i + 1, state,
                        extra={"train_step": i + 1, "data": stream.state()},
                    )
            saver.wait()
            return state, log
        except SimulatedFailure:
            restarts += 1
            log["restarts"] = restarts
            if restarts > fcfg.max_restarts:
                raise
            saver.wait()
            state = None          # full restart: rebuild + restore newest ckpt
