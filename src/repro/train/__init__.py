from .checkpoint import AsyncCheckpointer, latest_step, restore, save
from .data import DataConfig, FileStream, SyntheticStream, make_batch_specs
from .fault import FaultConfig, SimulatedFailure, StragglerWatchdog, run_supervised
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .train_step import init_train_state, make_train_step

__all__ = [
    "AsyncCheckpointer", "latest_step", "restore", "save",
    "DataConfig", "FileStream", "SyntheticStream", "make_batch_specs",
    "FaultConfig", "SimulatedFailure", "StragglerWatchdog", "run_supervised",
    "AdamWConfig", "adamw_init", "adamw_update",
    "init_train_state", "make_train_step",
]
