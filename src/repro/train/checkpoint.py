"""Checkpointing: atomic, async-capable, reshard-on-restore (elastic).

Format: one ``.npz`` with flattened '/'-joined tree paths + a json manifest
(step, data-pipeline state, tree structure).  Writes go to a temp file and
are atomically renamed, so a crash mid-save never corrupts the latest
checkpoint; an optional background thread makes saves non-blocking
(train-loop overlap).  ``restore`` device_puts onto the *current* mesh
sharding, so a job restarted on a different mesh shape (elastic scaling)
resharding happens transparently.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer"]


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, state, extra: Optional[dict] = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(state)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
    final = os.path.join(ckpt_dir, f"step_{step}.npz")
    # npz entry names cannot contain some chars reliably; index them
    names = sorted(flat)
    np.savez(tmp, **{f"a{i}": flat[k] for i, k in enumerate(names)})
    os.replace(tmp, final)
    manifest = {
        "step": step,
        "names": names,
        "extra": extra or {},
        "dtypes": {k: str(flat[k].dtype) for k in names},
    }
    mtmp = os.path.join(ckpt_dir, f".tmp_step_{step}.json")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.replace(mtmp, os.path.join(ckpt_dir, f"step_{step}.json"))
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for fn in os.listdir(ckpt_dir):
        if fn.startswith("step_") and fn.endswith(".json"):
            steps.append(int(fn[len("step_"):-len(".json")]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, sharding_tree=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``sharding_tree`` (same structure) triggers
    device_put with the current mesh's shardings — elastic resharding."""
    with open(os.path.join(ckpt_dir, f"step_{step}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(ckpt_dir, f"step_{step}.npz"))
    flat = {k: data[f"a{i}"] for i, k in enumerate(manifest["names"])}

    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]
    ]
    shard_leaves = (
        jax.tree_util.tree_flatten(sharding_tree)[0] if sharding_tree is not None
        else [None] * len(paths)
    )
    out = []
    for pth, lk, sh in zip(paths, leaves_like, shard_leaves):
        arr = flat[pth]
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Background-thread saver: snapshot to host, return immediately."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, ckpt_dir: str, step: int, state, extra=None):
        self.wait()
        host_state = jax.tree.map(np.asarray, jax.device_get(state))

        def _work():
            save(ckpt_dir, step, host_state, extra)

        self._thread = threading.Thread(target=_work, daemon=True)
        self._thread.start()
