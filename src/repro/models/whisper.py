"""Whisper-style encoder-decoder backbone (conv/mel frontend is a STUB:
inputs are precomputed frame embeddings, per the assignment).  LayerNorm +
biases + gelu MLPs + learned decoder positions, sinusoidal encoder positions.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.sharding import shard

from .layers import (
    attn_apply,
    attn_init,
    dense,
    layernorm,
    mlp_apply,
    mlp_init,
    ninit,
    sinusoid_pos,
)

__all__ = ["init_params", "forward", "init_cache"]

MAX_DEC_POS = 1 << 16


def _ln_init(d):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def _enc_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": _ln_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg, dtype),
        "ln2": _ln_init(cfg.d_model),
        "mlp": mlp_init(ks[1], cfg.d_model, cfg.d_ff, "gelu", dtype, bias=True),
    }


def _dec_layer_init(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": _ln_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg, dtype),
        "ln_x": _ln_init(cfg.d_model),
        "xattn": attn_init(ks[1], cfg, dtype),
        "ln2": _ln_init(cfg.d_model),
        "mlp": mlp_init(ks[2], cfg.d_model, cfg.d_ff, "gelu", dtype, bias=True),
    }


def init_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg, dtype))(
        jax.random.split(ks[0], cfg.n_enc_layers)
    )
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg, dtype))(
        jax.random.split(ks[1], cfg.n_layers)
    )
    return {
        "embed": {"w": ninit(ks[2], (cfg.padded_vocab, cfg.d_model), dtype, scale=0.02)},
        "pos_embed": {"w": ninit(ks[3], (MAX_DEC_POS, cfg.d_model), dtype, scale=0.01)},
        "layers_enc": enc,
        "layers_dec": dec,
        "ln_enc": _ln_init(cfg.d_model),
        "ln_f": _ln_init(cfg.d_model),
    }


def _encode(params, frames, cfg, par):
    dtype = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(dtype) + sinusoid_pos(frames.shape[1], cfg.d_model, dtype)[None]
    x = shard(x, "batch", "seq", None)
    B, S = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        h = layernorm(x, p["ln1"], cfg.norm_eps)
        a, _ = attn_apply(p["attn"], h, cfg, pos=pos, inv_freq=None,
                          causal=False, mode="train")
        x = x + a
        h = layernorm(x, p["ln2"], cfg.norm_eps)
        x = shard(x + mlp_apply(p["mlp"], h, "gelu", cfg.ax), "batch", "seq", None)
        return x, 0

    x, _ = jax.lax.scan(body, x, params["layers_enc"])
    return layernorm(x, params["ln_enc"], cfg.norm_eps)


def _dec_layer(p, x, cfg, *, pos, enc_kv, mode, cache, cache_index, max_cache_len):
    h = layernorm(x, p["ln1"], cfg.norm_eps)
    a, new_self = attn_apply(p["attn"], h, cfg, pos=pos, inv_freq=None, causal=True,
                             mode=mode, cache=cache["self"] if cache else None,
                             cache_index=cache_index, max_cache_len=max_cache_len)
    x = x + a
    h = layernorm(x, p["ln_x"], cfg.norm_eps)
    a, _ = attn_apply(p["xattn"], h, cfg, pos=pos, inv_freq=None, causal=False,
                      mode="decode" if mode == "decode" else "train",
                      cross_kv=enc_kv)
    x = x + a
    h = layernorm(x, p["ln2"], cfg.norm_eps)
    x = shard(x + mlp_apply(p["mlp"], h, "gelu", cfg.ax), "batch", "seq", None)
    return x, new_self


def _cross_kv(p, enc_out, cfg):
    """Precompute per-layer cross-attention K/V from encoder states."""
    B, S, _ = enc_out.shape
    hd = cfg.head_dim_
    k = dense(enc_out, p["xattn"]["k"], cfg.ax, "attn_qkv").reshape(B, S, cfg.n_kv_heads, hd)
    v = dense(enc_out, p["xattn"]["v"], cfg.ax, "attn_qkv").reshape(B, S, cfg.n_kv_heads, hd)
    return k, v


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    hd = cfg.head_dim_
    L = cfg.n_layers
    self_c = {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, hd), dtype),
    }
    cross = jnp.zeros((L, 2, batch, enc_len, cfg.n_kv_heads, hd), dtype)
    return {"self": self_c, "cross": cross}


def forward(params, batch, cfg: ModelConfig, par: Optional[ParallelConfig] = None,
            *, mode="train", cache=None, cache_index=None, max_cache_len=0):
    """batch: {'frames': (B,T,D) stub embeddings, 'tokens': (B,S)} for
    train/prefill; decode uses cached cross-K/V."""
    par = par or ParallelConfig()
    dtype = jnp.dtype(cfg.compute_dtype)

    if mode == "decode":
        enc_kv_all = cache["cross"]          # (L, 2, B, S_enc, KV, hd) stacked
    else:
        enc_out = _encode(params, batch["frames"], cfg, par)
        enc_kv_all = jax.vmap(lambda p: jnp.stack(_cross_kv(p, enc_out, cfg)))(
            params["layers_dec"]
        )

    tok = batch["tokens"]
    B, S = tok.shape
    if mode == "decode":
        pos_idx = jnp.full((B, 1), cache_index, jnp.int32)
    else:
        pos_idx = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x = jnp.take(params["embed"]["w"], tok, axis=0).astype(dtype)
    x = x + jnp.take(params["pos_embed"]["w"], pos_idx, axis=0).astype(dtype)
    x = shard(x, "batch", "seq", None)

    def body(carry, xs):
        x = carry
        p, ekv, cc = xs
        enc_kv = (ekv[0], ekv[1])
        x, new_self = _dec_layer(
            p, x, cfg, pos=pos_idx, enc_kv=enc_kv, mode=mode,
            cache={"self": cc} if mode == "decode" else None,
            cache_index=cache_index, max_cache_len=max_cache_len,
        )
        return x, (new_self if mode != "train" else 0)

    if mode == "decode":
        xs = (params["layers_dec"], enc_kv_all, cache["self"])
    else:
        L = cfg.n_layers
        xs = (params["layers_dec"], enc_kv_all,
              jnp.zeros((L,), jnp.float32))
    x, ys = jax.lax.scan(body, x, xs)

    x = layernorm(x, params["ln_f"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"].astype(x.dtype))
    logits = shard(logits, "batch", None, "vocab")  # vocab-parallel loss

    new_cache = None
    if mode == "prefill":
        new_cache = {"self": ys, "cross": enc_kv_all}
    elif mode == "decode":
        new_cache = {"self": ys, "cross": enc_kv_all}
    return logits, new_cache, jnp.zeros((), jnp.float32)
