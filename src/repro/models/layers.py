"""Shared model layers: projections (exact or SWAPPER-approximate), norms,
RoPE/M-RoPE, GQA attention (chunked flash-style for long context, cached for
decode), MLPs, embeddings.

Parameters are plain nested dicts of arrays.  Logical sharding axes are
derived from parameter *paths* by ``axes_for_path`` (see launch/sharding.py
for the logical->mesh mapping); activations carry explicit logical
constraints via ``shard(...)`` which no-ops outside a mesh context.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AxPolicy, ModelConfig
from repro.launch.sharding import shard

__all__ = [
    "dense",
    "rmsnorm",
    "layernorm",
    "make_rope",
    "apply_rope",
    "attention",
    "attn_init",
    "attn_apply",
    "mlp_init",
    "mlp_apply",
    "axes_for_path",
]


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def ninit(key, shape, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def axes_for_path(path: str, ndim: int):
    """Logical axes for a parameter, derived from its '/'-joined path.
    A leading 'layers' segment (scan-stacked) contributes a None axis."""
    parts = path.split("/")
    stacked = parts and parts[0] == "layers"
    if stacked:
        parts = parts[1:]
    leaf = "/".join(parts)
    base_ndim = ndim - (1 if stacked else 0)

    def a(*axes):
        assert len(axes) == base_ndim, (path, ndim, axes)
        return (("layers",) if stacked else ()) + tuple(axes)

    if leaf.endswith("embed/w") or leaf == "lm_head/w":
        return a("vocab", "embed") if not leaf.startswith("pos") else a(None, "embed")
    if leaf == "pos_embed/w":
        return a(None, "embed")
    if "/q/w" in leaf or leaf.endswith("q/w"):
        return a("embed", "heads")
    if leaf.endswith(("k/w", "v/w")):
        return a("embed", "heads")
    if leaf.endswith("o/w"):
        return a("heads", "embed")
    if leaf.endswith(("q/b", "k/b", "v/b")):
        return a("heads")
    if leaf.endswith("router/w"):
        return a("embed", "experts")
    if leaf.startswith("experts/") or "/experts/" in leaf:
        if leaf.endswith(("in/w", "gate/w")):
            return a("experts", "embed", "ff")
        if leaf.endswith("out/w"):
            return a("experts", "ff", "embed")
    if leaf.endswith(("in/w", "gate/w")):
        return a("embed", "ff")
    if leaf.endswith("out/w"):
        return a("ff", "embed")
    if leaf.endswith(("in/b", "gate/b")):
        return a("ff")
    if leaf.endswith(("out/b", "o/b")):
        return a("embed")
    if leaf.endswith("scale") or leaf.endswith("bias"):
        return a(*([None] * base_ndim))
    # rg-lru / ssm specific
    if leaf.endswith(("wa/w", "wx/w")):
        return a("ff", "ff")
    if leaf.endswith("conv/w"):
        return a(None, "ff")
    if leaf.endswith(("a_log", "d_skip", "dt_bias", "lam")):
        return a(*(["ff"] if base_ndim == 1 else [None] * base_ndim))
    if leaf.endswith(("wb/w", "wc/w")):
        return a("embed", None)
    if leaf.endswith("wdt/w"):
        return a("embed", None)
    return tuple([None] * ndim)


# ---------------------------------------------------------------------------
# projections — exact or SWAPPER-approximate per policy
# ---------------------------------------------------------------------------

def dense(x, p, ax: Optional[AxPolicy] = None, target: str = ""):
    """y = x @ w (+ b).  Routes through the SWAPPER approximate path when the
    policy covers this projection target (DESIGN.md §5).  Under an open
    adaptive-runtime scope the swap config enters as a traced triple instead
    of a baked constant, so the controller can re-tune without recompiles."""
    w = p["w"]
    if ax is not None and target in ax.targets:
        from repro.quant.ax import ax_dense, ax_dense_dyn
        from repro.runtime.scope import active_scope

        scope = active_scope()
        dyn = scope.triple_for(target) if scope is not None else None
        if dyn is not None:
            y = ax_dense_dyn(x, w.astype(x.dtype), ax, dyn, scope=scope, target=target)
        else:
            y = ax_dense(x, w.astype(x.dtype), ax)
    else:
        y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm(x, p, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def layernorm(x, p, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (+ M-RoPE stub for qwen2-vl)
# ---------------------------------------------------------------------------

def make_rope(head_dim: int, theta: float):
    inv = 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))
    return jnp.asarray(inv, jnp.float32)  # (hd/2,)


def apply_rope(x, pos, inv_freq):
    """x (B,S,H,hd); pos (B,S) int32 or (B,S,3) for M-RoPE."""
    hd = x.shape[-1]
    half = hd // 2
    if pos.ndim == 3:  # M-RoPE: temporal/height/width sections over freq dims
        sec = [half // 4, (half * 3) // 8, half - half // 4 - (half * 3) // 8]
        freqs = []
        start = 0
        for i, s in enumerate(sec):
            f = pos[..., i : i + 1].astype(jnp.float32) * inv_freq[start : start + s]
            freqs.append(f)
            start += s
        ang = jnp.concatenate(freqs, axis=-1)  # (B,S,half)
    else:
        ang = pos[..., None].astype(jnp.float32) * inv_freq  # (B,S,half)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def sinusoid_pos(seq, d_model, dtype):
    pos = np.arange(seq)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    ang = pos / (10000 ** (dim / d_model))
    emb = np.zeros((seq, d_model), np.float32)
    emb[:, 0::2] = np.sin(ang)
    emb[:, 1::2] = np.cos(ang)
    return jnp.asarray(emb, dtype)


# ---------------------------------------------------------------------------
# attention — chunked (flash-style online softmax) + decode path
# ---------------------------------------------------------------------------

def _mask_bias(qi, kj, *, causal, window, dtype):
    """(..., q, k) additive mask bias from global positions qi, kj."""
    d = qi[..., :, None] - kj[..., None, :]
    m = jnp.full(d.shape, True)
    if causal:
        m = m & (d >= 0)
    if window:
        m = m & (d < window)
    return jnp.where(m, 0.0, -1e30).astype(dtype)


# Cost-accounting mode for the dry-run: XLA's HloCostAnalysis counts a
# while-loop body ONCE regardless of trip count, so the roofline pass
# compiles small unrolled model variants and extrapolates (launch/dryrun.py).
# When True, the attention chunk loops are fully unrolled (and the q loop
# collapsed) so every FLOP appears in the HLO exactly once.
COST_MODE = False


def chunked_attention(
    q, k, v, q_pos, k_pos, *, causal=True, window=0, q_chunk=512, kv_chunk=1024,
):
    """Flash-style attention with O(chunk^2) memory.

    q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) with H = KV * G.
    Positions are global indices (decode offsets supported).
    """
    B, Sq, H, hd = q.shape
    if COST_MODE:
        q_chunk = Sq  # single q block; kv scan unrolled below
    _, Sk, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    # pad to multiples (positions padded with -1 -> masked out by causal)
    def padq(x, fill=0):
        pad = nq * q_chunk - Sq
        return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2), constant_values=fill)

    def padk(x, fill=0):
        pad = nk * kv_chunk - Sk
        return jnp.pad(x, [(0, 0), (0, pad)] + [(0, 0)] * (x.ndim - 2), constant_values=fill)

    qg = padq(qg)
    qp = padq(q_pos, fill=-(2**30))
    kk = padk(k)
    vv = padk(v)
    kp = padk(k_pos, fill=2**30)

    qg = qg.reshape(B, nq, q_chunk, KV, G, hd)
    qp = qp.reshape(B, nq, q_chunk)
    kk = kk.reshape(B, nk, kv_chunk, KV, hd)
    vv = vv.reshape(B, nk, kv_chunk, KV, hd)
    kp = kp.reshape(B, nk, kv_chunk)

    def q_block(args):
        qb, qpb = args  # (B, qc, KV, G, hd), (B, qc)

        def kv_step(carry, blk):
            m_prev, l_prev, acc = carry
            kb, vb, kpb = blk  # (B, kc, KV, hd), (B, kc)
            s = jnp.einsum("bqkgh,bckh->bkgqc", qb, kb).astype(jnp.float32) * scale
            bias = _mask_bias(qpb[:, None, None, :], kpb[:, None, None, :],
                              causal=causal, window=window, dtype=jnp.float32)
            s = s + bias  # (B,KV,G,qc,kc)
            m_new = jnp.maximum(m_prev, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(-1)
            pv = jnp.einsum("bkgqc,bckh->bkgqh", p.astype(vb.dtype), vb).astype(jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kk.swapaxes(0, 1), vv.swapaxes(0, 1), kp.swapaxes(0, 1)),
            unroll=nk if COST_MODE else 1,
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, hd)

    if nq == 1:
        out = q_block((qg[:, 0], qp[:, 0]))[:, None]
    else:
        out = jax.lax.map(q_block, (qg.swapaxes(0, 1), qp.swapaxes(0, 1))).swapaxes(0, 1)
    out = out.reshape(B, nq * q_chunk, KV, G, hd)[:, :Sq]
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, q_pos, kv_len, *, window=0):
    """Single-token attention over a (possibly ring-buffered) cache.

    q: (B, 1, H, hd); caches: (B, S, KV, hd); kv_len: valid prefix length.
    """
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bckh->bkgc", qg, k_cache).astype(jnp.float32) * scale
    idx = jnp.arange(S)[None, :]
    valid = idx < kv_len[:, None]
    if window:
        valid = valid & (idx > (q_pos[:, None] - window))
    valid = valid & (idx <= q_pos[:, None])
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgc,bckh->bkgh", p, v_cache)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (init + apply with optional cache)
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, dtype):
    hd = cfg.head_dim_
    H = cfg.n_heads * hd
    KVH = cfg.n_kv_heads * hd
    ks = jax.random.split(key, 4)
    p = {
        "q": {"w": ninit(ks[0], (cfg.d_model, H), dtype)},
        "k": {"w": ninit(ks[1], (cfg.d_model, KVH), dtype)},
        "v": {"w": ninit(ks[2], (cfg.d_model, KVH), dtype)},
        "o": {"w": ninit(ks[3], (H, cfg.d_model), dtype)},
    }
    if cfg.qkv_bias:
        for nm, width in (("q", H), ("k", KVH), ("v", KVH)):
            p[nm]["b"] = jnp.zeros((width,), dtype)
    return p


def attn_apply(
    p, x, cfg: ModelConfig, *, pos, inv_freq, causal=True, window=0,
    mode="train", cache=None, cache_index=None, max_cache_len=0,
    q_chunk=512, kv_chunk=1024, cross_kv=None, prompt_lens=None,
    write_mask=None,
):
    """GQA attention block.

    mode='train'   — chunked flash-style attention, no cache, returns (y, None)
    mode='prefill' — same compute, additionally returns a decode-ready cache
                     padded to ``max_cache_len`` (ring layout for local layers)
    mode='decode'  — S==1 step against ``cache``; writes this step's K/V at
                     ``cache_index`` (mod ring for local layers — positions
                     older than the window being overwritten IS the window
                     mask) and returns the updated cache.

    ``cache_index`` is a scalar (one position for the whole batch, the wave
    path) or an int32 ``(B,)`` vector (per-slot positions, the token-granular
    path: each slot writes its own cache row and attends its own prefix
    length).  ``write_mask`` — optional ``(B,)`` bool gating the per-slot
    cache write (False rows are dropped, keeping a retired slot's cache
    region inert).  ``prompt_lens`` — optional ``(B,)`` int32 of real prompt
    lengths for prefill: right-pad key positions beyond a slot's length are
    pushed outside every causal window so padded prompts attend only to real
    tokens.
    """
    B, S, _ = x.shape
    hd = cfg.head_dim_
    ax = cfg.ax
    q = dense(x, p["q"], ax, "attn_qkv").reshape(B, S, cfg.n_heads, hd)
    if cross_kv is None:
        k = dense(x, p["k"], ax, "attn_qkv").reshape(B, S, cfg.n_kv_heads, hd)
        v = dense(x, p["v"], ax, "attn_qkv").reshape(B, S, cfg.n_kv_heads, hd)
        if inv_freq is not None:
            q = apply_rope(q, pos, inv_freq)
            k = apply_rope(k, pos, inv_freq)
    else:
        k, v = cross_kv  # precomputed encoder K/V (whisper cross-attention)

    cdtype = jnp.dtype(cfg.compute_dtype)
    new_cache = None
    if mode == "decode" and cross_kv is None:
        ring = cache["k"].shape[1]
        ci = jnp.asarray(cache_index, jnp.int32)
        slot = (ci % ring) if window else ci
        if ci.ndim == 0:
            kc = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, slot, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, slot, 0, 0))
        else:
            # per-slot positions: each batch row writes its own cache row;
            # masked rows are redirected out of bounds and dropped, so a
            # retired slot's cache region stays byte-identical until a fresh
            # request is spliced in
            tgt = slot if write_mask is None else jnp.where(write_mask, slot, ring)
            rows = jnp.arange(B)
            kc = cache["k"].at[rows, tgt].set(k[:, 0].astype(cache["k"].dtype),
                                              mode="drop")
            vc = cache["v"].at[rows, tgt].set(v[:, 0].astype(cache["v"].dtype),
                                              mode="drop")
        kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
        vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
        valid = jnp.minimum(ci + 1, ring)
        # scalar per-batch position (M-RoPE decode uses the temporal stream)
        qp = pos[:, 0] if pos.ndim == 2 else pos[:, 0, 0]
        out = decode_attention(
            q, kc, vc,
            q_pos=(jnp.full((B,), ring - 1, jnp.int32) if window else qp),
            kv_len=jnp.broadcast_to(valid.astype(jnp.int32), (B,)),
        )
        new_cache = {"k": kc, "v": vc}
    elif mode == "decode":
        out = decode_attention(
            q, k, v,
            q_pos=jnp.full((B,), k.shape[1] - 1, jnp.int32),
            kv_len=jnp.full((B,), k.shape[1], jnp.int32),
        )
    else:
        qpos = pos if pos.ndim == 2 else pos[..., 0]
        if cross_kv is not None:  # enc-dec cross attention: kv has its own axis
            kpos = jnp.broadcast_to(
                jnp.arange(k.shape[1], dtype=jnp.int32)[None], (B, k.shape[1])
            )
        else:
            kpos = qpos
        if prompt_lens is not None and cross_kv is None:
            # pad-mask prefill: push right-pad key positions outside every
            # causal window, so real queries attend only to real tokens
            # (pad queries produce garbage rows that nothing reads — the
            # engine samples at each slot's last *real* position)
            idx = jnp.arange(S, dtype=jnp.int32)[None, :]
            kpos = jnp.where(idx < prompt_lens[:, None], kpos, 2 ** 30)
        out = chunked_attention(
            q, k, v, qpos, kpos, causal=causal, window=window,
            q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        if mode == "prefill" and cross_kv is None:
            if window:
                assert prompt_lens is None, (
                    "pad-mask prefill: ring (sliding-window) caches hold the "
                    "last `window` positions including pads; token-granular "
                    "serving supports full-attention cache layouts only")
                ring = min(window, max_cache_len)
                take = min(ring, S)
                import numpy as _np

                last_pos = _np.arange(S - take, S)
                slots = _np.mod(last_pos, ring)
                kc = jnp.zeros((B, ring, cfg.n_kv_heads, hd), cdtype)
                vc = jnp.zeros((B, ring, cfg.n_kv_heads, hd), cdtype)
                kc = kc.at[:, slots].set(k[:, -take:].astype(cdtype))
                vc = vc.at[:, slots].set(v[:, -take:].astype(cdtype))
            else:
                pad = max_cache_len - S
                kc = jnp.pad(k.astype(cdtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
                vc = jnp.pad(v.astype(cdtype), ((0, 0), (0, pad), (0, 0), (0, 0)))
            kc = shard(kc, "batch", "kv_seq", "kv_heads", None)
            vc = shard(vc, "batch", "kv_seq", "kv_heads", None)
            new_cache = {"k": kc, "v": vc}

    out = out.reshape(B, S, cfg.n_heads * hd)
    return dense(out, p["o"], ax, "attn_out"), new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, act, dtype, bias=False):
    ks = jax.random.split(key, 3)
    p = {"in": {"w": ninit(ks[0], (d_model, d_ff), dtype)},
         "out": {"w": ninit(ks[1], (d_ff, d_model), dtype)}}
    if act == "silu":  # swiglu
        p["gate"] = {"w": ninit(ks[2], (d_model, d_ff), dtype)}
    if bias:
        p["in"]["b"] = jnp.zeros((d_ff,), dtype)
        p["out"]["b"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(p, x, act, ax: Optional[AxPolicy] = None):
    h = dense(x, p["in"], ax, "mlp")
    if act == "silu":
        h = jax.nn.silu(dense(x, p["gate"], ax, "mlp")) * h
    else:
        h = jax.nn.gelu(h)
    return dense(h, p["out"], ax, "mlp")
