"""Model zoo: the 10 assigned architectures over a generic decoder stack
(dense/moe/hybrid/ssm/vlm) plus a whisper-style encoder-decoder."""
from .registry import decode_step, init_cache, init_params, input_specs, prefill, train_loss

__all__ = ["decode_step", "init_cache", "init_params", "input_specs", "prefill", "train_loss"]
