"""Non-dense temporal / FFN blocks: MoE (token-choice top-k with capacity,
expert-parallel), RG-LRU (RecurrentGemma), and Mamba2 SSD (chunked
state-space duality).  All are jit/scan/vmap-safe and provide decode paths
with O(1) state."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.sharding import shard

from .layers import dense, ninit

__all__ = [
    "moe_init", "moe_apply",
    "rglru_init", "rglru_apply",
    "ssd_init", "ssd_apply",
]

CAPACITY_FACTOR = 1.25


# ===========================================================================
# Mixture of Experts — token-choice top-k, capacity-bounded scatter dispatch,
# experts sharded over the 'model' axis (EP).
# ===========================================================================

def moe_init(key, cfg: ModelConfig, dtype):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": ninit(ks[0], (D, E), jnp.float32)},
        "experts": {
            "in": {"w": ninit(ks[1], (E, D, F), dtype, scale=1.0 / math.sqrt(D))},
            "gate": {"w": ninit(ks[2], (E, D, F), dtype, scale=1.0 / math.sqrt(D))},
            "out": {"w": ninit(ks[3], (E, F, D), dtype, scale=1.0 / math.sqrt(F))},
        },
    }
    if cfg.n_shared_experts:
        from .layers import mlp_init

        p["shared"] = mlp_init(ks[4], D, cfg.n_shared_experts * cfg.moe_d_ff,
                               "silu", dtype)
    return p


def _dispatch(flat, topi, k, E, C, dtype):
    """Capacity-bounded scatter dispatch: running per-expert slot counters.
    Returns (buf (E,C,D), slots [T]xk, keeps [T]xk)."""
    T, D = flat.shape
    buf = jnp.zeros((E, C, D), dtype)
    slots, keeps = [], []
    counts = jnp.zeros((E,), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(topi[:, j], E, dtype=jnp.int32)    # (T, E)
        pos = jnp.cumsum(oh, axis=0) - oh + counts[None, :]
        counts = counts + oh.sum(0)
        slot = (pos * oh).sum(-1)                              # (T,)
        keep = slot < C
        slots.append(jnp.where(keep, slot, C - 1))
        keeps.append(keep)
        buf = buf.at[topi[:, j], slots[-1]].add(
            flat * keep[:, None].astype(flat.dtype), mode="drop"
        )
    return buf, jnp.stack(slots, 1), jnp.stack(keeps, 1)


def _dispatch_distributed(flat, topi, k, E, C_loc, dtype, mesh, batch_axes):
    """Per-data-shard capacity dispatch via shard_map (the production EP
    pattern).  A global-cumsum scatter would force GSPMD to all-reduce the
    whole (E,C,D) buffer across data shards every layer (measured: ~70 GB/dev
    per step on granite); giving every data shard its own capacity slice
    turns that into an all-to-all-sized reshard (EXPERIMENTS.md §Perf)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    axes = batch_axes if isinstance(batch_axes, tuple) else (batch_axes,)

    def local(fl, ti):
        buf, slots, keeps = _dispatch(fl, ti, k, E, C_loc, dtype)
        return buf, slots, keeps

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None)),
        out_specs=(P(None, axes, None), P(axes, None), P(axes, None)),
        check_rep=False,
    )
    return fn(flat, topi)


def moe_apply(p, x, cfg: ModelConfig):
    from repro.launch.sharding import _ctx

    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    flat = x.reshape(T, D)

    logits = (flat.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                      # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    st = getattr(_ctx, "state", None)
    mesh = st[0] if st else None
    token_axes = None
    n_shards = 1
    if mesh is not None and st[1]["batch"]:
        b = st[1]["batch"]
        token_axes = b if isinstance(b, tuple) else (b,)
        # tokens (B,S,D)->(T,D): the flattened T dim carries the composite
        # batch x seq sharding (row-major), so dispatch over both
        if st[1].get("seq"):
            token_axes = token_axes + (st[1]["seq"],)
        n_shards = int(np.prod([mesh.shape[a] for a in token_axes]))

    win, wg, wout = (p["experts"][n]["w"] for n in ("in", "gate", "out"))

    def expert_ffn(buf):
        # expert FFN (swiglu), batched over E — EP over the 'model' axis with
        # the capacity dim kept sharded over the data axes, so the
        # tokens->experts reshard is an all-to-all (NOT buffer replication)
        buf = shard(buf, "experts", "batch", None)             # dispatch a2a
        h = jnp.einsum("ecd,edf->ecf", buf, win.astype(buf.dtype))
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(buf.dtype))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wout.astype(buf.dtype))
        return shard(y, "experts", "batch", None)

    if n_shards > 1 and T % n_shards == 0:
        # distributed: per-token-shard capacity.  Dispatch scatter and the
        # combine gather run shard-LOCALLY (shard_map) against each shard's
        # own capacity slice; the only cross-device movement is the
        # (E,C,D) buffer resharding tokens<->experts — a true all-to-all.
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        flat_c = jax.lax.with_sharding_constraint(
            flat, NamedSharding(mesh, P(token_axes, None)))
        topi_c = jax.lax.with_sharding_constraint(
            topi, NamedSharding(mesh, P(token_axes, None)))
        T_loc = T // n_shards
        C_loc = int(np.ceil(T_loc * k / E * cfg.moe_capacity))
        C_loc = min(max(C_loc, 8), T_loc)
        buf, slots, keeps = _dispatch_distributed(
            flat_c, topi_c, k, E, C_loc, x.dtype, mesh, token_axes
        )

        y = expert_ffn(buf)
        # combine all-to-all: bring each shard's capacity slice home
        y = jax.lax.with_sharding_constraint(
            y, NamedSharding(mesh, P(None, token_axes, None)))

        def local_combine(y_loc, ti, tv, sl, kp):
            yf = y_loc.reshape(E * C_loc, D)
            o = jnp.zeros((ti.shape[0], D), x.dtype)
            for j in range(k):
                idx = ti[:, j].astype(jnp.int32) * C_loc + sl[:, j]
                w = (tv[:, j] * kp[:, j].astype(jnp.float32)).astype(x.dtype)
                o = o + jnp.take(yf, idx, axis=0) * w[:, None]
            return o

        tok_spec = P(token_axes, None)
        out = shard_map(
            local_combine, mesh=mesh,
            in_specs=(P(None, token_axes, None), tok_spec, tok_spec, tok_spec,
                      tok_spec),
            out_specs=tok_spec,
            check_rep=False,
        )(y, topi_c, topv, slots, keeps)
    else:
        # reference path (single device / tests): global capacity
        C_tot = int(np.ceil(T * k / E * cfg.moe_capacity))
        C_tot = min(max(C_tot, 8), T)
        buf, slots, keeps = _dispatch(flat, topi, k, E, C_tot, x.dtype)
        y = expert_ffn(buf)
        out = jnp.zeros((T, D), x.dtype)
        yflat = y.reshape(-1, D)
        for j in range(k):
            idx = topi[:, j].astype(jnp.int32) * C_tot + slots[:, j]
            gathered = jnp.take(yflat, idx, axis=0)
            w = (topv[:, j] * keeps[:, j].astype(jnp.float32)).astype(x.dtype)
            out = out + gathered * w[:, None]

    if "shared" in p:
        from .layers import mlp_apply

        out = out + mlp_apply(p["shared"], flat, "silu", cfg.ax).reshape(T, D)
    # aux load-balancing loss term is returned by the caller via probs stats
    aux = E * jnp.mean(
        jnp.mean(probs, axis=0) * jnp.mean(jax.nn.one_hot(topi[:, 0], E), axis=0)
    )
    return out.reshape(B, S, D), aux


# ===========================================================================
# RG-LRU (RecurrentGemma / Griffin)
# ===========================================================================

_LRU_C = 8.0


def rglru_init(key, cfg: ModelConfig, dtype):
    D, R = cfg.d_model, cfg.d_rnn
    ks = jax.random.split(key, 6)
    # Lambda parametrizes the per-channel decay a = exp(-c*softplus(lam)*r);
    # init spreads decays across the (0.9, 0.999)-ish band (Griffin recipe).
    lam = jnp.asarray(np.random.default_rng(0).uniform(0.3, 0.8, R), jnp.float32)
    return {
        "in": {"w": ninit(ks[0], (D, R), dtype)},
        "gate": {"w": ninit(ks[1], (D, R), dtype)},
        "conv": {"w": ninit(ks[2], (4, R), dtype, scale=0.5)},
        "wa": {"w": ninit(ks[3], (R, R), dtype)},
        "wx": {"w": ninit(ks[4], (R, R), dtype)},
        "lam": lam,
        "out": {"w": ninit(ks[5], (R, D), dtype)},
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, width W.  x (B,S,Ch), w (W,Ch).
    state (B, W-1, Ch) for decode; returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W))
    new_state = xp[:, -(W - 1) :] if W > 1 else None
    return y, new_state


def rglru_apply(p, x, cfg: ModelConfig, cache: Optional[dict] = None):
    """Returns (y, new_cache).  cache = {'h': (B,R) f32, 'conv': (B,3,R)}."""
    B, S, D = x.shape
    xr = dense(x, p["in"], cfg.ax, "mlp")
    gate = dense(x, p["gate"], cfg.ax, "mlp")
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = _causal_conv(xr, p["conv"]["w"].astype(xr.dtype), conv_state)

    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["wa"]["w"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ p["wx"]["w"].astype(jnp.float32))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * r            # (B,S,R)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-9)) * (i * xf)

    if cache is None or S > 1:
        h0 = cache["h"][:, None, :] if cache is not None else None

        def comb(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
        h = bb if h0 is None else bb + aa * h0
        h_last = h[:, -1, :]
    else:
        h = (a[:, 0] * cache["h"] + b[:, 0])[:, None, :]
        h_last = h[:, 0]

    y = (h.astype(x.dtype)) * jax.nn.gelu(gate)
    out = dense(y, p["out"], cfg.ax, "mlp")
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_last, "conv": new_conv}
    return out, new_cache


# ===========================================================================
# Mamba2 SSD (state-space duality, chunked)
# ===========================================================================

def ssd_init(key, cfg: ModelConfig, dtype):
    D = cfg.d_model
    din = cfg.ssm_expand * D
    H = din // cfg.ssm_head_dim
    N = cfg.ssm_state
    ks = jax.random.split(key, 8)
    return {
        "in": {"w": ninit(ks[0], (D, din), dtype)},
        "gate": {"w": ninit(ks[1], (D, din), dtype)},
        "wb": {"w": ninit(ks[2], (D, N), dtype)},
        "wc": {"w": ninit(ks[3], (D, N), dtype)},
        "wdt": {"w": ninit(ks[4], (D, H), dtype)},
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "a_log": jnp.asarray(np.log(np.linspace(1.0, 16.0, H)), jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "conv": {"w": ninit(ks[5], (4, din + 2 * N), dtype, scale=0.5)},
        "out": {"w": ninit(ks[6], (din, D), dtype)},
    }


def ssd_apply(p, x, cfg: ModelConfig, cache: Optional[dict] = None):
    """Chunked SSD.  cache = {'h': (B,H,hd,N) f32, 'conv': (B,3,Ch)}."""
    B, S, D = x.shape
    hd = cfg.ssm_head_dim
    din = cfg.ssm_expand * D
    H = din // hd
    N = cfg.ssm_state
    ax = cfg.ax

    xin = dense(x, p["in"], ax, "mlp")
    z = dense(x, p["gate"], ax, "mlp")
    Bc = dense(x, p["wb"], None, "")
    Cc = dense(x, p["wc"], None, "")
    dt = jax.nn.softplus(
        (x @ p["wdt"]["w"].astype(x.dtype)).astype(jnp.float32) + p["dt_bias"]
    )                                                           # (B,S,H)

    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv"]["w"].astype(x.dtype), conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :din]
    Bc = conv_out[..., din : din + N].astype(jnp.float32)
    Cc = conv_out[..., din + N :].astype(jnp.float32)

    a = jnp.exp(-jnp.exp(p["a_log"]) * dt)                      # (B,S,H) in (0,1)
    xh = xin.reshape(B, S, H, hd).astype(jnp.float32)
    dx = dt[..., None] * xh                                     # (B,S,H,hd)

    if cache is not None and S == 1:
        h0 = cache["h"]                                         # (B,H,hd,N)
        h = a[:, 0, :, None, None] * h0 + dx[:, 0, :, :, None] * Bc[:, 0, None, None, :]
        y = jnp.einsum("bhdn,bn->bhd", h, Cc[:, 0])
        y = y + p["d_skip"][None, :, None] * xh[:, 0]
        y = y.reshape(B, 1, din)
        out = dense((y.astype(x.dtype)) * jax.nn.silu(z), p["out"], ax, "mlp")
        return out, {"h": h, "conv": new_conv}

    # ---- chunked scan over sequence --------------------------------------
    L = min(cfg.ssm_chunk, S)
    assert S % L == 0, (S, L)
    nc = S // L

    def r(t, *shape):
        return t.reshape(B, nc, L, *shape)

    a_c = r(a, H)
    la = jnp.cumsum(jnp.log(jnp.maximum(a_c, 1e-30)), axis=2)   # (B,nc,L,H)
    dx_c = r(dx, H, hd)
    B_c = r(Bc, N)
    C_c = r(Cc, N)

    # intra-chunk (attention-like): Y1[j] = sum_{i<=j} (C_j.B_i) decay(i->j) dx_i
    sbc = jnp.einsum("bnjs,bnis->bnij", C_c, B_c)               # [..., i, j]
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]          # [..., j, i, H]
    mask = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])[None, None, :, :, None]
    # mask BEFORE exp: exp(+large) on the upper triangle would be inf and
    # poison gradients through the where (inf * 0 = nan in the vjp)
    w_ji = jnp.exp(jnp.where(mask, diff, -1e30))                # (B,nc,j,i,H)
    y_intra = jnp.einsum("bnij,bnjih,bnihd->bnjhd", sbc, w_ji, dx_c)

    # chunk summaries: T_n = sum_i decay(i->end) dx_i B_i^T   (B,nc,H,hd,N)
    dec_end = jnp.exp(la[:, :, -1:, :] - la)                    # (B,nc,L,H)
    Tn = jnp.einsum("bnlh,bnlhd,bnls->bnhds", dec_end, dx_c, B_c)
    A_n = jnp.exp(la[:, :, -1, :])                              # (B,nc,H)

    # cross-chunk scan
    h_init = cache["h"] if cache is not None else jnp.zeros((B, H, hd, N), jnp.float32)

    def chunk_step(h, blk):
        A_k, T_k = blk                                           # (B,H), (B,H,hd,N)
        h_new = A_k[:, :, None, None] * h + T_k
        return h_new, h
    h_last, h_prev = jax.lax.scan(
        chunk_step, h_init, (A_n.swapaxes(0, 1), Tn.swapaxes(0, 1))
    )
    h_prev = h_prev.swapaxes(0, 1)                               # (B,nc,H,hd,N) state BEFORE chunk

    # inter-chunk: Y2[j] = C_j . (decay(start->j) * h_prev)
    dec_from_start = jnp.exp(la)                                 # (B,nc,L,H)
    y_inter = jnp.einsum("bnls,bnlh,bnhds->bnlhd", C_c, dec_from_start, h_prev)

    y = (y_intra + y_inter).reshape(B, S, H, hd)
    y = y + p["d_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, din).astype(x.dtype) * jax.nn.silu(z)
    out = dense(y, p["out"], ax, "mlp")
    new_cache = {"h": h_last, "conv": new_conv} if cache is not None else None
    return out, new_cache
