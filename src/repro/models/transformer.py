"""Generic decoder-only stack covering the dense / moe / hybrid / ssm / vlm
families.  Layers of the repeating pattern are scan-stacked per
position-in-period (compile-time O(period), not O(n_layers)); leading
heterogeneous layers (e.g. deepseek's dense layer 0) and the pattern
remainder are unrolled.

Layer kinds (ModelConfig.layer_kinds()):
    'global'    — full-attention block + FFN
    'local'     — sliding-window attention block + FFN
    'recurrent' — RG-LRU block + FFN
    'ssm'       — Mamba2 SSD block (no separate FFN branch)
    'dense_ffn' — full attention + dense FFN (inside MoE models)

Modes:
    train   — logits for next-token loss, no caches
    prefill — logits + decode-ready cache pytree (padded to max_cache_len)
    decode  — single-token step against the cache (cache_index = position)
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.sharding import shard

from . import blocks
from .layers import attn_apply, attn_init, make_rope, mlp_apply, mlp_init, ninit, rmsnorm

__all__ = ["init_params", "forward", "Stack", "init_cache"]


# ---------------------------------------------------------------------------
# per-layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, kind: str, dtype):
    ks = jax.random.split(key, 4)
    p = {"ln1": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}}
    if kind in ("global", "local", "dense_ffn"):
        p["attn"] = attn_init(ks[0], cfg, dtype)
    elif kind == "recurrent":
        p["rec"] = blocks.rglru_init(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = blocks.ssd_init(ks[0], cfg, dtype)
        return p  # mamba block: single residual branch
    else:
        raise ValueError(kind)
    p["ln2"] = {"scale": jnp.zeros((cfg.d_model,), jnp.float32)}
    if cfg.family == "moe" and kind != "dense_ffn":
        p["moe"] = blocks.moe_init(ks[1], cfg, dtype)
    else:
        ff = cfg.d_ff
        p["mlp"] = mlp_init(ks[1], cfg.d_model, ff, cfg.act, dtype,
                            bias=cfg.qkv_bias and cfg.act == "gelu")
    return p


def _layer_apply(p, x, cfg: ModelConfig, kind: str, *, pos, inv_freq, mode,
                 cache=None, cache_index=None, max_cache_len=0,
                 prompt_lens=None, write_mask=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["ln1"], cfg.norm_eps)
    if kind in ("global", "local", "dense_ffn"):
        window = cfg.local_window if kind == "local" else 0
        a, new_cache = attn_apply(
            p["attn"], h, cfg, pos=pos, inv_freq=inv_freq, causal=True,
            window=window, mode=mode, cache=cache, cache_index=cache_index,
            max_cache_len=max_cache_len, prompt_lens=prompt_lens,
            write_mask=write_mask,
        )
    elif kind == "recurrent":
        rc = cache
        if mode == "prefill" and rc is None:
            rc = _empty_cache(cfg, kind, x.shape[0], max_cache_len, x.dtype)
        a, new_cache = blocks.rglru_apply(p["rec"], h, cfg, rc if mode != "train" else None)
    elif kind == "ssm":
        rc = cache
        if mode == "prefill" and rc is None:
            rc = _empty_cache(cfg, kind, x.shape[0], max_cache_len, x.dtype)
        a, new_cache = blocks.ssd_apply(p["ssm"], h, cfg, rc if mode != "train" else None)
        x = shard(x + a, "batch", "seq", None)
        return x, new_cache, aux
    else:
        raise ValueError(kind)
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        m, aux = blocks.moe_apply(p["moe"], h, cfg)
    else:
        m = mlp_apply(p["mlp"], h, cfg.act, cfg.ax)
    x = shard(x + m, "batch", "seq", None)
    return x, new_cache, aux


def _empty_cache(cfg: ModelConfig, kind: str, batch, max_len, dtype):
    if kind == "ssm":
        din = cfg.ssm_expand * cfg.d_model
        H = din // cfg.ssm_head_dim
        return {
            "h": jnp.zeros((batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((batch, 3, din + 2 * cfg.ssm_state), dtype),
        }
    hd = cfg.head_dim_
    if kind in ("global", "dense_ffn"):
        shp = (batch, max_len, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "local":
        ring = min(cfg.local_window, max_len)
        shp = (batch, ring, cfg.n_kv_heads, hd)
        return {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
    if kind == "recurrent":
        return {"h": jnp.zeros((batch, cfg.d_rnn), jnp.float32),
                "conv": jnp.zeros((batch, 3, cfg.d_rnn), dtype)}
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack structure
# ---------------------------------------------------------------------------

class Stack:
    """Which layers are scan-stacked (repeating pattern) vs unrolled."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        kinds = list(cfg.layer_kinds())
        self.lead_kinds = kinds[: cfg.first_dense]
        body = kinds[cfg.first_dense:]
        period = (list(cfg.pattern) if cfg.pattern
                  else (["ssm"] if cfg.family == "ssm"
                        else (["global", "moe_"][0:1] if cfg.family != "moe" else ["global"])))
        # normalize: for moe family the body kind string is still 'global'
        self.period = period
        self.n_periods = len(body) // len(period)
        self.rest_kinds = body[self.n_periods * len(period):]


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Decode cache pytree for a model (used by tests / serving)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    st = Stack(cfg)
    cache = {}
    for i, kind in enumerate(st.lead_kinds):
        cache[f"lead{i}"] = _empty_cache(cfg, kind, batch, max_len, dtype)
    if st.n_periods:
        cache["stack"] = {
            f"p{j}": jax.tree.map(
                lambda l: jnp.broadcast_to(l, (st.n_periods,) + l.shape).copy(),
                _empty_cache(cfg, kind, batch, max_len, dtype),
            )
            for j, kind in enumerate(st.period)
        }
    for i, kind in enumerate(st.rest_kinds):
        cache[f"rest{i}"] = _empty_cache(cfg, kind, batch, max_len, dtype)
    return cache


def init_params(key, cfg: ModelConfig, dtype=None):
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    st = Stack(cfg)
    keys = jax.random.split(key, 8)
    V = cfg.padded_vocab
    params = {
        "embed": {"w": ninit(keys[0], (V, cfg.d_model), dtype, scale=0.02)},
        "ln_f": {"scale": jnp.zeros((cfg.d_model,), jnp.float32)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": ninit(keys[1], (V, cfg.d_model), dtype, scale=0.02)}
    for i, kind in enumerate(st.lead_kinds):
        params[f"lead{i}"] = _layer_init(jax.random.fold_in(keys[2], i), cfg, kind, dtype)
    if st.n_periods:
        def stacked(key, kind):
            return jax.vmap(lambda k: _layer_init(k, cfg, kind, dtype))(
                jax.random.split(key, st.n_periods)
            )
        params["layers"] = {
            f"p{j}": stacked(jax.random.fold_in(keys[3], j), kind)
            for j, kind in enumerate(st.period)
        }
    for i, kind in enumerate(st.rest_kinds):
        params[f"rest{i}"] = _layer_init(jax.random.fold_in(keys[4], i), cfg, kind, dtype)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _embed_in(params, batch, cfg: ModelConfig, dtype):
    if "embeds" in batch:  # vlm-style stub frontend
        x = batch["embeds"].astype(dtype)
        B, S = x.shape[:2]
    else:
        tok = batch["tokens"]
        B, S = tok.shape
        x = jnp.take(params["embed"]["w"], tok, axis=0).astype(dtype)
        if cfg.family != "ssm":
            x = x * jnp.asarray(cfg.d_model, dtype) ** 0.5 if cfg.tie_embeddings else x
    if "pos" in batch:
        pos = batch["pos"]
    else:
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        if cfg.mrope:
            pos = jnp.broadcast_to(pos[..., None], (B, S, 3))
    return x, pos


def forward(
    params,
    batch,
    cfg: ModelConfig,
    par: Optional[ParallelConfig] = None,
    *,
    mode: str = "train",
    cache=None,
    cache_index=None,
    max_cache_len: int = 0,
    prompt_lens=None,
    write_mask=None,
):
    """Returns (logits, new_cache, aux_loss).

    ``cache_index`` — decode position: a scalar (whole-batch, the wave path)
    or an int32 ``(B,)`` vector of per-slot positions (token-granular
    serving).  ``prompt_lens`` — optional ``(B,)`` real prompt lengths for
    pad-mask prefill (right-padded prompts attend only to real tokens);
    requires a full-attention stack (no ring/recurrent/ssm state, which
    would absorb the pad tail).  ``write_mask`` — optional ``(B,)`` bool
    gating decode cache writes per slot (retired slots stay inert).
    """
    par = par or ParallelConfig()
    dtype = jnp.dtype(cfg.compute_dtype)
    st = Stack(cfg)
    if prompt_lens is not None:
        assert all(k in ("global", "dense_ffn") for k in cfg.layer_kinds()), (
            f"pad-mask prefill needs a full-attention stack; "
            f"{cfg.name} has kinds {sorted(set(cfg.layer_kinds()))}")
    x, pos = _embed_in(params, batch, cfg, dtype)
    x = shard(x, "batch", "seq", None)
    B = x.shape[0]
    if mode == "decode" and "pos" not in batch:
        ci = jnp.asarray(cache_index, jnp.int32)
        pos = jnp.broadcast_to(ci[:, None] if ci.ndim == 1 else ci, (B, 1))
        pos = pos.astype(jnp.int32)
        if cfg.mrope:
            pos = jnp.broadcast_to(pos[..., None], (B, 1, 3))
    inv_freq = make_rope(cfg.head_dim_, cfg.rope_theta) if cfg.n_heads else None

    apply_kw = dict(pos=pos, inv_freq=inv_freq, mode=mode,
                    cache_index=cache_index, max_cache_len=max_cache_len,
                    prompt_lens=prompt_lens if mode != "decode" else None,
                    write_mask=write_mask if mode == "decode" else None)
    new_cache = {}
    aux = jnp.zeros((), jnp.float32)

    # --- leading unrolled layers ------------------------------------------
    for i, kind in enumerate(st.lead_kinds):
        lc = cache[f"lead{i}"] if mode == "decode" else None
        x, nc, a = _layer_apply(params[f"lead{i}"], x, cfg, kind, cache=lc, **apply_kw)
        aux = aux + a
        if mode != "train":
            new_cache[f"lead{i}"] = nc

    # --- scan over pattern periods -----------------------------------------
    if st.n_periods:
        period = st.period

        def body(carry, xs):
            x, aux = carry
            pp, cc = xs
            ncs = {}
            for j, kind in enumerate(period):
                lc = cc[f"p{j}"] if cc is not None else None
                x, nc, a = _layer_apply(pp[f"p{j}"], x, cfg, kind, cache=lc, **apply_kw)
                aux = aux + a
                ncs[f"p{j}"] = nc if nc is not None else 0
            return (x, aux), (ncs if mode != "train" else 0)

        scan_body = body
        if mode == "train" and par.remat == "layer":
            scan_body = jax.checkpoint(body, prevent_cse=False)
        elif mode == "train" and par.remat == "dots":
            scan_body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        cache_xs = cache["stack"] if mode == "decode" else None
        if mode == "decode":
            xs = (params["layers"], cache_xs)
        else:
            xs = (params["layers"], None)
            # scan requires a pytree with a leading axis; replace None by
            # per-period dummies
            xs = (params["layers"],
                  {f"p{j}": jnp.zeros((st.n_periods,), jnp.float32) for j in range(len(period))})

            def body_nocache(carry, xs):
                x, aux = carry
                pp, _ = xs
                ncs = {}
                for j, kind in enumerate(period):
                    x2, nc, a = _layer_apply(pp[f"p{j}"], x, cfg, kind, cache=None, **apply_kw)
                    x = x2
                    aux = aux + a
                    ncs[f"p{j}"] = nc if nc is not None else 0
                return (x, aux), (ncs if mode == "prefill" else 0)

            scan_body = body_nocache
            if mode == "train" and par.remat == "layer":
                scan_body = jax.checkpoint(body_nocache, prevent_cse=False)
            elif mode == "train" and par.remat == "dots":
                scan_body = jax.checkpoint(
                    body_nocache, prevent_cse=False,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        if par.scan_layers:
            (x, aux), ys = jax.lax.scan(scan_body, (x, aux), xs)
            if mode != "train":
                new_cache["stack"] = ys
        else:
            ys_list = []
            for n in range(st.n_periods):
                sl = jax.tree.map(lambda t: t[n], xs)
                (x, aux), y = scan_body((x, aux), sl)
                ys_list.append(y)
            if mode != "train":
                new_cache["stack"] = jax.tree.map(lambda *ts: jnp.stack(ts), *ys_list)

    # --- trailing unrolled layers -------------------------------------------
    for i, kind in enumerate(st.rest_kinds):
        lc = cache[f"rest{i}"] if mode == "decode" else None
        x, nc, a = _layer_apply(params[f"rest{i}"], x, cfg, kind, cache=lc, **apply_kw)
        aux = aux + a
        if mode != "train":
            new_cache[f"rest{i}"] = nc

    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    head_w = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", x, head_w.astype(x.dtype))
    logits = shard(logits, "batch", None, "vocab")  # vocab-parallel loss
    return logits, (new_cache if mode != "train" else None), aux
