"""Model registry: init / loss / prefill / decode entry points per family,
plus ``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run — weak-type
correct, shardable, zero allocation)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ParallelConfig, ShapeConfig

from . import transformer, whisper

__all__ = ["init_params", "train_loss", "prefill", "decode_step", "input_specs", "init_cache"]


def _mod(cfg: ModelConfig):
    return whisper if cfg.family == "encdec" else transformer


def init_params(key, cfg: ModelConfig, dtype=None):
    return _mod(cfg).init_params(key, cfg, dtype)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int = 0):
    if cfg.family == "encdec":
        return whisper.init_cache(cfg, batch, max_len, enc_len or max_len)
    return transformer.init_cache(cfg, batch, max_len)


def train_loss(params, batch, cfg: ModelConfig, par: Optional[ParallelConfig] = None):
    """Next-token (or seq2seq) CE + MoE aux; returns (loss, metrics)."""
    logits, _, aux = _mod(cfg).forward(params, batch, cfg, par, mode="train")
    labels = batch["labels"]
    if cfg.padded_vocab != cfg.vocab:
        # mask the padded tail out of the softmax (ids never reference it)
        pad_mask = (jnp.arange(cfg.padded_vocab) >= cfg.vocab).astype(jnp.float32)
        logits = logits.astype(jnp.float32) - 1e9 * pad_mask
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"ce": loss, "aux": aux}


def prefill(params, batch, cfg: ModelConfig, par=None, *, max_cache_len: int,
            prompt_lens=None):
    """``prompt_lens`` — optional (B,) int32 of real prompt lengths: the
    pad-mask prefill path (right-padded prompts attend only to real tokens;
    full-attention stacks only — see ``transformer.forward``)."""
    if prompt_lens is not None:
        assert cfg.family != "encdec", "pad-mask prefill: encdec unsupported"
    logits, cache, _ = _mod(cfg).forward(
        params, batch, cfg, par, mode="prefill", max_cache_len=max_cache_len,
        **({} if prompt_lens is None else {"prompt_lens": prompt_lens})
    )
    return logits, cache


def decode_step(params, cache, tokens, cache_index, cfg: ModelConfig, par=None,
                write_mask=None):
    """One serving step: tokens (B, 1) at position ``cache_index`` — a
    scalar (whole batch) or an int32 (B,) vector of per-slot positions.
    ``write_mask`` (B,) bool gates per-slot cache writes (vector path;
    transformer families only — encdec decode has no per-slot plumbing)."""
    if write_mask is not None:
        assert cfg.family != "encdec", "per-slot decode: encdec unsupported"
    batch = {"tokens": tokens}
    logits, new_cache, _ = _mod(cfg).forward(
        params, batch, cfg, par, mode="decode", cache=cache,
        cache_index=cache_index,
        **({} if write_mask is None else {"write_mask": write_mask})
    )
    return logits, new_cache


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct only)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Abstract inputs for (arch x shape).  No device allocation."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.compute_dtype)

    def tok(shp):
        return jax.ShapeDtypeStruct(shp, i32)

    if shape.kind == "train":
        if cfg.family == "encdec":
            # audio: precomputed frame embeddings (stub frontend) + text
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                "tokens": tok((B, min(S, 448))),
                "labels": tok((B, min(S, 448))),
            }
        if cfg.family == "vlm":
            return {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                "pos": tok((B, S, 3)),
                "labels": tok((B, S)),
            }
        return {"tokens": tok((B, S)), "labels": tok((B, S))}

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                "tokens": tok((B, min(S, 448))),
            }
        if cfg.family == "vlm":
            return {
                "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                "pos": tok((B, S, 3)),
            }
        return {"tokens": tok((B, S))}

    # decode: one token against a cache of size S
    specs = {"tokens": tok((B, 1))}
    cache = jax.eval_shape(
        lambda: init_cache(cfg, B, S, enc_len=min(S, 1500) if cfg.family == "encdec" else 0)
    )
    return {"tokens": specs["tokens"], "cache": cache}
