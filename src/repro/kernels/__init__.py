"""Pallas TPU kernels for the SWAPPER compute hot-spots.

  ax_matmul     — int8 approximate matmul with fused SWAPPER operand swap
                  (the paper's technique as a production matmul VPU kernel;
                  DESIGN.md §4/§5)
  tuning_sweep  — component-level exhaustive tuning sweep (row stats of the
                  E0/E1/oracle error surfaces; rank-1 reduction)

ops.py holds the jit'd wrappers, ref.py the pure-jnp oracles.
"""
from .ops import ax_matmul, ax_matmul_dequant, ax_matmul_grid, component_sweep_pallas
from .ref import ax_matmul_grid_ref, ax_matmul_ref, tile_hist_ref, tuning_sweep_ref

__all__ = [
    "ax_matmul",
    "ax_matmul_dequant",
    "ax_matmul_grid",
    "component_sweep_pallas",
    "ax_matmul_ref",
    "ax_matmul_grid_ref",
    "tile_hist_ref",
    "tuning_sweep_ref",
]
