"""Pallas TPU kernel: int8 approximate matmul with fused SWAPPER swapping.

``C[m, n] = sum_k axmul(A[m, k], B[k, n])`` where ``axmul`` is a closed-form
approximate-multiplier family from ``repro.core.multipliers`` and the SWAPPER
single-bit decision is fused *ahead of* each scalar multiply as a pair of
vector selects (the TPU-idiomatic form of the paper's ``xchg``; DESIGN.md §4).

TPU adaptation notes
--------------------
* The MXU computes exact products, so an approximate-multiplier inner product
  is a **VPU** workload: int8 loads -> int32 lanes, shifts/masks/mul/select,
  int32 accumulation.  Block shapes are chosen so the (bm, bn) accumulator,
  the (bm, bk) / (bk, bn) operand tiles and the (bm, bn) broadcast temporary
  fit VMEM with MXU-aligned (multiple-of-128) lane dims.
* The K reduction runs as the innermost grid dimension with output-block
  revisiting (init at k==0, accumulate after), the standard Pallas matmul
  reduction pattern.  Within a tile the reduction is slab-blocked: K is
  processed in (bm, k_slab, bn) sublane slabs with one select/multiply/
  reduce per slab instead of ``bk`` rank-1 steps (see ``_accumulate_tile``).
* The LUT path (arbitrary 8-bit circuits, EvoApprox compatibility) keeps the
  64 Ki-entry table resident in VMEM (256 KiB as int32) and gathers per
  element; on real TPUs a VMEM gather lowers slowly, so the closed-form path
  is the production path (see DESIGN.md).  Both validate in interpret mode.

Validated in ``interpret=True`` mode against ``ref.py`` (this container has
no TPU); block specs and layouts are written for a real v5e target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig, swap_mask_dyn

__all__ = ["ax_matmul_pallas", "ax_matmul_grid_pallas", "HIST_WIDTH"]

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _swap_select(a, b, swap: Optional[SwapConfig]):
    """Branch-free SWAPPER front-end on int32 lanes (broadcasts ok)."""
    if swap is None:
        return a, b
    src = a if swap.operand == "A" else b
    sel = ((src >> swap.bit) & 1) == swap.value
    aa = jnp.where(sel, b, a)
    bb = jnp.where(sel, a, b)
    return aa, bb


DEFAULT_K_SLAB = 8   # sublanes per reduction slab (one VPU register of int32)


def HIST_WIDTH(bits: int) -> int:
    """Columns of a tile histogram row: one count per magnitude-bit position
    plus a trailing negative-sign count — the same layout as the streaming
    telemetry's ``bit_probs`` statistic (``runtime.telemetry._bit_counts``)."""
    return bits + 1


def _hist_row(blk_i32, bits: int):
    """(bits+1,) int32 occupancy counts of one operand block: per-position
    set **magnitude** bits, then the negative count (raw two's-complement
    bits are a poor drift statistic for signed operands — see telemetry)."""
    shifts = jnp.arange(bits, dtype=jnp.int32)
    mag = jnp.abs(blk_i32)
    cnt = jnp.sum((mag[:, :, None] >> shifts) & 1, axis=(0, 1), dtype=jnp.int32)
    neg = jnp.sum((blk_i32 < 0).astype(jnp.int32), dtype=jnp.int32)
    return jnp.concatenate([cnt, neg[None]])


def _pick_k_slab(bk: int, k_slab: Optional[int]) -> int:
    """Largest divisor of ``bk`` that is <= ``k_slab`` (None = default)."""
    from repro.core.tiling import largest_divisor_leq

    return largest_divisor_leq(bk, DEFAULT_K_SLAB if k_slab is None else k_slab)


def _accumulate_tile(a_ref, b_ref, o_ref, select, mult: AxMult, bk: int,
                     k_slab: Optional[int] = None, hist_ref=None):
    """Shared (bm, bn) output-tile accumulation (K innermost, output-block
    revisiting): ``select(a, b)`` applies the SWAPPER front-end — static
    config for ``_ax_matmul_kernel``, scalar-prefetched triple for the grid
    kernel.

    ``hist_ref`` — optional (1, 1, 2, bits+1) int32 output block: tile-local
    bit-occupancy histograms, accumulated here at the existing per-tile
    reduction point (the operand blocks are already VMEM-resident for the
    reduction, so the counts cost a handful of extra VPU reductions and no
    additional loads).  Row 0 counts the A tile (bm x K elements over the
    whole reduction), row 1 the B tile (K x bn); the layout matches the
    telemetry drift statistic (magnitude-bit counts + sign count).  This is
    what lets the adaptive controller see *within-matmul* operand structure
    and populate per-row-tile swap grids from live traffic.

    The K reduction is slab-blocked sublane vectorization: instead of ``bk``
    rank-1 VPU steps (one (bm, 1) x (1, bn) broadcast multiply per k), each
    loop iteration materializes a (bm, ks, bn) slab — ks sublanes of A
    against ks rows of B — and performs ONE select/multiply/reduce over the
    slab, cutting the loop trip count (and per-step select/multiply dispatch
    overhead) by ks while keeping the slab temporary VMEM-resident
    (bm * ks * bn * 4 B = 512 KiB at the default 128/8/128).  ``k_slab=1``
    reproduces the legacy rank-1 schedule (kept as the benchmark baseline)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        if hist_ref is not None:
            hist_ref[...] = jnp.zeros_like(hist_ref)

    a_blk = a_ref[...].astype(jnp.int32)          # (bm, bk)
    b_blk = b_ref[...].astype(jnp.int32)          # (bk, bn)
    if hist_ref is not None:
        bits = mult.bits
        hist_ref[0, 0, 0, :] += _hist_row(a_blk, bits)
        hist_ref[0, 0, 1, :] += _hist_row(b_blk, bits)
    ks = _pick_k_slab(bk, k_slab)

    def body(s, acc):
        # (bm, ks, bn) slab: ks consecutive rank-1 products, one dispatch
        a_slab = jax.lax.dynamic_slice_in_dim(a_blk, s * ks, ks, axis=1)  # (bm, ks)
        b_slab = jax.lax.dynamic_slice_in_dim(b_blk, s * ks, ks, axis=0)  # (ks, bn)
        aa, bb = select(a_slab[:, :, None], b_slab[None, :, :])
        prod = mult.fn(aa, bb).astype(jnp.int32)                          # (bm, ks, bn)
        return acc + jnp.sum(prod, axis=1, dtype=jnp.int32)

    acc = jax.lax.fori_loop(0, bk // ks, body, jnp.zeros(o_ref.shape, jnp.int32))
    o_ref[...] += acc


def _ax_matmul_kernel(a_ref, b_ref, o_ref, *rest, mult: AxMult, swap, bk: int,
                      k_slab: Optional[int] = None):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost.
    With ``tile_hist`` the histogram block arrives as a second output ref."""
    _accumulate_tile(a_ref, b_ref, o_ref,
                     lambda a, b: _swap_select(a, b, swap), mult, bk,
                     k_slab=k_slab, hist_ref=rest[0] if rest else None)


def ax_matmul_pallas(
    a: jax.Array,                 # (M, K) int8
    b: jax.Array,                 # (K, N) int8
    mult: AxMult,
    swap: Optional[SwapConfig] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    k_slab: Optional[int] = None,
    tile_hist: bool = False,
    interpret: bool = True,
):
    """Blocked approximate matmul; returns int32 (M, N).  ``k_slab`` sets
    the sublane depth of the vectorized K reduction (None = auto; 1 = the
    legacy rank-1 schedule, kept for benchmarking).

    ``tile_hist=True`` additionally returns a (M/bm, N/bn, 2, bits+1) int32
    tile-local bit-occupancy histogram (per output tile: magnitude-bit +
    sign counts of the A and B operand tiles), accumulated inside the K
    reduction — the kernel-side feed of the per-tile adaptive loop."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(_ax_matmul_kernel, mult=mult, swap=swap, bk=bk,
                               k_slab=k_slab)
    out_shape = jax.ShapeDtypeStruct((M, N), jnp.int32)
    out_specs = pl.BlockSpec((bm, bn), lambda i, j, k: (i, j))
    if tile_hist:
        hw = HIST_WIDTH(mult.bits)
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((grid[0], grid[1], 2, hw), jnp.int32)]
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1, 2, hw), lambda i, j, k: (i, j, 0, 0))]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(a, b)


# ---------------------------------------------------------------------------
# granular (per-tile) swap-config grids — the adaptive-runtime kernel
# ---------------------------------------------------------------------------

def _ax_matmul_grid_kernel(cfg_ref, a_ref, b_ref, o_ref, *rest, mult: AxMult,
                           bk: int, k_slab: Optional[int] = None):
    """Like ``_ax_matmul_kernel`` but the swap decision comes from a
    scalar-prefetched (grid_m, grid_n, 3) int32 triple grid indexed by the
    output-tile coordinates: op_is_a / bit / value are runtime values, so the
    policy (down to per-row-tile granularity) changes without recompiling."""
    i, j = pl.program_id(0), pl.program_id(1)
    op_is_a = cfg_ref[i, j, 0]
    bit = cfg_ref[i, j, 1]
    value = cfg_ref[i, j, 2]

    def select(a, b):
        # core.swapper owns the triple semantics; pure jnp, fine in-kernel
        sel = swap_mask_dyn(a, b, op_is_a, bit, value)    # slab broadcast
        return jnp.where(sel, b, a), jnp.where(sel, a, b)

    _accumulate_tile(a_ref, b_ref, o_ref, select, mult, bk, k_slab=k_slab,
                     hist_ref=rest[0] if rest else None)


def ax_matmul_grid_pallas(
    a: jax.Array,                 # (M, K) int8
    b: jax.Array,                 # (K, N) int8
    mult: AxMult,
    cfg_grid: jax.Array,          # (M/bm, N/bn, 3) int32 (op_is_a, bit, value)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    k_slab: Optional[int] = None,
    tile_hist: bool = False,
    interpret: bool = True,
):
    """Blocked approximate matmul with a per-output-tile swap-config grid
    (scalar prefetch: the grid is resident in SMEM before the body runs).

    ``tile_hist=True`` additionally returns the (M/bm, N/bn, 2, bits+1)
    int32 tile-local bit-occupancy histogram (see :func:`ax_matmul_pallas`)
    — the same compiled program both *applies* the per-tile policy and
    *observes* the per-tile operand distribution that drives its next
    re-tune, which is the whole per-tile adaptive loop in one dispatch."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (M // bm, N // bn, K // bk)
    assert cfg_grid.shape == (grid[0], grid[1], 3), (cfg_grid.shape, grid)

    kernel = functools.partial(_ax_matmul_grid_kernel, mult=mult, bk=bk,
                               k_slab=k_slab)
    out_shape = jax.ShapeDtypeStruct((M, N), jnp.int32)
    out_specs = pl.BlockSpec((bm, bn), lambda i, j, k, cfg: (i, j))
    if tile_hist:
        hw = HIST_WIDTH(mult.bits)
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((grid[0], grid[1], 2, hw), jnp.int32)]
        out_specs = [out_specs,
                     pl.BlockSpec((1, 1, 2, hw), lambda i, j, k, cfg: (i, j, 0, 0))]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, cfg: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, cfg: (k, j)),
        ],
        out_specs=out_specs,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(cfg_grid.astype(jnp.int32), a, b)
