"""Pallas TPU kernel: int8 approximate matmul with fused SWAPPER swapping.

``C[m, n] = sum_k axmul(A[m, k], B[k, n])`` where ``axmul`` is a closed-form
approximate-multiplier family from ``repro.core.multipliers`` and the SWAPPER
single-bit decision is fused *ahead of* each scalar multiply as a pair of
vector selects (the TPU-idiomatic form of the paper's ``xchg``; DESIGN.md §4).

TPU adaptation notes
--------------------
* The MXU computes exact products, so an approximate-multiplier inner product
  is a **VPU** workload: int8 loads -> int32 lanes, shifts/masks/mul/select,
  int32 accumulation.  Block shapes are chosen so the (bm, bn) accumulator,
  the (bm, bk) / (bk, bn) operand tiles and the (bm, bn) broadcast temporary
  fit VMEM with MXU-aligned (multiple-of-128) lane dims.
* The K reduction runs as the innermost grid dimension with output-block
  revisiting (init at k==0, accumulate after), the standard Pallas matmul
  reduction pattern.
* The LUT path (arbitrary 8-bit circuits, EvoApprox compatibility) keeps the
  64 Ki-entry table resident in VMEM (256 KiB as int32) and gathers per
  element; on real TPUs a VMEM gather lowers slowly, so the closed-form path
  is the production path (see DESIGN.md).  Both validate in interpret mode.

Validated in ``interpret=True`` mode against ``ref.py`` (this container has
no TPU); block specs and layouts are written for a real v5e target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig

__all__ = ["ax_matmul_pallas"]


def _swap_select(a, b, swap: Optional[SwapConfig]):
    """Branch-free SWAPPER front-end on int32 lanes (broadcasts ok)."""
    if swap is None:
        return a, b
    src = a if swap.operand == "A" else b
    sel = ((src >> swap.bit) & 1) == swap.value
    aa = jnp.where(sel, b, a)
    bb = jnp.where(sel, a, b)
    return aa, bb


def _ax_matmul_kernel(a_ref, b_ref, o_ref, *, mult: AxMult, swap, bk: int, k_steps: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_blk = a_ref[...].astype(jnp.int32)          # (bm, bk)
    b_blk = b_ref[...].astype(jnp.int32)          # (bk, bn)

    def body(k, acc):
        # rank-1 slab: every scalar product of A[:, k] x B[k, :]
        a_col = jax.lax.dynamic_slice_in_dim(a_blk, k, 1, axis=1)   # (bm, 1)
        b_row = jax.lax.dynamic_slice_in_dim(b_blk, k, 1, axis=0)   # (1, bn)
        aa, bb = _swap_select(a_col, b_row, swap)
        prod = mult.fn(aa, bb).astype(jnp.int32)                    # (bm, bn)
        return acc + prod

    acc = jax.lax.fori_loop(0, bk, body, jnp.zeros(o_ref.shape, jnp.int32))
    o_ref[...] += acc


def ax_matmul_pallas(
    a: jax.Array,                 # (M, K) int8
    b: jax.Array,                 # (K, N) int8
    mult: AxMult,
    swap: Optional[SwapConfig] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked approximate matmul; returns int32 (M, N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(
        _ax_matmul_kernel, mult=mult, swap=swap, bk=bk, k_steps=grid[2]
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(a, b)
