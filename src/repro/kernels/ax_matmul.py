"""Pallas TPU kernel: int8 approximate matmul with fused SWAPPER swapping.

``C[m, n] = sum_k axmul(A[m, k], B[k, n])`` where ``axmul`` is a closed-form
approximate-multiplier family from ``repro.core.multipliers`` and the SWAPPER
single-bit decision is fused *ahead of* each scalar multiply as a pair of
vector selects (the TPU-idiomatic form of the paper's ``xchg``; DESIGN.md §4).

TPU adaptation notes
--------------------
* The MXU computes exact products, so an approximate-multiplier inner product
  is a **VPU** workload: int8 loads -> int32 lanes, shifts/masks/mul/select,
  int32 accumulation.  Block shapes are chosen so the (bm, bn) accumulator,
  the (bm, bk) / (bk, bn) operand tiles and the (bm, bn) broadcast temporary
  fit VMEM with MXU-aligned (multiple-of-128) lane dims.
* The K reduction runs as the innermost grid dimension with output-block
  revisiting (init at k==0, accumulate after), the standard Pallas matmul
  reduction pattern.
* The LUT path (arbitrary 8-bit circuits, EvoApprox compatibility) keeps the
  64 Ki-entry table resident in VMEM (256 KiB as int32) and gathers per
  element; on real TPUs a VMEM gather lowers slowly, so the closed-form path
  is the production path (see DESIGN.md).  Both validate in interpret mode.

Validated in ``interpret=True`` mode against ``ref.py`` (this container has
no TPU); block specs and layouts are written for a real v5e target.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig, swap_mask_dyn

__all__ = ["ax_matmul_pallas", "ax_matmul_grid_pallas"]

# renamed TPUCompilerParams -> CompilerParams across jax releases
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _swap_select(a, b, swap: Optional[SwapConfig]):
    """Branch-free SWAPPER front-end on int32 lanes (broadcasts ok)."""
    if swap is None:
        return a, b
    src = a if swap.operand == "A" else b
    sel = ((src >> swap.bit) & 1) == swap.value
    aa = jnp.where(sel, b, a)
    bb = jnp.where(sel, a, b)
    return aa, bb


def _accumulate_tile(a_ref, b_ref, o_ref, select, mult: AxMult, bk: int):
    """Shared (bm, bn) output-tile accumulation (K innermost, output-block
    revisiting): ``select(a_col, b_row)`` applies the SWAPPER front-end —
    static config for ``_ax_matmul_kernel``, scalar-prefetched triple for the
    grid kernel."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_blk = a_ref[...].astype(jnp.int32)          # (bm, bk)
    b_blk = b_ref[...].astype(jnp.int32)          # (bk, bn)

    def body(k, acc):
        # rank-1 slab: every scalar product of A[:, k] x B[k, :]
        a_col = jax.lax.dynamic_slice_in_dim(a_blk, k, 1, axis=1)   # (bm, 1)
        b_row = jax.lax.dynamic_slice_in_dim(b_blk, k, 1, axis=0)   # (1, bn)
        aa, bb = select(a_col, b_row)
        prod = mult.fn(aa, bb).astype(jnp.int32)                    # (bm, bn)
        return acc + prod

    acc = jax.lax.fori_loop(0, bk, body, jnp.zeros(o_ref.shape, jnp.int32))
    o_ref[...] += acc


def _ax_matmul_kernel(a_ref, b_ref, o_ref, *, mult: AxMult, swap, bk: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost."""
    _accumulate_tile(a_ref, b_ref, o_ref,
                     lambda a, b: _swap_select(a, b, swap), mult, bk)


def ax_matmul_pallas(
    a: jax.Array,                 # (M, K) int8
    b: jax.Array,                 # (K, N) int8
    mult: AxMult,
    swap: Optional[SwapConfig] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked approximate matmul; returns int32 (M, N)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (M // bm, N // bn, K // bk)

    kernel = functools.partial(_ax_matmul_kernel, mult=mult, swap=swap, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(a, b)


# ---------------------------------------------------------------------------
# granular (per-tile) swap-config grids — the adaptive-runtime kernel
# ---------------------------------------------------------------------------

def _ax_matmul_grid_kernel(cfg_ref, a_ref, b_ref, o_ref, *, mult: AxMult, bk: int):
    """Like ``_ax_matmul_kernel`` but the swap decision comes from a
    scalar-prefetched (grid_m, grid_n, 3) int32 triple grid indexed by the
    output-tile coordinates: op_is_a / bit / value are runtime values, so the
    policy (down to per-row-tile granularity) changes without recompiling."""
    i, j = pl.program_id(0), pl.program_id(1)
    op_is_a = cfg_ref[i, j, 0]
    bit = cfg_ref[i, j, 1]
    value = cfg_ref[i, j, 2]

    def select(a_col, b_row):
        # core.swapper owns the triple semantics; pure jnp, fine in-kernel
        sel = swap_mask_dyn(a_col, b_row, op_is_a, bit, value)      # (bm, bn)
        return jnp.where(sel, b_row, a_col), jnp.where(sel, a_col, b_row)

    _accumulate_tile(a_ref, b_ref, o_ref, select, mult, bk)


def ax_matmul_grid_pallas(
    a: jax.Array,                 # (M, K) int8
    b: jax.Array,                 # (K, N) int8
    mult: AxMult,
    cfg_grid: jax.Array,          # (M/bm, N/bn, 3) int32 (op_is_a, bit, value)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked approximate matmul with a per-output-tile swap-config grid
    (scalar prefetch: the grid is resident in SMEM before the body runs)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (a.shape, b.shape, (bm, bn, bk))
    grid = (M // bm, N // bn, K // bk)
    assert cfg_grid.shape == (grid[0], grid[1], 3), (cfg_grid.shape, grid)

    kernel = functools.partial(_ax_matmul_grid_kernel, mult=mult, bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, cfg: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, cfg: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k, cfg: (i, j)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(cfg_grid.astype(jnp.int32), a, b)
