"""Jit'd public wrappers around the Pallas kernels.

This module is the supported kernel API surface (``repro.kernels``): each
wrapper pins the static arguments (multiplier, block shapes, slab depth,
histogram flag) into the jit key so repeated calls with the same
configuration reuse one compiled program, while everything the adaptive
runtime changes at run time — operands and per-tile swap-config grids —
enters as ordinary traced arrays.  ``ref.py`` holds the bit-exact host
oracles every wrapper is tested against.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig
from repro.core.tuning import (
    ComponentResult,
    accs_from_row_stats,
    operand_values,
    result_from_accs,
)

from .ax_matmul import ax_matmul_grid_pallas, ax_matmul_pallas
from .tuning_sweep import tuning_sweep_pallas

__all__ = ["ax_matmul", "ax_matmul_dequant", "ax_matmul_grid", "component_sweep_pallas"]


@functools.partial(
    jax.jit,
    static_argnames=("mult", "swap", "block_m", "block_n", "block_k", "k_slab",
                     "tile_hist", "interpret"),
)
def ax_matmul(
    a: jax.Array,
    b: jax.Array,
    mult: AxMult,
    swap: Optional[SwapConfig] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    k_slab: Optional[int] = None,
    tile_hist: bool = False,
    interpret: bool = True,
):
    """int8 x int8 -> int32 approximate matmul with fused SWAPPER.

    ``(M, K) @ (K, N) -> (M, N)`` where every scalar product goes through
    ``mult`` with the single-bit ``swap`` decision applied ahead of it.
    ``k_slab`` controls the vectorized reduction depth (None = auto,
    1 = legacy rank-1 schedule).

    ``tile_hist=True`` returns ``(out, hist)`` where ``hist`` is the
    (M/block_m, N/block_n, 2, bits+1) int32 tile-local bit-occupancy
    histogram accumulated inside the K reduction (bit-exact vs
    ``ref.tile_hist_ref``; see ``runtime/telemetry.py`` for how the
    adaptive controller consumes the per-tile statistic)."""
    return ax_matmul_pallas(
        a, b, mult, swap,
        block_m=block_m, block_n=block_n, block_k=block_k, k_slab=k_slab,
        tile_hist=tile_hist, interpret=interpret,
    )


@functools.partial(
    jax.jit, static_argnames=("mult", "swap", "block_m", "block_n", "block_k", "interpret")
)
def ax_matmul_dequant(
    a: jax.Array,               # (M, K) int8
    b: jax.Array,               # (K, N) int8
    scale_a: jax.Array,         # (M, 1) f32 per-row
    scale_b: jax.Array,         # (1, N) f32 per-col
    mult: AxMult,
    swap: Optional[SwapConfig] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Quantized approximate matmul with dequantization epilogue."""
    acc = ax_matmul_pallas(
        a, b, mult, swap,
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )
    return (acc.astype(jnp.float32) * scale_a * scale_b).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=("mult", "block_m", "block_n", "block_k", "k_slab",
                     "tile_hist", "interpret"),
)
def ax_matmul_grid(
    a: jax.Array,                 # (M, K) int8
    b: jax.Array,                 # (K, N) int8
    mult: AxMult,
    cfg_grid: jax.Array,          # (M/bm, N/bn, 3) int32 swap triples
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    k_slab: Optional[int] = None,
    tile_hist: bool = False,
    interpret: bool = True,
):
    """Approximate matmul with a per-output-tile SWAPPER config grid.

    ``cfg_grid[ti, tj]`` is the (op_is_a, bit, value) triple applied to
    output tile (ti, tj); ``value == 2`` encodes NoSwap.  The grid is a
    *traced* operand (scalar prefetch, SMEM-resident before the body runs),
    so the adaptive runtime re-tunes tile configs — down to a different
    triple per row tile — without triggering a recompile.

    ``tile_hist=True`` returns ``(out, hist)`` with the same per-tile
    bit-occupancy histogram as :func:`ax_matmul`: one dispatch both applies
    the current per-tile policy and emits the per-tile operand statistics
    the controller uses to compute the next one (the closed per-tile loop)."""
    return ax_matmul_grid_pallas(
        a, b, mult, cfg_grid,
        block_m=block_m, block_n=block_n, block_k=block_k, k_slab=k_slab,
        tile_hist=tile_hist, interpret=interpret,
    )


def component_sweep_pallas(
    mult: AxMult,
    tile: int = 128,
    sample_bits: Optional[int] = None,
    seed: int = 0,
    interpret: bool = True,
) -> ComponentResult:
    """Component-level tuning driven by the Pallas sweep kernel — a drop-in
    replacement for ``repro.core.tuning.component_sweep`` (cross-checked in
    tests/test_kernels.py)."""
    vals = operand_values(mult.bits, mult.signed, sample_bits, seed)
    stats = jax.device_get(
        tuning_sweep_pallas(mult, jnp.asarray(vals), tile=tile, interpret=interpret)
    )
    r0, r1, orc = accs_from_row_stats(vals, stats)
    return result_from_accs(mult, vals, r0, r1, orc)
