"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig, apply_swapper, apply_swapper_dyn
from repro.core.tuning import tile_stats_jnp

__all__ = ["ax_matmul_ref", "ax_matmul_grid_ref", "tuning_sweep_ref"]


def ax_matmul_ref(a, b, mult: AxMult, swap: Optional[SwapConfig] = None):
    """O(M*N*K) reference: materialize every scalar approximate product with
    the SWAPPER decision applied, then reduce over K.  int32 (M, N)."""
    A = a.astype(jnp.int32)[:, :, None]   # (M, K, 1)
    B = b.astype(jnp.int32)[None, :, :]   # (1, K, N)
    prod = apply_swapper(mult, A, B, swap).astype(jnp.int32)
    return jnp.sum(prod, axis=1, dtype=jnp.int32)


def ax_matmul_grid_ref(a, b, mult: AxMult, cfg_grid):
    """Per-output-tile dynamic-config reference: tile (ti, tj) of the output
    uses the (op_is_a, bit, value) triple at ``cfg_grid[ti, tj]``."""
    M, N = a.shape[0], b.shape[1]
    gm, gn = cfg_grid.shape[0], cfg_grid.shape[1]
    assert M % gm == 0 and N % gn == 0, (a.shape, b.shape, cfg_grid.shape)
    tm, tn = M // gm, N // gn
    rows = []
    for ti in range(gm):
        blocks = []
        A = a[ti * tm:(ti + 1) * tm].astype(jnp.int32)[:, :, None]
        for tj in range(gn):
            B = b[:, tj * tn:(tj + 1) * tn].astype(jnp.int32)[None, :, :]
            t = cfg_grid[ti, tj]
            prod = apply_swapper_dyn(mult, A, B, t[0], t[1], t[2]).astype(jnp.int32)
            blocks.append(jnp.sum(prod, axis=1, dtype=jnp.int32))
        rows.append(jnp.concatenate(blocks, axis=1))
    return jnp.concatenate(rows, axis=0)


def tuning_sweep_ref(mult: AxMult, a_vals, b_vals):
    """The component-tuning tile oracle (row stats of E0/E1/oracle)."""
    return tile_stats_jnp(mult, a_vals, b_vals)
