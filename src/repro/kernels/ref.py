"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig, apply_swapper
from repro.core.tuning import tile_stats_jnp

__all__ = ["ax_matmul_ref", "tuning_sweep_ref"]


def ax_matmul_ref(a, b, mult: AxMult, swap: Optional[SwapConfig] = None):
    """O(M*N*K) reference: materialize every scalar approximate product with
    the SWAPPER decision applied, then reduce over K.  int32 (M, N)."""
    A = a.astype(jnp.int32)[:, :, None]   # (M, K, 1)
    B = b.astype(jnp.int32)[None, :, :]   # (1, K, N)
    prod = apply_swapper(mult, A, B, swap).astype(jnp.int32)
    return jnp.sum(prod, axis=1, dtype=jnp.int32)


def tuning_sweep_ref(mult: AxMult, a_vals, b_vals):
    """The component-tuning tile oracle (row stats of E0/E1/oracle)."""
    return tile_stats_jnp(mult, a_vals, b_vals)
