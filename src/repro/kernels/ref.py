"""Pure-jnp/numpy oracles for every Pallas kernel in this package."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.multipliers import AxMult
from repro.core.swapper import SwapConfig, apply_swapper, apply_swapper_dyn
from repro.core.tuning import tile_stats_jnp

__all__ = ["ax_matmul_ref", "ax_matmul_grid_ref", "tile_hist_ref",
           "tuning_sweep_ref"]


def ax_matmul_ref(a, b, mult: AxMult, swap: Optional[SwapConfig] = None):
    """O(M*N*K) reference: materialize every scalar approximate product with
    the SWAPPER decision applied, then reduce over K.  int32 (M, N)."""
    A = a.astype(jnp.int32)[:, :, None]   # (M, K, 1)
    B = b.astype(jnp.int32)[None, :, :]   # (1, K, N)
    prod = apply_swapper(mult, A, B, swap).astype(jnp.int32)
    return jnp.sum(prod, axis=1, dtype=jnp.int32)


def ax_matmul_grid_ref(a, b, mult: AxMult, cfg_grid):
    """Per-output-tile dynamic-config reference: tile (ti, tj) of the output
    uses the (op_is_a, bit, value) triple at ``cfg_grid[ti, tj]``."""
    M, N = a.shape[0], b.shape[1]
    gm, gn = cfg_grid.shape[0], cfg_grid.shape[1]
    assert M % gm == 0 and N % gn == 0, (a.shape, b.shape, cfg_grid.shape)
    tm, tn = M // gm, N // gn
    rows = []
    for ti in range(gm):
        blocks = []
        A = a[ti * tm:(ti + 1) * tm].astype(jnp.int32)[:, :, None]
        for tj in range(gn):
            B = b[:, tj * tn:(tj + 1) * tn].astype(jnp.int32)[None, :, :]
            t = cfg_grid[ti, tj]
            prod = apply_swapper_dyn(mult, A, B, t[0], t[1], t[2]).astype(jnp.int32)
            blocks.append(jnp.sum(prod, axis=1, dtype=jnp.int32))
        rows.append(jnp.concatenate(blocks, axis=1))
    return jnp.concatenate(rows, axis=0)


def tile_hist_ref(a, b, bits: int, gm: int, gn: int) -> np.ndarray:
    """Host oracle for the kernels' ``tile_hist`` second output: the
    (gm, gn, 2, bits+1) int32 tile-local bit-occupancy histogram.

    Output tile (ti, tj) reduces A rows ``[ti*bm, (ti+1)*bm)`` against B
    columns ``[tj*bn, (tj+1)*bn)`` over the whole K dimension, so its
    histogram counts every element of those operand tiles: per-position set
    *magnitude* bits plus a trailing negative-sign count (row 0 = the A
    tile, row 1 = the B tile).  The A histogram is therefore identical
    across a row of output tiles and the B histogram across a column —
    exactly what the kernel's per-(bm, bn)-tile accumulation produces."""
    a = np.asarray(a, np.int64)
    b = np.asarray(b, np.int64)
    M, N = a.shape[0], b.shape[1]
    assert M % gm == 0 and N % gn == 0, (a.shape, b.shape, gm, gn)
    tm, tn = M // gm, N // gn

    def counts(blk):
        mag = np.abs(blk)
        cnt = [int(((mag >> s) & 1).sum()) for s in range(bits)]
        return np.asarray(cnt + [int((blk < 0).sum())], np.int32)

    hist = np.zeros((gm, gn, 2, bits + 1), np.int32)
    for ti in range(gm):
        ca = counts(a[ti * tm:(ti + 1) * tm, :])
        for tj in range(gn):
            hist[ti, tj, 0] = ca
            hist[ti, tj, 1] = counts(b[:, tj * tn:(tj + 1) * tn])
    return hist


def tuning_sweep_ref(mult: AxMult, a_vals, b_vals):
    """The component-tuning tile oracle (row stats of E0/E1/oracle)."""
    return tile_stats_jnp(mult, a_vals, b_vals)
