"""Pallas TPU kernel: component-level SWAPPER tuning sweep.

Computes, over the full (a, b) operand grid, per-a row statistics of the two
error surfaces E0(a,b) = |m(a,b) - ab| and E1(a,b) = |m(b,a) - ab| and of the
pointwise oracle min(E0, E1):

    lo/hi  — exact 16-bit limb sums of the absolute error (uint32)
    mx     — row maximum (WCE)
    cnt    — nonzero count (EP)
    sq     — float32 sum of squared error (MSE)
    rel    — float32 sum of relative error (ARE)

Column statistics are *not* computed: E1 is the transpose of E0, so the
per-b column stats equal the other surface's row stats (DESIGN.md §4 rank-1
reduction).  Every one of the paper's 4M swap configurations and all five
error metrics are then scored from these vectors by the host driver — the
whole tuning phase is O(2^(2M)) work instead of the paper's O(4M * 2^(2M))
circuit stimulations.

Grid: (N/T, N/T) with the b-tile dimension innermost; the (T,) row-stat
output blocks are indexed by the a-tile only and are revisited across the
inner dimension with init-at-j==0 accumulation (the standard Pallas reduction
pattern).  Validated in interpret mode against ``ref.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ax_matmul import _CompilerParams

from repro.core.metrics import abs_err
from repro.core.multipliers import AxMult

__all__ = ["tuning_sweep_pallas", "STAT_NAMES", "SURF_NAMES"]

STAT_NAMES = ("lo", "hi", "mx", "cnt", "sq", "rel")
SURF_NAMES = ("r0", "r1", "orc")


def _row_stats_tuple(e, exact_abs_f):
    lo = jnp.sum(e & jnp.uint32(0xFFFF), axis=1, dtype=jnp.uint32)
    hi = jnp.sum(e >> jnp.uint32(16), axis=1, dtype=jnp.uint32)
    mx = jnp.max(e, axis=1)
    cnt = jnp.sum((e != 0).astype(jnp.int32), axis=1, dtype=jnp.int32)
    ef = e.astype(jnp.float32)
    sq = jnp.sum(ef * ef, axis=1, dtype=jnp.float32)
    rel = jnp.sum(ef / jnp.maximum(exact_abs_f, 1.0), axis=1, dtype=jnp.float32)
    return lo, hi, mx, cnt, sq, rel


def _sweep_kernel(a_ref, b_ref, *out_refs, mult: AxMult):
    j = pl.program_id(1)

    A = a_ref[...][:, None].astype(jnp.int32)
    B = b_ref[...][None, :].astype(jnp.int32)
    p0 = mult.fn(A, B)
    p1 = mult.fn(B, A)
    exact = mult.exact_product(A, B)
    e0 = abs_err(p0, exact, mult.signed)
    e1 = abs_err(p1, exact, mult.signed)
    emin = jnp.minimum(e0, e1)
    if mult.signed:
        exact_abs = jnp.abs(exact.astype(jnp.float32))
    else:
        exact_abs = exact.astype(jnp.float32)

    stats = (
        _row_stats_tuple(e0, exact_abs)
        + _row_stats_tuple(e1, exact_abs)
        + _row_stats_tuple(emin, exact_abs)
    )

    @pl.when(j == 0)
    def _init():
        for ref in out_refs:
            ref[...] = jnp.zeros_like(ref)

    for idx, (ref, val) in enumerate(zip(out_refs, stats)):
        if STAT_NAMES[idx % 6] == "mx":
            ref[...] = jnp.maximum(ref[...], val.astype(ref.dtype))
        else:
            ref[...] += val.astype(ref.dtype)


def tuning_sweep_pallas(mult: AxMult, vals: jax.Array, tile: int = 128,
                        interpret: bool = True):
    """Full-grid sweep over ``vals x vals``.  Returns
    ``{surf: {stat: (N,) array}}`` for surf in (r0, r1, orc)."""
    n = vals.shape[0]
    tile = min(tile, n)
    assert n % tile == 0
    grid = (n // tile, n // tile)

    dtypes = dict(lo=jnp.uint32, hi=jnp.uint32, mx=jnp.uint32,
                  cnt=jnp.int32, sq=jnp.float32, rel=jnp.float32)
    out_shape = [
        jax.ShapeDtypeStruct((n,), dtypes[s]) for _ in SURF_NAMES for s in STAT_NAMES
    ]
    out_specs = [
        pl.BlockSpec((tile,), lambda i, j: (i,)) for _ in range(len(out_shape))
    ]

    kernel = functools.partial(_sweep_kernel, mult=mult)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i, j: (i,)),
            pl.BlockSpec((tile,), lambda i, j: (j,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=_CompilerParams(dimension_semantics=("parallel", "arbitrary")),
    )(vals, vals)

    it = iter(outs)
    return {surf: {s: next(it) for s in STAT_NAMES} for surf in SURF_NAMES}
