"""Retune audit trail: an append-only structured event log next to the
PolicyStore.

Every policy mutation the :class:`~repro.runtime.AdaptiveController` makes
appends one JSON line — trigger target, drift score, winning triple (or
tile-grid digest), predicted gain, and the store version the change was
published as — so "why did this replica retune?" is answerable after the
fact and the policy history is **replayable**: walking ``read()`` in order
reproduces the exact sequence of ``policy_v{N}.json`` versions the fleet
served (each event's ``store_version`` points at the immutable JSON the
store kept).

The log is plain JSONL with O_APPEND single-writer semantics — the same
single-writer guarantee the PolicyStore already enforces covers it, and a
crash mid-write loses at most the final partial line (``read`` skips it,
and the next writer resumes ``seq`` from the last *complete* event).
Appends fsync before returning, so an acknowledged event survives a
process kill (the same durability contract ``PolicyStore.publish`` makes).
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import List, Optional

__all__ = ["AUDIT_FILENAME", "AuditLog", "audit_for_store", "grid_digest"]

AUDIT_FILENAME = "audit.jsonl"


def grid_digest(grid) -> str:
    """Short stable digest of a tile grid (or any int array): the audit
    event stays one line while still identifying the exact published grid."""
    import numpy as np

    arr = np.ascontiguousarray(np.asarray(grid, np.int32))
    return hashlib.sha256(arr.tobytes() + str(arr.shape).encode()).hexdigest()[:12]


class AuditLog:
    """Append-only JSONL event log (see module docstring)."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._seq = self._last_seq() + 1

    def _last_seq(self) -> int:
        last = -1
        for ev in self.read():
            last = max(last, int(ev.get("seq", -1)))
        return last

    def append(self, kind: str, **fields) -> dict:
        """Append one event; returns the record written (with its assigned
        monotonic ``seq`` and wall-clock ``unix_time``)."""
        ev = dict(seq=self._seq, kind=kind, unix_time=time.time(), **fields)
        self._seq += 1
        line = json.dumps(ev, sort_keys=True, default=_jsonable)
        with open(self.path, "a") as f:
            # a crash mid-append can leave a torn line with no terminator;
            # start clean so the new event is not glued onto the wreckage
            if f.tell() and not self._ends_with_newline():
                f.write("\n")
            f.write(line)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        return ev

    def _ends_with_newline(self) -> bool:
        with open(self.path, "rb") as f:
            f.seek(-1, os.SEEK_END)
            return f.read(1) == b"\n"

    def read(self) -> List[dict]:
        """Every complete event in append order (a torn final line from a
        crash mid-append is skipped)."""
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    continue               # torn tail write
        return out

    def __len__(self) -> int:
        return len(self.read())


def _jsonable(v):
    import numpy as np

    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, np.generic):
        return v.item()
    return repr(v)


def audit_for_store(store) -> Optional["AuditLog"]:
    """The audit log that lives next to a ``fleet.PolicyStore`` (``None``
    for a store-less controller unless one is passed explicitly)."""
    root = getattr(store, "root", None)
    if root is None:
        return None
    return AuditLog(os.path.join(root, AUDIT_FILENAME))
