"""Per-request quality-of-result (QoR) attribution.

SWAPPER's error telemetry already leaves every gated decode step as
limb-exact per-target (and per-row-tile) absolute-error sums; what it could
not answer is *whose* error that was: which requests, layers and tiles are
burning the error budget right now.  :class:`ErrorAttributor` closes that
gap host-side, with zero traced-code changes:

* the scheduler assigns every request a **correlation id** at admission
  (unique across splices/backfills even when rids recur across drains);
* each gated token step's record tree is reduced to per-target step MAE
  (and a per-tile MAE vector where tile telemetry is on) and charged to
  the correlation ids that were **live in that step** — the record is a
  batch-level sample, so a request's attribution is its *exposure*: the
  per-target error profile of the steps it was being decoded in (an
  explicitly step-weighted approximation, stated in the summary);
* at retirement the request's exposure becomes the ``Completion.qor``
  summary — per-target mean step MAE, each target's **share** of the
  request's total error, the top-k contributing targets (annotated with
  their worst tile), and the attribution basis.  Requests that retire with
  zero observed decode steps (``max_new == 1`` admissions) fall back to
  the fleet-level profile accumulated so far (``basis="fleet"``).

Everything here is plain-numpy host code over records that already crossed
the device boundary; the field names mirror ``runtime.telemetry``'s record
schema (``err_lo``/``err_hi``/``n``, ``tile_err_lo``/``tile_err_hi``/
``tile_n``, the ``@tiles`` key suffix) — a cross-check test pins the two
in sync so ``repro.obs`` stays import-free of the runtime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .metrics import QOR_MAE_BUCKETS, default_registry

__all__ = [
    "TILE_KEY_SUFFIX",
    "step_error_summary",
    "ErrorAttributor",
]

# mirrors runtime.telemetry.TILE_KEY_SUFFIX (pinned by a test; obs imports
# nothing from the runtime so instrumentation can never perturb traces)
TILE_KEY_SUFFIX = "@tiles"

_REG = default_registry()
_REQ_MAE = _REG.histogram(
    "repro_qor_request_mae",
    "per-request mean step MAE by target at retirement (QoR attribution; "
    "product units of the approximate multiplier)",
    buckets=QOR_MAE_BUCKETS)
_REQS = _REG.counter(
    "repro_qor_requests_total",
    "requests retired with a QoR attribution summary, by basis "
    "(request = own decode exposure / fleet = zero-step fallback)")
_SHARE = _REG.gauge(
    "repro_qor_error_share",
    "fleet-level share of cumulative attributed error by target "
    "(refreshed at every retirement)")


def _limb_mae(lo, hi, n) -> Optional[float]:
    """Recombine 16-bit error-limb sums into a mean absolute error (the
    same arithmetic ``TargetTelemetry.update`` applies)."""
    n = float(np.sum(np.asarray(n, np.float64)))
    if n <= 0:
        return None
    lo = float(np.sum(np.asarray(lo, np.float64)))
    hi = float(np.sum(np.asarray(hi, np.float64)))
    return (lo + hi * 65536.0) / n


def step_error_summary(records: Dict[str, Dict[str, np.ndarray]]
                       ) -> Tuple[Dict[str, float], Dict[str, np.ndarray]]:
    """Reduce one step's record tree to ``(per-target step MAE,
    per-target per-tile MAE vectors)``.  Records without error limbs (or
    with ``n == 0`` — a gated-off zero record) are skipped."""
    scalars: Dict[str, float] = {}
    tiles: Dict[str, np.ndarray] = {}
    for key, rec in records.items():
        if key.endswith(TILE_KEY_SUFFIX):
            if "tile_err_lo" not in rec:
                continue                  # pre-QoR tile record: no limbs
            lo = np.asarray(rec["tile_err_lo"], np.float64)
            hi = np.asarray(rec["tile_err_hi"], np.float64)
            n = np.asarray(rec["tile_n"], np.float64)
            # stacked per-call arrays: sum the call axis, keep tiles
            lo = lo.reshape(-1, lo.shape[-1]).sum(axis=0)
            hi = hi.reshape(-1, hi.shape[-1]).sum(axis=0)
            n = np.maximum(n.reshape(-1, n.shape[-1]).sum(axis=0), 1.0)
            tiles[key[:-len(TILE_KEY_SUFFIX)]] = (lo + hi * 65536.0) / n
            continue
        if "err_lo" not in rec:
            continue
        mae = _limb_mae(rec["err_lo"], rec["err_hi"], rec["n"])
        if mae is not None:
            scalars[key] = mae
    return scalars, tiles


@dataclasses.dataclass
class _RequestExposure:
    corr: str
    rid: int
    steps: int = 0
    err: Dict[str, float] = dataclasses.field(default_factory=dict)
    err_steps: Dict[str, int] = dataclasses.field(default_factory=dict)
    tile_err: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    tile_steps: Dict[str, int] = dataclasses.field(default_factory=dict)


class ErrorAttributor:
    """Host-side per-request error attribution over step telemetry.

    Lifecycle (driven by ``fleet.scheduler`` in token-granular mode):
    :meth:`begin` at the admission splice, :meth:`observe_step` with each
    gated step's host records plus the correlation ids live in that step,
    :meth:`finish` at retirement — returning the summary the scheduler
    attaches to the ``Completion``.
    """

    def __init__(self, top_k: int = 3):
        self.top_k = int(top_k)
        self._live: Dict[str, _RequestExposure] = {}
        # fleet-level accumulators: per-target cumulative step MAE — the
        # zero-exposure fallback profile and the _SHARE gauge source
        self._fleet_err: Dict[str, float] = {}
        self._fleet_tiles: Dict[str, np.ndarray] = {}
        self._fleet_steps = 0
        self.finished = 0

    # -- lifecycle -----------------------------------------------------
    def begin(self, corr: str, rid: int) -> None:
        self._live[corr] = _RequestExposure(corr=corr, rid=rid)

    def observe_step(self, records: Dict[str, Dict[str, np.ndarray]],
                     live: Sequence[str]) -> None:
        """Charge one gated step's error profile to the requests that were
        live in it.  Unknown correlation ids (already retired when a stale
        record lands) are dropped silently."""
        scalars, tiles = step_error_summary(records)
        if not scalars and not tiles:
            return
        self._fleet_steps += 1
        for t, mae in scalars.items():
            self._fleet_err[t] = self._fleet_err.get(t, 0.0) + mae
        for t, vec in tiles.items():
            acc = self._fleet_tiles.get(t)
            self._fleet_tiles[t] = (vec.copy() if acc is None
                                    or acc.shape != vec.shape else acc + vec)
        for corr in live:
            rq = self._live.get(corr)
            if rq is None:
                continue
            rq.steps += 1
            for t, mae in scalars.items():
                rq.err[t] = rq.err.get(t, 0.0) + mae
                rq.err_steps[t] = rq.err_steps.get(t, 0) + 1
            for t, vec in tiles.items():
                acc = rq.tile_err.get(t)
                rq.tile_err[t] = (vec.copy() if acc is None
                                  or acc.shape != vec.shape else acc + vec)
                rq.tile_steps[t] = rq.tile_steps.get(t, 0) + 1

    def finish(self, corr: str) -> Optional[dict]:
        """Close out a request: pop its exposure and build the summary
        (None only for a correlation id that was never begun)."""
        rq = self._live.pop(corr, None)
        if rq is None:
            return None
        basis = "request"
        err, err_steps = rq.err, rq.err_steps
        tile_err, tile_steps = rq.tile_err, rq.tile_steps
        if not err and self._fleet_steps > 0:
            # zero observed decode steps (1-token request): attribute the
            # fleet profile so the completion still carries the QoR signal
            basis = "fleet"
            err = dict(self._fleet_err)
            err_steps = {t: self._fleet_steps for t in err}
            tile_err = dict(self._fleet_tiles)
            tile_steps = {t: self._fleet_steps for t in tile_err}
        targets = {t: err[t] / max(err_steps.get(t, 1), 1) for t in err}
        total = sum(err.values())
        share = {t: (err[t] / total if total > 0 else 0.0) for t in err}
        tiles = {t: (tile_err[t] / max(tile_steps.get(t, 1), 1)).tolist()
                 for t in tile_err}
        top: List[dict] = []
        for t in sorted(share, key=share.get, reverse=True)[:self.top_k]:
            entry = dict(where=t, share=share[t], ew_mae=targets[t])
            tv = tile_err.get(t)
            if tv is not None and tv.size and tv.sum() > 0:
                entry["top_tile"] = int(np.argmax(tv))
                entry["tile_share"] = float(tv.max() / tv.sum())
            top.append(entry)
        self.finished += 1
        _REQS.inc(1, basis=basis)
        for t, mae in targets.items():
            _REQ_MAE.observe(mae, target=t)
        fleet_total = sum(self._fleet_err.values())
        if fleet_total > 0:
            for t, v in self._fleet_err.items():
                _SHARE.set(v / fleet_total, target=t)
        return dict(corr=rq.corr, rid=rq.rid, steps=rq.steps, basis=basis,
                    ew_mae=targets, share=share, tiles=tiles, top=top,
                    weighting="step-exposure")

    # -- introspection -------------------------------------------------
    def fleet_share(self) -> Dict[str, float]:
        total = sum(self._fleet_err.values())
        if total <= 0:
            return {}
        return {t: v / total for t, v in sorted(self._fleet_err.items())}

    def describe(self) -> str:
        share = ", ".join(f"{t}={s:.2f}" for t, s in self.fleet_share().items())
        return (f"qor finished={self.finished} live={len(self._live)} "
                f"steps={self._fleet_steps} share=[{share}]")
