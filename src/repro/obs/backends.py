"""Push exporter backends: StatsD line protocol and OTLP-JSON.

The PR-6 exposition layer is pull-shaped (Prometheus text over
``/metrics``, JSONL snapshots); fleets that live behind Datadog/Telegraf
agents or an OpenTelemetry collector want the registry **pushed** instead.
Both backends here implement one interface —
``exporter.push(registry) -> int`` (payload units emitted), ``close()`` —
over the same :class:`~repro.obs.metrics.MetricsRegistry` reads the pull
path uses, stdlib-only:

* :class:`StatsdExporter` — `StatsD line protocol
  <https://github.com/statsd/statsd/blob/master/docs/metric_types.md>`_
  over UDP with DogStatsD ``|#tag:value`` labels; counters as ``|c``,
  gauges as ``|g``, histograms flattened to ``.sum``/``.count`` and
  interpolated ``.p50``/``.p99`` gauge reads (UDP agents cannot ingest
  bucket vectors).  Lines pack into <= ``mtu``-byte datagrams; an optional
  ``mirror`` file receives every line (CI captures the artifact even if
  the datagram is dropped — UDP is fire-and-forget by design).
* :class:`OtlpJsonExporter` — `OTLP/JSON
  <https://opentelemetry.io/docs/specs/otlp/>`_ ``resourceMetrics``
  payloads, either appended to a ``.jsonl`` file or POSTed to an HTTP
  endpoint (``http(s)://.../v1/metrics``).  Histograms keep full bucket
  vectors here (non-cumulative ``bucketCounts`` + ``explicitBounds``, per
  the OTLP data model).

Rendering functions (:func:`statsd_lines`, :func:`otlp_json`) are pure and
deterministic — metrics sorted by name, series by label key, timestamps
injected by the caller — so both wire formats are golden-file tested.
"""
from __future__ import annotations

import json
import socket
import time
import urllib.error
import urllib.request
from typing import List, Optional, Sequence

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, _INF,
                      default_registry)

__all__ = [
    "statsd_lines",
    "StatsdExporter",
    "otlp_json",
    "OtlpJsonExporter",
    "push_all",
]

_REG = default_registry()
_PUSHES = _REG.counter(
    "repro_obs_pushes_total",
    "registry pushes through an exporter backend, by backend")


def _tags(key) -> str:
    if not key:
        return ""
    return "|#" + ",".join(f"{k}:{v}" for k, v in key)


def statsd_lines(registry: Optional[MetricsRegistry] = None,
                 prefix: str = "") -> List[str]:
    """Render the registry as StatsD/DogStatsD lines (deterministic
    ordering; histogram percentiles interpolated at stated bucket
    resolution, see ``Histogram.percentile``)."""
    registry = registry or default_registry()
    lines: List[str] = []
    for m in registry.metrics():
        name = prefix + m.name
        if isinstance(m, Counter):
            for key in sorted(m.series()):
                lines.append(f"{name}:{m.series()[key]:g}|c{_tags(key)}")
        elif isinstance(m, Gauge):
            for key in sorted(m.series()):
                lines.append(f"{name}:{m.series()[key]:g}|g{_tags(key)}")
        elif isinstance(m, Histogram):
            for key in sorted(m.series()):
                labels = dict(key)
                snap = m.snapshot(**labels)
                lines.append(f"{name}.sum:{snap['sum']:g}|g{_tags(key)}")
                lines.append(f"{name}.count:{snap['count']:g}|g{_tags(key)}")
                for q in (0.5, 0.99):
                    v = m.percentile(q, interpolate=True, **labels)
                    if v is not None:
                        lines.append(f"{name}.p{int(q * 100)}:{v:g}|g"
                                     f"{_tags(key)}")
    return lines


class StatsdExporter:
    """StatsD push over UDP (optionally mirrored to a file)."""

    backend = "statsd"

    def __init__(self, host: str = "127.0.0.1", port: int = 8125,
                 prefix: str = "", mtu: int = 1400,
                 mirror: Optional[str] = None):
        self.addr = (host, int(port))
        self.prefix = prefix
        self.mtu = int(mtu)
        self.mirror = mirror
        self.sock = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        self.lines_sent = 0
        self.packets_sent = 0

    @classmethod
    def from_spec(cls, spec: str, **kw) -> "StatsdExporter":
        """``HOST:PORT`` (the ``launch/serve --statsd`` argument form)."""
        host, _, port = spec.rpartition(":")
        return cls(host=host or "127.0.0.1", port=int(port), **kw)

    def push(self, registry: Optional[MetricsRegistry] = None) -> int:
        lines = statsd_lines(registry, prefix=self.prefix)
        packet: List[str] = []
        size = 0
        for line in lines:
            n = len(line) + 1
            if packet and size + n > self.mtu:
                self._send(packet)
                packet, size = [], 0
            packet.append(line)
            size += n
        if packet:
            self._send(packet)
        if self.mirror and lines:
            with open(self.mirror, "a") as f:
                f.write("\n".join(lines) + "\n")
        self.lines_sent += len(lines)
        _PUSHES.inc(1, backend=self.backend)
        return len(lines)

    def _send(self, lines: Sequence[str]) -> None:
        try:
            self.sock.sendto("\n".join(lines).encode(), self.addr)
            self.packets_sent += 1
        except OSError:
            pass                    # fire-and-forget: UDP loss is expected

    def close(self) -> None:
        self.sock.close()


def _attrs(key) -> List[dict]:
    return [dict(key=k, value=dict(stringValue=str(v))) for k, v in key]


def otlp_json(registry: Optional[MetricsRegistry] = None,
              time_unix_nano: int = 0,
              service_name: str = "repro-swapper") -> dict:
    """Render the registry as one OTLP/JSON ``resourceMetrics`` payload.
    ``time_unix_nano`` is caller-injected so payloads are reproducible
    (golden-file tested with 0)."""
    registry = registry or default_registry()
    ts = str(int(time_unix_nano))
    metrics = []
    for m in registry.metrics():
        entry = dict(name=m.name, description=m.help)
        if isinstance(m, Counter):
            entry["sum"] = dict(
                dataPoints=[
                    dict(attributes=_attrs(key), timeUnixNano=ts,
                         asDouble=float(m.series()[key]))
                    for key in sorted(m.series())],
                aggregationTemporality=2,      # CUMULATIVE
                isMonotonic=True)
        elif isinstance(m, Gauge):
            entry["gauge"] = dict(
                dataPoints=[
                    dict(attributes=_attrs(key), timeUnixNano=ts,
                         asDouble=float(m.series()[key]))
                    for key in sorted(m.series())])
        elif isinstance(m, Histogram):
            points = []
            for key in sorted(m.series()):
                snap = m.snapshot(**dict(key))
                cum = snap["buckets"]
                counts, prev = [], 0
                for _, acc in cum:
                    counts.append(acc - prev)
                    prev = acc
                points.append(dict(
                    attributes=_attrs(key), timeUnixNano=ts,
                    count=str(snap["count"]), sum=float(snap["sum"]),
                    bucketCounts=[str(c) for c in counts],
                    explicitBounds=[e for e, _ in cum if e != _INF]))
            entry["histogram"] = dict(dataPoints=points,
                                      aggregationTemporality=2)
        metrics.append(entry)
    return dict(resourceMetrics=[dict(
        resource=dict(attributes=[dict(
            key="service.name",
            value=dict(stringValue=service_name))]),
        scopeMetrics=[dict(
            scope=dict(name="repro.obs"),
            metrics=metrics)])])


class OtlpJsonExporter:
    """OTLP-JSON push to a ``.jsonl`` file or an HTTP collector endpoint."""

    backend = "otlp"

    def __init__(self, target: str, service_name: str = "repro-swapper",
                 timeout_s: float = 2.0):
        self.target = target
        self.service_name = service_name
        self.timeout_s = float(timeout_s)
        self.is_http = target.startswith(("http://", "https://"))
        self.payloads_sent = 0
        self.errors = 0

    def push(self, registry: Optional[MetricsRegistry] = None,
             time_unix_nano: Optional[int] = None) -> int:
        if time_unix_nano is None:
            time_unix_nano = time.time_ns()
        payload = otlp_json(registry, time_unix_nano=time_unix_nano,
                            service_name=self.service_name)
        body = json.dumps(payload, sort_keys=True)
        if self.is_http:
            req = urllib.request.Request(
                self.target, data=body.encode(), method="POST",
                headers={"Content-Type": "application/json"})
            try:
                urllib.request.urlopen(req, timeout=self.timeout_s).close()
            except (urllib.error.URLError, OSError):
                self.errors += 1      # collector down: degrade, don't crash
                return 0
        else:
            with open(self.target, "a") as f:
                f.write(body)
                f.write("\n")
        self.payloads_sent += 1
        _PUSHES.inc(1, backend=self.backend)
        return 1

    def close(self) -> None:
        pass


def push_all(exporters: Sequence, registry=None) -> int:
    """Push the registry through every configured backend; returns total
    payload units emitted (the serve driver calls this on its metrics-hold
    cadence and once at drain)."""
    return sum(e.push(registry) for e in exporters)
