"""Metric exposition: Prometheus text format, an HTTP scrape endpoint, and
JSONL snapshots for offline diffing.

* :func:`prometheus_text` renders a registry in the `Prometheus text
  exposition format
  <https://prometheus.io/docs/instrumenting/exposition_formats/>`_
  (HELP/TYPE headers, cumulative ``_bucket{le=...}`` histogram series).
* :func:`start_metrics_server` serves it at ``/metrics`` from a stdlib
  ``http.server`` daemon thread (``launch/serve --metrics-port``) — no
  third-party dependency, scrapeable by any Prometheus/curl.
* :func:`write_snapshot` appends one self-contained JSON object per call to
  a ``.jsonl`` file — the offline twin of a scrape, diffable across runs
  and uploaded as a CI artifact next to ``BENCH_6.json``.
"""
from __future__ import annotations

import http.server
import json
import threading
import time
from typing import Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, default_registry

__all__ = [
    "prometheus_text",
    "registry_snapshot",
    "write_snapshot",
    "MetricsServer",
    "start_metrics_server",
]


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _fmt_labels(items) -> str:
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in items)
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def _fmt_le(edge: float) -> str:
    return "+Inf" if edge == float("inf") else _fmt_value(edge)


def prometheus_text(registry: Optional[MetricsRegistry] = None) -> str:
    """The registry in Prometheus text exposition format (deterministic
    ordering: metrics by name, series by label key — golden-file tested)."""
    registry = registry or default_registry()
    lines = []
    for m in registry.metrics():
        lines.append(f"# HELP {m.name} {_escape(m.help)}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, (Counter, Gauge)):
            for key in sorted(m.series()):
                lines.append(f"{m.name}{_fmt_labels(key)} "
                             f"{_fmt_value(m.series()[key])}")
        elif isinstance(m, Histogram):
            for key in sorted(m.series()):
                labels = dict(key)
                for edge, acc in m.cumulative(**labels):
                    le = _fmt_labels(tuple(key) + (("le", _fmt_le(edge)),))
                    lines.append(f"{m.name}_bucket{le} {acc}")
                snap = m.snapshot(**labels)
                lines.append(f"{m.name}_sum{_fmt_labels(key)} "
                             f"{_fmt_value(snap['sum'])}")
                lines.append(f"{m.name}_count{_fmt_labels(key)} "
                             f"{snap['count']}")
    return "\n".join(lines) + "\n"


def registry_snapshot(registry: Optional[MetricsRegistry] = None) -> dict:
    """JSON-ready dump of every series (the ``write_snapshot`` payload)."""
    registry = registry or default_registry()
    out = {}
    for m in registry.metrics():
        series = {}
        for key in sorted(m.series()):
            label_str = ",".join(f"{k}={v}" for k, v in key) or "_"
            if isinstance(m, Histogram):
                snap = m.snapshot(**dict(key))
                series[label_str] = dict(
                    sum=snap["sum"], count=snap["count"],
                    buckets=[[_fmt_le(e), c] for e, c in snap["buckets"]])
            else:
                series[label_str] = m.series()[key]
        out[m.name] = dict(kind=m.kind, help=m.help, series=series)
    return out


def write_snapshot(path: str,
                   registry: Optional[MetricsRegistry] = None,
                   **meta) -> dict:
    """Append one snapshot object (plus caller metadata, e.g. a run label)
    as a JSON line; returns the object written."""
    obj = dict(unix_time=time.time(), metrics=registry_snapshot(registry),
               **meta)
    with open(path, "a") as f:
        f.write(json.dumps(obj, sort_keys=True))
        f.write("\n")
    return obj


class _Handler(http.server.BaseHTTPRequestHandler):
    registry: MetricsRegistry = None  # set per-server via type()

    def do_GET(self):  # noqa: N802 (stdlib API name)
        if self.path.split("?")[0] not in ("/metrics", "/"):
            self.send_response(404)
            self.end_headers()
            return
        body = prometheus_text(self.registry).encode()
        self.send_response(200)
        self.send_header("Content-Type", "text/plain; version=0.0.4")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):  # silence per-request stderr spam
        pass


class MetricsServer:
    """`/metrics` scrape endpoint on a daemon thread (stdlib only)."""

    def __init__(self, port: int, registry: Optional[MetricsRegistry] = None,
                 host: str = "0.0.0.0"):
        registry = registry or default_registry()
        handler = type("BoundHandler", (_Handler,), {"registry": registry})
        self.httpd = http.server.ThreadingHTTPServer((host, port), handler)
        self.port = self.httpd.server_address[1]   # resolved when port == 0
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name=f"metrics:{self.port}",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        self.httpd.shutdown()
        self.httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def start_metrics_server(port: int,
                         registry: Optional[MetricsRegistry] = None,
                         host: str = "0.0.0.0") -> MetricsServer:
    """Start the scrape endpoint (``port=0`` binds an ephemeral port,
    reported as ``server.port``)."""
    return MetricsServer(port, registry, host=host)
