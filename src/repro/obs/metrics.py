"""Metrics registry: counters, gauges and histograms with label sets.

The unified observability substrate every subsystem reports into (ISSUE 6 /
ROADMAP tentpole 1's measurement half): the serving engine counts program
retraces, the continuous batcher observes TTFT / end-to-end latency
histograms and occupancy gauges, the drift detector exports per-target
drift scores, and the policy store exports its published version and each
replica's adoption lag.  Everything here is **host-side only** — a metric
update is a dict write under a lock, never a traced op — so instrumenting a
path cannot perturb compiled programs, tokens, or telemetry (the PR-5
bit-identity and zero-recompile guarantees are regression-tested with the
instrumentation live).

Design (deliberately prometheus-client-shaped, stdlib-only):

* a :class:`MetricsRegistry` owns named metrics; :func:`default_registry`
  is the process-wide instance the instrumented subsystems use.  Metric
  creation is get-or-create — two modules may declare the same metric —
  but re-declaring with a different type or help string raises.
* every metric holds a family of **series** keyed by its label set
  (``counter.inc(1, mode="wave")``); label order never matters.
* :class:`Histogram` uses explicit cumulative ``le`` bucket edges (values
  land in every bucket whose edge is >= the value, Prometheus semantics)
  plus ``sum``/``count``, and exposes :meth:`Histogram.percentile` so hosts
  can read p50/p99 straight off the bucket counts.

Exposition lives in :mod:`repro.obs.export` (Prometheus text format over a
stdlib HTTP thread + JSONL snapshots for offline diffing).
"""
from __future__ import annotations

import bisect
import threading
import warnings
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "LATENCY_BUCKETS",
    "TTFT_BUCKETS",
    "E2E_BUCKETS",
    "DISPATCH_BUCKETS",
    "QOR_MAE_BUCKETS",
    "bucket_percentile",
    "default_registry",
    "reset_default_registry",
]

# default Histogram edges: serving latencies from 50us (a cached token step)
# to 2 minutes (a cold-compile wave), roughly log-spaced
LATENCY_BUCKETS = (
    0.00005, 0.0002, 0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 120.0,
)

# Serving-latency bucket families tuned from the recorded BENCH_6/7
# distributions instead of the generic LATENCY_BUCKETS defaults.  The CI
# container's serving_table run put token-granular TTFT/e2e p50 around
# 5.7 s and the wave e2e p99 around 8.1 s (compile-dominated cold starts),
# while post-warmup token steps land in the 5-50 ms range — so the edges
# cluster resolution where observations actually fall and top out at ~2x
# the observed p99 rather than a generic 120 s tail.  On faster hosts the
# same shapes slide left into the dense sub-second region, so coverage
# stays fine there too (the +Inf-coverage check below guards the tail).
TTFT_BUCKETS = (
    0.002, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.0, 3.0, 4.5, 6.0, 8.0, 12.0, 18.0,
)
E2E_BUCKETS = (
    0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.0, 3.0, 4.5, 6.0, 8.0, 10.0, 13.0, 18.0, 27.0,
)
# dispatch walls (prefill / decode loop / one token step): ~0.2 ms cached
# steps up to the multi-second cold-compile first dispatch
DISPATCH_BUCKETS = (
    0.0002, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.02, 0.04,
    0.08, 0.15, 0.3, 0.6, 1.2, 2.5, 5.0, 10.0,
)
# per-request QoR attribution: mean absolute error of an 8-bit approximate
# multiplier in product units — geometric edges spanning near-exact (<1)
# through the worst trunc-family configs (~10^5)
QOR_MAE_BUCKETS = (
    1.0, 4.0, 16.0, 64.0, 256.0, 1024.0, 4096.0,
    16384.0, 65536.0, 262144.0,
)

_INF = float("inf")

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    """Canonical (sorted) label tuple — label order never matters."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared label-series bookkeeping for the three metric types."""

    kind = "untyped"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, object] = {}
        self._lock = threading.Lock()

    def _zero(self):
        raise NotImplementedError

    def _get(self, labels: Dict[str, str]):
        key = _label_key(labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = self._zero()
            return key

    def series(self) -> Dict[LabelKey, object]:
        """Snapshot of {label-key: value} for every series."""
        with self._lock:
            return dict(self._series)

    def clear(self) -> None:
        with self._lock:
            self._series.clear()


class Counter(_Metric):
    """Monotonically increasing count (e.g. retraces, splices, retunes)."""

    kind = "counter"

    def _zero(self):
        return 0.0

    def inc(self, amount: float = 1.0, **labels) -> None:
        assert amount >= 0, f"counter {self.name}: negative inc {amount}"
        key = self._get(labels)
        with self._lock:
            self._series[key] += amount

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every label set (the process-wide count)."""
        with self._lock:
            return float(sum(self._series.values()))


class Gauge(_Metric):
    """Point-in-time value (occupancy, queue depth, drift score, lag)."""

    kind = "gauge"

    def _zero(self):
        return 0.0

    def set(self, value: float, **labels) -> None:
        key = self._get(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._get(labels)
        with self._lock:
            self._series[key] += amount

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # trailing +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Cumulative-bucket histogram with explicit ``le`` edges.

    ``observe(v)`` increments the first bucket whose edge satisfies
    ``v <= le`` plus every bucket after it at exposition time (Prometheus
    cumulative semantics; internally counts are per-bucket and cumulated on
    read, so ``observe`` stays O(log buckets))."""

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help)
        edges = tuple(sorted(float(b) for b in buckets))
        assert edges and all(
            b < a for b, a in zip(edges, edges[1:]) or [(0, 1)]
        ) or len(set(edges)) == len(edges), f"duplicate bucket edges {edges}"
        self.buckets = edges

    def _zero(self):
        return _HistSeries(len(self.buckets))

    def observe(self, value: float, **labels) -> None:
        key = self._get(labels)
        idx = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            s: _HistSeries = self._series[key]
            s.counts[idx] += 1          # idx == len(buckets) -> +Inf bucket
            s.sum += float(value)
            s.count += 1

    def cumulative(self, **labels) -> List[Tuple[float, int]]:
        """[(le_edge, cumulative_count), ..., (inf, total)] for one series."""
        s = self._series.get(_label_key(labels))
        if s is None:
            return [(b, 0) for b in self.buckets] + [(_INF, 0)]
        out, acc = [], 0
        for edge, c in zip(list(self.buckets) + [_INF], s.counts):
            acc += c
            out.append((edge, acc))
        return out

    def snapshot(self, **labels) -> dict:
        s = self._series.get(_label_key(labels))
        if s is None:
            return dict(sum=0.0, count=0,
                        buckets=self.cumulative(**labels))
        return dict(sum=s.sum, count=s.count, buckets=self.cumulative(**labels))

    def percentile(self, q: float, interpolate: bool = False,
                   **labels) -> Optional[float]:
        """Bucket-resolution quantile (q in [0, 1]).

        ``interpolate=False`` (the historical default) returns the smallest
        bucket edge whose cumulative count covers q of the observations —
        a bucket-*ceiling* value.  ``interpolate=True`` linearly
        interpolates inside the covering bucket (Prometheus
        ``histogram_quantile`` semantics, lower bound 0 for the first
        bucket), which is what should be compared against exact sample
        percentiles; the residual uncertainty is the covering bucket's
        width (:meth:`percentile_resolution`).  None when the series is
        empty; quantiles landing in the +Inf bucket report the largest
        finite edge either way (resolution is unbounded there)."""
        cum = self.cumulative(**labels)
        total = cum[-1][1]
        if total == 0:
            return None
        need = q * total
        prev_edge, prev_acc = 0.0, 0
        for edge, acc in cum:
            if acc >= need:
                if edge == _INF:
                    return self.buckets[-1]
                if not interpolate:
                    return edge
                if acc == prev_acc:      # need == 0 edge case
                    return prev_edge
                frac = (need - prev_acc) / (acc - prev_acc)
                return prev_edge + frac * (edge - prev_edge)
            prev_edge, prev_acc = edge, acc
        return self.buckets[-1]

    def percentile_resolution(self, q: float, **labels) -> Optional[float]:
        """Width of the bucket the q-quantile lands in — the explicit
        resolution an interpolated percentile read carries (inf when the
        quantile sits in the +Inf bucket, None when the series is empty)."""
        cum = self.cumulative(**labels)
        total = cum[-1][1]
        if total == 0:
            return None
        need = q * total
        prev_edge = 0.0
        for edge, acc in cum:
            if acc >= need:
                return _INF if edge == _INF else edge - prev_edge
            prev_edge = edge
        return _INF


def bucket_percentile(samples: Sequence[float], edges: Sequence[float],
                      q: float) -> Tuple[Optional[float], Optional[float]]:
    """(interpolated quantile, bucket resolution) of ``samples`` as a
    histogram with the given finite ``edges`` would report them — the
    offline twin of :meth:`Histogram.percentile` for per-batch sample
    lists (e.g. a scheduler's ``request_log``), so exact order statistics
    and histogram reads can be compared at a stated resolution instead of
    exact-vs-bucket-floor."""
    samples = [float(s) for s in samples]
    if not samples:
        return None, None
    edges = tuple(sorted(float(e) for e in edges))
    counts = [0] * (len(edges) + 1)
    for s in samples:
        counts[bisect.bisect_left(edges, s)] += 1
    total = len(samples)
    need = q * total
    prev_edge, acc = 0.0, 0
    for edge, c in zip(edges, counts):
        prev_acc, acc = acc, acc + c
        if acc >= need:
            width = edge - prev_edge
            if acc == prev_acc:
                return prev_edge, width
            frac = (need - prev_acc) / (acc - prev_acc)
            return prev_edge + frac * width, width
        prev_edge = edge
    return edges[-1], _INF          # quantile in the +Inf bucket


class MetricsRegistry:
    """Named metric collection with get-or-create declaration semantics."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _declare(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                assert isinstance(m, cls), (
                    f"metric {name!r} already declared as {m.kind}, "
                    f"not {cls.kind}")
                assert m.help == help, (
                    f"metric {name!r} re-declared with different help: "
                    f"{m.help!r} vs {help!r}")
                if kw.get("buckets") is not None:
                    assert tuple(sorted(map(float, kw["buckets"]))) == m.buckets, (
                        f"histogram {name!r} re-declared with different buckets")
                return m
            m = cls(name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str) -> Counter:
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str) -> Gauge:
        return self._declare(Gauge, name, help)

    def histogram(self, name: str, help: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._declare(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def metrics(self) -> Iterable[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def clear_values(self) -> None:
        """Reset every series (metric declarations stay) — test isolation."""
        for m in self.metrics():
            m.clear()

    def bucket_coverage(self, threshold: float = 0.05,
                        min_count: int = 20) -> List[dict]:
        """Histogram series whose +Inf bucket holds more than ``threshold``
        of their observations — the signal that a bucket family no longer
        covers the live distribution and needs re-tuning (how the
        BENCH-derived families above were produced).  Series with fewer
        than ``min_count`` observations are skipped (one cold-compile
        outlier is not a coverage problem)."""
        findings = []
        for m in self.metrics():
            if not isinstance(m, Histogram):
                continue
            for key in sorted(m.series()):
                snap = m.snapshot(**dict(key))
                count = snap["count"]
                if count < min_count:
                    continue
                inf_hits = count - snap["buckets"][-2][1]
                frac = inf_hits / count
                if frac > threshold:
                    findings.append(dict(
                        name=m.name, labels=dict(key), count=count,
                        inf_fraction=frac, top_edge=m.buckets[-1]))
        return findings

    def check_bucket_coverage(self, threshold: float = 0.05,
                              min_count: int = 20,
                              warn: bool = True) -> List[dict]:
        """:meth:`bucket_coverage` + a ``UserWarning`` per finding (the
        serve driver calls this at exit so an out-of-range bucket family
        is loud instead of silently truncating every percentile read)."""
        findings = self.bucket_coverage(threshold, min_count)
        if warn:
            for f in findings:
                warnings.warn(
                    f"histogram {f['name']}{f['labels'] or ''}: "
                    f"{f['inf_fraction']:.0%} of {f['count']} observations "
                    f"above the top bucket edge {f['top_edge']} — bucket "
                    f"family needs re-tuning", stacklevel=2)
        return findings


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry the instrumented subsystems report into."""
    return _DEFAULT


def reset_default_registry() -> None:
    """Zero every series in the default registry (declarations persist, so
    module-level metric handles stay valid) — used by tests to isolate
    counter deltas."""
    _DEFAULT.clear_values()
