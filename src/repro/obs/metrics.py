"""Metrics registry: counters, gauges and histograms with label sets.

The unified observability substrate every subsystem reports into (ISSUE 6 /
ROADMAP tentpole 1's measurement half): the serving engine counts program
retraces, the continuous batcher observes TTFT / end-to-end latency
histograms and occupancy gauges, the drift detector exports per-target
drift scores, and the policy store exports its published version and each
replica's adoption lag.  Everything here is **host-side only** — a metric
update is a dict write under a lock, never a traced op — so instrumenting a
path cannot perturb compiled programs, tokens, or telemetry (the PR-5
bit-identity and zero-recompile guarantees are regression-tested with the
instrumentation live).

Design (deliberately prometheus-client-shaped, stdlib-only):

* a :class:`MetricsRegistry` owns named metrics; :func:`default_registry`
  is the process-wide instance the instrumented subsystems use.  Metric
  creation is get-or-create — two modules may declare the same metric —
  but re-declaring with a different type or help string raises.
* every metric holds a family of **series** keyed by its label set
  (``counter.inc(1, mode="wave")``); label order never matters.
* :class:`Histogram` uses explicit cumulative ``le`` bucket edges (values
  land in every bucket whose edge is >= the value, Prometheus semantics)
  plus ``sum``/``count``, and exposes :meth:`Histogram.percentile` so hosts
  can read p50/p99 straight off the bucket counts.

Exposition lives in :mod:`repro.obs.export` (Prometheus text format over a
stdlib HTTP thread + JSONL snapshots for offline diffing).
"""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "LATENCY_BUCKETS",
    "default_registry",
    "reset_default_registry",
]

# default Histogram edges: serving latencies from 50us (a cached token step)
# to 2 minutes (a cold-compile wave), roughly log-spaced
LATENCY_BUCKETS = (
    0.00005, 0.0002, 0.001, 0.005, 0.02, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 120.0,
)

_INF = float("inf")

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, str]) -> LabelKey:
    """Canonical (sorted) label tuple — label order never matters."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Metric:
    """Shared label-series bookkeeping for the three metric types."""

    kind = "untyped"

    def __init__(self, name: str, help: str):
        self.name = name
        self.help = help
        self._series: Dict[LabelKey, object] = {}
        self._lock = threading.Lock()

    def _zero(self):
        raise NotImplementedError

    def _get(self, labels: Dict[str, str]):
        key = _label_key(labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = self._zero()
            return key

    def series(self) -> Dict[LabelKey, object]:
        """Snapshot of {label-key: value} for every series."""
        with self._lock:
            return dict(self._series)

    def clear(self) -> None:
        with self._lock:
            self._series.clear()


class Counter(_Metric):
    """Monotonically increasing count (e.g. retraces, splices, retunes)."""

    kind = "counter"

    def _zero(self):
        return 0.0

    def inc(self, amount: float = 1.0, **labels) -> None:
        assert amount >= 0, f"counter {self.name}: negative inc {amount}"
        key = self._get(labels)
        with self._lock:
            self._series[key] += amount

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))

    def total(self) -> float:
        """Sum over every label set (the process-wide count)."""
        with self._lock:
            return float(sum(self._series.values()))


class Gauge(_Metric):
    """Point-in-time value (occupancy, queue depth, drift score, lag)."""

    kind = "gauge"

    def _zero(self):
        return 0.0

    def set(self, value: float, **labels) -> None:
        key = self._get(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = self._get(labels)
        with self._lock:
            self._series[key] += amount

    def value(self, **labels) -> float:
        return float(self._series.get(_label_key(labels), 0.0))


class _HistSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)   # trailing +Inf bucket
        self.sum = 0.0
        self.count = 0


class Histogram(_Metric):
    """Cumulative-bucket histogram with explicit ``le`` edges.

    ``observe(v)`` increments the first bucket whose edge satisfies
    ``v <= le`` plus every bucket after it at exposition time (Prometheus
    cumulative semantics; internally counts are per-bucket and cumulated on
    read, so ``observe`` stays O(log buckets))."""

    kind = "histogram"

    def __init__(self, name: str, help: str,
                 buckets: Sequence[float] = LATENCY_BUCKETS):
        super().__init__(name, help)
        edges = tuple(sorted(float(b) for b in buckets))
        assert edges and all(
            b < a for b, a in zip(edges, edges[1:]) or [(0, 1)]
        ) or len(set(edges)) == len(edges), f"duplicate bucket edges {edges}"
        self.buckets = edges

    def _zero(self):
        return _HistSeries(len(self.buckets))

    def observe(self, value: float, **labels) -> None:
        key = self._get(labels)
        idx = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            s: _HistSeries = self._series[key]
            s.counts[idx] += 1          # idx == len(buckets) -> +Inf bucket
            s.sum += float(value)
            s.count += 1

    def cumulative(self, **labels) -> List[Tuple[float, int]]:
        """[(le_edge, cumulative_count), ..., (inf, total)] for one series."""
        s = self._series.get(_label_key(labels))
        if s is None:
            return [(b, 0) for b in self.buckets] + [(_INF, 0)]
        out, acc = [], 0
        for edge, c in zip(list(self.buckets) + [_INF], s.counts):
            acc += c
            out.append((edge, acc))
        return out

    def snapshot(self, **labels) -> dict:
        s = self._series.get(_label_key(labels))
        if s is None:
            return dict(sum=0.0, count=0,
                        buckets=self.cumulative(**labels))
        return dict(sum=s.sum, count=s.count, buckets=self.cumulative(**labels))

    def percentile(self, q: float, **labels) -> Optional[float]:
        """Bucket-resolution quantile (q in [0, 1]): the smallest bucket edge
        whose cumulative count covers q of the observations (None when the
        series is empty; +Inf-bucket hits report the largest finite edge)."""
        cum = self.cumulative(**labels)
        total = cum[-1][1]
        if total == 0:
            return None
        need = q * total
        for edge, acc in cum:
            if acc >= need:
                return edge if edge != _INF else self.buckets[-1]
        return self.buckets[-1]


class MetricsRegistry:
    """Named metric collection with get-or-create declaration semantics."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _declare(self, cls, name: str, help: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                assert isinstance(m, cls), (
                    f"metric {name!r} already declared as {m.kind}, "
                    f"not {cls.kind}")
                assert m.help == help, (
                    f"metric {name!r} re-declared with different help: "
                    f"{m.help!r} vs {help!r}")
                if kw.get("buckets") is not None:
                    assert tuple(sorted(map(float, kw["buckets"]))) == m.buckets, (
                        f"histogram {name!r} re-declared with different buckets")
                return m
            m = cls(name, help, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str) -> Counter:
        return self._declare(Counter, name, help)

    def gauge(self, name: str, help: str) -> Gauge:
        return self._declare(Gauge, name, help)

    def histogram(self, name: str, help: str,
                  buckets: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._declare(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def metrics(self) -> Iterable[_Metric]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def clear_values(self) -> None:
        """Reset every series (metric declarations stay) — test isolation."""
        for m in self.metrics():
            m.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry the instrumented subsystems report into."""
    return _DEFAULT


def reset_default_registry() -> None:
    """Zero every series in the default registry (declarations persist, so
    module-level metric handles stay valid) — used by tests to isolate
    counter deltas."""
    _DEFAULT.clear_values()
