"""Declarative SLOs with multi-window burn-rate alerting.

An :class:`SLOSpec` names a stream of good/bad events — request latency
against a target, or per-target QoR MAE against a guard band around the
drift reference — plus an **objective**: the fraction of events allowed to
be bad (the error budget).  :class:`SLOEngine` consumes events, tracks a
short and a long trailing window, and alerts only when *both* windows burn
budget faster than ``burn_alert`` times the sustainable rate — the
multi-window pattern (short window = still happening, long window = not a
blip) from the SRE burn-rate playbook, here over **event-count** windows
rather than wall-clock so evaluation is deterministic and testable.

Alert transitions are edge-triggered into the retune audit log
(``kind="slo_alert"`` / ``"slo_clear"``), and specs marked
``veto_promotion`` gate the PR-7 canary path: while such a spec is
alerting, :meth:`SLOEngine.vetoes_promotion` is true and the controller
refuses to ``promote()`` a candidate — a degraded QoR SLO means the
holdout score cannot be trusted to represent live traffic.

Like the rest of ``repro.obs`` this is dependency-free host code: the
engine is handed plain floats (the scheduler feeds latencies, the
controller feeds per-target MAE and the drift reference).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .metrics import default_registry

__all__ = [
    "SLOSpec",
    "SLOAlert",
    "SLOEngine",
    "default_serving_slos",
]

_REG = default_registry()
_BURN = _REG.gauge(
    "repro_slo_burn_rate",
    "trailing error-budget burn rate by SLO and window "
    "(1.0 = exactly consuming budget, >1 overspending)")
_BUDGET = _REG.gauge(
    "repro_slo_budget_remaining",
    "fraction of the long-window error budget still unspent, by SLO")
_ALERTS = _REG.counter(
    "repro_slo_alerts_total",
    "edge-triggered SLO alert activations, by SLO")


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One service-level objective.

    ``kind="latency"``: an event is *bad* when the observed seconds exceed
    ``threshold``.  ``kind="qor"``: an event is bad when the per-target
    MAE exceeds ``threshold`` times the engine's reference for ``source``
    (the drift-reference guard band); with no reference installed,
    ``threshold`` is an absolute MAE bound.
    """
    name: str
    kind: str                      # "latency" | "qor"
    source: str                    # latency stream name / telemetry target
    threshold: float               # seconds, or guard-band multiplier
    objective: float = 0.05        # allowed bad-event fraction (budget)
    short_window: int = 16         # events; "is it still happening"
    long_window: int = 64          # events; "is it not a blip"
    burn_alert: float = 2.0        # alert when both windows burn >= this
    min_events: int = 8            # per window, before it can alert
    veto_promotion: bool = False   # alerting => controller.promote() veto

    def __post_init__(self):
        if self.kind not in ("latency", "qor"):
            raise ValueError(f"unknown SLO kind {self.kind!r}")
        if not 0.0 < self.objective < 1.0:
            raise ValueError("objective must be a fraction in (0, 1)")
        if self.short_window > self.long_window:
            raise ValueError("short_window must be <= long_window")


@dataclasses.dataclass(frozen=True)
class SLOAlert:
    """A snapshot of an alerting SLO at evaluation time."""
    slo: str
    kind: str
    source: str
    burn_short: float
    burn_long: float
    events: int
    veto_promotion: bool


class _SpecState:
    def __init__(self, spec: SLOSpec):
        self.spec = spec
        self.short: Deque[bool] = collections.deque(maxlen=spec.short_window)
        self.long: Deque[bool] = collections.deque(maxlen=spec.long_window)
        self.events = 0
        self.bad = 0
        self.alerting = False

    def push(self, is_bad: bool) -> None:
        self.short.append(is_bad)
        self.long.append(is_bad)
        self.events += 1
        self.bad += int(is_bad)

    def burn(self, window: Deque[bool]) -> float:
        if not window:
            return 0.0
        return (sum(window) / len(window)) / self.spec.objective

    def ready(self) -> bool:
        return (len(self.short) >= min(self.spec.min_events,
                                       self.spec.short_window)
                and len(self.long) >= min(self.spec.min_events,
                                          self.spec.long_window))


class SLOEngine:
    """Evaluates a set of :class:`SLOSpec` over observed events.

    ``audit`` is any object with an ``append(kind, **fields)`` method
    (the PR-6 :class:`repro.obs.audit.AuditLog`); alert transitions are
    recorded there so SLO history lands next to retune/canary/rollback
    history in the same ``audit.jsonl``.
    """

    def __init__(self, specs: Sequence[SLOSpec], audit=None):
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names in {names}")
        self._states: Dict[str, _SpecState] = {
            s.name: _SpecState(s) for s in specs}
        self._audit = audit
        self._references: Dict[str, float] = {}

    # -- event ingestion ----------------------------------------------
    def observe_latency(self, source: str, seconds: float) -> None:
        """Feed one latency sample to every latency spec on ``source``."""
        for st in self._states.values():
            if st.spec.kind == "latency" and st.spec.source == source:
                st.push(float(seconds) > st.spec.threshold)
                self._evaluate(st)

    def set_reference(self, target: str, mae: float) -> None:
        """Install/refresh the drift-reference MAE a qor spec's guard
        band multiplies (the controller calls this at rebase)."""
        self._references[target] = float(mae)

    def observe_qor(self, target: str, mae: float) -> None:
        """Feed one per-target MAE sample to every qor spec on it."""
        for st in self._states.values():
            if st.spec.kind != "qor" or st.spec.source != target:
                continue
            ref = self._references.get(target)
            bound = (st.spec.threshold * ref if ref is not None
                     else st.spec.threshold)
            st.push(float(mae) > bound)
            self._evaluate(st)

    # -- evaluation ----------------------------------------------------
    def _evaluate(self, st: _SpecState) -> None:
        spec = st.spec
        bs, bl = st.burn(st.short), st.burn(st.long)
        _BURN.set(bs, slo=spec.name, window="short")
        _BURN.set(bl, slo=spec.name, window="long")
        allowed = max(len(st.long) * spec.objective, 1e-12)
        _BUDGET.set(max(0.0, 1.0 - sum(st.long) / allowed), slo=spec.name)
        now = (st.ready() and bs >= spec.burn_alert
               and bl >= spec.burn_alert)
        if now and not st.alerting:
            _ALERTS.inc(1, slo=spec.name)
            if self._audit is not None:
                self._audit.append(
                    "slo_alert", slo=spec.name, slo_kind=spec.kind,
                    source=spec.source, burn_short=round(bs, 4),
                    burn_long=round(bl, 4), events=st.events,
                    veto_promotion=spec.veto_promotion)
        elif st.alerting and not now:
            if self._audit is not None:
                self._audit.append(
                    "slo_clear", slo=spec.name, burn_short=round(bs, 4),
                    burn_long=round(bl, 4), events=st.events)
        st.alerting = now

    # -- queries -------------------------------------------------------
    def burn_rate(self, name: str) -> Tuple[float, float]:
        st = self._states[name]
        return st.burn(st.short), st.burn(st.long)

    def events(self, name: str) -> int:
        """Total events observed by SLO ``name`` (liveness probe)."""
        return self._states[name].events

    def alerting(self) -> List[SLOAlert]:
        out = []
        for st in self._states.values():
            if st.alerting:
                bs, bl = st.burn(st.short), st.burn(st.long)
                out.append(SLOAlert(
                    slo=st.spec.name, kind=st.spec.kind,
                    source=st.spec.source, burn_short=bs, burn_long=bl,
                    events=st.events,
                    veto_promotion=st.spec.veto_promotion))
        return out

    def vetoes_promotion(self) -> Optional[str]:
        """Name of an alerting veto-bearing SLO, or None — the PR-7
        canary path consults this before ``store.promote()``."""
        for st in self._states.values():
            if st.alerting and st.spec.veto_promotion:
                return st.spec.name
        return None

    def describe(self) -> str:
        parts = []
        for st in self._states.values():
            bs, bl = st.burn(st.short), st.burn(st.long)
            flag = "!" if st.alerting else ""
            parts.append(f"{flag}{st.spec.name}({bs:.1f}/{bl:.1f})")
        return "slo " + " ".join(parts)


def default_serving_slos(ttft_s: float = 8.0, e2e_s: float = 13.0,
                         mae_band: float = 1.5,
                         qor_targets: Sequence[str] = ("mlp",),
                         ) -> List[SLOSpec]:
    """The stock serving SLO set: latency p-targets sized from the tuned
    TTFT/e2e bucket families (a sample beyond the recorded BENCH_6/7 p99
    region is *bad*), plus a QoR guard band per telemetry target that
    vetoes canary promotion while alerting."""
    specs = [
        SLOSpec(name="ttft", kind="latency", source="ttft",
                threshold=ttft_s, objective=0.05),
        SLOSpec(name="e2e", kind="latency", source="e2e",
                threshold=e2e_s, objective=0.05),
    ]
    for t in qor_targets:
        specs.append(SLOSpec(
            name=f"qor_{t}", kind="qor", source=t, threshold=mae_band,
            objective=0.1, veto_promotion=True))
    return specs
