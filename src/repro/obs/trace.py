"""Trace spans: Chrome-trace-format timelines for the serving/adaptation loop.

A :class:`TraceRecorder` collects events in the `Chrome Trace Event format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
(load the saved JSON in ``chrome://tracing`` / Perfetto): an
admission -> prefill -> splice -> decode -> retire request lifetime renders
as one visually inspectable timeline.  Recording is **opt-in and host-side
only**: with no recorder installed every hook is a dict lookup + early
return, and nothing here ever enters a traced computation — instrumented
paths stay bit-identical (tested).

Surface:

* ``with span("prefill", rid=3):`` — a complete ("X") event timing the
  block; nested spans nest visually via the shared thread track.
* ``instant("splice", slot=2)`` — a zero-duration marker ("i").
* ``async_begin("request", 7)`` / ``async_end("request", 7)`` — an async
  ("b"/"e") pair spanning a request's whole queue->retire lifetime across
  waves/steps (Chrome draws them as arrows above the thread tracks).
* ``device_trace(logdir)`` — opt-in context manager around
  ``jax.profiler.start_trace`` for device-level deep dives next to the
  host-side timeline (XLA/TensorBoard trace; heavyweight, never on by
  default).
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Optional

__all__ = [
    "TraceRecorder",
    "install_recorder",
    "current_recorder",
    "span",
    "instant",
    "async_begin",
    "async_end",
    "device_trace",
]


class TraceRecorder:
    """In-memory Chrome-trace event buffer (microsecond timestamps relative
    to recorder creation; ``pid`` is the OS pid, ``tid`` the Python thread
    ident, so multi-threaded servers get one track per thread)."""

    def __init__(self):
        self._events = []
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()

    # -- clock ---------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def _push(self, ev: dict) -> None:
        with self._lock:
            self._events.append(ev)

    def _base(self, name: str, ph: str, cat: str, args: dict) -> dict:
        return dict(name=name, ph=ph, cat=cat, pid=os.getpid(),
                    tid=threading.get_ident(), ts=self.now_us(),
                    args={k: _jsonable(v) for k, v in args.items()})

    # -- event kinds ---------------------------------------------------
    def complete(self, name: str, start_us: float, dur_us: float,
                 cat: str = "serve", **args) -> None:
        ev = self._base(name, "X", cat, args)
        ev["ts"] = start_us
        ev["dur"] = dur_us
        self._push(ev)

    def instant(self, name: str, cat: str = "serve", **args) -> None:
        ev = self._base(name, "i", cat, args)
        ev["s"] = "t"                      # thread-scoped instant
        self._push(ev)

    def async_begin(self, name: str, ident, cat: str = "request",
                    **args) -> None:
        ev = self._base(name, "b", cat, args)
        ev["id"] = str(ident)
        self._push(ev)

    def async_end(self, name: str, ident, cat: str = "request",
                  **args) -> None:
        ev = self._base(name, "e", cat, args)
        ev["id"] = str(ident)
        self._push(ev)

    # -- output --------------------------------------------------------
    def events(self) -> list:
        with self._lock:
            return list(self._events)

    def to_json(self) -> str:
        return json.dumps({"traceEvents": self.events(),
                           "displayTimeUnit": "ms"}, indent=None)

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")
        return path


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except TypeError:
        return repr(v)


_CURRENT: Optional[TraceRecorder] = None


def install_recorder(rec: Optional[TraceRecorder]) -> Optional[TraceRecorder]:
    """Install (or, with ``None``, remove) the process trace recorder;
    returns the previous one so callers can restore it."""
    global _CURRENT
    prev, _CURRENT = _CURRENT, rec
    return prev


def current_recorder() -> Optional[TraceRecorder]:
    return _CURRENT


@contextlib.contextmanager
def span(name: str, cat: str = "serve", **args):
    """Time a block as a complete trace event.  No-recorder case is a
    near-free early exit — safe to leave on hot host loops."""
    rec = _CURRENT
    if rec is None:
        yield
        return
    t0 = rec.now_us()
    try:
        yield
    finally:
        rec.complete(name, t0, rec.now_us() - t0, cat=cat, **args)


def instant(name: str, cat: str = "serve", **args) -> None:
    rec = _CURRENT
    if rec is not None:
        rec.instant(name, cat=cat, **args)


def async_begin(name: str, ident, cat: str = "request", **args) -> None:
    rec = _CURRENT
    if rec is not None:
        rec.async_begin(name, ident, cat=cat, **args)


def async_end(name: str, ident, cat: str = "request", **args) -> None:
    rec = _CURRENT
    if rec is not None:
        rec.async_end(name, ident, cat=cat, **args)


@contextlib.contextmanager
def device_trace(logdir: str):
    """Opt-in ``jax.profiler`` device trace around a block (writes an
    XLA/TensorBoard trace under ``logdir``).  Heavyweight — pair it with the
    host-side spans only for deep dives (``launch/serve --device-trace``)."""
    import jax

    jax.profiler.start_trace(logdir)
    try:
        yield
    finally:
        jax.profiler.stop_trace()
