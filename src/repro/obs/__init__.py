"""Unified observability layer (DESIGN: registry -> spans -> audit -> gates).

One measurement substrate for the whole runtime (ISSUE 6):

  metrics — counter/gauge/histogram registry with label sets; the
            process-wide :func:`default_registry` every subsystem reports
            into, exported as Prometheus text + JSONL snapshots (export)
  trace   — Chrome-trace-format span API (admission -> prefill -> splice ->
            decode -> retire on one timeline) + opt-in jax.profiler hook
  export  — Prometheus exposition over a stdlib http.server thread
            (``launch/serve --metrics-port``) and JSONL snapshot diffs
  audit   — append-only retune event log next to the PolicyStore (trigger,
            drift score, winning triple / tile-grid digest, predicted gain,
            store version): policy history is replayable after the fact

Quality-of-result observability (ISSUE 8) layers on top:

  qor      — per-request error attribution: correlation ids threaded from
             scheduler admission through token steps; each Completion
             carries per-target/per-tile ew-MAE shares and top-k
             contributors reduced host-side from the step records
  slo      — declarative SLO specs + multi-window burn-rate evaluation;
             alert events land in the audit log, and an alerting QoR SLO
             vetoes canary promotion in the controller
  backends — push exporters (StatsD line protocol over UDP, OTLP-JSON
             file/HTTP) behind one interface next to the Prometheus pull
             path (``launch/serve --statsd`` / ``--otlp-out``)

plus **recompile accounting as a first-class metric**: every compiled-
program install in the serving engine (``_ADAPTIVE_FNS`` / ``_TOKEN_FNS`` /
the fused + prefill lru caches) counts into ``repro_retraces_total{kind=}``,
and :func:`install_jax_compile_listener` additionally counts XLA backend
compiles via ``jax.monitoring`` — so "zero recompiles across splices and
policy updates" is a live gauge the token-granular batcher asserts on and
CI gates (``serving.zero_recompiles``), instead of a per-test re-derivation.

Everything in this package is host-side and dependency-free within
``repro`` (it imports nothing from the runtime), so instrumentation can
never perturb a traced computation — the bit-identity guarantees are
regression-tested with the instrumentation live.

Metric name catalogue: see docs/observability.md.
"""
from __future__ import annotations

from . import audit, backends, export, metrics, qor, slo, trace
from .audit import AUDIT_FILENAME, AuditLog, audit_for_store, grid_digest
from .backends import (OtlpJsonExporter, StatsdExporter, otlp_json, push_all,
                       statsd_lines)
from .export import (MetricsServer, prometheus_text, registry_snapshot,
                     start_metrics_server, write_snapshot)
from .metrics import (DISPATCH_BUCKETS, E2E_BUCKETS, LATENCY_BUCKETS,
                      QOR_MAE_BUCKETS, TTFT_BUCKETS, Counter, Gauge,
                      Histogram, MetricsRegistry, bucket_percentile,
                      default_registry, reset_default_registry)
from .qor import ErrorAttributor, step_error_summary
from .slo import SLOAlert, SLOEngine, SLOSpec, default_serving_slos
from .trace import (TraceRecorder, async_begin, async_end, current_recorder,
                    device_trace, install_recorder, instant, span)

__all__ = [
    "audit", "backends", "export", "metrics", "qor", "slo", "trace",
    "AUDIT_FILENAME", "AuditLog", "audit_for_store", "grid_digest",
    "OtlpJsonExporter", "StatsdExporter", "otlp_json", "push_all",
    "statsd_lines",
    "MetricsServer", "prometheus_text", "registry_snapshot",
    "start_metrics_server", "write_snapshot",
    "LATENCY_BUCKETS", "TTFT_BUCKETS", "E2E_BUCKETS", "DISPATCH_BUCKETS",
    "QOR_MAE_BUCKETS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "bucket_percentile", "default_registry", "reset_default_registry",
    "ErrorAttributor", "step_error_summary",
    "SLOAlert", "SLOEngine", "SLOSpec", "default_serving_slos",
    "TraceRecorder", "async_begin", "async_end", "current_recorder",
    "device_trace", "install_recorder", "instant", "span",
    "RETRACES", "JAX_COMPILES", "count_retrace", "retrace_total",
    "install_jax_compile_listener",
]


# ---------------------------------------------------------------------------
# recompile accounting
# ---------------------------------------------------------------------------

# one series per program-cache kind: "token_step" (the token-granular
# per-step program), "fused_adaptive" (the telemetry-carrying scan),
# "fused" (the plain decode scan), "prefill" (per-bucket pad-mask prefill).
# A retrace == a cache-miss install of a compiled program; traced-value
# changes (policy updates, splices, new waves) never count.
RETRACES = default_registry().counter(
    "repro_retraces_total",
    "compiled-program installs in the serving engine by program kind "
    "(policy updates and splices change traced values only and never count)")

# XLA backend compiles observed via jax.monitoring (opt-in listener):
# includes everything jit-compiled in-process, e.g. the controller's
# re-tune scorers — a superset of the engine's program installs.
JAX_COMPILES = default_registry().counter(
    "repro_jax_compiles_total",
    "XLA backend compiles observed via jax.monitoring (install the "
    "listener with obs.install_jax_compile_listener)")


def count_retrace(kind: str) -> None:
    """Record one compiled-program install of ``kind``."""
    RETRACES.inc(1, kind=kind)


def retrace_total(kind: str = None) -> float:
    """Current retrace count — one kind, or the process-wide total."""
    if kind is None:
        return RETRACES.total()
    return RETRACES.value(kind=kind)


_JAX_LISTENER_INSTALLED = False

# jax.monitoring duration-event names that mark one backend compile
# (jax >= 0.4: '/jax/core/compile/backend_compile_duration')
_COMPILE_EVENT_PREFIX = "/jax/core/compile/backend_compile"


def install_jax_compile_listener() -> bool:
    """Register a ``jax.monitoring`` listener counting XLA backend compiles
    into ``repro_jax_compiles_total`` (idempotent; listeners cannot be
    unregistered, so this is opt-in — ``launch/serve`` installs it whenever
    any observability flag is set).  Returns True when newly installed."""
    global _JAX_LISTENER_INSTALLED
    if _JAX_LISTENER_INSTALLED:
        return False
    import jax.monitoring

    def _on_duration(name: str, duration: float, **kw) -> None:
        if name.startswith(_COMPILE_EVENT_PREFIX):
            JAX_COMPILES.inc(1)

    jax.monitoring.register_event_duration_secs_listener(_on_duration)
    _JAX_LISTENER_INSTALLED = True
    return True
