"""Unified observability layer (DESIGN: registry -> spans -> audit -> gates).

One measurement substrate for the whole runtime (ISSUE 6):

  metrics — counter/gauge/histogram registry with label sets; the
            process-wide :func:`default_registry` every subsystem reports
            into, exported as Prometheus text + JSONL snapshots (export)
  trace   — Chrome-trace-format span API (admission -> prefill -> splice ->
            decode -> retire on one timeline) + opt-in jax.profiler hook
  export  — Prometheus exposition over a stdlib http.server thread
            (``launch/serve --metrics-port``) and JSONL snapshot diffs
  audit   — append-only retune event log next to the PolicyStore (trigger,
            drift score, winning triple / tile-grid digest, predicted gain,
            store version): policy history is replayable after the fact

plus **recompile accounting as a first-class metric**: every compiled-
program install in the serving engine (``_ADAPTIVE_FNS`` / ``_TOKEN_FNS`` /
the fused + prefill lru caches) counts into ``repro_retraces_total{kind=}``,
and :func:`install_jax_compile_listener` additionally counts XLA backend
compiles via ``jax.monitoring`` — so "zero recompiles across splices and
policy updates" is a live gauge the token-granular batcher asserts on and
CI gates (``serving.zero_recompiles``), instead of a per-test re-derivation.

Everything in this package is host-side and dependency-free within
``repro`` (it imports nothing from the runtime), so instrumentation can
never perturb a traced computation — the bit-identity guarantees are
regression-tested with the instrumentation live.

Metric name catalogue: see docs/observability.md.
"""
from __future__ import annotations

from . import audit, export, metrics, trace
from .audit import AUDIT_FILENAME, AuditLog, audit_for_store, grid_digest
from .export import (MetricsServer, prometheus_text, registry_snapshot,
                     start_metrics_server, write_snapshot)
from .metrics import (LATENCY_BUCKETS, Counter, Gauge, Histogram,
                      MetricsRegistry, default_registry,
                      reset_default_registry)
from .trace import (TraceRecorder, async_begin, async_end, current_recorder,
                    device_trace, install_recorder, instant, span)

__all__ = [
    "audit", "export", "metrics", "trace",
    "AUDIT_FILENAME", "AuditLog", "audit_for_store", "grid_digest",
    "MetricsServer", "prometheus_text", "registry_snapshot",
    "start_metrics_server", "write_snapshot",
    "LATENCY_BUCKETS", "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_registry", "reset_default_registry",
    "TraceRecorder", "async_begin", "async_end", "current_recorder",
    "device_trace", "install_recorder", "instant", "span",
    "RETRACES", "JAX_COMPILES", "count_retrace", "retrace_total",
    "install_jax_compile_listener",
]


# ---------------------------------------------------------------------------
# recompile accounting
# ---------------------------------------------------------------------------

# one series per program-cache kind: "token_step" (the token-granular
# per-step program), "fused_adaptive" (the telemetry-carrying scan),
# "fused" (the plain decode scan), "prefill" (per-bucket pad-mask prefill).
# A retrace == a cache-miss install of a compiled program; traced-value
# changes (policy updates, splices, new waves) never count.
RETRACES = default_registry().counter(
    "repro_retraces_total",
    "compiled-program installs in the serving engine by program kind "
    "(policy updates and splices change traced values only and never count)")

# XLA backend compiles observed via jax.monitoring (opt-in listener):
# includes everything jit-compiled in-process, e.g. the controller's
# re-tune scorers — a superset of the engine's program installs.
JAX_COMPILES = default_registry().counter(
    "repro_jax_compiles_total",
    "XLA backend compiles observed via jax.monitoring (install the "
    "listener with obs.install_jax_compile_listener)")


def count_retrace(kind: str) -> None:
    """Record one compiled-program install of ``kind``."""
    RETRACES.inc(1, kind=kind)


def retrace_total(kind: str = None) -> float:
    """Current retrace count — one kind, or the process-wide total."""
    if kind is None:
        return RETRACES.total()
    return RETRACES.value(kind=kind)


_JAX_LISTENER_INSTALLED = False

# jax.monitoring duration-event names that mark one backend compile
# (jax >= 0.4: '/jax/core/compile/backend_compile_duration')
_COMPILE_EVENT_PREFIX = "/jax/core/compile/backend_compile"


def install_jax_compile_listener() -> bool:
    """Register a ``jax.monitoring`` listener counting XLA backend compiles
    into ``repro_jax_compiles_total`` (idempotent; listeners cannot be
    unregistered, so this is opt-in — ``launch/serve`` installs it whenever
    any observability flag is set).  Returns True when newly installed."""
    global _JAX_LISTENER_INSTALLED
    if _JAX_LISTENER_INSTALLED:
        return False
    import jax.monitoring

    def _on_duration(name: str, duration: float, **kw) -> None:
        if name.startswith(_COMPILE_EVENT_PREFIX):
            JAX_COMPILES.inc(1)

    jax.monitoring.register_event_duration_secs_listener(_on_duration)
    _JAX_LISTENER_INSTALLED = True
    return True
