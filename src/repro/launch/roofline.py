"""Roofline analysis from compiled dry-run artifacts (DESIGN.md §8).

Hardware constants (TPU v5e-class, per guided spec):
    197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.

compute  term = per-device HLO FLOPs / peak
memory   term = per-device HLO bytes accessed / HBM bandwidth
collective term = per-device collective operand bytes (parsed from the
post-SPMD HLO) / ICI link bandwidth

MODEL_FLOPS (6·N·D train / 2·N·tokens serve) over total compiled FLOPs is
the usefulness ratio — it catches remat/redundant-compute waste.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

__all__ = ["HW", "collective_bytes", "roofline_report", "model_flops", "param_count"]

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

HW = dict(peak_flops=PEAK_FLOPS, hbm_bw=HBM_BW, ici_bw=ICI_BW)

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,4096]' -> bytes.  Tuples handled by the caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in a (post-SPMD,
    per-device) HLO module.  Returns {op_kind: bytes} + '_total'."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # ops look like:  %x = bf16[8,128]{1,0} all-gather(...), replica_groups=...
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=]*?)\s*([\w\-]+)\(", s)
        if not m:
            continue
        op = m.group(2)
        kind = None
        for c in _COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        out[kind] += _shape_bytes(m.group(1))
    out["_total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def param_count(cfg) -> int:
    """Analytic parameter count (total / active for MoE)."""
    D, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    embed = V * D * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "ssm":
        din = cfg.ssm_expand * D
        per = D * din * 2 + D * (2 * cfg.ssm_state) + D * (din // cfg.ssm_head_dim) + din * D
        return embed + L * per
    hd = cfg.head_dim_
    attn = D * cfg.n_heads * hd + 2 * D * cfg.n_kv_heads * hd + cfg.n_heads * hd * D
    mlp_mult = 3 if cfg.act == "silu" else 2
    total = embed
    active = embed
    for kind in cfg.layer_kinds():
        if kind == "recurrent":
            R = cfg.d_rnn
            t = 2 * D * R + 2 * R * R + R * D
        else:
            t = attn
        if cfg.family == "moe" and kind != "dense_ffn":
            e_all = cfg.n_experts * mlp_mult * D * cfg.moe_d_ff
            e_act = (cfg.top_k + cfg.n_shared_experts) * mlp_mult * D * cfg.moe_d_ff
            total += t + e_all + D * cfg.n_experts
            active += t + e_act
            continue
        ff = mlp_mult * D * cfg.d_ff
        total += t + ff
        active += t + ff
    if cfg.family == "encdec":
        # encoder layers (attn + mlp) + decoder cross-attn already excluded;
        # approximate: encoder adds n_enc_layers * (attn + mlp), decoder adds
        # cross-attn per layer
        total += cfg.n_enc_layers * (attn + mlp_mult * D * cfg.d_ff) + L * attn
        active = total
    return int(total if cfg.family != "moe" else active)


def model_flops(cfg, shape) -> float:
    """6*N*D for train (N_active for MoE), 2*N*tokens for serving."""
    n = param_count(cfg)
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    if shape.kind == "train":
        return 6.0 * n * toks
    return 2.0 * n * toks


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float
    peak_bytes_per_dev: Optional[float] = None

    @property
    def t_compute(self):
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def dominant(self):
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self):
        total = self.flops_per_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self):
        """fraction of the compute roofline achieved at the bound:
        (useful model FLOP time at peak) / (dominant term time)."""
        t_model = self.model_flops / self.chips / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_model / t_bound if t_bound else 0.0

    def row(self):
        return dict(
            arch=self.arch, shape=self.shape, mesh=self.mesh, chips=self.chips,
            t_compute=self.t_compute, t_memory=self.t_memory,
            t_collective=self.t_collective, dominant=self.dominant,
            model_flops=self.model_flops, hlo_flops_per_dev=self.flops_per_dev,
            useful_ratio=self.useful_ratio,
            roofline_fraction=self.roofline_fraction,
            peak_bytes_per_dev=self.peak_bytes_per_dev,
        )


def roofline_report(arch, shape, mesh_name, chips, cost, hlo_text, cfg, shape_cfg,
                    peak_bytes=None) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)["_total"]
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_dev=flops, bytes_per_dev=byts, coll_bytes_per_dev=float(coll),
        model_flops=model_flops(cfg, shape_cfg), peak_bytes_per_dev=peak_bytes,
    )
