import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and extract memory / cost / collective statistics
for the roofline analysis (EXPERIMENTS.md §Dry-run, §Roofline).

The two lines above MUST stay first: jax locks the device count on first
initialization, and the dry-run needs 512 host placeholder devices.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, LONG_CONTEXT_OK, SHAPES, ParallelConfig
from repro.configs.base import AxPolicy
from repro.models import registry
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step

from .mesh import (
    batch_shardings,
    cache_shardings,
    make_production_mesh,
    param_shardings,
    state_shardings,
)
from .roofline import collective_bytes, roofline_report
from .sharding import set_mesh_ctx


def skip_reason(arch: str, shape_name: str):
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return "long_500k needs sub-quadratic attention (pure full-attention arch; DESIGN.md §6)"
    return None


def _n_periods(cfg):
    if cfg.family == "encdec":
        return cfg.n_layers
    period = len(cfg.pattern) if cfg.pattern else 1
    return (cfg.n_layers - cfg.first_dense) // period


def _variant_cfg(cfg, k: int):
    """Same model with k pattern-periods (lead/rest layers kept) — used for
    the finite-difference cost extrapolation: XLA's HloCostAnalysis counts a
    while-loop body once regardless of trip count, so the full scan-over-
    layers compile underreports FLOPs/collectives by ~n_periods.  We compile
    1- and 2-period UNROLLED variants and scale the per-period delta."""
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, n_layers=k, n_enc_layers=k)
    period = len(cfg.pattern) if cfg.pattern else 1
    body = cfg.n_layers - cfg.first_dense
    rest = body - (body // period) * period
    return dataclasses.replace(cfg, n_layers=cfg.first_dense + k * period + rest)


def build_cell(cfg, shape_name: str, mesh, par: ParallelConfig,
               ax: AxPolicy = None):
    """Returns (fn, args_specs, in_shardings) ready to lower."""
    if ax is not None:
        cfg = dataclasses.replace(cfg, ax=ax)
    shape = SHAPES[shape_name]
    specs = registry.input_specs(cfg, shape)

    params_shape = jax.eval_shape(partial(registry.init_params, cfg=cfg),
                                  jax.random.PRNGKey(0))
    p_sh = param_shardings(mesh, par, params_shape)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        state_shape = {
            "params": params_shape,
            "opt": jax.eval_shape(partial(adamw_init, cfg=opt_cfg), params_shape),
        }
        s_sh = state_shardings(mesh, par, state_shape)
        b_sh = batch_shardings(mesh, specs)
        step = make_train_step(cfg, par, opt_cfg)

        def fn(state, batch):
            with set_mesh_ctx(mesh, par):
                return step(state, batch)

        return fn, (state_shape, specs), (s_sh, b_sh), cfg, shape

    if shape.kind == "prefill":
        max_len = shape.seq_len + 64

        def fn(params, batch):
            with set_mesh_ctx(mesh, par):
                return registry.prefill(params, batch, cfg, par, max_cache_len=max_len)

        b_sh = batch_shardings(mesh, specs)
        return fn, (params_shape, specs), (p_sh, b_sh), cfg, shape

    # decode
    cache_shape = specs["cache"]
    tok = specs["tokens"]
    c_sh = cache_shardings(mesh, par, cache_shape, cfg)
    t_sh = batch_shardings(mesh, {"tokens": tok})["tokens"]

    def fn(params, cache, tokens):
        with set_mesh_ctx(mesh, par):
            return registry.decode_step(
                params, cache, tokens, jnp.int32(shape.seq_len - 1), cfg, par
            )

    return fn, (params_shape, cache_shape, tok), (p_sh, c_sh, t_sh), cfg, shape


def _compile_stats(cfg, shape_name, mesh, par, ax):
    fn, arg_shapes, in_sh, cfg2, shape = build_cell(cfg, shape_name, mesh, par, ax)
    jfn = jax.jit(fn, in_shardings=in_sh)
    with mesh:
        lowered = jfn.lower(*arg_shapes)
        compiled = lowered.compile()
    cost_list = compiled.cost_analysis()
    cost = cost_list if isinstance(cost_list, dict) else (cost_list[0] if cost_list else {})
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    return dict(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        coll=coll,
        mem=mem,
        cfg=cfg2,
        shape=shape,
    )


def run_cell(arch: str, shape_name: str, multi_pod: bool, par: ParallelConfig,
             ax: AxPolicy = None, verbose=True, extrapolate=True, mesh=None,
             cfg_patch: dict = None):
    from repro.models import layers as _layers

    reason = skip_reason(arch, shape_name)
    if mesh is not None:
        mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    else:
        mesh_name = "2x16x16" if multi_pod else "16x16"
    if reason:
        row = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skip", "reason": reason}
        if verbose:
            print(json.dumps(row))
            sys.stdout.flush()
        return row
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    cfg = ARCHS[arch]
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    t0 = time.time()

    # 1) FULL compile: proves the cell lowers/compiles and gives memory.
    full = _compile_stats(cfg, shape_name, mesh, par, ax)
    t1 = time.time()

    # 2) Cost extrapolation (XLA counts while bodies once): compile 1- and
    #    2-period variants with layer scan + attention chunk loops unrolled,
    #    scale the per-period delta by the period count.
    P = _n_periods(cfg)
    if extrapolate and P > 1:
        par_u = dataclasses.replace(par, scan_layers=False)
        _layers.COST_MODE = True
        try:
            v1 = _compile_stats(_variant_cfg(cfg, 1), shape_name, mesh, par_u, ax)
            v2 = _compile_stats(_variant_cfg(cfg, 2), shape_name, mesh, par_u, ax)
        finally:
            _layers.COST_MODE = False
        flops = v1["flops"] + (P - 1) * (v2["flops"] - v1["flops"])
        byts = v1["bytes"] + (P - 1) * (v2["bytes"] - v1["bytes"])
        coll_total = (v1["coll"]["_total"]
                      + (P - 1) * (v2["coll"]["_total"] - v1["coll"]["_total"]))
        coll_detail = {
            k: int(v1["coll"][k] + (P - 1) * (v2["coll"][k] - v1["coll"][k]))
            for k in v1["coll"] if k != "_total"
        }
        cost_src = "extrapolated_1p2p"
    else:
        flops, byts = full["flops"], full["bytes"]
        coll_total = full["coll"]["_total"]
        coll_detail = {k: v for k, v in full["coll"].items() if k != "_total"}
        cost_src = "full"
    t2 = time.time()

    mem = full["mem"]
    peak_bytes = None
    if mem is not None and hasattr(mem, "temp_size_in_bytes"):
        peak_bytes = (
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    cost = {"flops": flops, "bytes accessed": byts}
    rl = roofline_report(arch, shape_name, mesh_name, chips, cost, "",
                         full["cfg"], full["shape"], peak_bytes=peak_bytes)
    rl.coll_bytes_per_dev = float(coll_total)
    row = rl.row()
    row.update(
        status="ok",
        compile_s=round(t1 - t0, 1),
        cost_compile_s=round(t2 - t1, 1),
        cost_source=cost_src,
        n_periods=P,
        collectives={k: v for k, v in coll_detail.items() if v},
        memory={
            a: int(getattr(mem, a))
            for a in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes")
            if mem is not None and hasattr(mem, a)
        },
        ax=(ax.mult_name if ax else None),
    )
    if verbose:
        print(json.dumps(row, default=float))
        sys.stdout.flush()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--ax", action="store_true",
                    help="SWAPPER approximate-matmul mode (mxu backend)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--pad-vocab", type=int, default=1)
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--patch", default=None,
                    help="JSON dict of ModelConfig field overrides")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--seq-shard", type=int, default=1)
    ap.add_argument("--remat", default="layer")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    par = ParallelConfig(fsdp=bool(args.fsdp), seq_shard=bool(args.seq_shard),
                         remat=args.remat, grad_accum=args.grad_accum,
                         dp_only=args.dp_only)
    ax = AxPolicy(backend="mxu") if args.ax else None
    cfg_patch = dict(json.loads(args.patch)) if args.patch else {}
    if args.pad_vocab > 1:
        cfg_patch["pad_vocab_multiple"] = args.pad_vocab
    cfg_patch = cfg_patch or None

    cells = []
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    rows = []
    fail = 0
    for a, s, mp in cells:
        try:
            rows.append(run_cell(a, s, mp, par, ax, cfg_patch=cfg_patch,
                                 extrapolate=not args.no_extrapolate))
        except Exception as e:
            fail += 1
            rows.append({"arch": a, "shape": s,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "error", "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]})
            print(json.dumps(rows[-1]))
            sys.stdout.flush()
    if args.out:
        with open(args.out, "w") as f:
            for r in rows:
                f.write(json.dumps(r, default=float) + "\n")
    ok = sum(1 for r in rows if r["status"] == "ok")
    sk = sum(1 for r in rows if r["status"] == "skip")
    print(f"\n== dry-run: {ok} ok, {sk} skipped, {fail} failed, "
          f"{len(rows)} cells ==")
    sys.exit(1 if fail else 0)


if __name__ == "__main__":
    main()
