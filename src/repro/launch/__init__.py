from .mesh import make_production_mesh
from .sharding import set_mesh_ctx, shard

__all__ = ["make_production_mesh", "set_mesh_ctx", "shard"]
