"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Parameters and activations are annotated with *logical* axis names; this
module maps them to mesh axes:

    batch   -> ('pod', 'data') on the multi-pod mesh, ('data',) single-pod
    embed   -> 'data' when FSDP is on (2-D weight sharding), else replicated
    heads/ff/vocab/experts -> 'model'   (tensor/expert parallelism)
    seq     -> 'model' when sequence-parallel residuals are on
    kv_seq  -> 'model'                  (decode KV-cache sequence sharding)

``set_mesh_ctx`` installs a mesh + rules for the duration of a lowering;
``shard()`` is a no-op outside of it, so models run unmodified on one device.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig

__all__ = ["axis_rules", "set_mesh_ctx", "shard", "spec_for", "param_spec", "current_mesh"]

_ctx = threading.local()


def axis_rules(mesh: Mesh, par: ParallelConfig) -> dict:
    if par.dp_only:
        # small models: no tensor parallelism — the 'model' axis joins the
        # batch (pure DP), parameters FSDP-shard over 'data'
        batch_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
        return {
            "batch": batch_axes,
            "embed": "data" if par.fsdp else None,
            "heads": None, "kv_heads": None, "ff": None, "vocab": None,
            "experts": "model" if par.ep else None,
            "seq": None, "kv_seq": None, "layers": None, None: None,
        }
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return {
        "batch": batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None),
        "embed": "data" if par.fsdp else None,
        "heads": "model",
        "kv_heads": None,        # GQA kv-head counts often < mesh model size
        "ff": "model",
        "vocab": "model",
        "experts": "model" if par.ep else None,
        "seq": "model" if par.seq_shard else None,
        "kv_seq": "model",
        "layers": None,
        None: None,
    }


@contextlib.contextmanager
def set_mesh_ctx(mesh: Mesh, par: ParallelConfig):
    rules = axis_rules(mesh, par)
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield rules
    finally:
        _ctx.state = prev


def current_mesh() -> Optional[Mesh]:
    st = getattr(_ctx, "state", None)
    return st[0] if st else None


def _dedup(parts):
    """A mesh axis may appear at most once in a PartitionSpec: keep the first
    occurrence (e.g. MoE expert weights shard 'experts' over model; the 'ff'
    dim then stays unsharded)."""
    seen = set()
    out = []
    for ax in parts:
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if any(n in seen for n in names):
            out.append(None)
        else:
            seen.update(names)
            out.append(ax)
    return out


def spec_for(logical_axes: Tuple, rules=None) -> P:
    if rules is None:
        st = getattr(_ctx, "state", None)
        if st is None:
            return P()
        rules = st[1]
    return P(*_dedup([rules.get(a, None) for a in logical_axes]))


def shard(x, *logical_axes):
    """with_sharding_constraint by logical axes; no-op without a mesh ctx."""
    st = getattr(_ctx, "state", None)
    if st is None:
        return x
    mesh, rules = st
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = spec_for(tuple(logical_axes), rules)
    # drop constraints that do not divide the dimension (e.g. 8 kv heads on a
    # 16-way model axis): replace by None on that dim
    fixed = []
    for dim, ax in zip(x.shape, spec):
        names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        size = 1
        for nm in names:
            size *= mesh.shape[nm]
        fixed.append(ax if size and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


def param_spec(logical_axes: Tuple, mesh: Mesh, par: ParallelConfig, shape=None) -> P:
    """PartitionSpec for a parameter, dropping non-divisible constraints and
    deduplicating repeated mesh axes (first occurrence wins)."""
    rules = axis_rules(mesh, par)
    spec = _dedup([rules.get(a, None) for a in logical_axes])
    if shape is not None:
        for i, (dim, ax) in enumerate(zip(shape, spec)):
            names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            size = 1
            for nm in names:
                size *= mesh.shape[nm]
            if size == 0 or dim % size != 0:
                spec[i] = None
    return P(*spec)



