"""Serving driver: batched prefill + decode with the sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --batch 4 --prompt-len 64 --new-tokens 32 [--ax]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduced
from repro.configs.base import AxPolicy
from repro.models import init_params
from repro.serve import ServeConfig, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ax", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    if args.ax:
        cfg = dataclasses.replace(cfg, ax=AxPolicy(backend="mxu"))

    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        prompt = {
            "frames": jnp.asarray(rng.normal(0, 1, (args.batch, args.prompt_len,
                                                     cfg.d_model)), jnp.bfloat16),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab,
                                               (args.batch, 8)), jnp.int32),
        }
    else:
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}

    t0 = time.time()
    out = generate(params, prompt, cfg,
                   ServeConfig(max_new_tokens=args.new_tokens,
                               temperature=args.temperature))
    dt = time.time() - t0
    toks = out.size
    print(f"arch={cfg.name} generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(np.asarray(out)[:, :16])


if __name__ == "__main__":
    main()
