"""Serving driver: batched prefill + decode with the sharded KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --batch 4 --prompt-len 64 --new-tokens 32 [--ax] [--adaptive]

``--adaptive`` attaches the online adaptive SWAPPER runtime: the decode step
streams operand/error telemetry, a drift detector scores the live operand
distribution against the one the policy was tuned on, and on drift the
controller re-tunes the swap config in place — zero recompilations.  In
``--smoke`` mode a synthetic distribution drift is injected mid-generation
(``--drift-at``) to exercise the loop end-to-end.

``--tile-rows N`` (with ``--adaptive`` or ``--fleet``) switches the runtime
to per-row-tile granularity: projections serve (N, 1, 3) swap-config grids,
telemetry is collected per row tile, and tile-granular re-tunes publish
``SwapPolicy.tile_grids`` — all with zero recompiles (see
docs/architecture.md).

``--fleet N`` instead runs the mesh-native serving stack: an N-replica
("data",) mesh, the continuous-batching scheduler admitting variable-length
synthetic requests into fixed-shape decode slots, one fused adaptive
``lax.scan`` dispatch per wave with in-graph (psum) telemetry aggregation,
and re-tunes published through the versioned ``PolicyStore``
(``--policy-store``); each logical replica's ``PolicyReader`` staleness
(store versions behind CURRENT) is reported at the end.  On CPU, force
replicas with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

``--token-granular`` (with ``--fleet``) switches the batcher to
token-granular continuous batching: decode runs one compiled per-step
program with per-slot cache positions, and a finished slot admits the next
FIFO request *mid-flight* — its prompt is pad-mask prefilled into the
slot's cache region and spliced into the running batch at the next step
boundary (zero recompiles; per-request tokens bit-identical to the
wave-granular oracle under greedy decoding).

``--chaos-plan PATH`` (with ``--fleet``) installs a ``fleet.chaos``
``FaultPlan`` for the run: deterministic injected faults (torn publishes,
poisoned telemetry, replica kills, stalls) exercise the guarded-rollout /
quarantine / store-recovery paths end-to-end (docs/robustness.md).  The
fleet controller runs with ``canary=True``: retune winners are holdout-
canaried before promotion and regressed adoptions auto-roll back.

Observability (``repro.obs``, see docs/observability.md): ``--metrics-port``
serves live Prometheus ``/metrics`` (``--metrics-hold`` keeps it up after
the run), ``--obs-dir`` writes a Chrome-trace timeline + metric snapshots
at exit, ``--device-trace`` adds a jax.profiler device trace; any of them
also turns on XLA-compile accounting via ``jax.monitoring``.
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import ARCHS, reduced
from repro.configs.base import AxPolicy
from repro.models import init_params
from repro.serve import ServeConfig, generate


@contextlib.contextmanager
def _observability(args):
    """Driver-level observability setup (all opt-in, see docs/observability.md):

    * ``--metrics-port P`` — serve ``/metrics`` (Prometheus text) from a
      stdlib http.server thread for the whole run; ``--metrics-hold S``
      keeps the process alive S extra seconds after serving finishes so an
      external scraper can land at least one scrape.
    * ``--obs-dir DIR`` — install a trace recorder and, at exit, write
      ``DIR/trace.json`` (Chrome trace: load in chrome://tracing/Perfetto),
      ``DIR/metrics.prom`` (final Prometheus snapshot) and one JSON line in
      ``DIR/metrics.jsonl``.
    * ``--device-trace DIR`` — additionally wrap the run in a
      ``jax.profiler`` device trace (heavyweight XLA/TensorBoard dump).

    * ``--statsd HOST:PORT`` — push the registry as StatsD/DogStatsD lines
      over UDP at exit (``--statsd-mirror FILE`` additionally appends every
      line to FILE — the CI artifact, immune to UDP loss).
    * ``--otlp-out PATH|URL`` — push one OTLP-JSON ``resourceMetrics``
      payload to a ``.jsonl`` file (or POST it to an ``http(s)://``
      collector endpoint) at exit.

    Any of these also installs the ``jax.monitoring`` compile listener, so
    ``repro_jax_compiles_total`` counts every XLA backend compile.  At exit
    the bucket-coverage check runs: any histogram family whose +Inf bucket
    swallowed >5% of its observations warns loudly."""
    enabled = (args.metrics_port is not None or args.obs_dir
               or args.device_trace or args.statsd or args.otlp_out)
    if not enabled:
        yield
        return
    obs.install_jax_compile_listener()
    server = (obs.start_metrics_server(args.metrics_port)
              if args.metrics_port is not None else None)
    if server is not None:
        print(f"[obs] serving /metrics on port {server.port}")
    exporters = []
    if args.statsd:
        exporters.append(obs.StatsdExporter.from_spec(
            args.statsd, mirror=args.statsd_mirror))
        print(f"[obs] statsd push -> udp://{args.statsd}"
              + (f" (mirror {args.statsd_mirror})"
                 if args.statsd_mirror else ""))
    if args.otlp_out:
        exporters.append(obs.OtlpJsonExporter(args.otlp_out))
        print(f"[obs] otlp-json push -> {args.otlp_out}")
    rec = None
    if args.obs_dir:
        os.makedirs(args.obs_dir, exist_ok=True)
        rec = obs.TraceRecorder()
        obs.install_recorder(rec)
    dev = (obs.device_trace(args.device_trace) if args.device_trace
           else contextlib.nullcontext())
    try:
        with dev:
            yield
    finally:
        if args.obs_dir:
            obs.install_recorder(None)
            rec.save(os.path.join(args.obs_dir, "trace.json"))
            with open(os.path.join(args.obs_dir, "metrics.prom"), "w") as f:
                f.write(obs.prometheus_text())
            obs.write_snapshot(os.path.join(args.obs_dir, "metrics.jsonl"),
                               run=" ".join(
                                   f"{k}={v}" for k, v in sorted(
                                       vars(args).items()) if v))
            print(f"[obs] trace + metrics snapshots written to {args.obs_dir}")
        if exporters:
            n = obs.push_all(exporters)
            print(f"[obs] pushed {n} payload units through "
                  f"{len(exporters)} backend(s)")
            for e in exporters:
                e.close()
        findings = obs.default_registry().check_bucket_coverage()
        if findings:
            print(f"[obs] {len(findings)} histogram series exceeded the "
                  f"+Inf-bucket coverage threshold (see warnings)")
        if server is not None:
            if args.metrics_hold > 0:
                print(f"[obs] holding /metrics open {args.metrics_hold}s")
                time.sleep(args.metrics_hold)
            server.close()


def _drift_hook(at_step: int, scale: float):
    """Returns a param_hook that, at ``at_step``, rescales every other row of
    the weights' *input* (second-to-last) axis.  Weight quantization groups
    reduce over exactly that axis, so an alternating pattern *within* each
    group shifts the int8 code (bit-occupancy) distribution of the quantized
    weights directly — uniform whole-column scaling would be quantization
    invariant.  A controlled stand-in for live traffic drift (it also
    perturbs downstream activations)."""
    done = {"fired": False}

    def hook(step, params):
        if step != at_step or done["fired"]:
            return params
        done["fired"] = True

        def perturb(w):
            if w.ndim < 2:
                return w
            mask = (jnp.arange(w.shape[-2]) % 2 == 0)[:, None]
            return jnp.where(mask, w * scale, w)

        print(f"[drift] step {step}: injected synthetic weight drift (x{scale})")
        return jax.tree.map(perturb, params)

    return hook


def _run_fleet(args, cfg):
    """The mesh-native serving stack: fleet mesh + continuous batcher +
    policy store (see module docstring)."""
    from repro.fleet import (BatcherConfig, ContinuousBatcher, PolicyReader,
                             PolicyStore, Request)
    from repro.launch.mesh import make_fleet_mesh
    from repro.runtime import AdaptiveConfig, AdaptiveController, SwapPolicy

    from repro.fleet import chaos

    n = args.fleet
    if len(jax.devices()) < n:
        raise SystemExit(
            f"--fleet {n}: only {len(jax.devices())} devices visible; on CPU "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    mesh = make_fleet_mesh(n)
    harness = None
    if args.chaos_plan:
        plan = chaos.FaultPlan.load(args.chaos_plan)
        harness = chaos.install(plan)
        print(f"[chaos] {plan.describe()}")
    # slots must divide over the replica axis: round the default up to a
    # multiple of n
    slots = args.slots or n * max(1, -(-4 // n))
    store = PolicyStore(args.policy_store)
    # the fleet driver runs guarded rollout: retune winners are canaried on
    # a ring-buffer holdout before promotion, and a regressed adoption
    # auto-rolls CURRENT back to last-good (docs/robustness.md)
    controller = AdaptiveController(
        SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
        cfg=AdaptiveConfig(min_observe_steps=2, cooldown_steps=2,
                           tile_rows=args.tile_rows, canary=True),
        store=store,
        log_fn=lambda line: print(f"[fleet] {line}"))
    resumed = controller.resume_from_store()
    print(f"[fleet] mesh={mesh.shape} slots={slots} store={store.root} "
          f"{'resumed v' + str(store.current_version()) if resumed else 'fresh'}")
    controller.warmup()
    # SLO/error-budget engine: latency objectives on the batcher's TTFT/e2e
    # stream plus per-target MAE guard bands anchored to the controller's
    # drift reference; a burning QoR SLO re-arms the rollback guard and
    # vetoes canary promotion (docs/observability.md)
    slo = obs.SLOEngine(obs.default_serving_slos(qor_targets=cfg.ax.targets),
                        audit=controller.audit)
    controller.attach_slo(slo)

    params = init_params(jax.random.PRNGKey(0), cfg)
    bcfg = BatcherConfig(n_slots=slots,
                         prompt_buckets=(args.prompt_len,),
                         new_token_bucket=args.new_tokens,
                         temperature=args.temperature,
                         token_granular=args.token_granular)
    bat = ContinuousBatcher(params, cfg, bcfg, adaptive=controller, mesh=mesh)
    bat.attach_slo(slo)
    # one logical PolicyReader per replica: they adopt the policy current at
    # spin-up and then surface the staleness metric (versions behind
    # CURRENT) until their next poll — the fleet lag monitor
    readers = [PolicyReader(store, cfg.ax.targets, tile_rows=args.tile_rows,
                            name=f"r{i}")
               for i in range(n)]
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        L = int(rng.integers(max(args.prompt_len // 2, 1), args.prompt_len + 1))
        bat.submit(Request(rid, rng.integers(0, cfg.vocab, L),
                           max_new=int(rng.integers(1, args.new_tokens + 1))))
    t0 = time.time()
    done = []
    while True:                # supervise the drain: an injected replica
        try:                   # kill restarts it (faults fire once per plan)
            done.extend(bat.run())
            break
        except chaos.InjectedFault as e:
            print(f"[chaos] survived injected crash ({e}); resuming drain")
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)
    print(f"[fleet] {bat.describe()}")
    print(f"[fleet] served {len(done)} requests / {toks} tokens in {dt:.2f}s "
          f"(incl. compile)")
    print(f"[fleet] {controller.telemetry.describe()}")
    print(f"[fleet] {bat.qor.describe()}")
    print(f"[fleet] {slo.describe()}")
    print(f"[fleet] re-tunes: {len(controller.retunes)} "
          f"tile re-tunes: {len(controller.tile_retunes)} "
          f"store v{store.current_version()} {controller.policy.describe()}")
    stale = [r.staleness() for r in readers]
    print("[fleet] replica staleness (versions behind CURRENT): "
          + " ".join(f"r{i}=v{r.version}+{s}" for i, (r, s)
                     in enumerate(zip(readers, stale))))
    for i, r in enumerate(readers):
        try:
            r.poll()
        except chaos.InjectedFault as e:
            print(f"[chaos] reader r{i} survived injected crash ({e}); "
                  f"re-polling")
            r.poll()
    print(f"[fleet] after poll: staleness="
          f"{[r.staleness() for r in readers]} (all replicas adopted "
          f"v{store.current_version()})")
    if harness is not None:
        print(f"[chaos] {harness.describe()}")
        if controller.rollbacks:
            print(f"[chaos] rollbacks: {controller.rollbacks}")
        chaos.uninstall()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ax", action="store_true")
    ap.add_argument("--adaptive", action="store_true",
                    help="online SWAPPER runtime (telemetry + drift-triggered re-tune)")
    ap.add_argument("--tile-rows", type=int, default=0, metavar="N",
                    help="per-row-tile adaptation granularity (0 = scalar "
                         "configs; N > 0 = N-row-tile config grids + tile "
                         "telemetry, with --adaptive/--fleet)")
    ap.add_argument("--drift-at", type=int, default=None,
                    help="decode step at which to inject synthetic drift "
                         "(default: new_tokens//3 with --adaptive --smoke; -1 disables)")
    ap.add_argument("--drift-scale", type=float, default=0.05)
    ap.add_argument("--policy-out", default=None,
                    help="write the final (possibly re-tuned) SwapPolicy JSON here")
    ap.add_argument("--fleet", type=int, default=0, metavar="N",
                    help="serve on an N-replica mesh via the continuous "
                         "batcher + policy store (implies --adaptive)")
    ap.add_argument("--token-granular", action="store_true",
                    help="--fleet: per-slot cache positions + mid-flight "
                         "admission (finished slots splice the next FIFO "
                         "request into the running batch; greedy only)")
    ap.add_argument("--slots", type=int, default=0,
                    help="--fleet decode slots per wave (default max(N, 4))")
    ap.add_argument("--requests", type=int, default=16,
                    help="--fleet synthetic request count")
    ap.add_argument("--policy-store", default="/tmp/repro_policy_store",
                    help="--fleet PolicyStore root directory")
    ap.add_argument("--chaos-plan", default=None, metavar="PATH",
                    help="--fleet: install a fleet.chaos FaultPlan JSON "
                         "(fault-injection run; see docs/robustness.md)")
    ap.add_argument("--metrics-port", type=int, default=None, metavar="P",
                    help="serve Prometheus /metrics on this port for the "
                         "whole run (0 = ephemeral, printed at startup)")
    ap.add_argument("--metrics-hold", type=float, default=0.0, metavar="S",
                    help="keep /metrics up S seconds after serving finishes "
                         "(lets an external scraper land a scrape)")
    ap.add_argument("--obs-dir", default=None, metavar="DIR",
                    help="write Chrome trace + Prometheus/JSONL metric "
                         "snapshots here at exit")
    ap.add_argument("--device-trace", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler device trace "
                         "(XLA/TensorBoard dump under DIR; heavyweight)")
    ap.add_argument("--statsd", default=None, metavar="HOST:PORT",
                    help="push the metric registry as StatsD/DogStatsD UDP "
                         "datagrams at exit")
    ap.add_argument("--statsd-mirror", default=None, metavar="FILE",
                    help="also append every StatsD line to FILE (lossless "
                         "CI artifact; requires --statsd)")
    ap.add_argument("--otlp-out", default=None, metavar="PATH|URL",
                    help="push one OTLP-JSON resourceMetrics payload at "
                         "exit: append to PATH (.jsonl) or POST to an "
                         "http(s):// collector endpoint")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    if args.ax or args.adaptive or args.fleet:
        cfg = dataclasses.replace(cfg, ax=AxPolicy(backend="mxu"))

    with _observability(args):
        if args.fleet:
            _run_fleet(args, cfg)
        else:
            _run_single(args, cfg)


def _run_single(args, cfg):
    controller = None
    param_hook = None
    if args.adaptive:
        from repro.runtime import AdaptiveConfig, AdaptiveController, SwapPolicy

        policy = SwapPolicy.from_ax_policy(cfg.ax)
        controller = AdaptiveController(
            policy, targets=cfg.ax.targets,
            cfg=AdaptiveConfig(min_observe_steps=2, cooldown_steps=4,
                               tile_rows=args.tile_rows),
            log_fn=lambda line: print(f"[adaptive] {line}"),
        )
        controller.warmup()
        drift_at = args.drift_at
        if drift_at is None:
            drift_at = args.new_tokens // 3 if args.smoke else -1
        if drift_at >= 0:
            param_hook = _drift_hook(drift_at, args.drift_scale)
        print(f"[adaptive] {policy.describe()}")

    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    if cfg.family == "encdec":
        prompt = {
            "frames": jnp.asarray(rng.normal(0, 1, (args.batch, args.prompt_len,
                                                     cfg.d_model)), jnp.bfloat16),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab,
                                               (args.batch, 8)), jnp.int32),
        }
    else:
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)}

    t0 = time.time()
    out = generate(params, prompt, cfg,
                   ServeConfig(max_new_tokens=args.new_tokens,
                               temperature=args.temperature),
                   adaptive=controller, param_hook=param_hook)
    dt = time.time() - t0
    toks = out.size
    print(f"arch={cfg.name} generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print(np.asarray(out)[:, :16])

    if controller is not None:
        print(f"[adaptive] {controller.telemetry.describe()}")
        print(f"[adaptive] re-tunes: {len(controller.retunes)} "
              f"tile re-tunes: {len(controller.tile_retunes)} "
              f"final {controller.policy.describe()}")
        if args.policy_out:
            controller.policy.save(args.policy_out)
            print(f"[adaptive] policy written to {args.policy_out}")


if __name__ == "__main__":
    main()
