import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb profiling aid: dump the largest collective/fusion ops of a
compiled dry-run cell (the 'profile' of DESIGN.md §8 — no real hardware).

    PYTHONPATH=src python -m repro.launch.hlo_analyze --arch mamba2-370m \
        --shape train_4k [--fsdp 0] [--top 25]
"""
import argparse
import re
from collections import defaultdict

import jax

from repro.configs import ARCHS, SHAPES, ParallelConfig
from repro.configs.base import AxPolicy

from .dryrun import build_cell
from .mesh import make_production_mesh
from .roofline import _SHAPE_RE, _shape_bytes


def top_ops(hlo_text: str, top: int = 25):
    rows = []
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"%?([\w.\-]+)\s*=\s*(\([^)]*\)|[^=]*?)\s*([\w\-]+)\(", s)
        if not m:
            continue
        name, shape_str, op = m.groups()
        if op in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast"):
            continue
        b = _shape_bytes(shape_str)
        if b:
            rows.append((b, op, name, shape_str[:90], s[:220]))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--seq-shard", type=int, default=1)
    ap.add_argument("--remat", default="layer")
    ap.add_argument("--ax", action="store_true")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--collectives-only", action="store_true")
    args = ap.parse_args()

    par = ParallelConfig(fsdp=bool(args.fsdp), seq_shard=bool(args.seq_shard),
                         remat=args.remat)
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cfg = ARCHS[args.arch]
    ax = AxPolicy(backend="mxu") if args.ax else None
    fn, shapes, in_sh, cfg2, shp = build_cell(cfg, args.shape, mesh, par, ax)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*shapes).compile()
    hlo = compiled.as_text()

    agg = defaultdict(lambda: [0, 0])
    for b, op, *_ in top_ops(hlo, top=10**6):
        agg[op][0] += b
        agg[op][1] += 1
    print("== per-op-kind totals (output bytes, count) ==")
    for op, (b, c) in sorted(agg.items(), key=lambda kv: -kv[1][0])[:20]:
        print(f"  {op:28s} {b/1e9:10.3f} GB  x{c}")

    print("\n== largest individual ops ==")
    shown = 0
    for b, op, name, shape_str, line in top_ops(hlo, top=10**4):
        if args.collectives_only and not any(
            op.startswith(c) for c in ("all-", "reduce-scatter", "collective")
        ):
            continue
        print(f"  {b/1e9:9.3f} GB {op:24s} {shape_str}")
        shown += 1
        if shown >= args.top:
            break


if __name__ == "__main__":
    main()
