"""Production mesh construction + state-sharding builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): (16, 16) "data" x "model" single-pod (256 chips), or
(2, 16, 16) "pod" x "data" x "model" for the 512-chip multi-pod dry-run.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.layers import axes_for_path

from .sharding import axis_rules, param_spec

__all__ = [
    "make_production_mesh",
    "make_fleet_mesh",
    "param_shardings",
    "state_shardings",
    "batch_shardings",
    "cache_shardings",
    "tree_paths",
]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_fleet_mesh(n_replicas: Optional[int] = None):
    """1-D ("data",) serving mesh: each replica holds full weights and
    serves its slice of the slot batch; the fleet telemetry psums over this
    axis (``fleet.collect``).  Defaults to every visible device — on a CPU
    host, force a multi-device fleet with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes (see examples/fleet_serve.py and tests/test_fleet.py)."""
    n = n_replicas or len(jax.devices())
    assert len(jax.devices()) >= n, (n, jax.devices())
    return jax.make_mesh((n,), ("data",))


def tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [
        "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in flat
    ]
    return paths, [leaf for _, leaf in flat], treedef


def param_shardings(mesh: Mesh, par: ParallelConfig, params_shape):
    """NamedSharding tree for a params pytree (of ShapeDtypeStructs)."""
    paths, leaves, treedef = tree_paths(params_shape)
    out = []
    for path, leaf in zip(paths, leaves):
        # whisper stacked decoder/encoder params count as scan-stacked
        norm = path
        if path.startswith(("layers_enc/", "layers_dec/")):
            norm = "layers/" + path.split("/", 1)[1]
        elif path.startswith("layers/"):
            norm = "layers/" + path.split("/", 2)[2]  # drop the p{j} segment
        axes = axes_for_path(norm, len(leaf.shape))
        out.append(NamedSharding(mesh, param_spec(axes, mesh, par, leaf.shape)))
    return jax.tree_util.tree_unflatten(treedef, out)


def state_shardings(mesh: Mesh, par: ParallelConfig, state_shape):
    """Shardings for the full train state {params, opt:{step,m,v[,ef]}} —
    optimizer moments follow their parameter's sharding (ZeRO-style)."""
    ps = param_shardings(mesh, par, state_shape["params"])
    out = {"params": ps, "opt": {"step": NamedSharding(mesh, P())}}
    for k in state_shape["opt"]:
        if k == "step":
            continue
        out["opt"][k] = ps
    return out


def _batch_axes(mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _div(dim, mesh, ax):
    names = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
    size = int(np.prod([mesh.shape[n] for n in names])) if names else 1
    return size > 0 and dim % size == 0


def batch_shardings(mesh: Mesh, batch_specs):
    """Input batch: shard the leading (global-batch) dim over pod+data; fall
    back to replication when not divisible (e.g. global_batch=1)."""
    b = _batch_axes(mesh)

    def spec(leaf):
        parts = [None] * len(leaf.shape)
        if len(leaf.shape) >= 1 and _div(leaf.shape[0], mesh, b):
            parts[0] = b
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(spec, batch_specs)


def cache_shardings(mesh: Mesh, par: ParallelConfig, cache_shape, cfg: ModelConfig):
    """Decode-cache shardings: KV caches shard batch over pod+data and the
    cache *sequence* dim over 'model' (decode attention then combines
    partial softmax stats with small all-reduces — GQA kv-head counts are
    frequently smaller than the model axis, so head-sharding is not an
    option at (16,16)).  Recurrent/SSM states shard batch only.  long_500k
    (batch=1) falls back to sequence-over-everything."""
    b = _batch_axes(mesh)
    paths, leaves, treedef = tree_paths(cache_shape)
    out = []
    for path, leaf in zip(paths, leaves):
        shp = leaf.shape
        nd = len(shp)
        leafname = path.rsplit("/", 1)[-1]
        stacked = path.startswith(("stack/", "self/", "cross")) or (
            cfg.family == "encdec"
        )
        parts = [None] * nd
        # locate the batch dim: stacked caches have a leading layer dim
        bdim = 0
        if stacked and nd >= 2:
            bdim = 1
            if "cross" in path and nd >= 3:
                bdim = 2
        if leafname in ("k", "v") or "cross" in path:
            sdim = bdim + 1
            if _div(shp[bdim], mesh, b):
                parts[bdim] = b
                if _div(shp[sdim], mesh, "model"):
                    parts[sdim] = "model"
            else:
                # batch=1 long-context: shard the sequence over both axes
                both = tuple(x for x in ((b if isinstance(b, tuple) else (b,)) + ("model",)) if x)
                if _div(shp[sdim], mesh, both):
                    parts[sdim] = both
                elif _div(shp[sdim], mesh, "model"):
                    parts[sdim] = "model"
        else:  # recurrent/ssm states, conv buffers
            if _div(shp[bdim], mesh, b):
                parts[bdim] = b
        out.append(NamedSharding(mesh, P(*parts)))
    return jax.tree_util.tree_unflatten(treedef, out)
