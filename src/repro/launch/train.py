"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-72b --smoke \
        --steps 200 --batch 8 --seq 128 [--ax] [--ckpt-dir /tmp/ck]

``--smoke`` uses the reduced same-family config (CPU-runnable ~100M-class
with --d-model overrides); omit it on real hardware for the full config.
Supervised: checkpoints every N steps, restarts on failure, straggler log.
With ``--adaptive``, re-tuned SwapPolicies are versioned into
``<ckpt_dir>/policy`` (the fleet ``PolicyStore`` format) and a restarted job
resumes the adapted policy, not the offline-tuned one.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ParallelConfig, reduced
from repro.configs.base import AxPolicy
from repro.models import init_params
from repro.train import (
    AdamWConfig,
    DataConfig,
    FaultConfig,
    SyntheticStream,
    init_train_state,
    make_train_step,
    run_supervised,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--compress", default="none", choices=["none", "bf16"])
    ap.add_argument("--ax", action="store_true", help="SWAPPER approximate matmuls")
    ap.add_argument("--tile-rows", type=int, default=0, metavar="N",
                    help="per-row-tile adaptation granularity for --adaptive "
                         "(0 = scalar configs)")
    ap.add_argument("--adaptive", action="store_true",
                    help="online adaptive SWAPPER (telemetry + drift re-tune)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = reduced(cfg)
    if args.ax or args.adaptive:
        cfg = dataclasses.replace(cfg, ax=AxPolicy(backend="mxu"))
    par = ParallelConfig(remat=args.remat, grad_accum=args.grad_accum, fsdp=False,
                         seq_shard=False)
    if args.adaptive:
        print(f"[adaptive] forcing scan_layers=False, remat=none (was "
              f"{args.remat}), grad_accum=1 (was {args.grad_accum}): telemetry "
              f"records must be outer-trace outputs (see train_step)")
        par = dataclasses.replace(par, scan_layers=False, remat="none", grad_accum=1)
    opt = AdamWConfig(lr=args.lr, compress=args.compress)

    stream = SyntheticStream(
        DataConfig(cfg.vocab, args.seq, args.batch, seed=0, mode="arith")
    )
    step = jax.jit(make_train_step(cfg, par, opt, adaptive=args.adaptive,
                                   tile_rows=args.tile_rows),
                   donate_argnums=(0,))

    if args.adaptive:
        import os

        from repro.fleet import PolicyStore
        from repro.runtime import AdaptiveController, SwapPolicy

        # policy checkpointing rides the PolicyStore format alongside the
        # train checkpoints: every re-tune publishes a new version under
        # <ckpt_dir>/policy, and an elastic restart resumes the *adapted*
        # policy instead of reverting to the offline-tuned one
        store = PolicyStore(os.path.join(args.ckpt_dir, "policy"))
        from repro.runtime import AdaptiveConfig

        controller = AdaptiveController(
            SwapPolicy.from_ax_policy(cfg.ax), targets=cfg.ax.targets,
            cfg=AdaptiveConfig(tile_rows=args.tile_rows),
            log_fn=lambda line: print(f"[adaptive] {line}"), store=store,
        )
        if controller.resume_from_store():
            print(f"[adaptive] resumed policy v{store.current_version()} "
                  f"from {store.root}")
        controller.warmup()

        pending = [None]   # one-step-stale observe keeps dispatch pipelined

        def step_fn(state, batch):
            state, metrics = step(state, jax.tree.map(jnp.asarray, batch),
                                  controller.dyn_tree())
            telem = metrics.pop("ax_telemetry")
            if pending[0] is not None:
                controller.observe(jax.device_get(pending[0]))
            pending[0] = telem
            return state, metrics
    else:
        def step_fn(state, batch):
            return step(state, jax.tree.map(jnp.asarray, batch))

    def make_state():
        params = init_params(jax.random.PRNGKey(0), cfg)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n/1e6:.1f}M ax={'on' if cfg.ax else 'off'}")
        return init_train_state(params, opt)

    t0 = time.time()

    def on_step(i, metrics):
        if (i + 1) % args.log_every == 0:
            print(f"step {i+1}: loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1):.3f}s/step)")

    state, log = run_supervised(
        make_state, step_fn, stream, args.steps,
        FaultConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        on_step=on_step,
    )
    if args.adaptive and pending[0] is not None:
        controller.observe(jax.device_get(pending[0]))   # flush final step
        print(f"[adaptive] {controller.telemetry.describe()}")
        print(f"[adaptive] re-tunes: {len(controller.retunes)} "
              f"store v{store.current_version()} "
              f"final {controller.policy.describe()}")
    print(f"done: {log}")


if __name__ == "__main__":
    main()
