from .engine import ServeConfig, generate

__all__ = ["ServeConfig", "generate"]
