"""Batched serving loop: prefill once, then greedy/temperature decode steps
against the sharded KV cache.

The non-adaptive hot path is **fully fused on device**: the whole token loop
(decode step + sampling + cache update) runs as one ``lax.scan``, so serving
``T`` tokens costs one dispatch instead of ``T`` host round-trips.  The
Python step loop is kept (``ServeConfig.fused=False``, or automatically when
a ``param_hook`` needs to mutate params mid-generation) and produces
bit-identical token sequences — the scan body performs the exact same ops in
the same order, including the RNG splits.

With an :class:`~repro.runtime.AdaptiveController` attached, the decode step
is compiled **once** with the SWAPPER config as a traced input and telemetry
summaries as extra outputs; each step the controller folds the telemetry in,
scores distribution drift, and re-tunes the policy in place — the jit cache
stays warm throughout (zero recompilations; see runtime/).  Telemetry is
decimated by ``ServeConfig.observe_every``: the observe gate enters the
compiled step as a traced boolean, so off-steps skip the summary compute
(``lax.cond``) *and* the host-side device_get without retracing anything.

**Adaptive decode is also fused** (``ServeConfig.fused=True``, no
``param_hook``): the whole adaptive token loop runs as one ``lax.scan`` with
the per-step telemetry records threaded through the scan carry — each gated
step scatter-adds its fixed-shape record into slot ``i // observe_every`` of
a ``ceil(T/k)``-slot carry buffer (off-steps contribute ``lax.cond`` zeros),
so adaptive serving pays **one dispatch per generation** and the host folds
the slot records into the controller afterwards.  The policy is therefore
frozen within a generation; re-tunes land between generations (the stepwise
loop remains for mid-generation adaptation and ``param_hook``).

With ``mesh=...`` the fused adaptive scan additionally runs under
``shard_map`` over the mesh batch axes: every shard decodes its batch slice
and the telemetry records are ``psum``/``pmax``/all-gathered **in-graph**
(``fleet.collect``) before leaving the trace, so one controller sees the
fleet-global operand distribution.

When the controller (or ``fleet.PolicyReader``) reports ``tile_rows > 0``,
decode runs **per-row-tile**: the policy enters as (tile_rows, 1, 3) config
grids instead of scalar triples, every projection additionally emits a
per-tile telemetry record (same scan-carry slots, same gate), and published
``SwapPolicy.tile_grids`` land in the compiled step as new traced int32
values — tile-granular adaptation with zero recompiles, exactly like the
scalar path (see docs/architecture.md).

**Decode positions are per-slot** (PR 5): every decode path carries an
int32 ``(B,)`` position vector instead of one scalar index, and per-slot
done-flags derived from ``slot_new_tokens`` gate sampling (a finished
slot's token freezes), cache writes (dropped — the slot's cache region
stays inert until a fresh request is spliced in), and the telemetry
scatter-add (all-retired steps contribute nothing).  ``prompt_lens``
switches prefill to the pad-mask path: right-padded prompts attend only to
real tokens, the first token samples at each slot's last *real* position,
and decode starts at position ``len`` per slot — a padded prompt's
generation is bit-identical to the same prompt served unpadded.  On top of
this, :func:`token_step` exposes a single-compilation per-step decode
(decode + sample + freeze) used by the token-granular continuous batcher
(``fleet.scheduler``) to splice new requests into a mid-flight batch at
step boundaries with zero recompiles.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import decode_step, prefill

__all__ = ["ServeConfig", "generate", "token_step", "prefill_one"]

# host-side observability (repro.obs): program-install accounting per cache
# kind — the live "zero recompiles" signal CI gates — plus dispatch-wall
# histograms.  All updates happen OUTSIDE traced code, so instrumentation
# cannot perturb tokens, telemetry, or compiled programs (tested).
_PREFILL_WALL = obs.default_registry().histogram(
    "repro_prefill_dispatch_seconds",
    "host wall of generate()'s prefill + first-token sample "
    "(async dispatch: excludes on-device completion)",
    buckets=obs.DISPATCH_BUCKETS)
_DECODE_WALL = obs.default_registry().histogram(
    "repro_decode_dispatch_seconds",
    "host wall of generate()'s decode-loop dispatch by path "
    "(async dispatch: excludes on-device completion)",
    buckets=obs.DISPATCH_BUCKETS)
_DECODE_TOKENS = obs.default_registry().counter(
    "repro_decode_tokens_total",
    "tokens produced by generate() decode loops (slots x steps)")
_SLOTS_RETIRED = obs.default_registry().counter(
    "repro_slots_retired_total",
    "slots whose done-flag fires before the scan/budget end "
    "(per-slot token budgets below the generation length)")


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 => greedy
    seed: int = 0
    fused: bool = True         # on-device lax.scan decode (non-adaptive path)
    observe_every: int = 1     # adaptive telemetry decimation period (k >= 1)


def _sampler(scfg: ServeConfig):
    def sample(logits, key):
        lg = logits[:, -1].astype(jnp.float32)
        if scfg.temperature > 0:
            return jax.random.categorical(key, lg / scfg.temperature, axis=-1)
        return jnp.argmax(lg, axis=-1)

    return sample


def generate(params, prompt_batch, cfg: ModelConfig, scfg: ServeConfig,
             par: Optional[ParallelConfig] = None, adaptive=None,
             param_hook: Optional[Callable] = None, mesh=None,
             prompt_lens=None, slot_new_tokens=None, max_cache_len=None):
    """prompt_batch: {'tokens': (B, S)} (or family-specific prefill inputs).
    Returns (B, max_new_tokens) int32.

    ``adaptive`` — optional AdaptiveController (or ``fleet.PolicyReader``)
    driving the dynamic SWAPPER policy for ``cfg.ax.targets`` projections
    during decode.
    ``param_hook(step, params) -> params`` — optional per-step parameter
    transform (used by the serve driver to inject synthetic distribution
    drift; values change, shapes don't, so the step is not retraced).  A hook
    forces the stepwise Python loop (params must change between steps).
    ``mesh`` — optional device mesh for the fleet path: the fused adaptive
    decode shards its batch over the mesh batch axes under ``shard_map`` and
    telemetry is aggregated in-graph (requires ``adaptive`` and
    ``scfg.fused``; greedy decoding is bit-identical to the single-host run,
    temperature sampling draws per-shard).
    ``prompt_lens`` — optional (B,) int32 of real prompt lengths: prefill
    runs pad-masked (padded slots attend only to real tokens), the first
    token samples at each slot's last real position, and decode positions
    start at ``prompt_lens`` per slot.
    ``slot_new_tokens`` — optional (B,) int32 per-slot token budgets (each
    ``<= scfg.max_new_tokens``): a slot that exhausts its budget retires in
    place — its token freezes (repeated in the output tail), its cache
    region stops being written, and an all-retired step stops contributing
    telemetry.
    ``max_cache_len`` — optional decode-cache length override (the
    scheduler passes one shared length so every prompt bucket reuses the
    same compiled decode program).
    """
    S = (prompt_batch["tokens"].shape[1] if "tokens" in prompt_batch
         else prompt_batch["embeds"].shape[1])
    B = jax.tree.leaves(prompt_batch)[0].shape[0]
    max_len = max_cache_len or (S + scfg.max_new_tokens + 1)
    assert max_len >= S + scfg.max_new_tokens + 1, (max_len, S, scfg)

    # per-slot (vectorized) decode is engaged only when a caller asks for it
    # (pad-mask prefill / per-slot budgets) or under a mesh (per-slot vectors
    # shard; scalars would have to be replicated-and-broadcast anyway).  The
    # default path keeps the scalar position index: one dynamic_update_slice
    # cache write instead of a per-row scatter, and encdec (whisper) decode
    # — which has no per-slot plumbing — keeps working.
    vec = (prompt_lens is not None or slot_new_tokens is not None
           or mesh is not None)
    if cfg.family == "encdec":
        assert not vec, ("per-slot decode (prompt_lens / slot_new_tokens / "
                         "mesh) is not supported for encdec models")

    pl = (None if prompt_lens is None
          else jnp.asarray(prompt_lens, jnp.int32).reshape(B))
    t0 = time.perf_counter()
    with obs.span("prefill", cat="engine", batch=B, seq=S):
        logits, cache = prefill(params, prompt_batch, cfg, par,
                                max_cache_len=max_len, prompt_lens=pl)
        key = jax.random.PRNGKey(scfg.seed)
        sample = _sampler(scfg)
        if pl is None:
            tok = sample(logits, key)
        else:
            # pad-mask path: the next token conditions on the last REAL prompt
            # position, not the pad tail
            tok = sample(logits[jnp.arange(B), pl - 1][:, None], key)
    _PREFILL_WALL.observe(time.perf_counter() - t0)
    n_steps = scfg.max_new_tokens - 1
    if vec:
        pos0 = pl if pl is not None else jnp.full((B,), S, jnp.int32)
        budget = (jnp.full((B,), n_steps, jnp.int32)
                  if slot_new_tokens is None
                  else jnp.asarray(slot_new_tokens, jnp.int32).reshape(B) - 1)
    else:
        pos0, budget = jnp.int32(S), None      # scalar legacy path

    if budget is not None:
        # retirement accounting: a slot whose budget sits below the full
        # generation length WILL freeze before the scan end (host-known —
        # the done-flag math is deterministic in slot_new_tokens)
        _SLOTS_RETIRED.inc(int(np.sum(np.asarray(budget) < n_steps)))

    if adaptive is None and param_hook is None and scfg.fused:
        assert mesh is None, "mesh= requires the adaptive fused path"
        path, run = "fused", lambda: _generate_fused(
            params, cache, tok, key, pos0, budget, cfg, scfg, par)
    elif adaptive is not None and param_hook is None and scfg.fused:
        path, run = "fused_adaptive", lambda: _generate_fused_adaptive(
            params, cache, tok, key, pos0, budget, B, cfg, scfg, par,
            adaptive, mesh)
    else:
        assert mesh is None, \
            "mesh= requires the adaptive fused path (no param_hook)"
        path, run = "stepwise", lambda: _generate_stepwise(
            params, cache, tok, key, pos0, budget, cfg, scfg, par, adaptive,
            param_hook)
    t0 = time.perf_counter()
    with obs.span("decode", cat="engine", path=path, batch=B,
                  steps=scfg.max_new_tokens):
        out = run()
    _DECODE_WALL.observe(time.perf_counter() - t0, path=path)
    _DECODE_TOKENS.inc(B * scfg.max_new_tokens)
    return out


@functools.lru_cache(maxsize=64)
def _fused_decode_fn(cfg, par, n_steps: int, temperature: float,
                     vectorized: bool = False):
    """Build (and cache) the jitted whole-loop decode scan.  Keyed on the
    hashable configs so repeated ``generate`` calls reuse the compiled
    program.  The scalar variant takes one traced ``start`` index (the
    pre-PR5 program: one dynamic_update_slice cache write per step); the
    ``vectorized`` variant takes per-slot (B,) positions and token budgets
    as traced vectors, so retired slots freeze without a branch."""
    obs.count_retrace("fused")          # lru miss == new compiled program
    scfg = ServeConfig(temperature=temperature)
    sample = _sampler(scfg)

    if not vectorized:
        @jax.jit
        def decode_scan(params, cache, tok0, key0, start):
            def step(carry, i):
                tok, cache, key = carry
                key, sub = jax.random.split(key)
                logits, cache = decode_step(params, cache, tok[:, None],
                                            start + i, cfg, par)
                tok = sample(logits, sub)
                return (tok, cache, key), tok

            (_, _, _), toks = jax.lax.scan(
                step, (tok0, cache, key0),
                jnp.arange(n_steps, dtype=jnp.int32))
            return toks                               # (n_steps, B)

        return decode_scan

    @jax.jit
    def decode_scan(params, cache, tok0, key0, pos0, budget):
        def step(carry, i):
            tok, cache, key, pos = carry
            key, sub = jax.random.split(key)
            active = i < budget                        # (B,) done-flags
            logits, cache = decode_step(params, cache, tok[:, None],
                                        pos, cfg, par, write_mask=active)
            tok = jnp.where(active, sample(logits, sub), tok)
            pos = pos + active.astype(jnp.int32)
            return (tok, cache, key, pos), tok

        (_, _, _, _), toks = jax.lax.scan(
            step, (tok0, cache, key0, pos0),
            jnp.arange(n_steps, dtype=jnp.int32))
        return toks                                   # (n_steps, B)

    return decode_scan


def _generate_fused(params, cache, tok, key, pos0, budget, cfg,
                    scfg: ServeConfig, par):
    """The whole decode loop (step + sample) as one on-device ``lax.scan``.
    ``budget is None`` selects the scalar (pre-PR5) program."""
    n_steps = scfg.max_new_tokens - 1
    if n_steps <= 0:
        return tok[:, None]
    decode_scan = _fused_decode_fn(cfg, par, n_steps, scfg.temperature,
                                   vectorized=budget is not None)
    if budget is None:
        toks = decode_scan(params, cache, tok, key, pos0)
    else:
        toks = decode_scan(params, cache, tok, key, pos0, budget)
    return jnp.concatenate([tok[:, None], jnp.swapaxes(toks, 0, 1)], axis=1)


# adaptive fused-decode program cache: (cfg, par, n_steps, temperature,
# k_obs, mesh, cache treedef, batch, tile_rows) -> jitted scan.  Policy
# values are traced inputs, so every policy update and every wave of a
# fixed-shape scheduler bucket reuses one entry (tests assert
# _cache_size() == 1).
_ADAPTIVE_FNS = {}


def _adaptive_decode_fn(cfg, par, n_steps: int, temperature: float,
                        k_obs: int, mesh, cache, batch: int,
                        tile_rows: int = 0, vectorized: bool = False):
    """Build (and cache) the fused adaptive decode: one ``lax.scan`` over the
    token loop with telemetry threaded through the scan carry, optionally
    shard_map'd over the mesh batch axes with in-graph record aggregation.

    ``tile_rows > 0`` is the per-row-tile mode: the dyn-tree leaves are
    (tile_rows, 1, 3) config grids, the scopes additionally emit per-tile
    records (they ride the same scan-carry slots — just more record
    fields), and the compiled program is keyed on the granularity, so
    scalar and tile policies each compile once and re-tunes never retrace
    either.

    ``vectorized`` (always on under a mesh) switches from the scalar
    ``start`` index to per-slot (B,) positions and budgets plus a
    *replicated* ``bmax`` scalar: the observe gate is ``(i % k_obs == 0) &
    (i < bmax)`` — equal to "any slot still live" but computed from the
    global budget maximum, so it is identical on every shard (a per-shard
    ``any(active)`` would let a fully-retired shard drop out of the psum
    while the single-host oracle still counts its frozen slots)."""
    treedef = jax.tree_util.tree_structure(cache)
    key = (cfg, par, n_steps, temperature, k_obs, mesh, treedef, batch,
           tile_rows, vectorized)
    if key in _ADAPTIVE_FNS:
        return _ADAPTIVE_FNS[key]
    obs.count_retrace("fused_adaptive")   # cache miss == new compiled program

    from repro.runtime import ax_scope

    # telemetry records must be fixed-shape scan-carry leaves: the layer
    # stack is unrolled inside the token-scan body (as in the stepwise path)
    dec_par = dataclasses.replace(par or ParallelConfig(), scan_layers=False)
    sample = _sampler(ServeConfig(temperature=temperature))
    n_obs = -(-n_steps // k_obs)          # carry slots: one per gated step

    if mesh is not None:
        assert vectorized, "the sharded adaptive decode is the vectorized one"
        from repro.fleet.collect import aggregate_records, shard_decode_specs, shard_map

        in_specs, out_specs, axes = shard_decode_specs(cache, batch, mesh)
    else:
        axes = ()

    def _probe_bufs(params, cache, tok0, pos0, dyn):
        def probe(params, cache, tok0, pos0, dyn):
            with ax_scope(dyn, collect=True, tile_rows=tile_rows) as sc:
                decode_step(params, cache, tok0[:, None], pos0, cfg, dec_par)
                return sc.collected()

        shapes = jax.eval_shape(probe, params, cache, tok0, pos0, dyn)
        return jax.tree.map(
            lambda s: jnp.zeros((n_obs,) + s.shape, s.dtype), shapes)

    if not vectorized:
        def decode_scan(params, cache, tok0, key0, start, dyn):
            bufs0 = _probe_bufs(params, cache, tok0, start, dyn)

            def step(carry, i):
                tok, cache, key, bufs = carry
                key, sub = jax.random.split(key)
                gate = (i % k_obs) == 0
                with ax_scope(dyn, collect=True, gate=gate,
                              tile_rows=tile_rows) as sc:
                    logits, cache = decode_step(params, cache, tok[:, None],
                                                start + i, cfg, dec_par)
                    telem = sc.collected()
                tok = sample(logits, sub)
                # off-steps produced lax.cond zeros, so the unconditional
                # scatter-add leaves exactly the gated step's record in its
                # slot
                bufs = jax.tree.map(lambda b, r: b.at[i // k_obs].add(r),
                                    bufs, telem)
                return (tok, cache, key, bufs), tok

            (_, _, _, bufs), toks = jax.lax.scan(
                step, (tok0, cache, key0, bufs0),
                jnp.arange(n_steps, dtype=jnp.int32))
            return toks, bufs                   # (n_steps, B), slot records
    else:
        def decode_scan(params, cache, tok0, key0, pos0, budget, bmax, dyn):
            bufs0 = _probe_bufs(params, cache, tok0, pos0, dyn)

            def step(carry, i):
                tok, cache, key, pos, bufs = carry
                key, sub = jax.random.split(key)
                active = i < budget              # (B,) per-slot done-flags
                # shard-invariant live gate (see docstring): bmax is the
                # global budget max, replicated under the mesh
                gate = ((i % k_obs) == 0) & (i < bmax)
                with ax_scope(dyn, collect=True, gate=gate,
                              tile_rows=tile_rows) as sc:
                    logits, cache = decode_step(params, cache, tok[:, None],
                                                pos, cfg, dec_par,
                                                write_mask=active)
                    telem = sc.collected()
                tok = jnp.where(active, sample(logits, sub), tok)
                pos = pos + active.astype(jnp.int32)
                bufs = jax.tree.map(lambda b, r: b.at[i // k_obs].add(r),
                                    bufs, telem)
                return (tok, cache, key, pos, bufs), tok

            (_, _, _, _, bufs), toks = jax.lax.scan(
                step, (tok0, cache, key0, pos0, bufs0),
                jnp.arange(n_steps, dtype=jnp.int32))
            bufs = aggregate_records(bufs, axes) if axes else bufs
            return toks, bufs                   # (n_steps, B), slot records

    if mesh is not None:
        decode_scan = shard_map(decode_scan, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=False)
    fn = jax.jit(decode_scan)
    _ADAPTIVE_FNS[key] = fn
    return fn


def _generate_fused_adaptive(params, cache, tok, key, pos0, budget, B, cfg,
                             scfg: ServeConfig, par, adaptive, mesh):
    """Whole adaptive decode loop as one dispatch: run the telemetry-carrying
    scan, then fold each observed slot's fleet record into the controller (in
    step order, matching the stepwise loop's observe sequence)."""
    n_steps = scfg.max_new_tokens - 1
    if n_steps <= 0:
        return tok[:, None]
    k_obs = max(1, int(scfg.observe_every))
    fn = _adaptive_decode_fn(cfg, par, n_steps, scfg.temperature, k_obs,
                             mesh, cache, B,
                             tile_rows=getattr(adaptive, "tile_rows", 0),
                             vectorized=budget is not None)
    if budget is None:
        toks, bufs = fn(params, cache, tok, key, pos0, adaptive.dyn_tree())
    else:
        toks, bufs = fn(params, cache, tok, key, pos0, budget,
                        jnp.max(budget), adaptive.dyn_tree())
    out = jnp.concatenate([tok[:, None], jnp.swapaxes(toks, 0, 1)], axis=1)
    bufs = jax.device_get(bufs)
    for j in range(-(-n_steps // k_obs)):
        adaptive.observe({t: {k: v[j] for k, v in rec.items()}
                          for t, rec in bufs.items()})
    return out


def _generate_stepwise(params, cache, tok, key, pos0, budget, cfg,
                       scfg: ServeConfig, par, adaptive, param_hook):
    """One host-dispatched decode step per token: the adaptive/telemetry path
    and the ``param_hook`` path (also the fused paths' correctness oracle).
    ``budget is None`` is the scalar (pre-PR5) loop; otherwise positions,
    done-flags and gated cache writes mirror the vectorized scans exactly
    (bit-identical tokens and telemetry, including the ``i < max(budget)``
    observe gate)."""
    out = [tok]
    vec = budget is not None

    if adaptive is None:
        step_fn = jax.jit(lambda p, c, t, i, m: decode_step(
            p, c, t, i, cfg, par, write_mask=m))
    else:
        from repro.runtime import ax_scope

        # telemetry records are per-projection-call outputs of the compiled
        # step; under lax.scan over layers they would be stuck inside the scan
        # body, so the adaptive decode unrolls the (short) period stack.
        # Routing per-layer telemetry through scan carries is a ROADMAP
        # follow-on.
        dec_par = dataclasses.replace(par or ParallelConfig(), scan_layers=False)
        tile_rows = getattr(adaptive, "tile_rows", 0)

        def _adaptive_step(p, c, t, i, m, dyn, gate):
            with ax_scope(dyn, collect=True, gate=gate,
                          tile_rows=tile_rows) as sc:
                logits, new_cache = decode_step(p, c, t, i, cfg, dec_par,
                                                write_mask=m)
                return logits, new_cache, sc.collected()

        step_fn = jax.jit(_adaptive_step)

    sample = _sampler(scfg)
    k_obs = max(1, int(scfg.observe_every))
    budget_np = np.asarray(budget) if vec else None
    pos = pos0
    pending = None   # one-step-stale observe: fetch step i-1's telemetry only
    for i in range(scfg.max_new_tokens - 1):   # after step i is dispatched, so
        key, sub = jax.random.split(key)       # async dispatch stays pipelined
        if param_hook is not None:
            params = param_hook(i, params)
        if vec:
            active_np = i < budget_np          # (B,) host-known done-flags
            active = jnp.asarray(active_np)
            alive = bool(i < budget_np.max())  # == the scans' i < bmax gate
        else:
            active, alive = None, True
        idx = pos if vec else jnp.int32(pos + i)
        if adaptive is None:
            logits, cache = step_fn(params, cache, tok[:, None], idx, active)
        else:
            gate = (i % k_obs == 0) and alive
            logits, cache, telem = step_fn(
                params, cache, tok[:, None], idx, active,
                adaptive.dyn_tree(), jnp.bool_(gate)
            )
            if pending is not None:
                adaptive.observe(jax.device_get(pending))
                pending = None
            if gate:       # off-steps produced zero records (lax.cond) —
                pending = telem   # never surface them to the controller
        if vec:
            tok = jnp.where(active, sample(logits, sub), tok)
            pos = pos + active.astype(jnp.int32)
        else:
            tok = sample(logits, sub)
        out.append(tok)
    if pending is not None:
        adaptive.observe(jax.device_get(pending))
    return jnp.stack(out, axis=1)


# ---------------------------------------------------------------------------
# token-granular serving: one compiled per-step decode + per-bucket prefill
# ---------------------------------------------------------------------------

# token-step program cache: (cfg, par, temperature, adaptive?, k_obs-free —
# the gate is a traced bool, mesh, cache treedef, batch, tile_rows) ->
# jitted step.  ONE entry serves the whole trace: mid-flight admissions and
# policy updates change traced values only (tests assert _cache_size() == 1).
_TOKEN_FNS = {}


def _token_step_fn(cfg, par, temperature: float, adaptive: bool, mesh,
                   cache, batch: int, tile_rows: int = 0):
    """Build (and cache) the jitted token-granular decode step:
    ``(params, cache, tok, sub, pos, active[, dyn, gate]) ->
    (tok', cache'[, telem])``.

    Decode + sampling + per-slot freeze run as one dispatch per token for
    the WHOLE slot batch; ``pos`` is the (B,) per-slot position vector and
    ``active`` the (B,) done-flags (False slots keep their token, skip
    their cache write, and — all-False — skip the telemetry summary).
    Under ``mesh`` the step is shard_map'd over the mesh batch axes with
    in-graph telemetry aggregation, exactly like the fused adaptive scan.
    """
    treedef = jax.tree_util.tree_structure(cache)
    fkey = (cfg, par, temperature, adaptive, mesh, treedef, batch, tile_rows)
    if fkey in _TOKEN_FNS:
        return _TOKEN_FNS[fkey]
    obs.count_retrace("token_step")       # cache miss == new compiled program

    sample = _sampler(ServeConfig(temperature=temperature))
    if mesh is not None:
        from repro.fleet.collect import (aggregate_records, shard_map,
                                         token_step_specs)

        in_specs, out_specs, axes = token_step_specs(cache, batch, mesh)
    else:
        axes = ()

    if adaptive:
        from repro.runtime import ax_scope

        dec_par = dataclasses.replace(par or ParallelConfig(),
                                      scan_layers=False)

        # the host only steps a batch with >= 1 live slot (the scheduler's
        # drain loop), so `gate` alone is the full observe condition — and
        # unlike an in-graph any(active) it is identical on every shard
        def step(params, cache, tok, sub, pos, active, dyn, gate):
            with ax_scope(dyn, collect=True, gate=gate,
                          tile_rows=tile_rows) as sc:
                logits, cache = decode_step(params, cache, tok[:, None],
                                            pos, cfg, dec_par,
                                            write_mask=active)
                telem = sc.collected()
            tok = jnp.where(active, sample(logits, sub), tok)
            telem = aggregate_records(telem, axes) if axes else telem
            return tok, cache, telem
    else:
        assert mesh is None, "mesh= requires the adaptive token step"

        def step(params, cache, tok, sub, pos, active):
            logits, cache = decode_step(params, cache, tok[:, None], pos,
                                        cfg, par, write_mask=active)
            return jnp.where(active, sample(logits, sub), tok), cache

    if mesh is not None:
        step = shard_map(step, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    fn = jax.jit(step)
    _TOKEN_FNS[fkey] = fn
    return fn


def token_step(params, cache, tok, sub, pos, active, cfg: ModelConfig,
               par: Optional[ParallelConfig] = None, *, temperature: float = 0.0,
               adaptive=None, mesh=None, gate=True):
    """One token-granular decode step (see :func:`_token_step_fn`).

    Returns ``(tok', cache')`` — plus the telemetry record tree when
    ``adaptive`` is attached (pass it to ``adaptive.observe`` after a
    ``device_get``; off-``gate`` steps return lax.cond zeros that must not
    reach the controller, mirroring the stepwise loop).
    """
    B = int(tok.shape[0])
    fn = _token_step_fn(cfg, par, temperature, adaptive is not None, mesh,
                        cache, B, tile_rows=getattr(adaptive, "tile_rows", 0))
    if adaptive is None:
        return fn(params, cache, tok, sub, pos, active)
    return fn(params, cache, tok, sub, pos, active, adaptive.dyn_tree(),
              jnp.bool_(gate))


@functools.lru_cache(maxsize=64)
def _prefill_one_fn(cfg, par, bucket: int, max_cache_len: int,
                    temperature: float):
    """Jitted single-request prefill for one prompt bucket: pad-masked
    forward, first token sampled at the last real position, cache padded to
    the shared ``max_cache_len`` so it splices straight into any slot of
    the token-granular decode cache."""
    obs.count_retrace("prefill")        # lru miss == new compiled program
    sample = _sampler(ServeConfig(temperature=temperature))

    @jax.jit
    def fn(params, toks, lens, key):
        logits, cache = prefill(params, {"tokens": toks}, cfg, par,
                                max_cache_len=max_cache_len,
                                prompt_lens=lens)
        lg = logits[jnp.arange(toks.shape[0]), lens - 1][:, None]
        return sample(lg, key), cache

    return fn


def prefill_one(params, tokens, length: int, cfg: ModelConfig,
                par: Optional[ParallelConfig] = None, *, max_cache_len: int,
                temperature: float = 0.0, key=None):
    """Prefill ONE padded request ``tokens`` (1, bucket) with real length
    ``length``; returns ``(first_token (1,), cache)`` with the cache padded
    to ``max_cache_len``.  Compiled once per prompt bucket."""
    fn = _prefill_one_fn(cfg, par, int(tokens.shape[1]), int(max_cache_len),
                         temperature)
    if key is None:
        key = jax.random.PRNGKey(0)
    return fn(params, jnp.asarray(tokens, jnp.int32),
              jnp.asarray([length], jnp.int32), key)


def splice_slot(cache, fresh, slot):
    """Write single-request decode-cache ``fresh`` (batch dim 1) into row
    ``slot`` of the slot-batched ``cache`` — the mid-flight admission
    splice.  The batch dim is axis 1 for scan-stacked ``stack/`` leaves and
    axis 0 elsewhere (same layout rule as ``fleet.collect.cache_pspecs``);
    ``slot`` is traced, so one compiled program serves every slot."""

    def one(path, big, small):
        bdim = 1 if (path and getattr(path[0], "key", None) == "stack") else 0
        start = [jnp.int32(0)] * big.ndim
        start[bdim] = jnp.asarray(slot, jnp.int32)
        return jax.lax.dynamic_update_slice(big, small.astype(big.dtype),
                                            tuple(start))

    return jax.tree_util.tree_map_with_path(one, cache, fresh)


_SPLICE_FN = jax.jit(splice_slot)


def splice_slot_jit(cache, fresh, slot):
    """Jitted :func:`splice_slot` (one program per cache treedef)."""
    return _SPLICE_FN(cache, fresh, jnp.int32(slot))
