"""Batched serving loop: prefill once, then greedy/temperature decode steps
against the sharded KV cache."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import decode_step, prefill

__all__ = ["ServeConfig", "generate"]


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 => greedy
    seed: int = 0


def generate(params, prompt_batch, cfg: ModelConfig, scfg: ServeConfig,
             par: Optional[ParallelConfig] = None):
    """prompt_batch: {'tokens': (B, S)} (or family-specific prefill inputs).
    Returns (B, max_new_tokens) int32."""
    S = (prompt_batch["tokens"].shape[1] if "tokens" in prompt_batch
         else prompt_batch["embeds"].shape[1])
    B = jax.tree.leaves(prompt_batch)[0].shape[0]
    max_len = S + scfg.max_new_tokens + 1

    logits, cache = prefill(params, prompt_batch, cfg, par, max_cache_len=max_len)
    key = jax.random.PRNGKey(scfg.seed)

    def sample(logits, key):
        lg = logits[:, -1].astype(jnp.float32)
        if scfg.temperature > 0:
            return jax.random.categorical(key, lg / scfg.temperature, axis=-1)
        return jnp.argmax(lg, axis=-1)

    tok = sample(logits, key)
    out = [tok]
    step_fn = jax.jit(
        lambda p, c, t, i: decode_step(p, c, t, i, cfg, par),
        static_argnames=(),
    )
    for i in range(scfg.max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, cache = step_fn(params, cache, tok[:, None], jnp.int32(S + i))
        tok = sample(logits, sub)
        out.append(tok)
    return jnp.stack(out, axis=1)
