"""Batched serving loop: prefill once, then greedy/temperature decode steps
against the sharded KV cache.

The non-adaptive hot path is **fully fused on device**: the whole token loop
(decode step + sampling + cache update) runs as one ``lax.scan``, so serving
``T`` tokens costs one dispatch instead of ``T`` host round-trips.  The
Python step loop is kept (``ServeConfig.fused=False``, or automatically when
a ``param_hook`` needs to mutate params mid-generation) and produces
bit-identical token sequences — the scan body performs the exact same ops in
the same order, including the RNG splits.

With an :class:`~repro.runtime.AdaptiveController` attached, the decode step
is compiled **once** with the SWAPPER config as a traced input and telemetry
summaries as extra outputs; each step the controller folds the telemetry in,
scores distribution drift, and re-tunes the policy in place — the jit cache
stays warm throughout (zero recompilations; see runtime/).  Telemetry is
decimated by ``ServeConfig.observe_every``: the observe gate enters the
compiled step as a traced boolean, so off-steps skip the summary compute
(``lax.cond``) *and* the host-side device_get without retracing anything.

**Adaptive decode is also fused** (``ServeConfig.fused=True``, no
``param_hook``): the whole adaptive token loop runs as one ``lax.scan`` with
the per-step telemetry records threaded through the scan carry — each gated
step scatter-adds its fixed-shape record into slot ``i // observe_every`` of
a ``ceil(T/k)``-slot carry buffer (off-steps contribute ``lax.cond`` zeros),
so adaptive serving pays **one dispatch per generation** and the host folds
the slot records into the controller afterwards.  The policy is therefore
frozen within a generation; re-tunes land between generations (the stepwise
loop remains for mid-generation adaptation and ``param_hook``).

With ``mesh=...`` the fused adaptive scan additionally runs under
``shard_map`` over the mesh batch axes: every shard decodes its batch slice
and the telemetry records are ``psum``/``pmax``/all-gathered **in-graph**
(``fleet.collect``) before leaving the trace, so one controller sees the
fleet-global operand distribution.

When the controller (or ``fleet.PolicyReader``) reports ``tile_rows > 0``,
decode runs **per-row-tile**: the policy enters as (tile_rows, 1, 3) config
grids instead of scalar triples, every projection additionally emits a
per-tile telemetry record (same scan-carry slots, same gate), and published
``SwapPolicy.tile_grids`` land in the compiled step as new traced int32
values — tile-granular adaptation with zero recompiles, exactly like the
scalar path (see docs/architecture.md).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import decode_step, prefill

__all__ = ["ServeConfig", "generate"]


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 => greedy
    seed: int = 0
    fused: bool = True         # on-device lax.scan decode (non-adaptive path)
    observe_every: int = 1     # adaptive telemetry decimation period (k >= 1)


def _sampler(scfg: ServeConfig):
    def sample(logits, key):
        lg = logits[:, -1].astype(jnp.float32)
        if scfg.temperature > 0:
            return jax.random.categorical(key, lg / scfg.temperature, axis=-1)
        return jnp.argmax(lg, axis=-1)

    return sample


def generate(params, prompt_batch, cfg: ModelConfig, scfg: ServeConfig,
             par: Optional[ParallelConfig] = None, adaptive=None,
             param_hook: Optional[Callable] = None, mesh=None):
    """prompt_batch: {'tokens': (B, S)} (or family-specific prefill inputs).
    Returns (B, max_new_tokens) int32.

    ``adaptive`` — optional AdaptiveController (or ``fleet.PolicyReader``)
    driving the dynamic SWAPPER policy for ``cfg.ax.targets`` projections
    during decode.
    ``param_hook(step, params) -> params`` — optional per-step parameter
    transform (used by the serve driver to inject synthetic distribution
    drift; values change, shapes don't, so the step is not retraced).  A hook
    forces the stepwise Python loop (params must change between steps).
    ``mesh`` — optional device mesh for the fleet path: the fused adaptive
    decode shards its batch over the mesh batch axes under ``shard_map`` and
    telemetry is aggregated in-graph (requires ``adaptive`` and
    ``scfg.fused``; greedy decoding is bit-identical to the single-host run,
    temperature sampling draws per-shard).
    """
    S = (prompt_batch["tokens"].shape[1] if "tokens" in prompt_batch
         else prompt_batch["embeds"].shape[1])
    B = jax.tree.leaves(prompt_batch)[0].shape[0]
    max_len = S + scfg.max_new_tokens + 1

    logits, cache = prefill(params, prompt_batch, cfg, par, max_cache_len=max_len)
    key = jax.random.PRNGKey(scfg.seed)
    sample = _sampler(scfg)
    tok = sample(logits, key)

    if adaptive is None and param_hook is None and scfg.fused:
        assert mesh is None, "mesh= requires the adaptive fused path"
        return _generate_fused(params, cache, tok, key, S, cfg, scfg, par)
    if adaptive is not None and param_hook is None and scfg.fused:
        return _generate_fused_adaptive(params, cache, tok, key, S, B, cfg,
                                        scfg, par, adaptive, mesh)
    assert mesh is None, "mesh= requires the adaptive fused path (no param_hook)"
    return _generate_stepwise(params, cache, tok, key, S, cfg, scfg, par,
                              adaptive, param_hook)


@functools.lru_cache(maxsize=64)
def _fused_decode_fn(cfg, par, n_steps: int, temperature: float):
    """Build (and cache) the jitted whole-loop decode scan.  Keyed on the
    hashable configs so repeated ``generate`` calls reuse the compiled
    program; the prompt length enters as a traced ``start`` index, so prompt
    shape changes retrace only via ``prefill``/cache shapes."""
    scfg = ServeConfig(temperature=temperature)
    sample = _sampler(scfg)

    @jax.jit
    def decode_scan(params, cache, tok0, key0, start):
        def step(carry, i):
            tok, cache, key = carry
            key, sub = jax.random.split(key)
            logits, cache = decode_step(params, cache, tok[:, None],
                                        start + i, cfg, par)
            tok = sample(logits, sub)
            return (tok, cache, key), tok

        (_, _, _), toks = jax.lax.scan(
            step, (tok0, cache, key0), jnp.arange(n_steps, dtype=jnp.int32))
        return toks                                   # (n_steps, B)

    return decode_scan


def _generate_fused(params, cache, tok, key, S, cfg, scfg: ServeConfig, par):
    """The whole decode loop (step + sample) as one on-device ``lax.scan``."""
    n_steps = scfg.max_new_tokens - 1
    if n_steps <= 0:
        return tok[:, None]
    decode_scan = _fused_decode_fn(cfg, par, n_steps, scfg.temperature)
    toks = decode_scan(params, cache, tok, key, jnp.int32(S))
    return jnp.concatenate([tok[:, None], jnp.swapaxes(toks, 0, 1)], axis=1)


# adaptive fused-decode program cache: (cfg, par, n_steps, temperature,
# k_obs, mesh, cache treedef, batch, tile_rows) -> jitted scan.  Policy
# values are traced inputs, so every policy update and every wave of a
# fixed-shape scheduler bucket reuses one entry (tests assert
# _cache_size() == 1).
_ADAPTIVE_FNS = {}


def _adaptive_decode_fn(cfg, par, n_steps: int, temperature: float,
                        k_obs: int, mesh, cache, batch: int,
                        tile_rows: int = 0):
    """Build (and cache) the fused adaptive decode: one ``lax.scan`` over the
    token loop with telemetry threaded through the scan carry, optionally
    shard_map'd over the mesh batch axes with in-graph record aggregation.

    ``tile_rows > 0`` is the per-row-tile mode: the dyn-tree leaves are
    (tile_rows, 1, 3) config grids, the scopes additionally emit per-tile
    records (they ride the same scan-carry slots — just more record
    fields), and the compiled program is keyed on the granularity, so
    scalar and tile policies each compile once and re-tunes never retrace
    either."""
    treedef = jax.tree_util.tree_structure(cache)
    key = (cfg, par, n_steps, temperature, k_obs, mesh, treedef, batch,
           tile_rows)
    if key in _ADAPTIVE_FNS:
        return _ADAPTIVE_FNS[key]

    from repro.runtime import ax_scope

    # telemetry records must be fixed-shape scan-carry leaves: the layer
    # stack is unrolled inside the token-scan body (as in the stepwise path)
    dec_par = dataclasses.replace(par or ParallelConfig(), scan_layers=False)
    sample = _sampler(ServeConfig(temperature=temperature))
    n_obs = -(-n_steps // k_obs)          # carry slots: one per gated step

    if mesh is not None:
        from repro.fleet.collect import aggregate_records, shard_decode_specs, shard_map

        in_specs, out_specs, axes = shard_decode_specs(cache, batch, mesh)
    else:
        axes = ()

    def decode_scan(params, cache, tok0, key0, start, dyn):
        def probe(params, cache, tok0, start, dyn):
            with ax_scope(dyn, collect=True, tile_rows=tile_rows) as sc:
                decode_step(params, cache, tok0[:, None], start, cfg, dec_par)
                return sc.collected()

        shapes = jax.eval_shape(probe, params, cache, tok0, start, dyn)
        bufs0 = jax.tree.map(
            lambda s: jnp.zeros((n_obs,) + s.shape, s.dtype), shapes)

        def step(carry, i):
            tok, cache, key, bufs = carry
            key, sub = jax.random.split(key)
            gate = (i % k_obs) == 0
            with ax_scope(dyn, collect=True, gate=gate,
                          tile_rows=tile_rows) as sc:
                logits, cache = decode_step(params, cache, tok[:, None],
                                            start + i, cfg, dec_par)
                telem = sc.collected()
            tok = sample(logits, sub)
            # off-steps produced lax.cond zeros, so the unconditional
            # scatter-add leaves exactly the gated step's record in its slot
            bufs = jax.tree.map(lambda b, r: b.at[i // k_obs].add(r),
                                bufs, telem)
            return (tok, cache, key, bufs), tok

        (_, _, _, bufs), toks = jax.lax.scan(
            step, (tok0, cache, key0, bufs0),
            jnp.arange(n_steps, dtype=jnp.int32))
        bufs = aggregate_records(bufs, axes) if axes else bufs
        return toks, bufs                       # (n_steps, B), slot records

    if mesh is not None:
        decode_scan = shard_map(decode_scan, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, check_rep=False)
    fn = jax.jit(decode_scan)
    _ADAPTIVE_FNS[key] = fn
    return fn


def _generate_fused_adaptive(params, cache, tok, key, S, B, cfg,
                             scfg: ServeConfig, par, adaptive, mesh):
    """Whole adaptive decode loop as one dispatch: run the telemetry-carrying
    scan, then fold each observed slot's fleet record into the controller (in
    step order, matching the stepwise loop's observe sequence)."""
    n_steps = scfg.max_new_tokens - 1
    if n_steps <= 0:
        return tok[:, None]
    k_obs = max(1, int(scfg.observe_every))
    fn = _adaptive_decode_fn(cfg, par, n_steps, scfg.temperature, k_obs,
                             mesh, cache, B,
                             tile_rows=getattr(adaptive, "tile_rows", 0))
    toks, bufs = fn(params, cache, tok, key, jnp.int32(S), adaptive.dyn_tree())
    out = jnp.concatenate([tok[:, None], jnp.swapaxes(toks, 0, 1)], axis=1)
    bufs = jax.device_get(bufs)
    for j in range(-(-n_steps // k_obs)):
        adaptive.observe({t: {k: v[j] for k, v in rec.items()}
                          for t, rec in bufs.items()})
    return out


def _generate_stepwise(params, cache, tok, key, S, cfg, scfg: ServeConfig, par,
                       adaptive, param_hook):
    """One host-dispatched decode step per token: the adaptive/telemetry path
    and the ``param_hook`` path (also the fused path's correctness oracle)."""
    out = [tok]

    if adaptive is None:
        step_fn = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg, par))
    else:
        from repro.runtime import ax_scope

        # telemetry records are per-projection-call outputs of the compiled
        # step; under lax.scan over layers they would be stuck inside the scan
        # body, so the adaptive decode unrolls the (short) period stack.
        # Routing per-layer telemetry through scan carries is a ROADMAP
        # follow-on.
        dec_par = dataclasses.replace(par or ParallelConfig(), scan_layers=False)
        tile_rows = getattr(adaptive, "tile_rows", 0)

        def _adaptive_step(p, c, t, i, dyn, gate):
            with ax_scope(dyn, collect=True, gate=gate,
                          tile_rows=tile_rows) as sc:
                logits, new_cache = decode_step(p, c, t, i, cfg, dec_par)
                return logits, new_cache, sc.collected()

        step_fn = jax.jit(_adaptive_step)

    sample = _sampler(scfg)
    k_obs = max(1, int(scfg.observe_every))
    pending = None   # one-step-stale observe: fetch step i-1's telemetry only
    for i in range(scfg.max_new_tokens - 1):   # after step i is dispatched, so
        key, sub = jax.random.split(key)       # async dispatch stays pipelined
        if param_hook is not None:
            params = param_hook(i, params)
        if adaptive is None:
            logits, cache = step_fn(params, cache, tok[:, None], jnp.int32(S + i))
        else:
            gate = (i % k_obs == 0)
            logits, cache, telem = step_fn(
                params, cache, tok[:, None], jnp.int32(S + i),
                adaptive.dyn_tree(), jnp.bool_(gate)
            )
            if pending is not None:
                adaptive.observe(jax.device_get(pending))
                pending = None
            if gate:       # off-steps produced zero records (lax.cond) —
                pending = telem   # never surface them to the controller
        tok = sample(logits, sub)
        out.append(tok)
    if pending is not None:
        adaptive.observe(jax.device_get(pending))
    return jnp.stack(out, axis=1)
