"""Batched serving loop: prefill once, then greedy/temperature decode steps
against the sharded KV cache.

With an :class:`~repro.runtime.AdaptiveController` attached, the decode step
is compiled **once** with the SWAPPER config as a traced input and telemetry
summaries as extra outputs; each step the controller folds the telemetry in,
scores distribution drift, and re-tunes the policy in place — the jit cache
stays warm throughout (zero recompilations; see runtime/).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import decode_step, prefill

__all__ = ["ServeConfig", "generate"]


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0   # 0 => greedy
    seed: int = 0


def generate(params, prompt_batch, cfg: ModelConfig, scfg: ServeConfig,
             par: Optional[ParallelConfig] = None, adaptive=None,
             param_hook: Optional[Callable] = None):
    """prompt_batch: {'tokens': (B, S)} (or family-specific prefill inputs).
    Returns (B, max_new_tokens) int32.

    ``adaptive`` — optional AdaptiveController driving the dynamic SWAPPER
    policy for ``cfg.ax.targets`` projections during decode.
    ``param_hook(step, params) -> params`` — optional per-step parameter
    transform (used by the serve driver to inject synthetic distribution
    drift; values change, shapes don't, so the step is not retraced).
    """
    S = (prompt_batch["tokens"].shape[1] if "tokens" in prompt_batch
         else prompt_batch["embeds"].shape[1])
    B = jax.tree.leaves(prompt_batch)[0].shape[0]
    max_len = S + scfg.max_new_tokens + 1

    logits, cache = prefill(params, prompt_batch, cfg, par, max_cache_len=max_len)
    key = jax.random.PRNGKey(scfg.seed)

    def sample(logits, key):
        lg = logits[:, -1].astype(jnp.float32)
        if scfg.temperature > 0:
            return jax.random.categorical(key, lg / scfg.temperature, axis=-1)
        return jnp.argmax(lg, axis=-1)

    tok = sample(logits, key)
    out = [tok]

    if adaptive is None:
        step_fn = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg, par))
    else:
        from repro.runtime import ax_scope

        # telemetry records are per-projection-call outputs of the compiled
        # step; under lax.scan over layers they would be stuck inside the scan
        # body, so the adaptive decode unrolls the (short) period stack.
        # Routing per-layer telemetry through scan carries is a ROADMAP
        # follow-on.
        dec_par = dataclasses.replace(par or ParallelConfig(), scan_layers=False)

        def _adaptive_step(p, c, t, i, dyn):
            with ax_scope(dyn, collect=True) as sc:
                logits, new_cache = decode_step(p, c, t, i, cfg, dec_par)
                return logits, new_cache, sc.collected()

        step_fn = jax.jit(_adaptive_step)

    pending = None   # one-step-stale observe: fetch step i-1's telemetry only
    for i in range(scfg.max_new_tokens - 1):   # after step i is dispatched, so
        key, sub = jax.random.split(key)       # async dispatch stays pipelined
        if param_hook is not None:
            params = param_hook(i, params)
        if adaptive is None:
            logits, cache = step_fn(params, cache, tok[:, None], jnp.int32(S + i))
        else:
            logits, cache, telem = step_fn(
                params, cache, tok[:, None], jnp.int32(S + i), adaptive.dyn_tree()
            )
            if pending is not None:
                adaptive.observe(jax.device_get(pending))
            pending = telem
        tok = sample(logits, sub)
        out.append(tok)
    if pending is not None:
        adaptive.observe(jax.device_get(pending))
    return jnp.stack(out, axis=1)
