"""Fleet-scale adaptive serving (DESIGN: shard -> aggregate -> publish).

Makes the online adaptive SWAPPER runtime mesh-native:

  collect   — in-graph cross-host telemetry aggregation: bit-occupancy and
              limb-exact error sums ``psum`` over the mesh batch axes inside
              the shard_map'd decode step, so ONE controller re-tunes from
              the fleet-global operand distribution
  store     — versioned ``PolicyStore``: single-writer / many-reader policy
              JSON with monotonic versions and an atomic CURRENT pointer;
              serve replicas and elastic restarts resume the *adapted*
              policy, never the offline-tuned one
  scheduler — continuous-batching ``ContinuousBatcher``: variable-length
              requests admitted into fixed-shape decode slots with pad-mask
              prefill — wave mode runs one fused adaptive ``lax.scan``
              dispatch per wave, token-granular mode splices the next FIFO
              request into a finished slot mid-flight via per-slot cache
              positions (zero recompiles across waves, splices, policy
              updates, and reader syncs)
  chaos     — deterministic fault-injection harness (``FaultPlan`` /
              ``ChaosHarness``) exercising the recovery paths above: torn
              publishes, corrupt policy JSON, poisoned telemetry, stalled
              steps, replica kills (docs/robustness.md)
"""
from . import chaos
from .chaos import ChaosHarness, FaultPlan, FaultSpec, InjectedFault
from .collect import (
    aggregate_records,
    batch_axis_names,
    make_sharded_summarizer,
    shard_decode_specs,
)
from .scheduler import BatcherConfig, Completion, ContinuousBatcher, Request
from .store import PolicyReader, PolicyStore

__all__ = [
    "aggregate_records",
    "batch_axis_names",
    "make_sharded_summarizer",
    "shard_decode_specs",
    "BatcherConfig",
    "Completion",
    "ContinuousBatcher",
    "Request",
    "PolicyReader",
    "PolicyStore",
    "chaos",
    "ChaosHarness",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
]
