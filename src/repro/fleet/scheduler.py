"""Continuous-batching scheduler: variable-length requests -> fixed-shape
decode slots -> one fused dispatch per wave.

Serving traffic arrives as requests of arbitrary prompt length and token
budget; the compiled fast path (the PR-2 fused ``lax.scan`` decode, now
adaptive and mesh-shardable) wants **fixed shapes**.  The
:class:`ContinuousBatcher` bridges the two:

* requests queue per **prompt bucket** (prompts right-pad to the bucket
  length by repeating their final token — the repo's models carry no
  attention pad-mask, so padding conditions the generation on the padded
  prompt; bucket granularity bounds that overhead and the stats report it);
* each **wave** admits up to ``n_slots`` same-bucket requests FIFO, fills
  idle slots by cycling the admitted prompts (their outputs are discarded),
  and runs ONE fused adaptive dispatch of ``new_token_bucket`` steps for the
  whole slot batch — under a mesh, slots shard over the batch axes and
  telemetry aggregates in-graph;
* every (bucket, token-budget) shape class compiles once; later waves —
  including waves after a policy re-tune or a ``PolicyReader`` sync — reuse
  the compiled program (the policy is traced int32 values).

Slots rebind between waves (wave-granular continuous batching).
Token-granular slot splicing — admitting a fresh request into a mid-flight
batch — needs per-slot cache indices in ``decode_step`` and is a ROADMAP
follow-on.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.serve import ServeConfig, generate

__all__ = ["Request", "Completion", "BatcherConfig", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (L,) int32 prompt
    max_new: int


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # (max_new,) int32 generated
    wave: int
    prompt_len: int
    bucket: int


@dataclasses.dataclass
class BatcherConfig:
    n_slots: int = 8                       # fixed decode batch (mesh-divisible)
    prompt_buckets: Sequence[int] = (16, 32, 64)
    new_token_bucket: int = 16             # fused scan length per wave
    observe_every: int = 1                 # telemetry decimation inside the scan
    temperature: float = 0.0
    seed: int = 0


class ContinuousBatcher:
    """Admission + wave execution over the fused adaptive decode.

    ``adaptive`` is either the fleet's re-tuning
    :class:`~repro.runtime.AdaptiveController` (the single store writer) or a
    replica-side :class:`~repro.fleet.store.PolicyReader` (synced before each
    wave); ``None`` serves the static policy through the non-adaptive fused
    scan (single-host only: the engine's sharded path is the adaptive scan,
    so ``mesh`` requires ``adaptive``).  ``mesh`` shards each wave's slots
    over the mesh batch axes.
    """

    def __init__(self, params, cfg: ModelConfig, bcfg: Optional[BatcherConfig] = None,
                 adaptive=None, mesh=None, par: Optional[ParallelConfig] = None):
        assert mesh is None or adaptive is not None, (
            "ContinuousBatcher: mesh= requires an adaptive controller/reader "
            "(the sharded decode program is the adaptive scan)")
        self.params = params
        self.cfg = cfg
        self.bcfg = bcfg or BatcherConfig()
        self.adaptive = adaptive
        self.mesh = mesh
        self.par = par
        self.queues: Dict[int, collections.deque] = {
            b: collections.deque() for b in sorted(self.bcfg.prompt_buckets)
        }
        self.wave = 0
        self._arrival = 0
        self._order: Dict[int, int] = {}     # rid -> arrival index (FIFO across buckets)
        self.stats = dict(waves=0, requests=0, real_tokens=0, padded_tokens=0,
                          filler_tokens=0)

    # -- admission -----------------------------------------------------
    def bucket_of(self, prompt_len: int) -> int:
        for b in sorted(self.queues):
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt length {prompt_len} exceeds largest bucket "
            f"{max(self.queues)}")

    def submit(self, req: Request) -> None:
        assert req.max_new >= 1, req
        assert req.max_new <= self.bcfg.new_token_bucket, (
            f"request {req.rid}: max_new {req.max_new} > token bucket "
            f"{self.bcfg.new_token_bucket}")
        assert req.rid not in self._order, f"duplicate pending rid {req.rid}"
        req.tokens = np.asarray(req.tokens, np.int32).reshape(-1)
        self.queues[self.bucket_of(len(req.tokens))].append(req)
        self._order[req.rid] = self._arrival
        self._arrival += 1

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # -- wave execution ------------------------------------------------
    def _pick_bucket(self) -> Optional[int]:
        """Bucket of the oldest waiting request (FIFO fairness across
        buckets; within a bucket the deque is already FIFO)."""
        best, best_order = None, None
        for b, q in self.queues.items():
            if q and (best_order is None or self._order[q[0].rid] < best_order):
                best, best_order = b, self._order[q[0].rid]
        return best

    def _pad(self, tokens: np.ndarray, bucket: int) -> np.ndarray:
        pad = bucket - len(tokens)
        if pad <= 0:
            return tokens[:bucket]
        return np.concatenate([tokens, np.full(pad, tokens[-1], np.int32)])

    def step(self) -> List[Completion]:
        """Run one wave; returns the completions it retired (empty when the
        queues are drained)."""
        bucket = self._pick_bucket()
        if bucket is None:
            return []
        bc = self.bcfg
        q = self.queues[bucket]
        admitted = [q.popleft() for _ in range(min(bc.n_slots, len(q)))]
        for req in admitted:                 # retired rids leave the FIFO map
            del self._order[req.rid]         # (long-running server: no leak)
        # idle slots cycle the admitted prompts (fixed shape, output discarded)
        slots = [admitted[i % len(admitted)] for i in range(bc.n_slots)]

        if self.adaptive is not None and hasattr(self.adaptive, "poll"):
            self.adaptive.poll()             # replica: adopt newer store policy

        batch = np.stack([self._pad(r.tokens, bucket) for r in slots])
        scfg = ServeConfig(max_new_tokens=bc.new_token_bucket,
                           temperature=bc.temperature, seed=bc.seed,
                           fused=True, observe_every=bc.observe_every)
        out = np.asarray(generate(
            self.params, {"tokens": jnp.asarray(batch)}, self.cfg, scfg,
            par=self.par, adaptive=self.adaptive, mesh=self.mesh))

        done = []
        for i, req in enumerate(admitted):
            done.append(Completion(req.rid, out[i, :req.max_new], self.wave,
                                   len(req.tokens), bucket))
            self.stats["real_tokens"] += int(req.max_new)
            self.stats["padded_tokens"] += int(
                bucket - len(req.tokens) + bc.new_token_bucket - req.max_new)
        self.stats["filler_tokens"] += (
            (bc.n_slots - len(admitted)) * (bucket + bc.new_token_bucket))
        self.stats["requests"] += len(admitted)
        self.stats["waves"] += 1
        self.wave += 1
        return done

    def run(self) -> List[Completion]:
        """Drain the queues; returns all completions in retirement order."""
        out: List[Completion] = []
        while self.pending():
            out.extend(self.step())
        return out

    def describe(self) -> str:
        s = self.stats
        useful = s["real_tokens"]
        total = useful + s["padded_tokens"] + s["filler_tokens"]
        util = useful / total if total else 1.0
        return (f"batcher waves={s['waves']} requests={s['requests']} "
                f"slot_util={util:.2f} (real={useful} padded={s['padded_tokens']} "
                f"filler={s['filler_tokens']})")
