"""Continuous-batching scheduler: variable-length requests -> fixed-shape
decode slots -> fused wave dispatches or token-granular slot splicing.

Serving traffic arrives as requests of arbitrary prompt length and token
budget; the compiled fast path (the PR-2 fused ``lax.scan`` decode, now
adaptive and mesh-shardable) wants **fixed shapes**.  The
:class:`ContinuousBatcher` bridges the two:

* requests queue per **prompt bucket**; prompts right-pad to the bucket
  length and prefill runs **pad-masked** (``prompt_lens``): the models'
  attention carries a pad-mask input, so a padded prompt attends only to
  its real tokens and generates bit-identically to the same prompt served
  unpadded (bucket granularity now costs only wasted compute, never wrong
  conditioning).  Pad-masking needs a full-attention stack — ring caches
  and recurrent/ssm state would absorb the pad tail — so other families
  keep the PR-3 repeat-pad wave behavior;
* **wave mode** (the default, and the bit-exactness oracle): each wave
  admits up to ``n_slots`` requests FIFO from the oldest bucket, backfills
  remaining slots with the next FIFO requests from *other* buckets whose
  prompts fit (their outputs are kept and counted — idle slots no longer
  cycle already-admitted prompts), and runs ONE fused adaptive dispatch of
  ``new_token_bucket`` steps with per-slot positions and per-slot token
  budgets (a slot that exhausts its budget retires in place);
* **token mode** (``BatcherConfig.token_granular``): slots retire and admit
  *mid-flight*.  Decode runs one compiled per-step program
  (``serve.engine.token_step``) over the slot batch with per-slot cache
  positions; when a slot finishes, the next FIFO request is prefilled into
  that slot's cache region (``serve.engine.prefill_one`` +
  ``splice_slot``) and spliced into the running batch at the next step
  boundary — no recompiles, no desync of the other slots.  Same prompts,
  same seeds => per-request tokens bit-identical to the wave oracle
  (greedy; tested);
* every compiled program is keyed on shape classes exactly as before (one
  prefill per prompt bucket, one decode program for the shared
  ``max_cache_len``); policy re-tunes and ``PolicyReader`` syncs change
  traced int32 values only — later waves, spliced admissions and adopted
  policies all reuse the same programs.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig, ParallelConfig
from repro.serve import ServeConfig, generate
from repro.serve.engine import prefill_one, splice_slot_jit, token_step
from repro.train.fault import StragglerWatchdog

from . import chaos

__all__ = ["Request", "Completion", "BatcherConfig", "ContinuousBatcher"]

# host-side observability (repro.obs; see docs/observability.md).  TTFT in
# wave mode equals e2e at wave granularity (the whole wave is one fused
# dispatch — a request's first token only materializes when the wave
# lands); token mode reports the real first-token latency, measured at the
# admission splice.  All instrumentation sits outside traced code.
_REG = obs.default_registry()
_OCCUPANCY = _REG.gauge(
    "repro_batcher_occupancy",
    "useful-token fraction of all decode-slot token positions (by mode)")
_QUEUE_DEPTH = _REG.gauge(
    "repro_queue_depth", "waiting requests per prompt bucket")
_ADMISSIONS = _REG.counter(
    "repro_admissions_total", "requests admitted into decode slots (by mode)")
_BACKFILLS = _REG.counter(
    "repro_backfills_total",
    "wave-mode idle slots backfilled from other buckets' FIFO heads")
_SPLICES = _REG.counter(
    "repro_splices_total",
    "token-mode mid-flight admissions spliced into a live batch")
_TTFT = _REG.histogram(
    "repro_request_ttft_seconds",
    "submit -> first token (wave mode: == e2e at wave granularity)",
    buckets=obs.TTFT_BUCKETS)
_E2E = _REG.histogram(
    "repro_request_e2e_seconds", "submit -> request retirement (by mode)",
    buckets=obs.E2E_BUCKETS)
_STEP_WALL = _REG.histogram(
    "repro_token_step_seconds",
    "host wall per token-granular decode step (dispatch + host bookkeeping)",
    buckets=obs.DISPATCH_BUCKETS)
_TOKENS_PER_S = _REG.gauge(
    "repro_decode_tokens_per_second",
    "real (non-pad, non-filler) tokens per wall second over the last drain")
_POST_WARMUP_RETRACES = _REG.gauge(
    "repro_decode_retraces_post_warmup",
    "token_step program installs after the first decode step of a drain — "
    "the live zero-recompile invariant (asserted 0; splices and policy "
    "updates must never retrace)")
_SHED = _REG.counter(
    "repro_requests_shed_total",
    "admissions refused because the bounded queue was full (load-shedding)")
_TIMEOUTS = _REG.counter(
    "repro_request_timeouts_total",
    "requests retired past their deadline_s (by where: queued / decoding)")
_STRAGGLERS = _REG.counter(
    "repro_step_stragglers_total",
    "decode steps/waves flagged slow by the straggler watchdog")


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray          # (L,) int32 prompt
    max_new: int
    # optional SLO: seconds from submit after which the request is retired
    # as a `timeout` completion instead of (or mid-) decoding.  None = no
    # deadline (the default keeps every existing call site byte-identical).
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # (max_new,) int32 generated
    wave: int                   # wave index (wave mode) / retire step (token)
    prompt_len: int
    bucket: int
    status: str = "ok"          # "ok" | "timeout" (partial/empty tokens)
    # correlation id assigned at submit — unique across splices/backfills
    # and across drains even when rids recur (qor attribution + trace key)
    corr: Optional[str] = None
    # per-request QoR attribution summary (obs.qor.ErrorAttributor.finish):
    # per-target/per-tile ew-MAE, error shares, top-k contributors.  Token
    # mode with an adaptive controller only; None in wave mode (the wave
    # oracle stays uninstrumented) and when telemetry is off.
    qor: Optional[dict] = None


@dataclasses.dataclass
class BatcherConfig:
    n_slots: int = 8                       # fixed decode batch (mesh-divisible)
    prompt_buckets: Sequence[int] = (16, 32, 64)
    new_token_bucket: int = 16             # fused scan length per wave
    observe_every: int = 1                 # telemetry decimation inside the scan
    temperature: float = 0.0
    seed: int = 0
    token_granular: bool = False           # mid-flight slot splicing (greedy)
    # admission control: refuse (shed) submits once this many requests wait;
    # None = unbounded (the pre-hardening behavior)
    max_queue: Optional[int] = None
    straggler_factor: float = 3.0          # per-step watchdog (train/fault)


class ContinuousBatcher:
    """Admission + execution over the fused adaptive decode (wave mode) or
    the per-step token-granular decode (``BatcherConfig.token_granular``).

    ``adaptive`` is either the fleet's re-tuning
    :class:`~repro.runtime.AdaptiveController` (the single store writer) or a
    replica-side :class:`~repro.fleet.store.PolicyReader` (synced before each
    wave / admission); ``None`` serves the static policy (single-host only:
    the engine's sharded path is the adaptive one, so ``mesh`` requires
    ``adaptive``).  ``mesh`` shards the decode slots over the mesh batch
    axes.
    """

    def __init__(self, params, cfg: ModelConfig, bcfg: Optional[BatcherConfig] = None,
                 adaptive=None, mesh=None, par: Optional[ParallelConfig] = None):
        assert mesh is None or adaptive is not None, (
            "ContinuousBatcher: mesh= requires an adaptive controller/reader "
            "(the sharded decode program is the adaptive scan)")
        self.params = params
        self.cfg = cfg
        self.bcfg = bcfg or BatcherConfig()
        # pad-mask prefill (and with it per-slot positions, budgets, and
        # idle-slot backfill) needs a full-attention stack: ring caches and
        # recurrent/ssm state would absorb the pad tail.  Other families
        # keep the PR-3 wave behavior (repeat-pad conditioning, idle slots
        # cycling admitted prompts).
        self.padmask = (cfg.family != "encdec" and all(
            k in ("global", "dense_ffn") for k in cfg.layer_kinds()))
        if self.bcfg.token_granular:
            assert self.padmask, (
                f"token-granular mode needs pad-mask prefill (full-attention "
                f"stack); {cfg.name} has kinds "
                f"{sorted(set(cfg.layer_kinds()))}")
            assert self.bcfg.temperature == 0.0, (
                "token-granular mode is greedy-only: the wave oracle's "
                "sampling key chain is shared across the batch, so only "
                "temperature=0 gives per-request bit-exactness")
        self.adaptive = adaptive
        self.mesh = mesh
        self.par = par
        self.queues: Dict[int, collections.deque] = {
            b: collections.deque() for b in sorted(self.bcfg.prompt_buckets)
        }
        self.wave = 0
        self._arrival = 0
        self._order: Dict[int, int] = {}     # rid -> arrival index (FIFO across buckets)
        self.stats = dict(waves=0, requests=0, real_tokens=0, padded_tokens=0,
                          filler_tokens=0, backfilled=0, splices=0,
                          decode_steps=0, decode_retraces_post_warmup=0,
                          shed=0, timeouts=0, stragglers=0)
        self.mode = "token" if self.bcfg.token_granular else "wave"
        # per-step (token mode) / per-wave straggler watchdog — the same
        # trailing-median detector the train loop supervises with
        self.watchdog = StragglerWatchdog(factor=self.bcfg.straggler_factor)
        self._submit_t: Dict[int, float] = {}    # rid -> submit perf_counter
        # per-request latency log (rid, bucket, prompt_len, max_new, ttft,
        # e2e seconds) — the source benchmarks/serving_table.py reduces to
        # TTFT/e2e p50/p99 per mode
        self.request_log: List[dict] = []
        # QoR attribution (obs.qor): correlation ids assigned at submit —
        # "<rid>#<arrival>" stays unique across splices/backfills and across
        # drains even when rids recur — and exposure accounting over the
        # token loop's step telemetry.  Wave mode carries the corr id on its
        # completions but never attributes (the oracle stays uninstrumented).
        self._corr: Dict[int, str] = {}          # pending rid -> corr id
        self.qor = obs.ErrorAttributor()
        # optional SLO engine (obs.slo, attach_slo): fed every request's
        # ttft/e2e sample as it retires
        self.slo = None

    def attach_slo(self, engine) -> None:
        """Attach an :class:`repro.obs.slo.SLOEngine` to the latency stream
        (sources ``"ttft"`` and ``"e2e"``)."""
        self.slo = engine

    def _update_queue_gauges(self) -> None:
        for b, q in self.queues.items():
            _QUEUE_DEPTH.set(len(q), bucket=str(b))

    def _record_latency(self, req: "Request", ttft: Optional[float],
                        e2e: float, observe_ttft: bool = True) -> None:
        if ttft is not None and observe_ttft:
            _TTFT.observe(ttft, mode=self.mode)
        _E2E.observe(e2e, mode=self.mode)
        if self.slo is not None:
            if ttft is not None:
                self.slo.observe_latency("ttft", ttft)
            self.slo.observe_latency("e2e", e2e)
        self.request_log.append(dict(
            rid=req.rid, bucket=self.bucket_of(len(req.tokens)),
            prompt_len=len(req.tokens), max_new=req.max_new,
            ttft=ttft, e2e=e2e))

    # -- admission -----------------------------------------------------
    def bucket_of(self, prompt_len: int) -> int:
        for b in sorted(self.queues):
            if prompt_len <= b:
                return b
        raise ValueError(
            f"prompt length {prompt_len} exceeds largest bucket "
            f"{max(self.queues)}")

    def submit(self, req: Request) -> bool:
        """Queue a request.  Returns False (and counts a shed) when the
        bounded admission queue (``BatcherConfig.max_queue``) is full —
        load-shedding at the door beats queueing work that will only time
        out inside."""
        if (self.bcfg.max_queue is not None
                and self.pending() >= self.bcfg.max_queue):
            self.stats["shed"] += 1
            _SHED.inc(1)
            obs.instant("shed", cat="scheduler", rid=req.rid,
                        pending=self.pending())
            return False
        assert req.max_new >= 1, req
        assert req.max_new <= self.bcfg.new_token_bucket, (
            f"request {req.rid}: max_new {req.max_new} > token bucket "
            f"{self.bcfg.new_token_bucket}")
        assert req.rid not in self._order, f"duplicate pending rid {req.rid}"
        req.tokens = np.asarray(req.tokens, np.int32).reshape(-1)
        self.queues[self.bucket_of(len(req.tokens))].append(req)
        self._order[req.rid] = self._arrival
        corr = f"{req.rid}#{self._arrival}"
        self._corr[req.rid] = corr
        self._arrival += 1
        self._submit_t[req.rid] = time.perf_counter()
        obs.async_begin("request", req.rid, prompt_len=len(req.tokens),
                        max_new=req.max_new, corr=corr)
        if self.bcfg.token_granular:
            # exposure accounting opens at submit so even a request that
            # times out queued (or retires within its admission step) still
            # closes with a summary (fleet-basis fallback)
            self.qor.begin(corr, req.rid)
        self._update_queue_gauges()
        return True

    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # -- deadlines -----------------------------------------------------
    def _deadline_passed(self, req: Request) -> bool:
        if req.deadline_s is None:
            return False
        t0 = self._submit_t.get(req.rid)
        return t0 is not None and time.perf_counter() - t0 > req.deadline_s

    def _timeout(self, req: Request, tokens, where: str) -> Completion:
        """Retire ``req`` past its deadline: a ``timeout`` completion with
        whatever tokens were generated so far (empty when still queued)."""
        self.stats["timeouts"] += 1
        _TIMEOUTS.inc(1, where=where)
        e2e = time.perf_counter() - self._submit_t.pop(
            req.rid, time.perf_counter())
        self._record_latency(req, None, e2e, observe_ttft=False)
        corr = self._corr.pop(req.rid, None)
        qor = self.qor.finish(corr) if corr is not None else None
        obs.instant("timeout", cat="scheduler", rid=req.rid, where=where)
        obs.async_end("request", req.rid, status="timeout")
        return Completion(req.rid, np.asarray(tokens, np.int32),
                          self.wave if self.mode == "wave"
                          else self.stats["decode_steps"],
                          len(req.tokens), self.bucket_of(len(req.tokens)),
                          status="timeout", corr=corr, qor=qor)

    def _expire_queued(self) -> List[Completion]:
        """Sweep the admission queues for requests whose deadline passed
        while waiting; retires them as empty ``timeout`` completions."""
        out = []
        for q in self.queues.values():
            expired = [r for r in q if self._deadline_passed(r)]
            if expired:
                dead = {r.rid for r in expired}
                keep = [r for r in q if r.rid not in dead]
                for r in expired:
                    del self._order[r.rid]
                    out.append(self._timeout(r, np.zeros(0, np.int32),
                                             where="queued"))
                q.clear()
                q.extend(keep)
        if out:
            self._update_queue_gauges()
        return out

    def max_cache_len(self) -> int:
        """One decode-cache length shared by every bucket: the decode
        program (and in token mode the step program) compiles once."""
        return max(self.queues) + self.bcfg.new_token_bucket + 1

    # -- FIFO helpers --------------------------------------------------
    def _pick_bucket(self, max_prompt_len: Optional[int] = None) -> Optional[int]:
        """Bucket whose HEAD is the globally oldest waiting request (FIFO
        fairness across buckets; within a bucket the deque is already FIFO).
        ``max_prompt_len`` skips buckets whose head doesn't fit."""
        best, best_order = None, None
        for b, q in self.queues.items():
            if not q:
                continue
            if max_prompt_len is not None and len(q[0].tokens) > max_prompt_len:
                continue
            if best_order is None or self._order[q[0].rid] < best_order:
                best, best_order = b, self._order[q[0].rid]
        return best

    def _pop_oldest(self, max_prompt_len: Optional[int] = None) -> Optional[Request]:
        """Pop the globally oldest request (optionally only if its prompt
        fits ``max_prompt_len``)."""
        b = self._pick_bucket(max_prompt_len)
        if b is None:
            return None
        req = self.queues[b].popleft()
        del self._order[req.rid]             # retired rids leave the FIFO map
        return req                           # (long-running server: no leak)

    def _pad(self, tokens: np.ndarray, bucket: int) -> np.ndarray:
        pad = bucket - len(tokens)
        if pad <= 0:
            return tokens[:bucket]
        return np.concatenate([tokens, np.full(pad, tokens[-1], np.int32)])

    # -- wave execution (the bit-exactness oracle) ---------------------
    def step(self) -> List[Completion]:
        """Run one wave; returns the completions it retired (empty when the
        queues are drained).  Requests whose deadline lapsed while queued
        retire first as ``timeout`` completions (never dispatched)."""
        faults = chaos.fire("sched.step", wave=self.wave, mode=self.mode)
        if any(f.kind == "crash_replica" for f in faults):
            raise chaos.InjectedFault("sched.step: replica killed")
        chaos.maybe_stall(faults, default=0.05)
        timed_out = self._expire_queued()
        bucket = self._pick_bucket()
        if bucket is None:
            return timed_out
        t_wave = time.perf_counter()
        bc = self.bcfg
        q = self.queues[bucket]
        admitted = []
        while q and len(admitted) < bc.n_slots:
            req = q.popleft()
            del self._order[req.rid]
            admitted.append(req)
        # backfill idle slots with the next FIFO requests from other buckets
        # whose prompts fit this wave's bucket — outputs are kept (the old
        # behavior cycled already-admitted prompts and discarded the copies).
        # Correct only under pad-mask prefill (a backfilled short prompt
        # must not condition on its pad tail).
        n_backfilled = 0
        while self.padmask and len(admitted) < bc.n_slots:
            req = self._pop_oldest(max_prompt_len=bucket)
            if req is None:
                break
            admitted.append(req)
            n_backfilled += 1
        # remaining idle slots cycle the admitted prompts (fixed shape) with
        # a 1-token budget: they retire after the prefill sample and stay
        # inert for the whole wave
        slots = [admitted[i % len(admitted)] for i in range(bc.n_slots)]
        filler = bc.n_slots - len(admitted)

        if self.adaptive is not None and hasattr(self.adaptive, "poll"):
            self.adaptive.poll()             # replica: adopt newer store policy

        batch = np.stack([self._pad(r.tokens, bucket) for r in slots])
        lens = np.asarray([len(r.tokens) for r in slots], np.int32)
        budgets = np.asarray(
            [r.max_new if i < len(admitted) else 1
             for i, r in enumerate(slots)], np.int32)
        scfg = ServeConfig(max_new_tokens=bc.new_token_bucket,
                           temperature=bc.temperature, seed=bc.seed,
                           fused=True, observe_every=bc.observe_every)
        padmask_kw = (dict(prompt_lens=lens, slot_new_tokens=budgets,
                           max_cache_len=self.max_cache_len())
                      if self.padmask else {})
        self._update_queue_gauges()
        with obs.span("wave", cat="scheduler", wave=self.wave, bucket=bucket,
                      admitted=len(admitted), backfilled=n_backfilled):
            out = np.asarray(generate(
                self.params, {"tokens": jnp.asarray(batch)}, self.cfg, scfg,
                par=self.par, adaptive=self.adaptive, mesh=self.mesh,
                **padmask_kw))
        t_done = time.perf_counter()

        done = []
        for i, req in enumerate(admitted):
            done.append(Completion(req.rid, out[i, :req.max_new], self.wave,
                                   len(req.tokens), bucket,
                                   corr=self._corr.pop(req.rid, None)))
            self.stats["real_tokens"] += int(req.max_new)
            self.stats["padded_tokens"] += int(
                bucket - len(req.tokens) + bc.new_token_bucket - req.max_new)
            e2e = t_done - self._submit_t.pop(req.rid, t_done)
            self._record_latency(req, e2e, e2e)   # wave TTFT == e2e (fused)
            obs.async_end("request", req.rid, wave=self.wave)
        self.stats["backfilled"] += n_backfilled
        self.stats["filler_tokens"] += filler * (bucket + bc.new_token_bucket)
        self.stats["requests"] += len(admitted)
        self.stats["waves"] += 1
        self.stats["decode_steps"] += bc.new_token_bucket - 1
        _ADMISSIONS.inc(len(admitted), mode=self.mode)
        _BACKFILLS.inc(n_backfilled)
        _OCCUPANCY.set(self.occupancy(), mode=self.mode)
        if self.watchdog.observe(t_done - t_wave):
            self.stats["stragglers"] += 1
            _STRAGGLERS.inc(1, mode=self.mode)
            obs.instant("straggler", cat="scheduler", wave=self.wave,
                        wall=t_done - t_wave)
        self.wave += 1
        return timed_out + done

    # -- token-granular execution --------------------------------------
    def _admit_into(self, slot: int, state: list, pos: np.ndarray,
                    tok: np.ndarray, cache, key):
        """Prefill the next FIFO request and splice it into ``slot``'s cache
        region; returns the (possibly updated) cache.  ``state[slot]`` stays
        ``None`` when the queues are drained.  Requests whose deadline
        lapsed while queued are retired as ``timeout`` completions instead
        of being prefilled (the prefill would be wasted work)."""
        expired = []
        req = self._pop_oldest()
        while req is not None and self._deadline_passed(req):
            expired.append(self._timeout(req, np.zeros(0, np.int32),
                                         where="queued"))
            req = self._pop_oldest()
        if req is None:
            state[slot] = None
            return cache, expired
        if self.adaptive is not None and hasattr(self.adaptive, "poll"):
            self.adaptive.poll()
        L = len(req.tokens)
        bucket = self.bucket_of(L)
        padded = self._pad(req.tokens, bucket)
        with obs.span("admit", cat="scheduler", rid=req.rid, slot=slot,
                      bucket=bucket):
            first, fresh = prefill_one(
                self.params, padded[None], L, self.cfg, self.par,
                max_cache_len=self.max_cache_len(),
                temperature=self.bcfg.temperature, key=key)
            cache = splice_slot_jit(cache, fresh, slot)
            first = int(np.asarray(first)[0])   # sync: first token on host
        obs.instant("splice", cat="scheduler", rid=req.rid, slot=slot)
        ttft = time.perf_counter() - self._submit_t.get(
            req.rid, time.perf_counter())
        _TTFT.observe(ttft, mode=self.mode)
        state[slot] = dict(req=req, remaining=req.max_new - 1, toks=[first],
                           ttft=ttft)
        pos[slot] = L
        tok[slot] = first
        self.stats["requests"] += 1
        self.stats["real_tokens"] += 1
        self.stats["padded_tokens"] += bucket - L
        _ADMISSIONS.inc(1, mode=self.mode)
        self._update_queue_gauges()
        if state[slot]["remaining"] == 0:    # max_new == 1: retire in place
            expired.extend(self._retire(slot, state))
        return cache, expired

    def _retire(self, slot: int, state: list,
                status: str = "ok") -> List[Completion]:
        st = state[slot]
        state[slot] = None
        req = st["req"]
        if status == "timeout":              # mid-decode deadline: keep the
            self.stats["timeouts"] += 1      # partial tokens, mark the cut
            _TIMEOUTS.inc(1, where="decoding")
            obs.instant("timeout", cat="scheduler", rid=req.rid,
                        where="decoding")
        e2e = time.perf_counter() - self._submit_t.pop(
            req.rid, time.perf_counter())
        # TTFT was already observed at the admission splice
        self._record_latency(req, st.get("ttft"), e2e, observe_ttft=False)
        corr = self._corr.pop(req.rid, None)
        qor = self.qor.finish(corr) if corr is not None else None
        obs.instant("retire", cat="scheduler", rid=req.rid, slot=slot)
        end_kw = dict(step=self.stats["decode_steps"], status=status)
        if qor is not None and qor["top"]:
            # the top contributor rides on the request's async trace span so
            # timeline views show *where* each request's error concentrated
            end_kw.update(qor_top=qor["top"][0]["where"],
                          qor_share=round(qor["top"][0]["share"], 4),
                          qor_basis=qor["basis"])
        obs.async_end("request", req.rid, **end_kw)
        return [Completion(req.rid, np.asarray(st["toks"], np.int32),
                           self.stats["decode_steps"], len(req.tokens),
                           self.bucket_of(len(req.tokens)), status=status,
                           corr=corr, qor=qor)]

    def _run_token_granular(self) -> List[Completion]:
        """Drain the queues with mid-flight admission: one compiled step
        program, slots retire and refill at step boundaries."""
        from repro.models import init_cache

        bc = self.bcfg
        B = bc.n_slots
        cache = init_cache(self.cfg, B, self.max_cache_len())
        key = jax.random.PRNGKey(bc.seed)
        state: list = [None] * B
        pos = np.zeros(B, np.int32)
        tok = np.zeros(B, np.int32)
        done: List[Completion] = []
        k_obs = max(1, int(bc.observe_every))
        pending = None

        t_drain = time.perf_counter()
        tokens_at_start = self.stats["real_tokens"]
        for s in range(B):                   # initial admission
            cache, d = self._admit_into(s, state, pos, tok, cache, key)
            done.extend(d)
        # zero-recompile invariant: the step program compiles once on the
        # first decode step of a cold process; everything after — splices,
        # retirements, policy adoptions — must reuse it.  Snapshot the
        # token_step install count after step 0 and assert no further
        # installs land during the drain (the live gauge CI gates).
        warmup_installs = None
        while any(st is not None for st in state):
            faults = chaos.fire("sched.step",
                                step=self.stats["decode_steps"],
                                mode=self.mode)
            if any(f.kind == "crash_replica" for f in faults):
                raise chaos.InjectedFault("sched.step: replica killed")
            chaos.maybe_stall(faults, default=0.05)
            active_np = np.asarray([st is not None for st in state])
            # the corr ids live in THIS step — captured before the retire/
            # splice sweep below, so telemetry produced by the step is
            # charged to exactly the requests that were decoding in it
            live_corrs = [self._corr[st["req"].rid]
                          for st in state if st is not None]
            key, sub = jax.random.split(key)
            gate = (self.stats["decode_steps"] % k_obs == 0)
            t_step = time.perf_counter()
            with obs.span("token_step", cat="scheduler",
                          step=self.stats["decode_steps"],
                          active=int(active_np.sum())):
                out = token_step(
                    self.params, cache, jnp.asarray(tok), sub,
                    jnp.asarray(pos), jnp.asarray(active_np), self.cfg,
                    self.par, temperature=bc.temperature,
                    adaptive=self.adaptive, mesh=self.mesh, gate=gate)
            step_wall = time.perf_counter() - t_step
            _STEP_WALL.observe(step_wall)
            if self.watchdog.observe(step_wall):
                self.stats["stragglers"] += 1
                _STRAGGLERS.inc(1, mode=self.mode)
                obs.instant("straggler", cat="scheduler",
                            step=self.stats["decode_steps"], wall=step_wall)
            if warmup_installs is None:
                warmup_installs = obs.retrace_total("token_step")
            if self.adaptive is not None:
                tok_d, cache, telem = out
                if pending is not None:      # one-step-stale observe keeps
                    self.adaptive.observe(pending)
                    pending = None           # the dispatch pipeline warm
                if gate:
                    # host transfer NOW (the tok sync below drains the same
                    # dispatch, so this adds no stall) — attribution must
                    # charge this step's live corr set before any of them
                    # retires in the sweep below; the controller still
                    # observes one step stale, exactly as before
                    host_telem = jax.device_get(telem)
                    self.qor.observe_step(host_telem, live_corrs)
                    pending = host_telem
            else:
                tok_d, cache = out
            tok = np.array(tok_d)        # writable copy (splices update rows)
            pos = pos + active_np
            n_active = int(active_np.sum())
            self.stats["real_tokens"] += n_active
            self.stats["filler_tokens"] += B - n_active
            self.stats["decode_steps"] += 1
            for s in range(B):               # retire + splice at the step
                st = state[s]                # boundary
                if st is None:
                    continue
                st["toks"].append(int(tok[s]))
                st["remaining"] -= 1
                timed_out = (st["remaining"] > 0
                             and self._deadline_passed(st["req"]))
                if st["remaining"] == 0 or timed_out:
                    done.extend(self._retire(
                        s, state, status="timeout" if timed_out else "ok"))
                    cache, d = self._admit_into(s, state, pos, tok, cache, key)
                    done.extend(d)
                    if state[s] is not None:
                        self.stats["splices"] += 1
                        _SPLICES.inc(1)
        if pending is not None and self.adaptive is not None:
            self.adaptive.observe(pending)
        post = (0 if warmup_installs is None
                else int(obs.retrace_total("token_step") - warmup_installs))
        self.stats["decode_retraces_post_warmup"] = post
        _POST_WARMUP_RETRACES.set(post)
        assert post == 0, (
            f"token-granular drain retraced the step program {post}x after "
            f"warmup — splices/policy updates must only change traced values")
        _OCCUPANCY.set(self.occupancy(), mode=self.mode)
        wall = time.perf_counter() - t_drain
        if wall > 0:
            _TOKENS_PER_S.set(
                (self.stats["real_tokens"] - tokens_at_start) / wall,
                mode=self.mode)
        return done

    def run(self) -> List[Completion]:
        """Drain the queues; returns all completions in retirement order."""
        if self.bcfg.token_granular:
            return self._run_token_granular()
        out: List[Completion] = []
        while self.pending():
            out.extend(self.step())
        return out

    def occupancy(self) -> float:
        s = self.stats
        useful = s["real_tokens"]
        total = useful + s["padded_tokens"] + s["filler_tokens"]
        return useful / total if total else 1.0

    def latency_summary(self) -> dict:
        """TTFT / e2e percentiles (seconds) over ``request_log``.

        The ``*_p50``/``*_p99`` keys are exact order statistics from the
        per-request records (unchanged interface).  Each also carries a
        bucket-resolution twin: ``*_bucketed`` is what the corresponding
        registry histogram (tuned ``TTFT_BUCKETS``/``E2E_BUCKETS`` family)
        reports for the same samples via linear interpolation, and
        ``*_resolution`` the covering bucket's width — so gates and humans
        comparing exact percentiles against histogram reads see a stated
        resolution instead of an exact-vs-bucket-floor mismatch.  Empty
        log -> empty dict."""
        if not self.request_log:
            return {}
        e2e = np.asarray([r["e2e"] for r in self.request_log])
        ttft = np.asarray([r["ttft"] for r in self.request_log
                           if r["ttft"] is not None])
        out = dict(requests=len(self.request_log),
                   e2e_p50=float(np.percentile(e2e, 50)),
                   e2e_p99=float(np.percentile(e2e, 99)))
        for q, name in ((0.50, "e2e_p50"), (0.99, "e2e_p99")):
            v, res = obs.bucket_percentile(e2e, obs.E2E_BUCKETS, q)
            out[name + "_bucketed"] = v
            out[name + "_resolution"] = res
        if ttft.size:
            out.update(ttft_p50=float(np.percentile(ttft, 50)),
                       ttft_p99=float(np.percentile(ttft, 99)))
            for q, name in ((0.50, "ttft_p50"), (0.99, "ttft_p99")):
                v, res = obs.bucket_percentile(ttft, obs.TTFT_BUCKETS, q)
                out[name + "_bucketed"] = v
                out[name + "_resolution"] = res
        return out

    def describe(self) -> str:
        s = self.stats
        return (f"batcher[{self.mode}] waves={s['waves']} "
                f"steps={s['decode_steps']} "
                f"requests={s['requests']} splices={s['splices']} "
                f"backfilled={s['backfilled']} "
                f"retraces={s['decode_retraces_post_warmup']} "
                f"shed={s['shed']} timeouts={s['timeouts']} "
                f"stragglers={s['stragglers']} "
                f"slot_util={self.occupancy():.2f} "
                f"(real={s['real_tokens']} padded={s['padded_tokens']} "
                f"filler={s['filler_tokens']})")
