"""Chaos / fault-injection harness for the serving-side adaptation loop.

The adaptation loop is a production dependency: a retune fit on corrupted
telemetry or a torn ``PolicyStore`` publish is adopted fleet-wide and makes
error *worse* — so the recovery paths need to be exercised as
deterministically as the happy paths.  This module is the injection half:

* a :class:`FaultSpec` names one fault — an injection **site** (a named
  hook compiled into the production code path, e.g. ``store.publish``), a
  fault **kind** valid at that site, and the 0-based visit count ``at``
  which the fault fires on;
* a :class:`FaultPlan` is an ordered, JSON-serializable collection of
  specs.  :meth:`FaultPlan.seeded` derives a plan deterministically from an
  integer seed, so a CI chaos lane replays the exact same fault sequence
  every run;
* a :class:`ChaosHarness` executes a plan: production call sites call
  :func:`fire` (a no-op returning ``[]`` unless a harness is installed —
  the **armed-but-idle** invariant: an installed harness whose plan never
  matches must leave behavior bit-identical), and the harness returns the
  specs due at this visit while counting what it injected.

Faults either *raise* :class:`InjectedFault` (simulated process kill —
subclasses ``train.fault.SimulatedFailure`` so the existing supervision
patterns catch it), *corrupt* on-disk state (torn ``CURRENT``, garbage
policy JSON), *poison* telemetry records in flight (NaN/Inf/outlier), or
*stall* (sleep) a step/retune/poll.  The consuming code paths decide the
semantics; this module only decides *when* and records *what fired*.

Usage::

    from repro.fleet import chaos

    plan = chaos.FaultPlan([
        chaos.FaultSpec("store.publish", "torn_current", at=1),
        chaos.FaultSpec("controller.observe", "poison_nan", at=3),
    ])
    with chaos.active(plan) as harness:
        ...   # serve; injected faults are counted in harness.fired
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

__all__ = [
    "SITES",
    "FaultSpec",
    "FaultPlan",
    "ChaosHarness",
    "InjectedFault",
    "active",
    "install",
    "uninstall",
    "current",
    "fire",
    "stall_seconds",
    "poison_records",
]


class InjectedFault(RuntimeError):
    """An injected crash (simulated process kill at the fault site).

    Subclasses the train loop's ``SimulatedFailure`` lazily at import of
    ``repro.train.fault`` would create an import cycle through the fleet
    package; instead ``train.fault.run_supervised``-style supervisors catch
    ``RuntimeError`` subclasses by name — serve-side supervisors (tests,
    ``benchmarks/chaos_table.py``) catch :class:`InjectedFault` directly.
    """


# Injection sites compiled into the production paths, and the fault kinds
# each site honors.  ``at`` counts visits of the site per harness.
SITES: Dict[str, Tuple[str, ...]] = {
    # PolicyStore.publish: kill mid temp-file write (orphan .tmp, no version
    # committed), kill after the version+heartbeat but before the CURRENT
    # swap, or tear the CURRENT pointer itself (garbage bytes) then die.
    "store.publish": ("kill_mid_write", "kill_before_current", "torn_current"),
    # After a successful publish: overwrite the just-published policy JSON
    # with garbage (simulates partial replication / disk corruption).
    "store.after_publish": ("corrupt_policy",),
    # PolicyReader.poll: delayed poll (slow replica) or replica kill.
    "reader.poll": ("delay_poll", "crash_replica"),
    # AdaptiveController.observe: poison the incoming telemetry records.
    "controller.observe": ("poison_nan", "poison_inf", "poison_outlier"),
    # AdaptiveController.retune: stall the sweep (slow host).
    "controller.retune": ("stall_retune",),
    # ContinuousBatcher decode step: stall one step or kill the replica.
    "sched.step": ("stall_step", "crash_replica"),
}


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault: fire ``kind`` on the ``at``-th visit of ``site``.

    ``arg`` carries the kind's scalar parameter (stall seconds for
    ``stall_*``/``delay_poll``, outlier scale for ``poison_outlier``);
    ``None`` means the consumer's default."""

    site: str
    kind: str
    at: int = 0
    arg: Optional[float] = None

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown chaos site {self.site!r} "
                             f"(known: {sorted(SITES)})")
        if self.kind not in SITES[self.site]:
            raise ValueError(f"fault kind {self.kind!r} not valid at "
                             f"{self.site!r} (valid: {SITES[self.site]})")

    def to_dict(self) -> dict:
        return dict(site=self.site, kind=self.kind, at=self.at, arg=self.arg)


class FaultPlan:
    """An ordered, deterministic, JSON-round-trippable set of faults."""

    def __init__(self, faults: Sequence[FaultSpec] = (),
                 seed: Optional[int] = None):
        self.faults: List[FaultSpec] = list(faults)
        self.seed = seed

    @classmethod
    def seeded(cls, seed: int, n_faults: int = 6,
               sites: Optional[Sequence[str]] = None,
               max_at: int = 8) -> "FaultPlan":
        """Derive a plan deterministically from ``seed``: ``n_faults``
        (site, kind, at) choices sampled without replacement over the
        (site, kind) space — the CI chaos lane pins one seed so every run
        replays the identical fault sequence."""
        rng = np.random.default_rng(seed)
        space = [(s, k) for s in (sites or sorted(SITES)) for k in SITES[s]]
        picks = rng.choice(len(space), size=min(n_faults, len(space)),
                           replace=False)
        faults = [FaultSpec(space[i][0], space[i][1],
                            at=int(rng.integers(0, max_at)))
                  for i in sorted(int(p) for p in picks)]
        return cls(faults, seed=seed)

    # -- serialization -------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(dict(seed=self.seed,
                               faults=[f.to_dict() for f in self.faults]),
                          indent=2)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        return cls([FaultSpec(**f) for f in d.get("faults", [])],
                   seed=d.get("seed"))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())

    def describe(self) -> str:
        parts = [f"{f.site}:{f.kind}@{f.at}" for f in self.faults]
        seed = "" if self.seed is None else f" seed={self.seed}"
        return f"faultplan[{len(self.faults)}{seed}] " + " ".join(parts)


_REG = obs.default_registry()
_INJECTED = _REG.counter(
    "repro_chaos_faults_injected_total",
    "faults the chaos harness fired, by site and kind")


class ChaosHarness:
    """Executes a :class:`FaultPlan`: counts visits per site, returns the
    due specs, and logs every injection (counter + fired list)."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.visits: Dict[str, int] = {}
        self.fired: List[Tuple[str, FaultSpec]] = []

    def poke(self, site: str, **ctx) -> List[FaultSpec]:
        n = self.visits.get(site, 0)
        self.visits[site] = n + 1
        hits = [f for f in self.plan.faults if f.site == site and f.at == n]
        for f in hits:
            self.fired.append((site, f))
            _INJECTED.inc(1, site=site, kind=f.kind)
            obs.instant("chaos_fault", cat="chaos", site=site, kind=f.kind,
                        visit=n, **ctx)
        return hits

    def fired_count(self, kind: Optional[str] = None) -> int:
        return sum(1 for _, f in self.fired if kind is None or f.kind == kind)

    def describe(self) -> str:
        return (f"chaos[{self.plan.describe()}] visits={dict(self.visits)} "
                f"fired={[(s, f.kind) for s, f in self.fired]}")


# ---------------------------------------------------------------------------
# module-level harness installation (production sites call fire())
# ---------------------------------------------------------------------------

_ACTIVE: Optional[ChaosHarness] = None


def install(plan_or_harness) -> ChaosHarness:
    """Install a harness process-wide; returns it.  Production call sites
    start injecting on their next visit."""
    global _ACTIVE
    h = (plan_or_harness if isinstance(plan_or_harness, ChaosHarness)
         else ChaosHarness(plan_or_harness))
    _ACTIVE = h
    return h


def uninstall() -> None:
    global _ACTIVE
    _ACTIVE = None


def current() -> Optional[ChaosHarness]:
    return _ACTIVE


@contextlib.contextmanager
def active(plan_or_harness):
    """``with chaos.active(plan) as harness: ...`` — scoped installation."""
    h = install(plan_or_harness)
    try:
        yield h
    finally:
        uninstall()


def fire(site: str, **ctx) -> List[FaultSpec]:
    """The production-side hook: returns the faults due at this visit of
    ``site`` ([] when no harness is installed — the common, free case)."""
    if _ACTIVE is None:
        return []
    return _ACTIVE.poke(site, **ctx)


# ---------------------------------------------------------------------------
# fault appliers shared by the consuming sites
# ---------------------------------------------------------------------------

def stall_seconds(faults: Sequence[FaultSpec], default: float = 0.05) -> float:
    """Total sleep the ``stall_*``/``delay_*`` faults in ``faults`` ask for
    (the caller sleeps; 0.0 when none are due)."""
    total = 0.0
    for f in faults:
        if f.kind.startswith(("stall_", "delay_")):
            total += default if f.arg is None else float(f.arg)
    return total


def maybe_stall(faults: Sequence[FaultSpec], default: float = 0.05) -> float:
    """Sleep for the stall faults in ``faults``; returns seconds slept."""
    s = stall_seconds(faults, default)
    if s > 0:
        time.sleep(s)
    return s


def poison_records(faults: Sequence[FaultSpec], records):
    """Apply the telemetry-poisoning faults in ``faults`` to a **copy** of a
    controller-bound record tree (``{target: {field: array}}``).

    * ``poison_nan``  — NaN the bit-occupancy counts (corrupt shard math);
    * ``poison_inf``  — +Inf the error limb sums;
    * ``poison_outlier`` — scale counts/limbs/samples by ``arg`` (default
      1000x): finite but absurd, the robust-z / bounds quarantine case.

    Non-poison faults are ignored, so sites can pass their full hit list."""
    kinds = [f for f in faults if f.kind.startswith("poison_")]
    if not kinds:
        return records
    out = {t: {k: np.array(v) for k, v in rec.items()}
           for t, rec in records.items()}
    for f in kinds:
        for target, rec in out.items():
            if f.kind == "poison_nan":
                for k in ("bits_a", "bits_b", "tile_bits_a"):
                    if k in rec:
                        rec[k] = np.full_like(
                            np.asarray(rec[k], np.float32), np.nan)
            elif f.kind == "poison_inf":
                for k in ("neg_a", "neg_b", "tile_neg_a"):
                    if k in rec:
                        rec[k] = np.full_like(
                            np.asarray(rec[k], np.float32), np.inf)
            elif f.kind == "poison_outlier":
                scale = 1000.0 if f.arg is None else float(f.arg)
                for k in ("bits_a", "bits_b", "err_lo", "err_hi",
                          "a_smp", "b_smp"):
                    if k in rec:
                        v = np.asarray(rec[k])
                        rec[k] = (v.astype(np.float64) * scale).astype(
                            np.float64 if np.issubdtype(v.dtype, np.floating)
                            else np.int64)
    return out
