"""Versioned SWAPPER policy store: the fleet's single source of policy truth.

A :class:`PolicyStore` persists :class:`~repro.runtime.policy.SwapPolicy`
JSON under monotonically increasing versions with a **single-writer /
many-reader** protocol:

* the writer (the fleet's :class:`~repro.runtime.AdaptiveController`)
  publishes each re-tuned policy as ``policy_v{N}.json`` followed by an
  atomic ``CURRENT`` pointer swap — a reader never sees a torn write, and a
  crash mid-publish leaves the previous version current;
* readers (serve replicas, restarted trainers) poll ``CURRENT`` and reload
  only when the version advanced, so steady-state polling is one small
  ``read()`` per check and adopting a new policy changes **traced int32
  values only** (zero recompiles downstream).

The same directory format doubles as the train loop's policy checkpoint
(``launch/train --adaptive`` publishes on re-tune and resumes the newest
version on elastic restart — see ``AdaptiveController.resume_from_store``).

Published policies carry the *whole* granularity hierarchy — global /
per-target / per-layer scalar configs AND per-row-tile ``tile_grids`` — in
one JSON document, so a tile-granular re-tune propagates to every replica
through the exact same version bump as a scalar one (see
``docs/policy-lifecycle.md`` for the full lifecycle).
"""
from __future__ import annotations

import os
import re
from typing import List, Optional, Sequence, Tuple

from repro import obs
from repro.runtime.policy import SwapPolicy

__all__ = ["PolicyStore", "PolicyReader"]

_CURRENT = "CURRENT"
_HEARTBEAT = "HEARTBEAT"
_FMT = "policy_v{:06d}.json"
_RX = re.compile(r"^policy_v(\d{6})\.json$")

# host-side observability (repro.obs).  The published-version gauge plus the
# per-replica staleness gauge together disambiguate the two zero-lag cases:
# published == 0 means nothing was ever published (staleness 0 is vacuous);
# published > 0 with staleness k > 0 means that replica is k versions behind.
_REG = obs.default_registry()
_PUBLISHED = _REG.gauge(
    "repro_policy_store_published",
    "current PolicyStore version (0 = nothing published yet)")
_PUBLISHES = _REG.counter(
    "repro_policy_publishes_total", "policies published by this process")
_STALENESS = _REG.gauge(
    "repro_replica_staleness",
    "store versions this replica's adopted policy is behind CURRENT")
_ADOPTIONS = _REG.counter(
    "repro_policy_adoptions_total",
    "newer store policies adopted by this replica's poll()")
_POLL_FAST = _REG.counter(
    "repro_policy_poll_total",
    "PolicyReader.poll calls by path (heartbeat fast-path vs full read)")


class PolicyStore:
    """Directory-backed versioned policy storage (see module docstring).

    Layout::

        <root>/CURRENT              # text file: current version number
        <root>/policy_v000001.json  # immutable once written
        <root>/policy_v000002.json
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._last_published: Optional[int] = None

    # -- paths ---------------------------------------------------------
    def _path(self, version: int) -> str:
        return os.path.join(self.root, _FMT.format(version))

    def versions(self) -> List[int]:
        out = []
        for fn in os.listdir(self.root):
            m = _RX.match(fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    # -- reader side ---------------------------------------------------
    def current_version(self) -> Optional[int]:
        """The published version per the ``CURRENT`` pointer (falls back to
        the newest on-disk version if the pointer is missing)."""
        try:
            with open(os.path.join(self.root, _CURRENT)) as f:
                return int(f.read().strip())
        except (FileNotFoundError, ValueError):
            vs = self.versions()
            return vs[-1] if vs else None

    def load(self, version: int) -> SwapPolicy:
        return SwapPolicy.load(self._path(version))

    def load_current(self) -> Optional[Tuple[int, SwapPolicy]]:
        """(version, policy) of the current pointer, or None when empty.
        Retries once if the pointed-at file was pruned mid-read."""
        for _ in range(2):
            v = self.current_version()
            if v is None:
                return None
            try:
                return v, self.load(v)
            except FileNotFoundError:
                continue
        return None

    # -- writer side ---------------------------------------------------
    def publish(self, policy: SwapPolicy) -> int:
        """Persist ``policy`` as the next version and swing ``CURRENT``.

        Single-writer: raises if another writer advanced the store past this
        instance's last publish (split-brain guard — a fleet has exactly one
        re-tuning controller).  The policy's own ``version`` is rewritten to
        the store version so readers compare a single counter.
        """
        cur = self.current_version()
        if (self._last_published is not None and cur is not None
                and cur > self._last_published):
            raise RuntimeError(
                f"PolicyStore single-writer violation: on-disk version {cur} "
                f"> last published {self._last_published} (second writer?)")
        version = (cur or 0) + 1
        policy.version = version
        path = self._path(version)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(policy.to_json())
        os.replace(tmp, path)
        # heartbeat BEFORE the CURRENT swap: a crash between the two leaves
        # hb > CURRENT, which readers treat as "never cache, take the full
        # path" — degraded to pre-heartbeat polling, never a missed publish
        # (the reverse order could hide a committed version from fast-path
        # readers forever)
        self._touch_heartbeat(version)
        cur_tmp = os.path.join(self.root, _CURRENT + ".tmp")
        with open(cur_tmp, "w") as f:
            f.write(str(version))
        os.replace(cur_tmp, os.path.join(self.root, _CURRENT))
        self._last_published = version
        _PUBLISHED.set(version)
        _PUBLISHES.inc(1)
        return version

    def _touch_heartbeat(self, version: int) -> None:
        """Touch ``HEARTBEAT`` with ``mtime_ns == version``: readers
        fast-path their poll on one ``stat()`` of this file.  Setting the
        mtime to the version (instead of wall time) makes the signal
        strictly monotonic and immune to filesystem mtime granularity —
        two publishes inside one clock quantum still produce two distinct
        heartbeat values."""
        path = os.path.join(self.root, _HEARTBEAT)
        if not os.path.exists(path):
            with open(path, "w"):
                pass
        os.utime(path, ns=(version, version))

    def heartbeat_ns(self) -> Optional[int]:
        """``HEARTBEAT`` mtime_ns (== last published version), or None when
        the store predates heartbeats / has never published."""
        try:
            return os.stat(os.path.join(self.root, _HEARTBEAT)).st_mtime_ns
        except FileNotFoundError:
            return None

    def prune(self, keep_last: int = 8) -> List[int]:
        """Drop all but the newest ``keep_last`` versions (never the current
        one).  Returns the versions removed."""
        vs = self.versions()
        cur = self.current_version()
        drop = [v for v in vs[:-keep_last] if v != cur] if keep_last else []
        for v in drop:
            os.remove(self._path(v))
        return drop


class PolicyReader:
    """A serve replica's view of the store: polls ``CURRENT``, adopts newer
    policies, and exposes the same ``dyn_tree()`` / ``observe()`` /
    ``tile_rows`` surface the engine expects from an adaptive controller —
    so a replica runs the exact same zero-recompile dynamic decode program
    as the re-tuning host (including per-row-tile config grids when
    ``tile_rows > 0``: a published ``tile_grids`` entry lands here as new
    traced int32 values on the next :meth:`poll`, no retrace), with
    telemetry collection decimated away (records are discarded; the fleet
    aggregate is owned by the writer).

    :meth:`staleness` is the replica's lag metric — how many store versions
    CURRENT has advanced past the one this replica serves.  It reads only
    the (small) CURRENT pointer, so fleet monitors can sample it cheaply
    without forcing an adoption (``launch/serve --fleet`` prints it per
    replica)."""

    def __init__(self, store: PolicyStore, targets: Sequence[str],
                 tile_rows: int = 0, name: str = "replica"):
        self.store = store
        self.targets = tuple(targets)
        self.tile_rows = int(tile_rows)
        self.name = name
        self.version: int = -1
        self.policy: Optional[SwapPolicy] = None
        self._dyn_cache = None
        self._hb_seen: Optional[int] = None    # heartbeat ns at last full poll
        self.poll()

    def poll(self) -> bool:
        """Adopt the store's current policy if newer; True when it changed.

        Fast path: the writer touches ``HEARTBEAT`` with ``mtime_ns ==
        version`` on every publish, so an unchanged heartbeat proves no
        publish happened since the last full poll and the whole check is one
        ``stat()`` — no ``CURRENT`` read, no JSON load.  Stores without a
        heartbeat (pre-heartbeat layouts, manual edits) always take the full
        path."""
        hb = self.store.heartbeat_ns()
        if hb is not None and hb == self._hb_seen:
            _POLL_FAST.inc(1, path="heartbeat")
            self._set_staleness(0 if self.version >= hb else None)
            return False
        _POLL_FAST.inc(1, path="full")
        v = self.store.current_version()
        # cache the heartbeat only once CURRENT caught up to it: hb >
        # CURRENT happens in the instant (or crash window) between the
        # writer's heartbeat touch and pointer swap, and caching there
        # would fast-path right past the commit
        caught_up = hb is not None and v is not None and v >= hb
        if v is None or v == self.version:
            self._hb_seen = hb if caught_up else None
            self._set_staleness(None)
            return False
        got = self.store.load_current()
        if got is None:
            return False
        self.version, self.policy = got
        self._dyn_cache = None
        self._hb_seen = hb if caught_up else None
        _ADOPTIONS.inc(1, replica=self.name)
        self._set_staleness(None)
        return True

    def _set_staleness(self, known: Optional[int]) -> None:
        _STALENESS.set(self.staleness() if known is None else known,
                       replica=self.name)

    def staleness(self) -> int:
        """Store versions this replica is behind ``CURRENT`` (0 = serving
        the newest policy; one cheap pointer read, adopts nothing).  A
        replica that has never adopted anything (spun up against an empty
        store) counts as behind *every* published version — maximal lag,
        not zero."""
        v = self.store.current_version()
        if v is None:
            return 0
        return max(0, v - max(self.version, 0))

    # -- engine-facing surface (duck-typed AdaptiveController subset) --
    def dyn_tree(self):
        if self.policy is None:
            raise RuntimeError("PolicyReader: store is empty (no published policy)")
        if self._dyn_cache is None:
            self._dyn_cache = self.policy.dyn_tree(self.targets, self.tile_rows)
        return self._dyn_cache

    def observe(self, records) -> list:
        """Replicas do not own the fleet aggregate: records are dropped."""
        return []
