"""Versioned SWAPPER policy store: the fleet's single source of policy truth.

A :class:`PolicyStore` persists :class:`~repro.runtime.policy.SwapPolicy`
JSON under monotonically increasing versions with a **single-writer /
many-reader** protocol:

* the writer (the fleet's :class:`~repro.runtime.AdaptiveController`)
  publishes each re-tuned policy as ``policy_v{N}.json`` followed by an
  atomic ``CURRENT`` pointer swap — a reader never sees a torn write, and a
  crash mid-publish leaves the previous version current;
* readers (serve replicas, restarted trainers) poll ``CURRENT`` and reload
  only when the version advanced, so steady-state polling is one small
  ``read()`` per check and adopting a new policy changes **traced int32
  values only** (zero recompiles downstream).

The same directory format doubles as the train loop's policy checkpoint
(``launch/train --adaptive`` publishes on re-tune and resumes the newest
version on elastic restart — see ``AdaptiveController.resume_from_store``).

Published policies carry the *whole* granularity hierarchy — global /
per-target / per-layer scalar configs AND per-row-tile ``tile_grids`` — in
one JSON document, so a tile-granular re-tune propagates to every replica
through the exact same version bump as a scalar one (see
``docs/policy-lifecycle.md`` for the full lifecycle).
"""
from __future__ import annotations

import os
import re
import time
from typing import List, Optional, Sequence, Tuple

from repro import obs
from repro.runtime.policy import SwapPolicy

from . import chaos

__all__ = ["PolicyStore", "PolicyReader"]

_CURRENT = "CURRENT"
_HEARTBEAT = "HEARTBEAT"
_FMT = "policy_v{:06d}.json"
_RX = re.compile(r"^policy_v(\d{6})\.json$")
_CAND_SUFFIX = ".cand"
_RX_CAND = re.compile(r"^policy_v(\d{6})\.json\.cand$")
_RX_DEAD = re.compile(r"^policy_v(\d{6})\.json\.cand\.rejected$")

# reader-side load failures a replica must degrade through, never crash on:
# pruned/missing files, torn JSON, schema-mangled documents
_READ_ERRS = (OSError, ValueError, KeyError, TypeError)

# host-side observability (repro.obs).  The published-version gauge plus the
# per-replica staleness gauge together disambiguate the two zero-lag cases:
# published == 0 means nothing was ever published (staleness 0 is vacuous);
# published > 0 with staleness k > 0 means that replica is k versions behind.
_REG = obs.default_registry()
_PUBLISHED = _REG.gauge(
    "repro_policy_store_published",
    "current PolicyStore version (0 = nothing published yet)")
_PUBLISHES = _REG.counter(
    "repro_policy_publishes_total", "policies published by this process")
_STALENESS = _REG.gauge(
    "repro_replica_staleness",
    "store versions this replica's adopted policy is behind CURRENT")
_ADOPTIONS = _REG.counter(
    "repro_policy_adoptions_total",
    "newer store policies adopted by this replica's poll()")
_POLL_FAST = _REG.counter(
    "repro_policy_poll_total",
    "PolicyReader.poll calls by path (heartbeat fast-path vs full read)")
_READ_ERRORS = _REG.counter(
    "repro_store_read_errors",
    "reader-side policy load failures degraded through (pruned/corrupt "
    "CURRENT or policy JSON), by exception type")
_ROLLBACKS_STORE = _REG.counter(
    "repro_store_rollbacks_total",
    "CURRENT re-points to an older (last-good) version")
_RECOVERED_TMP = _REG.counter(
    "repro_store_recovered_tmp_total",
    "orphaned publish temp files swept at store open (crash mid-publish)")


class PolicyStore:
    """Directory-backed versioned policy storage (see module docstring).

    Layout::

        <root>/CURRENT              # text file: current version number
        <root>/policy_v000001.json  # immutable once written
        <root>/policy_v000002.json
    """

    def __init__(self, root: str, recover_stale_s: float = 60.0):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._last_published: Optional[int] = None
        self._recover(recover_stale_s)

    def _recover(self, stale_s: float) -> None:
        """Crash-recovery sweep at open: remove ``*.tmp`` orphans left by a
        writer killed between temp write and rename.  Only *stale* orphans
        (older than ``stale_s``) are swept — a fresh tmp may belong to a
        publish in flight in another process, and removing it would turn a
        reader's open into a writer crash."""
        now = time.time()
        for fn in os.listdir(self.root):
            if not fn.endswith(".tmp"):
                continue
            path = os.path.join(self.root, fn)
            try:
                if now - os.stat(path).st_mtime >= stale_s:
                    os.remove(path)
                    _RECOVERED_TMP.inc(1)
            except OSError:
                continue                     # raced with another sweeper

    # -- paths ---------------------------------------------------------
    def _path(self, version: int) -> str:
        return os.path.join(self.root, _FMT.format(version))

    def _cand_path(self, version: int) -> str:
        return self._path(version) + _CAND_SUFFIX

    def versions(self) -> List[int]:
        out = []
        for fn in os.listdir(self.root):
            m = _RX.match(fn)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def _all_allocated(self) -> List[int]:
        """Every version number with a file on disk — promoted AND pending
        candidates — so allocation never reuses (and overwrites) a number
        after a rollback or a rejected candidate."""
        out = set(self.versions())
        for fn in os.listdir(self.root):
            m = _RX_CAND.match(fn) or _RX_DEAD.match(fn)
            if m:
                out.add(int(m.group(1)))
        return sorted(out)

    # -- reader side ---------------------------------------------------
    def current_version(self) -> Optional[int]:
        """The published version per the ``CURRENT`` pointer (falls back to
        the newest on-disk version if the pointer is missing)."""
        try:
            with open(os.path.join(self.root, _CURRENT)) as f:
                return int(f.read().strip())
        except (FileNotFoundError, ValueError):
            vs = self.versions()
            return vs[-1] if vs else None

    def load(self, version: int) -> SwapPolicy:
        return SwapPolicy.load(self._path(version))

    def load_current(self) -> Optional[Tuple[int, SwapPolicy]]:
        """(version, policy) of the current pointer, or None when empty.
        Retries once if the pointed-at file was pruned mid-read."""
        for _ in range(2):
            v = self.current_version()
            if v is None:
                return None
            try:
                return v, self.load(v)
            except FileNotFoundError:
                continue
        return None

    def load_newest_loadable(self) -> Optional[Tuple[int, SwapPolicy]]:
        """(version, policy) of the newest version that actually parses —
        the reader's last line of defense when CURRENT is torn/pruned and
        the newest file is corrupt.  Never raises; None for an empty (or
        fully corrupt) store."""
        for v in reversed(self.versions()):
            try:
                return v, self.load(v)
            except _READ_ERRS as e:
                _READ_ERRORS.inc(1, error=type(e).__name__)
                continue
        return None

    # -- writer side ---------------------------------------------------
    def _fsync_dir(self) -> None:
        """fsync the store directory so a just-committed rename survives a
        host crash, not only a process kill."""
        try:
            fd = os.open(self.root, os.O_RDONLY)
        except OSError:
            return                            # platform without dir-fsync
        try:
            os.fsync(fd)
        except OSError:
            pass
        finally:
            os.close(fd)

    def _write_atomic(self, name: str, text: str) -> None:
        """fsync'd temp + rename: a reader sees the old bytes or the new
        bytes, never a torn file, and a committed write survives power loss."""
        path = os.path.join(self.root, name)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        self._fsync_dir()

    def _check_single_writer(self, cur: Optional[int]) -> None:
        if (self._last_published is not None and cur is not None
                and cur > self._last_published):
            raise RuntimeError(
                f"PolicyStore single-writer violation: on-disk version {cur} "
                f"> last published {self._last_published} (second writer?)")

    def _next_version(self) -> int:
        """Next unused version number.  ``max(allocated) + 1`` rather than
        ``CURRENT + 1``: after a rollback CURRENT points *behind* existing
        immutable files, and candidate files also hold numbers — neither may
        ever be overwritten."""
        allocated = self._all_allocated()
        cur = self.current_version() or 0
        return max(allocated[-1] if allocated else 0, cur) + 1

    def publish(self, policy: SwapPolicy) -> int:
        """Persist ``policy`` as the next version and swing ``CURRENT``.

        Crash-atomic: the version file and the CURRENT pointer are both
        fsync'd temp+rename writes, so a kill at ANY point leaves either the
        previous version current (version file may exist uncommitted — the
        next publish allocates past it) or the new version fully committed.
        Chaos site ``store.publish`` injects exactly those kills.

        Single-writer: raises if another writer advanced the store past this
        instance's last publish (split-brain guard — a fleet has exactly one
        re-tuning controller).  The policy's own ``version`` is rewritten to
        the store version so readers compare a single counter.
        """
        faults = {f.kind for f in chaos.fire("store.publish")}
        cur = self.current_version()
        self._check_single_writer(cur)
        version = self._next_version()
        policy.version = version
        path = self._path(version)
        if "kill_mid_write" in faults:
            body = policy.to_json()
            with open(path + ".tmp", "w") as f:
                f.write(body[: len(body) // 2])   # torn temp, never renamed
            raise chaos.InjectedFault("store.publish: killed mid temp write")
        self._write_atomic(_FMT.format(version), policy.to_json())
        # heartbeat BEFORE the CURRENT swap: a crash between the two leaves
        # hb > CURRENT, which readers treat as "never cache, take the full
        # path" — degraded to pre-heartbeat polling, never a missed publish
        # (the reverse order could hide a committed version from fast-path
        # readers forever)
        self._touch_heartbeat(version)
        if "kill_before_current" in faults:
            raise chaos.InjectedFault(
                "store.publish: killed between heartbeat and CURRENT swap")
        if "torn_current" in faults:
            # tear the pointer the non-atomic way a buggy writer would (the
            # production path above never does this): garbage bytes, then die
            with open(os.path.join(self.root, _CURRENT), "w") as f:
                f.write("torn\x00")
            raise chaos.InjectedFault("store.publish: CURRENT torn mid-swap")
        self._write_atomic(_CURRENT, str(version))
        self._last_published = version
        _PUBLISHED.set(version)
        _PUBLISHES.inc(1)
        for f in chaos.fire("store.after_publish", version=version):
            if f.kind == "corrupt_policy":
                with open(path, "w") as fh:
                    fh.write('{"mult_name": "mu')   # truncated JSON
        return version

    # -- candidate / promote / rollback (guarded rollout) --------------
    def publish_candidate(self, policy: SwapPolicy) -> int:
        """Persist ``policy`` as ``policy_v{N}.json.cand`` WITHOUT touching
        CURRENT or the heartbeat: readers never adopt a candidate (the
        ``.cand`` suffix keeps it out of :meth:`versions`), but the retune
        attempt is durably recorded before the canary runs.  Returns the
        reserved version number."""
        version = self._next_version()
        policy.version = version
        self._write_atomic(_FMT.format(version) + _CAND_SUFFIX,
                           policy.to_json())
        return version

    def candidate_version(self) -> Optional[int]:
        """Newest pending candidate version (None when none pending)."""
        out = [int(m.group(1)) for fn in os.listdir(self.root)
               if (m := _RX_CAND.match(fn))]
        return max(out) if out else None

    def promote(self, version: int) -> int:
        """Graduate a candidate to a full version: rename ``.cand`` into the
        immutable version file, then heartbeat + CURRENT swap exactly like
        :meth:`publish` (same crash window semantics)."""
        cand = self._cand_path(version)
        cur = self.current_version()
        self._check_single_writer(cur)
        if os.path.exists(cand):
            os.replace(cand, self._path(version))
            self._fsync_dir()
        elif not os.path.exists(self._path(version)):
            raise FileNotFoundError(f"no candidate or version {version}")
        self._touch_heartbeat(version)
        self._write_atomic(_CURRENT, str(version))
        self._last_published = version
        _PUBLISHED.set(version)
        _PUBLISHES.inc(1)
        return version

    def reject_candidate(self, version: int) -> None:
        """Drop a canary-rejected candidate.  The file is renamed (not
        removed) to ``.cand.rejected`` so its number stays allocated — the
        audit trail references it and :meth:`_next_version` must never hand
        the same number to a different policy — but it can no longer be
        promoted or adopted."""
        try:
            os.replace(self._cand_path(version),
                       self._cand_path(version) + ".rejected")
        except FileNotFoundError:
            pass

    def rollback(self, version: int) -> int:
        """Re-point CURRENT at an existing (last-good) version.  The
        heartbeat is touched with the rollback target, which is safe because
        readers compare heartbeats by *equality*, not order — a reader whose
        cached heartbeat doesn't match takes the full path and adopts the
        rolled-back version like any other publish."""
        if not os.path.exists(self._path(version)):
            raise FileNotFoundError(f"rollback target v{version} not on disk")
        self._touch_heartbeat(version)
        self._write_atomic(_CURRENT, str(version))
        # keep the single-writer guard watermark at the HIGHEST version this
        # writer ever committed: after rollback CURRENT < watermark is
        # expected, and only a *third-party* advance past the watermark
        # still trips the guard
        self._last_published = max(self._last_published or 0, version)
        _PUBLISHED.set(version)
        _ROLLBACKS_STORE.inc(1)
        return version

    def _touch_heartbeat(self, version: int) -> None:
        """Touch ``HEARTBEAT`` with ``mtime_ns == version``: readers
        fast-path their poll on one ``stat()`` of this file.  Setting the
        mtime to the version (instead of wall time) makes the signal immune
        to filesystem mtime granularity — two publishes inside one clock
        quantum still produce two distinct heartbeat values.  Readers
        compare heartbeats by EQUALITY (``hb == last seen``), never order:
        a :meth:`rollback` legitimately moves the value backwards."""
        path = os.path.join(self.root, _HEARTBEAT)
        if not os.path.exists(path):
            with open(path, "w"):
                pass
        os.utime(path, ns=(version, version))

    def heartbeat_ns(self) -> Optional[int]:
        """``HEARTBEAT`` mtime_ns (== last published version), or None when
        the store predates heartbeats / has never published."""
        try:
            return os.stat(os.path.join(self.root, _HEARTBEAT)).st_mtime_ns
        except FileNotFoundError:
            return None

    def prune(self, keep_last: int = 8) -> List[int]:
        """Drop all but the newest ``keep_last`` versions (never the current
        one).  Returns the versions removed."""
        vs = self.versions()
        cur = self.current_version()
        drop = [v for v in vs[:-keep_last] if v != cur] if keep_last else []
        for v in drop:
            os.remove(self._path(v))
        return drop


class PolicyReader:
    """A serve replica's view of the store: polls ``CURRENT``, adopts newer
    policies, and exposes the same ``dyn_tree()`` / ``observe()`` /
    ``tile_rows`` surface the engine expects from an adaptive controller —
    so a replica runs the exact same zero-recompile dynamic decode program
    as the re-tuning host (including per-row-tile config grids when
    ``tile_rows > 0``: a published ``tile_grids`` entry lands here as new
    traced int32 values on the next :meth:`poll`, no retrace), with
    telemetry collection decimated away (records are discarded; the fleet
    aggregate is owned by the writer).

    :meth:`staleness` is the replica's lag metric — how many store versions
    CURRENT has advanced past the one this replica serves.  It reads only
    the (small) CURRENT pointer, so fleet monitors can sample it cheaply
    without forcing an adoption (``launch/serve --fleet`` prints it per
    replica)."""

    def __init__(self, store: PolicyStore, targets: Sequence[str],
                 tile_rows: int = 0, name: str = "replica",
                 retries: int = 3, backoff_s: float = 0.005,
                 backoff_cap_s: float = 0.1):
        self.store = store
        self.targets = tuple(targets)
        self.tile_rows = int(tile_rows)
        self.name = name
        self.version: int = -1
        self.policy: Optional[SwapPolicy] = None
        self._dyn_cache = None
        self._hb_seen: Optional[int] = None    # heartbeat ns at last full poll
        self.retries = int(retries)            # capped-backoff load attempts
        self.backoff_s = float(backoff_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.read_errors = 0                   # degraded loads this replica saw
        self.poll()

    def poll(self) -> bool:
        """Adopt the store's current policy if newer; True when it changed.

        Fast path: the writer touches ``HEARTBEAT`` with ``mtime_ns ==
        version`` on every publish, so an unchanged heartbeat proves no
        publish happened since the last full poll and the whole check is one
        ``stat()`` — no ``CURRENT`` read, no JSON load.  Stores without a
        heartbeat (pre-heartbeat layouts, manual edits) always take the full
        path.

        Never crashes the replica on store damage: a load hitting a pruned,
        torn or corrupt file retries with capped exponential backoff
        (re-reading CURRENT each attempt — the writer may repair it between
        retries), then falls back to the newest *loadable* version, and as a
        last resort keeps serving the already-adopted policy.  Every
        degraded load increments ``repro_store_read_errors``."""
        for f in chaos.fire("reader.poll", replica=self.name):
            if f.kind == "delay_poll":
                time.sleep(0.02 if f.arg is None else float(f.arg))
            elif f.kind == "crash_replica":
                raise chaos.InjectedFault(
                    f"reader.poll: replica {self.name} killed")
        hb = self.store.heartbeat_ns()
        if hb is not None and hb == self._hb_seen:
            _POLL_FAST.inc(1, path="heartbeat")
            self._set_staleness(0 if self.version == hb else None)
            return False
        _POLL_FAST.inc(1, path="full")
        v = self.store.current_version()
        # cache the heartbeat only once CURRENT caught up to it: hb >
        # CURRENT happens in the instant (or crash window) between the
        # writer's heartbeat touch and pointer swap, and caching there
        # would fast-path right past the commit
        caught_up = hb is not None and v is not None and v >= hb
        if v is None or v == self.version:
            self._hb_seen = hb if caught_up else None
            self._set_staleness(None)
            return False
        got = self._load_degrading(v)
        if got is None or got[0] == self.version:
            self._set_staleness(None)
            return False
        self.version, self.policy = got
        self._dyn_cache = None
        self._hb_seen = hb if caught_up else None
        _ADOPTIONS.inc(1, replica=self.name)
        self._set_staleness(None)
        return True

    def _load_degrading(self, v: Optional[int]):
        """Load version ``v`` with retries + backoff, then fall back to the
        newest loadable version.  Returns (version, policy) or None; never
        raises (the replica keeps serving what it has)."""
        for attempt in range(max(self.retries, 1)):
            if v is None:
                break
            try:
                return v, self.store.load(v)
            except _READ_ERRS as e:
                self.read_errors += 1
                _READ_ERRORS.inc(1, error=type(e).__name__)
                obs.instant("store_read_error", cat="store",
                            replica=self.name, version=v,
                            error=type(e).__name__, attempt=attempt)
                if attempt + 1 < max(self.retries, 1):
                    time.sleep(min(self.backoff_s * (2 ** attempt),
                                   self.backoff_cap_s))
                v = self.store.current_version()   # writer may have repaired
        return self.store.load_newest_loadable()

    def _set_staleness(self, known: Optional[int]) -> None:
        _STALENESS.set(self.staleness() if known is None else known,
                       replica=self.name)

    def staleness(self) -> int:
        """Store versions this replica is behind ``CURRENT`` (0 = serving
        the newest policy; one cheap pointer read, adopts nothing).  A
        replica that has never adopted anything (spun up against an empty
        store) counts as behind *every* published version — maximal lag,
        not zero."""
        v = self.store.current_version()
        if v is None:
            return 0
        return max(0, v - max(self.version, 0))

    # -- engine-facing surface (duck-typed AdaptiveController subset) --
    def dyn_tree(self):
        if self.policy is None:
            raise RuntimeError("PolicyReader: store is empty (no published policy)")
        if self._dyn_cache is None:
            self._dyn_cache = self.policy.dyn_tree(self.targets, self.tile_rows)
        return self._dyn_cache

    def observe(self, records) -> list:
        """Replicas do not own the fleet aggregate: records are dropped."""
        return []
