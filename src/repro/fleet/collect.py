"""In-graph cross-host telemetry aggregation for the sharded SWAPPER runtime.

The adaptive runtime's telemetry records are built from *sums* (per-bit
occupancy counts, limb-exact error sums, element counts), one *max* (the
worst-case error) and two operand *samples* — so the fleet-global record is
an exact ``psum`` / ``pmax`` / ``all_gather`` over the mesh batch axes,
applied **inside the sharded step** before the records ever leave the trace
(the field classes are owned by ``runtime.telemetry``).  One controller then
re-tunes from the global operand distribution: no host-side gather, no
per-shard policy skew, and the collective costs a few KB per observed step.

``shard_decode_specs`` derives the shard_map partition specs for the serving
step (batch-sharded token/cache leaves, replicated params/policy) from the
same logical-axis rules as ``launch/sharding.axis_rules`` — the mesh batch
axes are exactly the axes the batch dimension maps to ("pod" + "data").
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.runtime.telemetry import (
    MAX_FIELDS,
    SAMPLE_FIELDS,
    SUM_FIELDS,
    operand_summary,
    tile_key,
    tile_summary,
)

try:  # jax >= 0.5 re-exports shard_map at the top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = [
    "shard_map",
    "batch_axis_names",
    "aggregate_records",
    "shard_decode_specs",
    "token_step_specs",
    "make_sharded_summarizer",
]

shard_map = _shard_map


def batch_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh axes the batch dimension shards over — mirrors the 'batch'
    rule of ``launch.sharding.axis_rules`` ('pod' + 'data')."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _reduce_field(name: str, leaf, axes: Tuple[str, ...]):
    if name in MAX_FIELDS:
        return jax.lax.pmax(leaf, axes)
    if name in SAMPLE_FIELDS:
        # concatenate shard samples along axis -2: the call axis for the
        # scalar records ((ncalls, S) / slot-buffered (slots, ncalls, S)),
        # and the sample axis for the tile records — their samples are laid
        # out (..., S, gm) sample-major precisely so this shared rule
        # extends each tile's sample column instead of inventing new tiles
        return jax.lax.all_gather(leaf, axes, axis=leaf.ndim - 2, tiled=True)
    assert name in SUM_FIELDS, f"unclassified telemetry field {name!r}"
    return jax.lax.psum(leaf, axes)


def aggregate_records(records: Dict[str, Dict[str, jax.Array]],
                      axes: Tuple[str, ...]):
    """Fleet-reduce a scope-collected record tree inside a shard_map'd step.

    Sum fields are ``psum``'d (bit-exact: occupancy counts are small-integer
    float32, limb sums are uint32 within the 32-shard overflow bound),
    ``err_max`` is ``pmax``'d, and the re-tune operand samples are
    all-gathered so the controller's ring buffers see every shard's traffic.
    The result is identical on every shard and bit-equal to the host-side
    ``runtime.telemetry.combine_records`` of the per-shard records.
    """
    if not axes:
        return records
    return {
        target: {k: _reduce_field(k, v, axes) for k, v in rec.items()}
        for target, rec in records.items()
    }


# ---------------------------------------------------------------------------
# partition specs for the sharded decode step
# ---------------------------------------------------------------------------

def _tree_path_strs(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat], [leaf for _, leaf in flat]


def cache_pspecs(cache, batch: int, axes: Tuple[str, ...]):
    """PartitionSpec tree sharding each decode-cache leaf's *batch* dim over
    ``axes`` (scan-stacked 'stack/' leaves carry a leading layer dim; the
    encoder-decoder cross-cache layout is not supported in the fleet path)."""
    paths, leaves = _tree_path_strs(cache)
    treedef = jax.tree_util.tree_structure(cache)
    specs = []
    for path, leaf in zip(paths, leaves):
        bdim = 1 if path.startswith("stack/") else 0
        assert leaf.shape[bdim] == batch, (
            f"fleet cache spec: leaf {path} shape {leaf.shape} has no batch "
            f"dim {batch} at axis {bdim}")
        specs.append(P(*([None] * bdim + [axes])))
    return jax.tree_util.tree_unflatten(treedef, specs)


def _batch_axes_checked(batch: int, mesh: Mesh) -> Tuple[str, ...]:
    axes = batch_axis_names(mesh)
    nshard = 1
    for a in axes:
        nshard *= mesh.shape[a]
    assert nshard and batch % nshard == 0, (
        f"fleet serving batch {batch} must divide the mesh batch axes "
        f"{axes} (|{axes}| = {nshard})")
    assert nshard <= 32, (
        f"{nshard} batch shards would overflow the uint32 error-limb psum "
        f"(see runtime.telemetry field classes: bound is 32 shards at "
        f"TELEMETRY_SAMPLE=2048)")
    return axes


def shard_decode_specs(cache, batch: int, mesh: Mesh):
    """(in_specs, out_specs, axes) for the shard_map'd fused adaptive decode
    ``(params, cache, tok0, key0, pos0, budget, bmax, dyn) -> (toks,
    telem)``:

    * params / RNG key / the global-budget-max scalar (the shard-invariant
      telemetry gate) / policy triples are replicated,
    * the token vector, the per-slot position/budget vectors and every
      cache leaf shard their batch dim,
    * output tokens stay batch-sharded; the telemetry tree is replicated
      (it was psum/pmax/all-gathered inside the step).
    """
    axes = _batch_axes_checked(batch, mesh)
    in_specs = (P(), cache_pspecs(cache, batch, axes), P(axes), P(),
                P(axes), P(axes), P(), P())
    out_specs = (P(None, axes), P())
    return in_specs, out_specs, axes


def token_step_specs(cache, batch: int, mesh: Mesh):
    """(in_specs, out_specs, axes) for the shard_map'd token-granular step
    ``(params, cache, tok, sub, pos, active, dyn, gate) -> (tok, cache,
    telem)``: per-slot vectors and cache leaves shard their batch dim,
    everything else is replicated (the telemetry tree was aggregated
    in-graph)."""
    axes = _batch_axes_checked(batch, mesh)
    cspecs = cache_pspecs(cache, batch, axes)
    in_specs = (P(), cspecs, P(axes), P(), P(axes), P(axes), P(), P())
    out_specs = (P(axes), cspecs, P())
    return in_specs, out_specs, axes


# ---------------------------------------------------------------------------
# model-free sharded summarizer (benchmarks / synthetic fleet streams)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def make_sharded_summarizer(mult_name: str, mesh: Mesh, target: str = "stream",
                            tile_rows: int = 0):
    """jit(shard_map(...)) producing the fleet-aggregated telemetry record of
    a raw int operand pair stream sharded over the mesh batch axes.  Feed the
    result straight to ``AdaptiveController.observe`` — the controller then
    re-tunes from the *global* operand distribution while each shard only
    ever summarized its local slice.

    ``tile_rows > 0`` additionally emits the per-row-tile record (sharding a
    2-D stream's *rows*, i.e. each shard summarizes its local row slice at
    ``tile_rows`` tiles): the returned dict then maps both ``target`` and
    ``tile_key(target)`` to fleet-aggregated records — tile histograms psum
    position-wise (shard-local row tile t pools into fleet tile t), tile
    samples all-gather along the sample axis, so the controller's per-tile
    re-tune sees every shard's traffic for each tile position."""
    from repro.core import multipliers as M

    mult = M.get(mult_name)
    axes = batch_axis_names(mesh)
    nshard = 1
    for a in axes:
        nshard *= mesh.shape[a]
    assert nshard <= 32, (
        f"{nshard} shards would overflow the uint32 error-limb psum")

    def local(a, b, dyn):
        rec = operand_summary(a, b, mult, dyn)
        if tile_rows == 0:                   # original single-record surface
            rec = {k: v[None] for k, v in rec.items()}   # leading call axis
            return aggregate_records({target: rec}, axes)[target]
        trec = tile_summary(a, b, mult, tile_rows, dyn=dyn)
        recs = {target: {k: v[None] for k, v in rec.items()},
                tile_key(target): {k: v[None] for k, v in trec.items()}}
        return aggregate_records(recs, axes)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(axes), P(axes), P()), out_specs=P(),
                  check_rep=False)
    return jax.jit(f)
