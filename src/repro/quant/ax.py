"""SWAPPER approximate matmul as a first-class LM projection (DESIGN.md §5).

Three backends:

* ``mxu`` — **beyond-paper production path**.  For *separable* multiplier
  families, m(a, b) = f(a) * g(b) elementwise (operand truncation zeroes low
  bits of each operand; partial-product perforation zeroes rows of B), so the
  approximate inner product factorizes into exact matmuls of transformed int8
  operands — which run on the MXU.  The two-limb factorization

      NoSwap:        C = f(A) @ g(B)
      swap on A bit: C = (s⊙g(A)) @ f(B) + ((1-s)⊙f(A)) @ g(B)
      swap on B bit: C = g(A) @ (s⊙f(B)) + f(A) @ ((1-s)⊙g(B))

  (s = the SWAPPER bit mask of the decision operand) is dispatched as a
  **single K-stacked int8 matmul** over a concatenated 2K inner dimension,
  ``[X1|X2] @ [Y1;Y2]`` — int32 accumulation makes the stacked reduction
  bit-identical to ``X1@Y1 + X2@Y2`` while halving the dispatch count and
  doubling MXU occupancy per call.  The pre-stacking 2-matmul forms are kept
  (``ax_matmul_int_2mm`` / ``ax_matmul_int_dyn_2mm``) as bit-identity oracles
  and benchmark baselines.  This turns the paper's per-multiply mechanism
  into MXU-rate compute instead of a VPU elementwise pipeline — bit-identical
  to the Pallas kernel (tested).

* ``kernel`` — the Pallas ``ax_matmul`` VPU kernel (arbitrary families,
  including LUT circuits).

* ``emul`` — pure-jnp reference (small shapes / tests).

Training uses a straight-through estimator: forward = approximate quantized
matmul, backward = exact matmul gradients.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AxPolicy
from repro.core import multipliers as M
from repro.core.swapper import SwapConfig, apply_swapper_dyn

__all__ = [
    "ax_dense",
    "ax_dense_dyn",
    "quantize_rows",
    "separable_transforms",
    "ax_matmul_int",
    "ax_matmul_int_dyn",
    "ax_matmul_int_2mm",
    "ax_matmul_int_dyn_2mm",
]


# ---------------------------------------------------------------------------
# separable closed forms
# ---------------------------------------------------------------------------

def _trunc_t(k):
    mask = jnp.int32(~((1 << k) - 1))

    def f(x):  # sign-magnitude low-bit truncation (matches multipliers.trunc)
        neg = x < 0
        mag = jnp.where(neg, -x, x) & mask
        return jnp.where(neg, -mag, mag)

    return f


def separable_transforms(mult_name: str) -> Optional[Tuple[Callable, Callable]]:
    """(f, g) with m(a,b) = f(a)*g(b), or None if the family is inseparable."""
    base = mult_name.split("_", 1)[1] if "_" in mult_name else mult_name
    if base.startswith("trunc"):
        ka, kb = (int(v) for v in base[len("trunc"):].split("_"))
        return _trunc_t(ka), _trunc_t(kb)
    if base.startswith("perf"):
        rows = tuple(int(v) for v in base[len("perf"):].split("_"))
        rowmask = 0
        for r in rows:
            rowmask |= 1 << r
        inv = jnp.int32(~rowmask)

        def g(x):
            neg = x < 0
            mag = jnp.where(neg, -x, x) & inv
            return jnp.where(neg, -mag, mag)

        return (lambda x: x), g
    return None


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

def quantize_rows(x, axis=-1):
    """Symmetric per-row int8 quantization along ``axis``."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _swap_mask(x_i32, cfg: SwapConfig):
    return (((x_i32 >> cfg.bit) & 1) == cfg.value)


def _int_mm(a, b):
    """Exact int8 matmul with int32 accumulation (MXU-native on TPU)."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _stacked_mm(x1, y1, x2, y2):
    """``X1 @ Y1 + X2 @ Y2`` as ONE int8 matmul over a concatenated 2K inner
    dimension: ``[X1|X2] @ [Y1;Y2]``.  int32 accumulation is exact, so the
    stacked reduction is bit-identical to the two-matmul sum while halving
    the dispatch count (one MXU pass over 2K instead of two over K)."""
    x = jnp.concatenate([x1, x2], axis=-1)
    y = jnp.concatenate([y1, y2], axis=0)
    return _int_mm(x, y)


def _mxu_limbs(ai, bi, f, g, swap: SwapConfig):
    """The (X1, Y1, X2, Y2) int8 limbs of the static swap factorization."""
    if swap.operand == "A":
        s = _swap_mask(ai, swap).astype(jnp.int32)
        return ((s * g(ai)).astype(jnp.int8), f(bi).astype(jnp.int8),
                ((1 - s) * f(ai)).astype(jnp.int8), g(bi).astype(jnp.int8))
    s = _swap_mask(bi, swap).astype(jnp.int32)
    return (g(ai).astype(jnp.int8), (s * f(bi)).astype(jnp.int8),
            f(ai).astype(jnp.int8), ((1 - s) * g(bi)).astype(jnp.int8))


def _mxu_limbs_dyn(ai, bi, f, g, op_is_a, bit, value):
    """The (X1, Y1, X2, Y2) limbs with the swap decision as traced scalars.

    With row mask sa (decision on A) / column mask sb (decision on B), each
    gated by op_is_a, ``X1 @ Y1 + X2 @ Y2`` equals the A-form or B-form
    static factorization for every triple.  ``value == 2`` (the NoSwap
    encoding) zeroes sa and sb, which zeroes one limb entirely — the traced
    NoSwap fast path: the compiled program stays config-agnostic and the
    zero limb contributes nothing to the stacked reduction."""
    is_a = op_is_a == 1
    sa = ((((ai >> bit) & 1) == value) & is_a).astype(jnp.int32)
    sb = ((((bi >> bit) & 1) == value) & ~is_a).astype(jnp.int32)
    x1 = jnp.where(is_a, sa * g(ai), g(ai)).astype(jnp.int8)
    y1 = jnp.where(is_a, f(bi), sb * f(bi)).astype(jnp.int8)
    x2 = jnp.where(is_a, (1 - sa) * f(ai), f(ai)).astype(jnp.int8)
    y2 = jnp.where(is_a, g(bi), (1 - sb) * g(bi)).astype(jnp.int8)
    return x1, y1, x2, y2


def _pad_for_kernel(a_i8, b_i8):
    """Flatten leading dims and zero-pad both operands to block multiples for
    the Pallas kernels.  Returns (a2d, b, lead_shape, m0, n0, (bm, bn, bk));
    callers crop ``out[:m0, :n0]`` and reshape to ``(*lead, n0)``."""
    lead = a_i8.shape[:-1]
    a2d = a_i8.reshape(-1, a_i8.shape[-1])
    m0, k0 = a2d.shape
    n0 = b_i8.shape[-1]
    bm, bn, bk = min(128, m0), min(128, n0), min(128, k0)

    def _pad(v, mult_, axis):
        pad = (-v.shape[axis]) % mult_
        if pad == 0:
            return v
        widths = [(0, 0)] * v.ndim
        widths[axis] = (0, pad)
        return jnp.pad(v, widths)

    a2d = _pad(_pad(a2d, bm, 0), bk, 1)
    bp = _pad(_pad(b_i8, bk, 0), bn, 1)
    return a2d, bp, lead, m0, n0, (bm, bn, bk)


def ax_matmul_int(a_i8, b_i8, policy: AxPolicy) -> jax.Array:
    """Approximate int matmul (..., K) @ (K, N) -> (..., N) int32.

    The mxu backend dispatches exactly one int8 ``dot_general`` per call:
    NoSwap is the plain ``f(A) @ g(B)``, a swap config K-stacks the two
    factorization limbs into a single matmul over the 2K inner dimension."""
    mult = M.get(policy.mult_name)
    swap = policy.swap
    if policy.backend == "mxu":
        sep = separable_transforms(policy.mult_name)
        assert sep is not None, f"{policy.mult_name} is not separable; use backend='kernel'"
        f, g = sep
        ai = a_i8.astype(jnp.int32)
        bi = b_i8.astype(jnp.int32)
        if swap is None:
            return _int_mm(f(ai).astype(jnp.int8), g(bi).astype(jnp.int8))
        return _stacked_mm(*_mxu_limbs(ai, bi, f, g, swap))
    if policy.backend == "kernel":
        from repro.kernels import ax_matmul as kernel_mm

        a2d, bp, lead, m0, n0, (bm, bn, bk) = _pad_for_kernel(a_i8, b_i8)
        out = kernel_mm(a2d, bp, mult, swap, block_m=bm, block_n=bn, block_k=bk)
        return out[:m0, :n0].reshape(*lead, n0)
    # 'emul'
    from repro.kernels.ref import ax_matmul_ref

    lead = a_i8.shape[:-1]
    a2d = a_i8.reshape(-1, a_i8.shape[-1])
    return ax_matmul_ref(a2d, b_i8, mult, swap).reshape(*lead, b_i8.shape[-1])


def ax_matmul_int_2mm(a_i8, b_i8, policy: AxPolicy) -> jax.Array:
    """The pre-K-stacking 2-matmul mxu factorization, retained as the
    bit-identity oracle and the old-path benchmark baseline (see
    ``benchmarks/perf_table.py``).  mxu backend only."""
    assert policy.backend == "mxu", policy.backend
    sep = separable_transforms(policy.mult_name)
    assert sep is not None, f"{policy.mult_name} is not separable"
    f, g = sep
    ai = a_i8.astype(jnp.int32)
    bi = b_i8.astype(jnp.int32)
    if policy.swap is None:
        return _int_mm(f(ai).astype(jnp.int8), g(bi).astype(jnp.int8))
    x1, y1, x2, y2 = _mxu_limbs(ai, bi, f, g, policy.swap)
    return _int_mm(x1, y1) + _int_mm(x2, y2)


# ---------------------------------------------------------------------------
# dynamic-config variants (the adaptive-runtime zero-recompile path)
# ---------------------------------------------------------------------------

def ax_matmul_int_dyn(a_i8, b_i8, policy: AxPolicy, dyn) -> jax.Array:
    """``ax_matmul_int`` with the swap decision as a traced (op_is_a, bit,
    value) int32 triple, so the adaptive controller can re-tune a serving
    step without recompiling it (value=2 encodes NoSwap).

    The mxu backend dispatches the factorization limbs of ``_mxu_limbs_dyn``
    as one K-stacked int8 matmul — bit-identical to the static path for
    every triple, still MXU-rate, and exactly one ``dot_general`` in the
    compiled step regardless of the traced config (NoSwap rides the same
    program with a zeroed limb)."""
    mult = M.get(policy.mult_name)
    op_is_a, bit, value = dyn[0], dyn[1], dyn[2]
    if policy.backend == "mxu":
        sep = separable_transforms(policy.mult_name)
        assert sep is not None, f"{policy.mult_name} is not separable; use backend='kernel'"
        f, g = sep
        ai = a_i8.astype(jnp.int32)
        bi = b_i8.astype(jnp.int32)
        return _stacked_mm(*_mxu_limbs_dyn(ai, bi, f, g, op_is_a, bit, value))
    if policy.backend == "kernel":
        from repro.kernels import ax_matmul_grid

        a2d, bp, lead, m0, n0, (bm, bn, bk) = _pad_for_kernel(a_i8, b_i8)
        gm, gn = a2d.shape[0] // bm, bp.shape[1] // bn
        grid = jnp.broadcast_to(jnp.asarray(dyn, jnp.int32), (gm, gn, 3))
        out = ax_matmul_grid(a2d, bp, mult, grid, block_m=bm, block_n=bn, block_k=bk)
        return out[:m0, :n0].reshape(*lead, n0)
    # 'emul'
    lead = a_i8.shape[:-1]
    A = a_i8.reshape(-1, a_i8.shape[-1]).astype(jnp.int32)[:, :, None]
    B = b_i8.astype(jnp.int32)[None, :, :]
    prod = apply_swapper_dyn(mult, A, B, op_is_a, bit, value).astype(jnp.int32)
    return jnp.sum(prod, axis=1, dtype=jnp.int32).reshape(*lead, b_i8.shape[-1])


def ax_matmul_int_dyn_2mm(a_i8, b_i8, policy: AxPolicy, dyn) -> jax.Array:
    """The pre-K-stacking 2-matmul dynamic mxu path (bit-identity oracle /
    benchmark baseline).  mxu backend only."""
    assert policy.backend == "mxu", policy.backend
    sep = separable_transforms(policy.mult_name)
    assert sep is not None, f"{policy.mult_name} is not separable"
    f, g = sep
    ai = a_i8.astype(jnp.int32)
    bi = b_i8.astype(jnp.int32)
    x1, y1, x2, y2 = _mxu_limbs_dyn(ai, bi, f, g, dyn[0], dyn[1], dyn[2])
    return _int_mm(x1, y1) + _int_mm(x2, y2)


# ---------------------------------------------------------------------------
# the projection layer
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ax_dense(x, w, policy: AxPolicy):
    """y = x @ w through the SWAPPER approximate path (quantize -> ax matmul
    -> dequantize); straight-through exact gradients for training."""
    return _ax_dense_fwd_impl(x, w, policy)


def _ax_dense_fwd_impl(x, w, policy):
    xq, sx = quantize_rows(x.astype(jnp.float32), axis=-1)
    wq, sw = quantize_rows(w.astype(jnp.float32), axis=0)
    acc = ax_matmul_int(xq, wq, policy)
    return (acc.astype(jnp.float32) * sx * sw).astype(x.dtype)


def _ax_dense_fwd(x, w, policy):
    return _ax_dense_fwd_impl(x, w, policy), (x, w)


def _ax_dense_bwd(policy, res, gy):
    x, w = res
    gy32 = gy.astype(jnp.float32)
    gx = (gy32 @ w.astype(jnp.float32).T).astype(x.dtype)
    xf = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    gw = (xf.T @ gy32.reshape(-1, gy.shape[-1])).astype(w.dtype)
    return gx, gw


ax_dense.defvjp(_ax_dense_fwd, _ax_dense_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _ax_dense_dyn_core(x, w, policy: AxPolicy, dyn, xq, sx, wq, sw):
    """Dequantized dynamic approximate matmul over *pre-quantized* operands.

    The quantization is hoisted into :func:`ax_dense_dyn` so the telemetry
    tap and the matmul share one set of ``quantize_rows`` results explicitly
    (the summary's tracers must belong to the outer trace to leave the jitted
    step, so it cannot live inside this custom_vjp boundary).  ``x``/``w``
    ride along as the straight-through gradient residuals."""
    acc = ax_matmul_int_dyn(xq, wq, policy, dyn)
    return (acc.astype(jnp.float32) * sx * sw).astype(x.dtype)


def _ax_dense_dyn_fwd(x, w, policy, dyn, xq, sx, wq, sw):
    return _ax_dense_dyn_core(x, w, policy, dyn, xq, sx, wq, sw), (x, w)


def _ax_dense_dyn_bwd(policy, res, gy):
    x, w = res
    gx, gw = _ax_dense_bwd(policy, res, gy)
    # integer inputs (config triple, int8 operands): symbolic-zero (float0)
    # cotangents; the f32 quantization scales get literal zeros (STE ignores
    # the quantization path entirely)
    f0 = jax.dtypes.float0
    return (gx, gw, np.zeros((3,), f0),
            np.zeros(x.shape, f0), jnp.zeros(x.shape[:-1] + (1,), jnp.float32),
            np.zeros(w.shape, f0), jnp.zeros((1, w.shape[-1]), jnp.float32))


_ax_dense_dyn_core.defvjp(_ax_dense_dyn_fwd, _ax_dense_dyn_bwd)


def ax_dense_dyn(x, w, policy: AxPolicy, dyn, scope=None, target: str = ""):
    """``ax_dense`` with a traced swap triple (adaptive runtime path); when a
    collecting scope is open, also emits the telemetry record for this call.
    ``quantize_rows`` runs once here and its results feed both the telemetry
    summary and the matmul core explicitly (no reliance on XLA CSE).  The
    scope's traced observe gate (if any) lets off-steps skip the summary
    compute entirely (``lax.cond``) while keeping the record shapes static."""
    xq, sx = quantize_rows(x.astype(jnp.float32), axis=-1)
    wq, sw = quantize_rows(w.astype(jnp.float32), axis=0)
    if scope is not None and scope.collect:
        from repro.runtime.telemetry import operand_summary

        scope.record(target, operand_summary(xq, wq, M.get(policy.mult_name),
                                             dyn, gate=scope.gate))
    return _ax_dense_dyn_core(x, w, policy, dyn, xq, sx, wq, sw)
