"""SWAPPER approximate matmul as a first-class LM projection (DESIGN.md §5).

Three backends:

* ``mxu`` — **beyond-paper production path**.  For *separable* multiplier
  families, m(a, b) = f(a) * g(b) elementwise (operand truncation zeroes low
  bits of each operand; partial-product perforation zeroes rows of B), so the
  approximate inner product factorizes into exact matmuls of transformed int8
  operands — which run on the MXU:

      NoSwap:        C = f(A) @ g(B)                       (1 int8 matmul)
      swap on A bit: C = (s⊙g(A)) @ f(B) + ((1-s)⊙f(A)) @ g(B)
      swap on B bit: C = g(A) @ (s⊙f(B)) + f(A) @ ((1-s)⊙g(B))
                                                           (2 int8 matmuls)

  where s is the SWAPPER bit mask of the decision operand.  This turns the
  paper's per-multiply mechanism into MXU-rate compute instead of a VPU
  elementwise pipeline — bit-identical to the Pallas kernel (tested).

* ``kernel`` — the Pallas ``ax_matmul`` VPU kernel (arbitrary families,
  including LUT circuits).

* ``emul`` — pure-jnp reference (small shapes / tests).

Training uses a straight-through estimator: forward = approximate quantized
matmul, backward = exact matmul gradients.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AxPolicy
from repro.core import multipliers as M
from repro.core.swapper import SwapConfig

__all__ = ["ax_dense", "quantize_rows", "separable_transforms", "ax_matmul_int"]


# ---------------------------------------------------------------------------
# separable closed forms
# ---------------------------------------------------------------------------

def _trunc_t(k):
    mask = jnp.int32(~((1 << k) - 1))

    def f(x):  # sign-magnitude low-bit truncation (matches multipliers.trunc)
        neg = x < 0
        mag = jnp.where(neg, -x, x) & mask
        return jnp.where(neg, -mag, mag)

    return f


def separable_transforms(mult_name: str) -> Optional[Tuple[Callable, Callable]]:
    """(f, g) with m(a,b) = f(a)*g(b), or None if the family is inseparable."""
    base = mult_name.split("_", 1)[1] if "_" in mult_name else mult_name
    if base.startswith("trunc"):
        ka, kb = (int(v) for v in base[len("trunc"):].split("_"))
        return _trunc_t(ka), _trunc_t(kb)
    if base.startswith("perf"):
        rows = tuple(int(v) for v in base[len("perf"):].split("_"))
        rowmask = 0
        for r in rows:
            rowmask |= 1 << r
        inv = jnp.int32(~rowmask)

        def g(x):
            neg = x < 0
            mag = jnp.where(neg, -x, x) & inv
            return jnp.where(neg, -mag, mag)

        return (lambda x: x), g
    return None


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

def quantize_rows(x, axis=-1):
    """Symmetric per-row int8 quantization along ``axis``."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _swap_mask(x_i32, cfg: SwapConfig):
    return (((x_i32 >> cfg.bit) & 1) == cfg.value)


def _int_mm(a, b):
    """Exact int8 matmul with int32 accumulation (MXU-native on TPU)."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def ax_matmul_int(a_i8, b_i8, policy: AxPolicy) -> jax.Array:
    """Approximate int matmul (..., K) @ (K, N) -> (..., N) int32."""
    mult = M.get(policy.mult_name)
    swap = policy.swap
    if policy.backend == "mxu":
        sep = separable_transforms(policy.mult_name)
        assert sep is not None, f"{policy.mult_name} is not separable; use backend='kernel'"
        f, g = sep
        ai = a_i8.astype(jnp.int32)
        bi = b_i8.astype(jnp.int32)
        if swap is None:
            return _int_mm(f(ai).astype(jnp.int8), g(bi).astype(jnp.int8))
        if swap.operand == "A":
            s = _swap_mask(ai, swap).astype(jnp.int32)
            a1 = (s * g(ai)).astype(jnp.int8)          # swapped rows take g
            a2 = ((1 - s) * f(ai)).astype(jnp.int8)
            return _int_mm(a1, f(bi).astype(jnp.int8)) + _int_mm(a2, g(bi).astype(jnp.int8))
        s = _swap_mask(bi, swap).astype(jnp.int32)
        b1 = (s * f(bi)).astype(jnp.int8)
        b2 = ((1 - s) * g(bi)).astype(jnp.int8)
        return _int_mm(g(ai).astype(jnp.int8), b1) + _int_mm(f(ai).astype(jnp.int8), b2)
    if policy.backend == "kernel":
        from repro.kernels import ax_matmul as kernel_mm

        lead = a_i8.shape[:-1]
        a2d = a_i8.reshape(-1, a_i8.shape[-1])
        m0, k0 = a2d.shape
        n0 = b_i8.shape[-1]

        def _pad(v, mult_, axis):
            pad = (-v.shape[axis]) % mult_
            if pad == 0:
                return v
            widths = [(0, 0)] * v.ndim
            widths[axis] = (0, pad)
            return jnp.pad(v, widths)

        bm = min(128, m0)
        bn = min(128, n0)
        bk = min(128, k0)
        a2d = _pad(_pad(a2d, bm, 0), bk, 1)
        bp = _pad(_pad(b_i8, bk, 0), bn, 1)
        out = kernel_mm(a2d, bp, mult, swap, block_m=bm, block_n=bn, block_k=bk)
        return out[:m0, :n0].reshape(*lead, n0)
    # 'emul'
    from repro.kernels.ref import ax_matmul_ref

    lead = a_i8.shape[:-1]
    a2d = a_i8.reshape(-1, a_i8.shape[-1])
    return ax_matmul_ref(a2d, b_i8, mult, swap).reshape(*lead, b_i8.shape[-1])


# ---------------------------------------------------------------------------
# the projection layer
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ax_dense(x, w, policy: AxPolicy):
    """y = x @ w through the SWAPPER approximate path (quantize -> ax matmul
    -> dequantize); straight-through exact gradients for training."""
    return _ax_dense_fwd_impl(x, w, policy)


def _ax_dense_fwd_impl(x, w, policy):
    xq, sx = quantize_rows(x.astype(jnp.float32), axis=-1)
    wq, sw = quantize_rows(w.astype(jnp.float32), axis=0)
    acc = ax_matmul_int(xq, wq, policy)
    return (acc.astype(jnp.float32) * sx * sw).astype(x.dtype)


def _ax_dense_fwd(x, w, policy):
    return _ax_dense_fwd_impl(x, w, policy), (x, w)


def _ax_dense_bwd(policy, res, gy):
    x, w = res
    gy32 = gy.astype(jnp.float32)
    gx = (gy32 @ w.astype(jnp.float32).T).astype(x.dtype)
    xf = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    gw = (xf.T @ gy32.reshape(-1, gy.shape[-1])).astype(w.dtype)
    return gx, gw


ax_dense.defvjp(_ax_dense_fwd, _ax_dense_bwd)
