"""SWAPPER approximate matmul as a first-class LM projection (DESIGN.md §5).

Three backends:

* ``mxu`` — **beyond-paper production path**.  For *separable* multiplier
  families, m(a, b) = f(a) * g(b) elementwise (operand truncation zeroes low
  bits of each operand; partial-product perforation zeroes rows of B), so the
  approximate inner product factorizes into exact matmuls of transformed int8
  operands — which run on the MXU.  The two-limb factorization

      NoSwap:        C = f(A) @ g(B)
      swap on A bit: C = (s⊙g(A)) @ f(B) + ((1-s)⊙f(A)) @ g(B)
      swap on B bit: C = g(A) @ (s⊙f(B)) + f(A) @ ((1-s)⊙g(B))

  (s = the SWAPPER bit mask of the decision operand) is dispatched as a
  **single K-stacked int8 matmul** over a concatenated 2K inner dimension,
  ``[X1|X2] @ [Y1;Y2]`` — int32 accumulation makes the stacked reduction
  bit-identical to ``X1@Y1 + X2@Y2`` while halving the dispatch count and
  doubling MXU occupancy per call.  The pre-stacking 2-matmul forms are kept
  (``ax_matmul_int_2mm`` / ``ax_matmul_int_dyn_2mm``) as bit-identity oracles
  and benchmark baselines.  This turns the paper's per-multiply mechanism
  into MXU-rate compute instead of a VPU elementwise pipeline — bit-identical
  to the Pallas kernel (tested).

* ``kernel`` — the Pallas ``ax_matmul`` VPU kernel (arbitrary families,
  including LUT circuits).

* ``emul`` — pure-jnp reference (small shapes / tests).

Training uses a straight-through estimator: forward = approximate quantized
matmul, backward = exact matmul gradients.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AxPolicy
from repro.core import multipliers as M
from repro.core.swapper import SwapConfig, apply_swapper_dyn
from repro.core.tiling import (largest_divisor_leq, rowtile_count,
                               rowtile_index, rowtile_span)

__all__ = [
    "ax_dense",
    "ax_dense_dyn",
    "quantize_rows",
    "separable_transforms",
    "ax_matmul_int",
    "ax_matmul_int_dyn",
    "ax_matmul_int_2mm",
    "ax_matmul_int_dyn_2mm",
]


# ---------------------------------------------------------------------------
# separable closed forms
# ---------------------------------------------------------------------------

def _trunc_t(k):
    mask = jnp.int32(~((1 << k) - 1))

    def f(x):  # sign-magnitude low-bit truncation (matches multipliers.trunc)
        neg = x < 0
        mag = jnp.where(neg, -x, x) & mask
        return jnp.where(neg, -mag, mag)

    return f


def separable_transforms(mult_name: str) -> Optional[Tuple[Callable, Callable]]:
    """(f, g) with m(a,b) = f(a)*g(b), or None if the family is inseparable."""
    base = mult_name.split("_", 1)[1] if "_" in mult_name else mult_name
    if base.startswith("trunc"):
        ka, kb = (int(v) for v in base[len("trunc"):].split("_"))
        return _trunc_t(ka), _trunc_t(kb)
    if base.startswith("perf"):
        rows = tuple(int(v) for v in base[len("perf"):].split("_"))
        rowmask = 0
        for r in rows:
            rowmask |= 1 << r
        inv = jnp.int32(~rowmask)

        def g(x):
            neg = x < 0
            mag = jnp.where(neg, -x, x) & inv
            return jnp.where(neg, -mag, mag)

        return (lambda x: x), g
    return None


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

def quantize_rows(x, axis=-1):
    """Symmetric per-row int8 quantization along ``axis``."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _swap_mask(x_i32, cfg: SwapConfig):
    return (((x_i32 >> cfg.bit) & 1) == cfg.value)


def _int_mm(a, b):
    """Exact int8 matmul with int32 accumulation (MXU-native on TPU)."""
    return jax.lax.dot_general(
        a, b, (((a.ndim - 1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _stacked_mm(*limbs):
    """``sum_i Xi @ Yi`` as ONE int8 matmul over a concatenated inner
    dimension: ``[X1|X2|...] @ [Y1;Y2;...]`` (``limbs`` alternates Xi, Yi).
    int32 accumulation is exact, so the stacked reduction is bit-identical
    to the matmul sum while collapsing the dispatch count to one (one MXU
    pass over 2K for the scalar swap factorization, 4K for the per-row-tile
    form)."""
    x = jnp.concatenate(limbs[0::2], axis=-1)
    y = jnp.concatenate(limbs[1::2], axis=0)
    return _int_mm(x, y)


def _mxu_limbs(ai, bi, f, g, swap: SwapConfig):
    """The (X1, Y1, X2, Y2) int8 limbs of the static swap factorization."""
    if swap.operand == "A":
        s = _swap_mask(ai, swap).astype(jnp.int32)
        return ((s * g(ai)).astype(jnp.int8), f(bi).astype(jnp.int8),
                ((1 - s) * f(ai)).astype(jnp.int8), g(bi).astype(jnp.int8))
    s = _swap_mask(bi, swap).astype(jnp.int32)
    return (g(ai).astype(jnp.int8), (s * f(bi)).astype(jnp.int8),
            f(ai).astype(jnp.int8), ((1 - s) * g(bi)).astype(jnp.int8))


def _mxu_limbs_dyn(ai, bi, f, g, op_is_a, bit, value):
    """The (X1, Y1, X2, Y2) limbs with the swap decision as traced scalars.

    With row mask sa (decision on A) / column mask sb (decision on B), each
    gated by op_is_a, ``X1 @ Y1 + X2 @ Y2`` equals the A-form or B-form
    static factorization for every triple.

    The ``value == 2`` NoSwap limb-zeroing encoding: no int8 operand has a
    bit equal to 2, so ``((x >> bit) & 1) == 2`` is identically False —
    sa and sb collapse to all-zero masks, which zeroes one limb entirely
    (``x1 = 0`` in the A form / ``y1 = 0`` in the B form) and reduces the
    K-stacked product to the plain ``f(A) @ g(B)``.  That is the traced
    NoSwap fast path: ONE compiled program is config-agnostic over all
    4M+1 triples, and NoSwap rides it with a zero limb contributing nothing
    to the stacked int32 reduction (bit-identical to the static NoSwap
    matmul; a structured-sparsity backend could skip the zero limb — see
    ROADMAP)."""
    is_a = op_is_a == 1
    sa = ((((ai >> bit) & 1) == value) & is_a).astype(jnp.int32)
    sb = ((((bi >> bit) & 1) == value) & ~is_a).astype(jnp.int32)
    x1 = jnp.where(is_a, sa * g(ai), g(ai)).astype(jnp.int8)
    y1 = jnp.where(is_a, f(bi), sb * f(bi)).astype(jnp.int8)
    x2 = jnp.where(is_a, (1 - sa) * f(ai), f(ai)).astype(jnp.int8)
    y2 = jnp.where(is_a, g(bi), (1 - sb) * g(bi)).astype(jnp.int8)
    return x1, y1, x2, y2


def _mxu_limbs_rowtile(ai, bi, f, g, row_triples, b_rep):
    """K-stacked limbs with a *per-row* swap decision (``row_triples`` is a
    traced (M, 3) int32 array, one triple per row of the 2-D ``ai``;
    ``b_rep`` the traced representative B-side triple — see
    ``_bside_representative``).

    Per-row decisions on the A operand are elementwise: the row's
    (bit, value) broadcasts down its K lanes, so the A-form factorization
    ``sa*g(A) @ f(B) + (1-sa)*f(A) @ g(B)`` holds row-wise.  Rows whose
    triple is a NoSwap encoding (``value == 2``, either operand) zero
    their slice of ``sa`` and ride the A-form pair (see
    ``_mxu_limbs_dyn``).

    A per-row *B-side* decision masks the weight operand, which cannot
    vary per output row inside a factorized matmul — but a B-side decision
    *shared by every B-side row* can: its column mask ``sb`` comes from the
    representative triple and B-side rows are routed to a second limb pair
    ``g(A) @ (sb*f(B)) + f(A) @ ((1-sb)*g(B))`` gated by a row indicator.
    The four pairs stack into ONE int8 ``dot_general`` over a 4K inner
    dimension, so the program stays single-dispatch and config-agnostic:
    A-side / NoSwap / uniform-B-side grids are all exact (the broadcast of
    any scalar config into a tile grid in particular).  The generality
    costs a 4K inner dimension even when the grid is A-side-only and the
    B-form limbs are runtime zeros — a deliberate correctness-first
    tradeoff: a static "A-side-only" program variant would be silently
    wrong the moment a B-side scalar config broadcasts into tile mode,
    so selecting it needs a host-side guard (ROADMAP follow-on).  Grids
    mixing
    *different* B-side triples are the one inexpressible case — rejected
    host-side by ``SwapPolicy.set_tile_grid``; the Pallas grid kernel
    executes them when wanted (``backend='kernel'``).  The controller's
    tile re-tune space (``controller.tile_triples``) is A-side/NoSwap only,
    which keeps its published grids exact on every backend.
    """
    op = row_triples[:, 0:1]
    bit = row_triples[:, 1:2]
    value = row_triples[:, 2:3]
    is_b = (op == 0) & (value <= 1)            # live B-side decision rows
    sa = ((((ai >> bit) & 1) == value) & (op == 1)).astype(jnp.int32)
    ib = is_b.astype(jnp.int32)
    ia = 1 - ib                                # A-side AND NoSwap rows
    sb = (((bi >> b_rep[1]) & 1) == b_rep[2]).astype(jnp.int32)
    return ((sa * g(ai)).astype(jnp.int8), f(bi).astype(jnp.int8),
            (ia * (1 - sa) * f(ai)).astype(jnp.int8), g(bi).astype(jnp.int8),
            (ib * g(ai)).astype(jnp.int8), (sb * f(bi)).astype(jnp.int8),
            (ib * f(ai)).astype(jnp.int8), ((1 - sb) * g(bi)).astype(jnp.int8))


def _bside_representative(flat_triples):
    """The (traced) B-side triple of a tile grid: ``set_tile_grid``
    guarantees at most one distinct B-side triple per grid, so the first
    B-side row is THE representative wherever it sits (grids with no
    B-side rows return an arbitrary row — its mask is then gated out by
    the all-zero ``ib`` indicator)."""
    is_b = (flat_triples[:, 0] == 0) & (flat_triples[:, 2] <= 1)
    return flat_triples[jnp.argmax(is_b)]


def _block_of(span: int, cap: int = 128) -> int:
    """Kernel block size aligned to a logical tile span, so no block
    straddles a tile."""
    return largest_divisor_leq(span, cap)


def _kernel_grid_tiled(a_i8, b_i8, mult, dyn):
    """Pallas grid-kernel dispatch of a logical (gm, gn, 3) config grid.

    The scalar-prefetch kernel applies one triple per *physical* block, so
    the block shape is chosen to align with the logical tile spans
    (``_block_of``: each block lies inside exactly one logical tile) and
    the logical grid is gathered onto the block grid with static indices —
    bit-exact per-tile semantics at any granularity, still zero recompiles
    across grid-value updates.  On a real TPU a production deployment picks
    ``gm`` so the tile span stays a multiple of the 128-lane MXU block (the
    alignment here then reduces to the default blocks)."""
    from repro.kernels import ax_matmul_grid

    lead = a_i8.shape[:-1]
    a2d = a_i8.reshape(-1, a_i8.shape[-1])
    m0, k0 = a2d.shape
    n0 = b_i8.shape[-1]
    g_m = rowtile_count(m0, int(dyn.shape[0]))
    g_n = rowtile_count(n0, int(dyn.shape[1]))
    rows_per = rowtile_span(m0, int(dyn.shape[0]))
    cols_per = rowtile_span(n0, int(dyn.shape[1]))
    bm, bn, bk = _block_of(rows_per), _block_of(cols_per), min(128, k0)
    a2d = _pad_to_multiple(_pad_to_multiple(a2d, bm, 0), bk, 1)
    bp = _pad_to_multiple(_pad_to_multiple(b_i8, bk, 0), bn, 1)
    gmk, gnk = a2d.shape[0] // bm, bp.shape[1] // bn
    ri = np.minimum((np.arange(gmk) * bm) // rows_per, g_m - 1)
    ci = np.minimum((np.arange(gnk) * bn) // cols_per, g_n - 1)
    grid = dyn.astype(jnp.int32)[ri][:, ci]
    out = ax_matmul_grid(a2d, bp, mult, grid, block_m=bm, block_n=bn, block_k=bk)
    return out[:m0, :n0].reshape(*lead, n0)


def _pad_to_multiple(v, mult_, axis):
    """Zero-pad ``v`` along ``axis`` up to the next multiple of ``mult_``
    (the Pallas kernels require block-divisible shapes; callers crop the
    output back)."""
    pad = (-v.shape[axis]) % mult_
    if pad == 0:
        return v
    widths = [(0, 0)] * v.ndim
    widths[axis] = (0, pad)
    return jnp.pad(v, widths)


def _pad_for_kernel(a_i8, b_i8):
    """Flatten leading dims and zero-pad both operands to block multiples for
    the Pallas kernels.  Returns (a2d, b, lead_shape, m0, n0, (bm, bn, bk));
    callers crop ``out[:m0, :n0]`` and reshape to ``(*lead, n0)``."""
    lead = a_i8.shape[:-1]
    a2d = a_i8.reshape(-1, a_i8.shape[-1])
    m0, k0 = a2d.shape
    n0 = b_i8.shape[-1]
    bm, bn, bk = min(128, m0), min(128, n0), min(128, k0)
    a2d = _pad_to_multiple(_pad_to_multiple(a2d, bm, 0), bk, 1)
    bp = _pad_to_multiple(_pad_to_multiple(b_i8, bk, 0), bn, 1)
    return a2d, bp, lead, m0, n0, (bm, bn, bk)


def ax_matmul_int(a_i8, b_i8, policy: AxPolicy) -> jax.Array:
    """Approximate int matmul (..., K) @ (K, N) -> (..., N) int32.

    The mxu backend dispatches exactly one int8 ``dot_general`` per call:
    NoSwap is the plain ``f(A) @ g(B)``, a swap config K-stacks the two
    factorization limbs into a single matmul over the 2K inner dimension."""
    mult = M.get(policy.mult_name)
    swap = policy.swap
    if policy.backend == "mxu":
        sep = separable_transforms(policy.mult_name)
        assert sep is not None, f"{policy.mult_name} is not separable; use backend='kernel'"
        f, g = sep
        ai = a_i8.astype(jnp.int32)
        bi = b_i8.astype(jnp.int32)
        if swap is None:
            return _int_mm(f(ai).astype(jnp.int8), g(bi).astype(jnp.int8))
        return _stacked_mm(*_mxu_limbs(ai, bi, f, g, swap))
    if policy.backend == "kernel":
        from repro.kernels import ax_matmul as kernel_mm

        a2d, bp, lead, m0, n0, (bm, bn, bk) = _pad_for_kernel(a_i8, b_i8)
        out = kernel_mm(a2d, bp, mult, swap, block_m=bm, block_n=bn, block_k=bk)
        return out[:m0, :n0].reshape(*lead, n0)
    # 'emul'
    from repro.kernels.ref import ax_matmul_ref

    lead = a_i8.shape[:-1]
    a2d = a_i8.reshape(-1, a_i8.shape[-1])
    return ax_matmul_ref(a2d, b_i8, mult, swap).reshape(*lead, b_i8.shape[-1])


def ax_matmul_int_2mm(a_i8, b_i8, policy: AxPolicy) -> jax.Array:
    """The pre-K-stacking 2-matmul mxu factorization, retained as the
    bit-identity oracle and the old-path benchmark baseline (see
    ``benchmarks/perf_table.py``).  mxu backend only."""
    assert policy.backend == "mxu", policy.backend
    sep = separable_transforms(policy.mult_name)
    assert sep is not None, f"{policy.mult_name} is not separable"
    f, g = sep
    ai = a_i8.astype(jnp.int32)
    bi = b_i8.astype(jnp.int32)
    if policy.swap is None:
        return _int_mm(f(ai).astype(jnp.int8), g(bi).astype(jnp.int8))
    x1, y1, x2, y2 = _mxu_limbs(ai, bi, f, g, policy.swap)
    return _int_mm(x1, y1) + _int_mm(x2, y2)


# ---------------------------------------------------------------------------
# dynamic-config variants (the adaptive-runtime zero-recompile path)
# ---------------------------------------------------------------------------

def ax_matmul_int_dyn(a_i8, b_i8, policy: AxPolicy, dyn) -> jax.Array:
    """``ax_matmul_int`` with the swap decision as a *traced* int32 input,
    so the adaptive controller can re-tune a serving step without
    recompiling it.  ``dyn`` is either

    * a (3,) (op_is_a, bit, value) triple — one decision for the whole
      projection (value=2 encodes NoSwap; see ``_mxu_limbs_dyn`` for the
      limb-zeroing encoding), or
    * a (gm, gn, 3) per-tile config grid (``SwapPolicy.tile_grid``) — the
      gm row tiles of the flattened token dimension each apply their own
      triple.  The grid is resampled to each backend's physical tiling with
      *static* indices, so tile-grid updates stay zero-recompile.

    Backends: mxu dispatches ONE K-stacked int8 ``dot_general`` for every
    scalar triple and for per-row-tile grids (A-side/NoSwap per tile; see
    ``_mxu_limbs_rowtile`` — gn must be 1); ``kernel`` routes the
    scalar-prefetch Pallas grid kernel (fully general grids); ``emul`` is
    the pure-jnp reference for both."""
    mult = M.get(policy.mult_name)
    dyn = jnp.asarray(dyn)
    tiled = dyn.ndim == 3
    if policy.backend == "mxu":
        sep = separable_transforms(policy.mult_name)
        assert sep is not None, f"{policy.mult_name} is not separable; use backend='kernel'"
        f, g = sep
        ai = a_i8.astype(jnp.int32)
        bi = b_i8.astype(jnp.int32)
        if tiled:
            assert dyn.shape[1] == 1, (
                f"mxu per-tile grids are row-granular (gn must be 1, got "
                f"{dyn.shape}); use backend='kernel' for column tiles")
            lead = a_i8.shape[:-1]
            a2 = ai.reshape(-1, ai.shape[-1])
            row_triples = dyn[:, 0, :][rowtile_index(a2.shape[0], dyn.shape[0])]
            out = _stacked_mm(*_mxu_limbs_rowtile(
                a2, bi, f, g, row_triples, _bside_representative(dyn[:, 0, :])))
            return out.reshape(*lead, b_i8.shape[-1])
        return _stacked_mm(*_mxu_limbs_dyn(ai, bi, f, g, dyn[0], dyn[1], dyn[2]))
    if policy.backend == "kernel":
        from repro.kernels import ax_matmul_grid

        if tiled:
            return _kernel_grid_tiled(a_i8, b_i8, mult, dyn)
        a2d, bp, lead, m0, n0, (bm, bn, bk) = _pad_for_kernel(a_i8, b_i8)
        gmk, gnk = a2d.shape[0] // bm, bp.shape[1] // bn
        grid = jnp.broadcast_to(dyn.astype(jnp.int32), (gmk, gnk, 3))
        out = ax_matmul_grid(a2d, bp, mult, grid, block_m=bm, block_n=bn, block_k=bk)
        return out[:m0, :n0].reshape(*lead, n0)
    # 'emul'
    lead = a_i8.shape[:-1]
    a2 = a_i8.reshape(-1, a_i8.shape[-1]).astype(jnp.int32)
    B = b_i8.astype(jnp.int32)
    if tiled:
        Mrows, N = a2.shape[0], b_i8.shape[-1]
        ri = rowtile_index(Mrows, dyn.shape[0])
        ci = rowtile_index(N, dyn.shape[1])
        rows = []
        for ti in range(int(dyn.shape[0])):
            sel = np.nonzero(ri == ti)[0]
            if len(sel) == 0:
                continue
            A = a2[sel[0]:sel[-1] + 1][:, :, None]
            blocks = []
            for tj in range(int(dyn.shape[1])):
                cs = np.nonzero(ci == tj)[0]
                if len(cs) == 0:
                    continue
                t = dyn[ti, tj]
                prod = apply_swapper_dyn(
                    mult, A, B[None, :, cs[0]:cs[-1] + 1], t[0], t[1], t[2])
                blocks.append(jnp.sum(prod.astype(jnp.int32), axis=1,
                                      dtype=jnp.int32))
            rows.append(jnp.concatenate(blocks, axis=1))
        return jnp.concatenate(rows, axis=0).reshape(*lead, N)
    prod = apply_swapper_dyn(mult, a2[:, :, None], B[None, :, :],
                             dyn[0], dyn[1], dyn[2]).astype(jnp.int32)
    return jnp.sum(prod, axis=1, dtype=jnp.int32).reshape(*lead, b_i8.shape[-1])


def ax_matmul_int_dyn_2mm(a_i8, b_i8, policy: AxPolicy, dyn) -> jax.Array:
    """The pre-K-stacking 2-matmul dynamic mxu path (bit-identity oracle /
    benchmark baseline).  mxu backend only."""
    assert policy.backend == "mxu", policy.backend
    sep = separable_transforms(policy.mult_name)
    assert sep is not None, f"{policy.mult_name} is not separable"
    f, g = sep
    ai = a_i8.astype(jnp.int32)
    bi = b_i8.astype(jnp.int32)
    x1, y1, x2, y2 = _mxu_limbs_dyn(ai, bi, f, g, dyn[0], dyn[1], dyn[2])
    return _int_mm(x1, y1) + _int_mm(x2, y2)


# ---------------------------------------------------------------------------
# the projection layer
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ax_dense(x, w, policy: AxPolicy):
    """y = x @ w through the SWAPPER approximate path (quantize -> ax matmul
    -> dequantize); straight-through exact gradients for training."""
    return _ax_dense_fwd_impl(x, w, policy)


def _ax_dense_fwd_impl(x, w, policy):
    xq, sx = quantize_rows(x.astype(jnp.float32), axis=-1)
    wq, sw = quantize_rows(w.astype(jnp.float32), axis=0)
    acc = ax_matmul_int(xq, wq, policy)
    return (acc.astype(jnp.float32) * sx * sw).astype(x.dtype)


def _ax_dense_fwd(x, w, policy):
    return _ax_dense_fwd_impl(x, w, policy), (x, w)


def _ax_dense_bwd(policy, res, gy):
    x, w = res
    gy32 = gy.astype(jnp.float32)
    gx = (gy32 @ w.astype(jnp.float32).T).astype(x.dtype)
    xf = x.astype(jnp.float32).reshape(-1, x.shape[-1])
    gw = (xf.T @ gy32.reshape(-1, gy.shape[-1])).astype(w.dtype)
    return gx, gw


ax_dense.defvjp(_ax_dense_fwd, _ax_dense_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _ax_dense_dyn_core(x, w, policy: AxPolicy, dyn, xq, sx, wq, sw):
    """Dequantized dynamic approximate matmul over *pre-quantized* operands.

    The quantization is hoisted into :func:`ax_dense_dyn` so the telemetry
    tap and the matmul share one set of ``quantize_rows`` results explicitly
    (the summary's tracers must belong to the outer trace to leave the jitted
    step, so it cannot live inside this custom_vjp boundary).  ``x``/``w``
    ride along as the straight-through gradient residuals."""
    acc = ax_matmul_int_dyn(xq, wq, policy, dyn)
    return (acc.astype(jnp.float32) * sx * sw).astype(x.dtype)


def _ax_dense_dyn_fwd(x, w, policy, dyn, xq, sx, wq, sw):
    return _ax_dense_dyn_core(x, w, policy, dyn, xq, sx, wq, sw), (x, w, dyn.shape)


def _ax_dense_dyn_bwd(policy, res, gy):
    x, w, dyn_shape = res
    gx, gw = _ax_dense_bwd(policy, res[:2], gy)
    # integer inputs (config triple/grid, int8 operands): symbolic-zero
    # (float0) cotangents; the f32 quantization scales get literal zeros
    # (STE ignores the quantization path entirely)
    f0 = jax.dtypes.float0
    return (gx, gw, np.zeros(dyn_shape, f0),
            np.zeros(x.shape, f0), jnp.zeros(x.shape[:-1] + (1,), jnp.float32),
            np.zeros(w.shape, f0), jnp.zeros((1, w.shape[-1]), jnp.float32))


_ax_dense_dyn_core.defvjp(_ax_dense_dyn_fwd, _ax_dense_dyn_bwd)


def ax_dense_dyn(x, w, policy: AxPolicy, dyn, scope=None, target: str = ""):
    """``ax_dense`` with the swap decision as a traced input (adaptive
    runtime path): ``dyn`` is a (3,) triple, or a (gm, 1, 3) per-row-tile
    grid when the scope runs in tile mode (``ax_matmul_int_dyn`` handles
    both with zero recompiles on value changes).

    When a collecting scope is open this also emits the telemetry records
    for the call: the scalar ``operand_summary`` (its live-policy error
    sample uses the first tile's triple when ``dyn`` is a grid — the bit
    statistics are policy-independent), plus a per-row-tile
    ``tile_summary`` under ``tile_key(target)`` when ``scope.tile_rows``
    is set — the feed of the controller's per-tile re-tune path.

    ``quantize_rows`` runs once here and its results feed both the
    telemetry summaries and the matmul core explicitly (no reliance on XLA
    CSE).  The scope's traced observe gate (if any) lets off-steps skip the
    summary compute entirely (``lax.cond``) while keeping record shapes
    static."""
    xq, sx = quantize_rows(x.astype(jnp.float32), axis=-1)
    wq, sw = quantize_rows(w.astype(jnp.float32), axis=0)
    dyn = jnp.asarray(dyn)
    if scope is not None and scope.collect:
        from repro.runtime.telemetry import operand_summary, tile_key, tile_summary

        mult = M.get(policy.mult_name)
        dyn_rep = dyn if dyn.ndim == 1 else dyn[0, 0]
        scope.record(target, operand_summary(xq, wq, mult, dyn_rep,
                                             gate=scope.gate))
        if scope.tile_rows > 0:
            scope.record(tile_key(target),
                         tile_summary(xq, wq, mult, scope.tile_rows,
                                      gate=scope.gate, dyn=dyn))
    return _ax_dense_dyn_core(x, w, policy, dyn, xq, sx, wq, sw)
