from .ax import ax_dense, quantize_rows, separable_transforms

__all__ = ["ax_dense", "quantize_rows", "separable_transforms"]
